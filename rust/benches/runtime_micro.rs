//! Runtime microbenchmarks.
//!
//! Primary: the reference-backend executor matrix — every proxy family's
//! full `train_step` through the naive (pre-tiling baseline), tiled, and
//! tiled+threaded configurations. The three are cross-checked bit-for-bit
//! before timing (`scenario::run_backend_bench`), a table of step times
//! and speedups is printed, and the record is written to
//! `BENCH_backend.json` at the repo root (the CI artifact; absolute
//! numbers are machine-dependent and deliberately not gated).
//!
//! Secondary, when `artifacts/` exists (`python python/compile/aot.py` +
//! the real `xla` binding): per-execute latency of the PJRT AOT kernels.

use tpu_pod_train::benchkit::{fmt_time, Bench, Table};
use tpu_pod_train::models::proxy::PROXY_FAMILIES;
use tpu_pod_train::runtime::{HostTensor, Runtime};
use tpu_pod_train::scenario::run_backend_bench;
use tpu_pod_train::util::rng::Rng;

fn main() {
    backend_matrix();
    pjrt_kernels();
}

/// Naive vs tiled vs threaded `train_step` over all proxy families.
fn backend_matrix() {
    let families: Vec<&str> = PROXY_FAMILIES.iter().map(|d| d.family).collect();
    let bench = run_backend_bench(&families, 30, 0)
        .expect("backend matrix failed the bit-identity cross-check");

    let mut table = Table::new(
        &format!("reference backend train_step ({} executor threads)", bench.threads),
        &["family", "batch", "naive", "tiled", "threaded", "tiled x", "threaded x"],
    );
    for c in &bench.cases {
        table.row(&[
            c.family.clone(),
            c.batch.to_string(),
            fmt_time(c.naive_step_s),
            fmt_time(c.tiled_step_s),
            fmt_time(c.threaded_step_s),
            format!("{:.2}", c.speedup_tiled()),
            format!("{:.2}", c.speedup_threaded()),
        ]);
    }
    table.print();
    println!(
        "\ngeomean threaded speedup vs naive: {:.2}x (max {:.2}x)",
        bench.geomean_speedup_threaded(),
        bench.max_speedup_threaded()
    );

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_backend.json");
    bench.write(path).expect("writing BENCH_backend.json");
    println!("wrote {path}");
}

/// PJRT AOT-kernel latencies; skipped when no artifacts are compiled.
fn pjrt_kernels() {
    let rt = match Runtime::with_dir("artifacts") {
        Ok(rt) => rt,
        Err(_) => {
            println!("\n(artifacts/ missing — skipping PJRT kernel benches)");
            return;
        }
    };
    let mut rng = Rng::new(0);
    let mut bench = Bench::default();

    // Optimizer kernel (16384 elements).
    let n = 16384;
    let w = HostTensor::new(vec![n], rng.normal_vec(n, 1.0));
    let g = HostTensor::new(vec![n], rng.normal_vec(n, 1.0));
    let v = HostTensor::new(vec![n], rng.normal_vec(n, 1.0));
    let hp = HostTensor::new(vec![4], vec![0.1, 0.01, 1e-4, 0.9]);
    bench.run("lars_unscaled_16384 execute", || {
        std::hint::black_box(
            rt.execute("lars_unscaled_16384", &[&w, &g, &v, &hp], &[]).unwrap(),
        );
    });

    // Attention kernel.
    let (b, h, s, d) = (8, 4, 64, 32);
    let q = HostTensor::new(vec![b, h, s, d], rng.normal_vec(b * h * s * d, 1.0));
    bench.run("attention_b8h4s64d32 execute", || {
        std::hint::black_box(rt.execute("attention_b8h4s64d32", &[&q, &q, &q], &[]).unwrap());
    });

    // Full train step (tiny transformer).
    let specs = rt.manifest.model_params("transformer_tiny").unwrap().to_vec();
    let params: Vec<HostTensor> = specs
        .iter()
        .map(|sp| HostTensor::new(sp.shape.clone(), rng.normal_vec(sp.numel(), 0.05)))
        .collect();
    let tokens: Vec<i32> = (0..8 * 64).map(|i| (i % 256) as i32).collect();
    bench.run("transformer_train_tiny execute (fwd+bwd)", || {
        let refs: Vec<&HostTensor> = params.iter().collect();
        std::hint::black_box(
            rt.execute("transformer_train_tiny", &refs, &[&tokens, &tokens]).unwrap(),
        );
    });
    println!(
        "\ncumulative PJRT time: {:.2}s over {} executions",
        rt.execute_seconds.borrow(),
        rt.executions.borrow()
    );
}
