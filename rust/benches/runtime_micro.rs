//! PJRT runtime microbenchmarks: per-execute latency of the AOT artifacts
//! (the L3 hot path's compute calls). PJRT-backend only: requires
//! `artifacts/` (`python python/compile/aot.py`) and the real `xla`
//! binding (see rust/src/runtime/xla.rs).

use tpu_pod_train::benchkit::Bench;
use tpu_pod_train::runtime::{HostTensor, Runtime};
use tpu_pod_train::util::rng::Rng;

fn main() {
    let rt = Runtime::with_dir("artifacts")
        .expect("PJRT backend required: build artifacts/ with python/compile/aot.py");
    let mut rng = Rng::new(0);
    let mut bench = Bench::default();

    // Optimizer kernel (16384 elements).
    let n = 16384;
    let w = HostTensor::new(vec![n], rng.normal_vec(n, 1.0));
    let g = HostTensor::new(vec![n], rng.normal_vec(n, 1.0));
    let v = HostTensor::new(vec![n], rng.normal_vec(n, 1.0));
    let hp = HostTensor::new(vec![4], vec![0.1, 0.01, 1e-4, 0.9]);
    bench.run("lars_unscaled_16384 execute", || {
        std::hint::black_box(
            rt.execute("lars_unscaled_16384", &[&w, &g, &v, &hp], &[]).unwrap(),
        );
    });

    // Attention kernel.
    let (b, h, s, d) = (8, 4, 64, 32);
    let q = HostTensor::new(vec![b, h, s, d], rng.normal_vec(b * h * s * d, 1.0));
    bench.run("attention_b8h4s64d32 execute", || {
        std::hint::black_box(rt.execute("attention_b8h4s64d32", &[&q, &q, &q], &[]).unwrap());
    });

    // Full train step (tiny transformer).
    let specs = rt.manifest.model_params("transformer_tiny").unwrap().to_vec();
    let params: Vec<HostTensor> = specs
        .iter()
        .map(|sp| HostTensor::new(sp.shape.clone(), rng.normal_vec(sp.numel(), 0.05)))
        .collect();
    let tokens: Vec<i32> = (0..8 * 64).map(|i| (i % 256) as i32).collect();
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    let _ = &mut inputs;
    bench.run("transformer_train_tiny execute (fwd+bwd)", || {
        let refs: Vec<&HostTensor> = params.iter().collect();
        std::hint::black_box(
            rt.execute("transformer_train_tiny", &refs, &[&tokens, &tokens]).unwrap(),
        );
    });
    println!("\ncumulative PJRT time: {:.2}s over {} executions",
             rt.execute_seconds.borrow(), rt.executions.borrow());
}
