//! §2 gradient-summation optimization: "over 1.5x speedup of gradient
//! summation throughput in the ResNet-50 model on TPU-v3 pods."
//!
//! Two measurements:
//!  1. modeled TPU time on the torus cost model with the real ResNet-50
//!     gradient tensor census (161 tensors, ~102 MB) — serial vs pipelined
//!     vs the per-tensor baseline, across pod sizes;
//!  2. REAL wallclock on the in-process fabric: the actual serial and
//!     pipelined schedules moving real f32 gradients between worker
//!     threads (8-core pod, ResNet-shaped tensor distribution scaled down).

use tpu_pod_train::benchkit::{fmt_ratio, Table};
use tpu_pod_train::collectives::{gradsum_pipelined_ws, gradsum_serial, GradSumWorkspace, Placement};
use tpu_pod_train::fabric::run_spmd;
use tpu_pod_train::netsim::cost::resnet50_gradient_bytes;
use tpu_pod_train::netsim::{ArAlgo, CostModel, GradSumModel, NetParams, Torus};

fn main() {
    // --- modeled TPU time -------------------------------------------------
    let tensors = resnet50_gradient_bytes();
    let mut t = Table::new(
        "Modeled gradient-summation time, ResNet-50 census (ms)",
        &["chips", "per-tensor", "serial fused", "pipelined", "speedup(serial/pipe)"],
    );
    for chips in [64usize, 256, 1024] {
        let net = CostModel::new(Torus::for_chips(chips), NetParams::default());
        let gs = GradSumModel { cost: &net, algo: ArAlgo::Torus2D };
        let (pt, se, pi) =
            (gs.per_tensor(&tensors), gs.serial(&tensors), gs.pipelined(&tensors));
        t.row(&[
            chips.to_string(),
            format!("{:.2}", pt * 1e3),
            format!("{:.2}", se * 1e3),
            format!("{:.2}", pi * 1e3),
            fmt_ratio(se / pi),
        ]);
    }
    t.print();
    println!("Paper: 'over 1.5x speedup' from the pipelined schedule at pod scale.");

    // --- real fabric: wallclock + message census ---------------------------
    // On this host the fabric's per-message cost is ~100x below a real
    // NIC/DMA path (and `nproc` may be 1, serializing all workers), so the
    // pipelined schedule's *overlap* cannot manifest in wallclock; what IS
    // structural — and what the TPU model above prices — is the message
    // census: the fused schedule sends ~40x fewer, larger packets.
    let sizes: Vec<usize> = resnet50_gradient_bytes()
        .iter()
        .map(|b| ((b / 4.0 / 16.0) as usize).max(1))
        .collect();
    let world = 8;
    let iters = 20usize;
    println!("\nReal fabric ({} tensors, {:.1}M elements, {world} cores, {iters} iters):",
             sizes.len(), sizes.iter().sum::<usize>() as f64 / 1e6);
    let sizes2 = sizes.clone();
    let stats = run_spmd(world, move |ep| {
        use std::sync::atomic::Ordering;
        use tpu_pod_train::collectives::all_reduce_scalars;
        use tpu_pod_train::util::timer::Timer;
        let place = Placement::new(world);
        let group: Vec<usize> = (0..world).collect();
        let mut tensors: Vec<Vec<f32>> =
            sizes2.iter().map(|&n| vec![1.0f32; n]).collect();
        let mut ws = GradSumWorkspace::default();
        let mut bar = [0.0f32];

        gradsum_serial(ep, &place, &mut tensors); // warm
        all_reduce_scalars(ep, &group, &mut bar);
        let m0 = ep.traffic.messages.load(Ordering::SeqCst);
        let t0 = Timer::start();
        for _ in 0..iters {
            gradsum_serial(ep, &place, &mut tensors);
        }
        let serial_s = t0.secs();
        all_reduce_scalars(ep, &group, &mut bar);
        let m1 = ep.traffic.messages.load(Ordering::SeqCst);

        gradsum_pipelined_ws(ep, &place, &mut tensors, 65536, &mut ws); // warm
        all_reduce_scalars(ep, &group, &mut bar);
        let m2 = ep.traffic.messages.load(Ordering::SeqCst);
        let t1 = Timer::start();
        for _ in 0..iters {
            gradsum_pipelined_ws(ep, &place, &mut tensors, 65536, &mut ws);
        }
        let pipe_s = t1.secs();
        all_reduce_scalars(ep, &group, &mut bar);
        let m3 = ep.traffic.messages.load(Ordering::SeqCst);
        (serial_s, pipe_s, m1 - m0, m3 - m2)
    });
    let (serial_s, pipe_s, serial_msgs, pipe_msgs) = stats[0];
    let per_iter = |m: u64| m as f64 / iters as f64;
    println!("  per-tensor schedule: {:.2} ms/iter, {:.0} messages/iter",
             serial_s * 1e3 / iters as f64, per_iter(serial_msgs));
    println!("  pipelined fused    : {:.2} ms/iter, {:.0} messages/iter",
             pipe_s * 1e3 / iters as f64, per_iter(pipe_msgs));
    println!("  → message reduction: {}", fmt_ratio(per_iter(serial_msgs) / per_iter(pipe_msgs)));
    println!("  → wallclock ratio here: {} (see note above; the TPU-scale win is", 
             fmt_ratio(serial_s / pipe_s));
    println!("    the modeled 1.7-1.8x, driven by DMA-setup amortization + overlap)");
}
