//! §2 weight-update sharding: reproduces the overhead numbers that motivate
//! it — "ResNet-50 ... LARS optimizer weight update overhead is about 6% of
//! the total device step time. In the MLPerf Transformer model, the ADAM
//! optimizer weight update time is about 45%" — and shows WUS removing the
//! overhead at scale, on both the device model and the real fabric.

use tpu_pod_train::benchkit::{fmt_ratio, Table};
use tpu_pod_train::costs::{PodLayout, StepCostModel, WeightUpdatePhase};
use tpu_pod_train::devicesim::{step_model, weight_update_cost, TPU_V3};
use tpu_pod_train::fabric::run_spmd;
use tpu_pod_train::models::model;
use tpu_pod_train::netsim::{CostModel, NetParams, Torus};
use tpu_pod_train::optim::{adam_step, AdamConfig, AdamState};
use tpu_pod_train::util::rng::Rng;
use tpu_pod_train::wus::{ShardPlan, ShardedAdam};

fn main() {
    // --- modeled overhead fractions (paper's 6% / 45%) --------------------
    let net = CostModel::new(Torus::for_chips(1024), NetParams::default());
    let mut t = Table::new(
        "Update share of device step at 2048 cores (replicated optimizer)",
        &["model", "examples/core", "update fraction", "paper"],
    );
    for (name, ex, units, paper) in [
        ("resnet50", 16.0, 1.0, "≈6%"),
        ("transformer", 1.0, 33.0, "≈45%"),
    ] {
        let m = model(name).unwrap();
        let s = step_model(
            &TPU_V3,
            &net,
            m.fwd_flops_per_example,
            m.hbm_bytes_per_example,
            ex,
            units,
            m.params,
            m.optimizer.bytes_per_param(),
            false,
        );
        t.row(&[
            name.to_string(),
            format!("{ex}"),
            format!("{:.1}%", 100.0 * s.update_fraction()),
            paper.to_string(),
        ]);
    }
    t.print();

    // Priced through the participation-aware costs layer: one shard per
    // participating core, the all-gather on the participating torus. The
    // WeightUpdatePhase picks min(replicated, sharded) when sharding is
    // on, so the "chosen" column is what simulate() actually charges.
    let mut t2 = Table::new(
        "Modeled update time: replicated vs sharded (ms, costs::WeightUpdatePhase)",
        &["model", "shards", "replicated", "sharded+allgather", "chosen", "win"],
    );
    for (name, cores) in [("resnet50", 2048usize), ("transformer", 2048), ("gnmt", 1024)] {
        let m = model(name).unwrap();
        let pod = PodLayout::from_layout(&m.layout(cores));
        let np = NetParams::default();
        let uc = weight_update_cost(
            &TPU_V3,
            &CostModel::new(pod.participating_torus(), np),
            m.params,
            m.optimizer.bytes_per_param(),
            pod.update_shards(),
        );
        let chosen = WeightUpdatePhase { dev: TPU_V3, net: np, sharding: true }.cost(&m, &pod);
        t2.row(&[
            name.to_string(),
            chosen.cores.to_string(),
            format!("{:.3}", uc.replicated * 1e3),
            format!("{:.3}", uc.sharded * 1e3),
            format!("{:.3}", chosen.seconds * 1e3),
            fmt_ratio(uc.replicated / uc.sharded),
        ]);
    }
    t2.print();

    // --- real fabric: replicated vs sharded Adam on ~0.9M params ----------
    // Pre-allocated state, timed inside one SPMD region. On a 1-CPU host
    // the replicated path's 8x-redundant compute is fully serialized, so
    // sharding shows its compute win directly.
    let sizes: Vec<usize> = vec![1 << 18, 1 << 19, 1 << 17, 12345];
    let world = 8;
    let iters = 20usize;
    let total: usize = sizes.iter().sum();
    println!("\nReal fabric ({world} cores, {:.2}M params, Adam, {iters} iters):",
             total as f64 / 1e6);
    let sz = sizes.clone();
    let out = run_spmd(world, move |ep| {
        use tpu_pod_train::collectives::all_reduce_scalars;
        use tpu_pod_train::util::timer::Timer;
        let group: Vec<usize> = (0..world).collect();
        let mut rng = Rng::new(1);
        let mut params: Vec<Vec<f32>> = sz.iter().map(|&s| rng.normal_vec(s, 0.1)).collect();
        let grads: Vec<Vec<f32>> = sz.iter().map(|&s| rng.normal_vec(s, 0.1)).collect();
        let mut bar = [0.0f32];

        // Replicated: every core updates every parameter.
        let mut st: Vec<AdamState> = sz.iter().map(|_| AdamState::default()).collect();
        for ti in 0..params.len() {
            adam_step(&AdamConfig::default(), 1e-3, 1, &mut params[ti], &grads[ti], &mut st[ti]);
        }
        all_reduce_scalars(ep, &group, &mut bar);
        let t0 = Timer::start();
        for it in 0..iters {
            for ti in 0..params.len() {
                adam_step(&AdamConfig::default(), 1e-3, 2 + it as u64, &mut params[ti],
                          &grads[ti], &mut st[ti]);
            }
        }
        all_reduce_scalars(ep, &group, &mut bar);
        let repl_s = t0.secs();

        // Sharded (WUS): 1/8 of the update each + all-gather.
        let plan = ShardPlan::balanced(&sz, world);
        let mut opt = ShardedAdam::new(AdamConfig::default(), plan, ep.rank);
        opt.step(ep, &group, 1e-3, &mut params, &grads);
        all_reduce_scalars(ep, &group, &mut bar);
        let t1 = Timer::start();
        for _ in 0..iters {
            opt.step(ep, &group, 1e-3, &mut params, &grads);
        }
        all_reduce_scalars(ep, &group, &mut bar);
        (repl_s, t1.secs())
    });
    let (repl_s, shard_s) = out[0];
    println!("  replicated update: {:.2} ms/iter", repl_s * 1e3 / iters as f64);
    println!("  sharded + gather : {:.2} ms/iter", shard_s * 1e3 / iters as f64);
    println!("  → real speedup from WUS: {}", fmt_ratio(repl_s / shard_s));
    println!("  (in-process, a weight all-gather costs the same memcpy/element as");
    println!("   the update itself, so the 8x compute saving is offset by gather");
    println!("   copies; on TPU the gather rides the torus at 2 B/param and");
    println!("   overlaps — the modeled table above carries the paper-scale win.)");
}
