//! Figure 8 reproduction: "Training epochs to converge when scaling to a
//! larger batch size."
//!
//! Two layers:
//!  1. the calibrated convergence curves of the five MLPerf models,
//!     swept through the scenario engine (`scenario::fig8_scenarios`);
//!  2. a REAL epochs-vs-batch sweep on the tiny transformer: train to a
//!     fixed eval accuracy at increasing global batch and report the
//!     steps x batch (examples) consumed — the live analogue of the curve
//!     (skips with a message when AOT artifacts are absent).

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::coordinator::{train, OptChoice, TrainConfig};
use tpu_pod_train::models::model;
use tpu_pod_train::optim::AdamConfig;
use tpu_pod_train::scenario::{fig8_scenarios, SweepRunner};

fn main() {
    let batches = [32usize, 128, 256, 1024, 2048, 4096, 32768];
    let report = SweepRunner::new(fig8_scenarios(&batches)).run().expect("fig8 sweep");
    let mut t = Table::new(
        "Fig. 8: epochs to converge vs global batch (calibrated curves)",
        &["model", "32", "128", "256", "1024", "2048", "4096", "32768"],
    );
    let mut rows: Vec<(String, Vec<String>)> = Vec::new();
    for r in &report.records {
        if rows.last().map(|(name, _)| name != &r.model).unwrap_or(true) {
            rows.push((r.model.clone(), vec![r.model.clone()]));
        }
        let m = model(&r.model).unwrap();
        let cell = if !r.converged {
            "DNF".into()
        } else if r.global_batch > m.max_batch {
            "—".into()
        } else {
            format!("{:.1}", r.epochs)
        };
        rows.last_mut().unwrap().1.push(cell);
    }
    for (_, row) in rows {
        t.row(&row);
    }
    t.print();
    println!("\nPaper anchors: SSD +22% epochs at 1024 vs 256, +27% more at 2048;");
    println!("Mask-RCNN does not converge above batch 128.");

    // --- live sweep: tiny transformer, fixed quality target --------------
    let mut t2 = Table::new(
        "Live: examples consumed to reach next-token acc 0.85 (transformer_tiny)",
        &["global batch (cores x 8)", "steps", "examples (steps x batch)"],
    );
    let mut live_ok = true;
    for cores in [1usize, 2, 4, 8] {
        let cfg = TrainConfig {
            eval_every: 5,
            eval_examples: 256,
            opt: OptChoice::Adam { cfg: AdamConfig::default(), lr: 3e-3 },
            quality_target: Some(0.85),
            steps: 400,
            ..TrainConfig::quick("transformer_tiny", cores, 400)
        };
        let rep = match train(&cfg) {
            Ok(rep) => rep,
            Err(e) => {
                println!("\n(live sweep skipped: {e:#})");
                live_ok = false;
                break;
            }
        };
        let batch = cores * 8;
        match rep.converged_at {
            Some(s) => t2.row(&[
                format!("{batch}"),
                s.to_string(),
                (s * batch).to_string(),
            ]),
            None => t2.row(&[format!("{batch}"), "DNF".into(), "—".into()]),
        }
    }
    if live_ok {
        t2.print();
        println!("\nShape check: examples-to-target grows with batch beyond the knee");
        println!("(larger batches waste gradient signal), matching Fig. 8's trend.");
    }
}
