//! Table 1 reproduction: ResNet-50 benchmark seconds on 2048 TPU cores at
//! batch 32K for the three optimizer configurations.
//!
//! Two layers of evidence:
//!  1. the scenario engine converts each configuration's epochs-to-converge
//!     into benchmark seconds (`scenario::table1_scenarios`);
//!  2. a REAL LARS experiment on the mini-CNN (examples/lars_study.rs digs
//!     deeper) validates that both variants train and that the unscaled
//!     family reaches higher accuracy under a decaying schedule (skips
//!     with a message when AOT artifacts are absent).

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::coordinator::{train, GradSumMode, OptChoice, TrainConfig};
use tpu_pod_train::optim::{LarsConfig, LarsVariant};
use tpu_pod_train::runtime::BackendChoice;
use tpu_pod_train::scenario::{table1_scenarios, SweepRunner};

fn main() {
    // --- simulated Table 1 (paper rows: 76.9 / 72.4 / 67.1 s) ------------
    let report = SweepRunner::new(table1_scenarios()).run().expect("table1 sweep");
    // Display metadata per row; the epochs column comes from the record
    // itself (the value that actually drove the simulated seconds).
    let rows = [
        ("Scaled momentum", 31.2, 25.0),
        ("Unscaled momentum", 31.2, 25.0),
        ("Unscaled momentum (tuned)", 29.0, 18.0),
    ];
    let paper = [76.9, 72.4, 67.1];
    let mut t = Table::new(
        "Table 1: ResNet-50 on 2048 TPU cores, batch 32K",
        &["Optimizer", "Base LR", "Warmup Ep", "Train Ep", "sim seconds", "paper seconds"],
    );
    for (((name, lr, warmup), paper_s), rec) in rows.iter().zip(paper).zip(&report.records) {
        t.row(&[
            name.to_string(),
            format!("{lr}"),
            format!("{warmup}"),
            format!("{}", rec.epochs),
            format!("{:.1}", rec.benchmark_seconds),
            format!("{paper_s}"),
        ]);
    }
    t.print();

    // --- real mini-CNN check: both variants train; relative quality ------
    let mut t2 = Table::new(
        "Live check (cnn_mini, 2 cores, warmup+decay, hard task): top-1 at step 40 / 400",
        &["variant", "acc @ step 40", "acc @ step 400"],
    );
    let mut live_ok = true;
    for (label, variant, momentum) in [
        ("scaled", LarsVariant::Scaled, 0.9f32),
        ("unscaled", LarsVariant::Unscaled, 0.9),
        ("unscaled tuned-mom", LarsVariant::Unscaled, 0.929),
    ] {
        let cfg = TrainConfig {
            model: "cnn_mini".into(),
            cores: 2,
            steps: 400,
            eval_every: 20,
            eval_examples: 512,
            opt: OptChoice::Lars {
                cfg: LarsConfig { variant, momentum, ..Default::default() },
                lr: 1.0,
            },
            use_wus: true,
            gradsum: GradSumMode::Pipelined { quantum: 4096 },
            backend: BackendChoice::Reference,
            batch_override: None,
            seed: 7,
            task_difficulty: 0.0,
            image_alpha: 0.3,
            quality_target: None,
            warmup_steps: 80,
            ..TrainConfig::quick("cnn_mini", 2, 400)
        };
        let rep = match train(&cfg) {
            Ok(rep) => rep,
            Err(e) => {
                println!("\n(live check skipped: {e:#})");
                live_ok = false;
                break;
            }
        };
        let at40 = rep.evals.iter().find(|e| e.step == 40).map(|e| e.accuracy).unwrap_or(0.0);
        let last = rep.evals.last().map(|e| e.accuracy).unwrap_or(0.0);
        t2.row(&[label.to_string(), format!("{at40:.3}"), format!("{last:.3}")]);
    }
    if live_ok {
        t2.print();
    }
}
