//! Table 1 reproduction: ResNet-50 benchmark seconds on 2048 TPU cores at
//! batch 32K for the three optimizer configurations.
//!
//! Two layers of evidence:
//!  1. the pod simulator converts each configuration's epochs-to-converge
//!     into benchmark seconds (the paper's table rows);
//!  2. a REAL LARS experiment on the mini-CNN (examples/lars_study.rs digs
//!     deeper) validates that both variants train and that the unscaled
//!     family reaches higher accuracy under a decaying schedule.

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::coordinator::{train, GradSumMode, OptChoice, TrainConfig};
use tpu_pod_train::models::model;
use tpu_pod_train::optim::{LarsConfig, LarsVariant};
use tpu_pod_train::simulator::{simulate, SimOptions};

fn main() {
    // --- simulated Table 1 (paper rows: 76.9 / 72.4 / 67.1 s) ------------
    let resnet = model("resnet50").unwrap();
    let rows = [
        ("Scaled momentum", 31.2, 25.0, 72.8),
        ("Unscaled momentum", 31.2, 25.0, 70.6),
        ("Unscaled momentum (tuned)", 29.0, 18.0, 64.0),
    ];
    let mut t = Table::new(
        "Table 1: ResNet-50 on 2048 TPU cores, batch 32K",
        &["Optimizer", "Base LR", "Warmup Ep", "Train Ep", "sim seconds", "paper seconds"],
    );
    let paper = [76.9, 72.4, 67.1];
    for ((name, lr, warmup, epochs), paper_s) in rows.iter().zip(paper) {
        let r = simulate(
            &resnet,
            2048,
            &SimOptions { epochs_override: Some(*epochs), ..Default::default() },
        );
        t.row(&[
            name.to_string(),
            format!("{lr}"),
            format!("{warmup}"),
            format!("{epochs}"),
            format!("{:.1}", r.benchmark_seconds),
            format!("{paper_s}"),
        ]);
    }
    t.print();

    // --- real mini-CNN check: both variants train; relative quality ------
    let mut t2 = Table::new(
        "Live check (cnn_mini, 2 cores, warmup+decay, hard task): top-1 at step 40 / 400",
        &["variant", "acc @ step 40", "acc @ step 400"],
    );
    for (label, variant, momentum) in [
        ("scaled", LarsVariant::Scaled, 0.9f32),
        ("unscaled", LarsVariant::Unscaled, 0.9),
        ("unscaled tuned-mom", LarsVariant::Unscaled, 0.929),
    ] {
        let cfg = TrainConfig {
            model: "cnn_mini".into(),
            cores: 2,
            steps: 400,
            eval_every: 20,
            eval_examples: 512,
            opt: OptChoice::Lars {
                cfg: LarsConfig { variant, momentum, ..Default::default() },
                lr: 1.0,
            },
            use_wus: true,
            gradsum: GradSumMode::Pipelined { quantum: 4096 },
            seed: 7,
            task_difficulty: 0.0,
            image_alpha: 0.3,
            quality_target: None,
            warmup_steps: 80,
        };
        let rep = train(&cfg).expect("train");
        let at40 = rep.evals.iter().find(|e| e.step == 40).map(|e| e.accuracy).unwrap_or(0.0);
        let last = rep.evals.last().map(|e| e.accuracy).unwrap_or(0.0);
        t2.row(&[label.to_string(), format!("{at40:.3}"), format!("{last:.3}")]);
    }
    t2.print();
}
