//! Sweep-engine throughput: the full 5-model §2 ablation grid through the
//! pre-memoization serial reference vs the memoized parallel engine
//! (`scenario::run_sweep_bench`). Writes `BENCH_sweep.json` at the
//! workspace root — the same record `tests/bench_sweep.rs` produces under
//! plain `cargo test` — so the sweep-engine perf trajectory is tracked
//! per commit.

use tpu_pod_train::benchkit::{fmt_ratio, fmt_time, Table};
use tpu_pod_train::scenario::{run_sweep_bench, AblationGrid};

fn main() {
    let grid = AblationGrid::full_paper();
    let bench = run_sweep_bench(&grid, 0).expect("sweep bench");

    let mut t = Table::new(
        "Ablation-grid sweep throughput (5 models x §2 axes x chip ladder)",
        &["engine", "wall", "points/s", "speedup"],
    );
    t.row(&[
        "reference (serial, uncached)".into(),
        fmt_time(bench.baseline_s),
        format!("{:.0}", bench.points_per_sec(bench.baseline_s)),
        fmt_ratio(1.0),
    ]);
    t.row(&[
        "memoized, 1 job".into(),
        fmt_time(bench.serial_s),
        format!("{:.0}", bench.points_per_sec(bench.serial_s)),
        fmt_ratio(bench.baseline_s / bench.serial_s.max(1e-12)),
    ]);
    t.row(&[
        format!("memoized, {} jobs", bench.jobs),
        fmt_time(bench.parallel_s),
        format!("{:.0}", bench.points_per_sec(bench.parallel_s)),
        fmt_ratio(bench.speedup_vs_baseline()),
    ]);
    t.print();
    println!(
        "\n({} scenarios, {} points; all three engines produced byte-identical reports.)",
        bench.scenarios, bench.points
    );

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sweep.json");
    match bench.write(path) {
        Ok(()) => println!("recorded {path}"),
        Err(e) => eprintln!("writing {path}: {e}"),
    }
}
