//! Figure 10 reproduction: "Speedup with model parallelism" — SSD 1.6x on
//! 4 cores; Mask-RCNN speedups at mp 2 and 4. The planner numbers come
//! from the scenario engine (`scenario::model_parallel_speedup`); a REAL
//! stripe-partitioned convolution wallclock measurement on the fabric
//! validates the halo protocol.

use tpu_pod_train::benchkit::{Bench, Table};
use tpu_pod_train::costs::spatial_factors;
use tpu_pod_train::devicesim::TPU_V3;
use tpu_pod_train::fabric::run_spmd;
use tpu_pod_train::models::model;
use tpu_pod_train::scenario::model_parallel_speedup;
use tpu_pod_train::spatial::{conv2d, conv2d_striped};
use tpu_pod_train::util::rng::Rng;

fn main() {
    let mut t = Table::new(
        "Fig. 10: model-parallel speedup (planner model)",
        &["model", "mp", "speedup", "halo+BN share", "paper"],
    );
    let paper: &[(&str, usize, &str)] =
        &[("ssd", 2, "—"), ("ssd", 4, "1.6x"), ("maskrcnn", 2, ">1x"), ("maskrcnn", 4, ">2x")];
    for &(name, mp, pap) in paper {
        let speedup = model_parallel_speedup(name, mp).expect("known model");
        let f = spatial_factors(&model(name).unwrap(), mp, &TPU_V3);
        t.row(&[
            name.to_string(),
            mp.to_string(),
            format!("{speedup:.2}x"),
            format!("{:.0}%", 100.0 * f.comm_fraction),
            pap.to_string(),
        ]);
    }
    t.print();

    // Real wallclock: stripe-partitioned conv vs single-threaded conv.
    println!("\nReal striped-conv wallclock on the fabric (64x32x16→32ch, 3x3):");
    let (h, w, cin, cout, k) = (64, 32, 16, 32, 3);
    let mut rng = Rng::new(0);
    let input = rng.normal_vec(h * w * cin, 1.0);
    let weights = rng.normal_vec(k * k * cin * cout, 0.2);
    let mut bench = Bench::default();
    let single = {
        let input = input.clone();
        let weights = weights.clone();
        bench.run("conv single-core", move || {
            std::hint::black_box(conv2d(&input, h, w, cin, &weights, k, cout));
        })
    };
    for world in [2usize, 4] {
        let input = input.clone();
        let weights = weights.clone();
        let r = bench.run(&format!("conv {world}-way stripes + halo"), move || {
            let input = input.clone();
            let weights = weights.clone();
            run_spmd(world, move |ep| {
                let group: Vec<usize> = (0..world).collect();
                let rows = tpu_pod_train::spatial::stripe_rows(h, world, ep.rank);
                let mine = &input[rows.start * w * cin..rows.end * w * cin];
                std::hint::black_box(conv2d_striped(
                    ep, &group, mine, h, w, cin, &weights, k, cout, false,
                ));
            });
        });
        println!("  → {world}-way real speedup: {:.2}x", single.mean_s / r.mean_s);
    }
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("\n(host has {cpus} CPU(s): with 1 CPU the stripe workers timeshare, so");
    println!(" a ratio ≈1.0x means the halo-exchange overhead is negligible; the");
    println!(" parallel speedup itself is what the planner model above prices.)");
}
