//! Microbenchmarks of the collective substrate on the in-process fabric:
//! ring vs 2-D all-reduce wallclock across payload sizes and world sizes,
//! plus the halo exchange. Complements the netsim cost model with real
//! numbers for the L3 perf pass (EXPERIMENTS.md §Perf).

use tpu_pod_train::benchkit::Bench;
use tpu_pod_train::collectives::{ring_all_reduce, torus2d_all_reduce, Placement};
use tpu_pod_train::fabric::run_spmd;

fn main() {
    let mut bench = Bench::default();
    for world in [4usize, 8, 16] {
        for elems in [1 << 12, 1 << 18, 1 << 22] {
            let label = format!("ring1d  w={world} n={elems}");
            bench.run(&label, move || {
                run_spmd(world, move |ep| {
                    let group: Vec<usize> = (0..world).collect();
                    let mut data = vec![ep.rank as f32; elems];
                    ring_all_reduce(ep, &group, &mut data);
                    std::hint::black_box(data[0]);
                });
            });
            let label = format!("torus2d w={world} n={elems}");
            bench.run(&label, move || {
                run_spmd(world, move |ep| {
                    let place = Placement::new(world);
                    let mut data = vec![ep.rank as f32; elems];
                    torus2d_all_reduce(ep, &place, &mut data);
                    std::hint::black_box(data[0]);
                });
            });
        }
    }
    println!("\n(2-D wins grow with world size — fewer serial ring steps per link.)");
}
