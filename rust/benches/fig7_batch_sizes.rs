//! Figure 7 reproduction: "Batch sizes used in scaling MLPerf models" —
//! the global batch each model uses at each pod slice, showing that only
//! ResNet-50 scales its batch aggressively while the others grow ≤2x and
//! lean on model parallelism instead. Driven by the scenario sweep engine
//! (`scenario::fig7_scenarios`).

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::models::model;
use tpu_pod_train::scenario::{fig7_scenarios, run_scenario};

fn main() {
    let mut t = Table::new(
        "Fig. 7: global batch size vs TPU-v3 cores",
        &["model", "128", "256", "512", "1024", "2048", "growth"],
    );
    for s in fig7_scenarios() {
        let m = model(&s.model).unwrap();
        let recs = run_scenario(&s).expect("scenario");
        let mut row = vec![s.model.clone()];
        let mut first = None;
        let mut last = None;
        for r in &recs {
            if r.cores > m.max_useful_cores() {
                row.push("—".into());
                continue;
            }
            if first.is_none() {
                first = Some(r.global_batch);
            }
            last = Some(r.global_batch);
            row.push(if r.mp > 1 {
                format!("{} (mp{})", r.global_batch, r.mp)
            } else {
                r.global_batch.to_string()
            });
        }
        let growth = last.unwrap() as f64 / first.unwrap() as f64;
        row.push(format!("{growth:.1}x"));
        t.row(&row);
    }
    t.print();
    println!("\nPaper §4: 'with the exception of ResNet-50, in all other MLPerf-0.6");
    println!("models batch size only increases two times or less.'");
}
