//! Figure 7 reproduction: "Batch sizes used in scaling MLPerf models" —
//! the global batch each model uses at each pod slice, showing that only
//! ResNet-50 scales its batch aggressively while the others grow ≤2x and
//! lean on model parallelism instead.

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::models::all_models;

fn main() {
    let slices = [128usize, 256, 512, 1024, 2048];
    let mut t = Table::new(
        "Fig. 7: global batch size vs TPU-v3 cores",
        &["model", "128", "256", "512", "1024", "2048", "growth"],
    );
    for m in all_models() {
        let mut row = vec![m.name.to_string()];
        let mut first = None;
        let mut last = None;
        for &cores in &slices {
            if cores > m.max_useful_cores() {
                row.push("—".into());
                continue;
            }
            let l = m.layout(cores);
            if first.is_none() {
                first = Some(l.global_batch);
            }
            last = Some(l.global_batch);
            row.push(if l.mp > 1 {
                format!("{} (mp{})", l.global_batch, l.mp)
            } else {
                l.global_batch.to_string()
            });
        }
        let growth = last.unwrap() as f64 / first.unwrap() as f64;
        row.push(format!("{growth:.1}x"));
        t.row(&row);
    }
    t.print();
    println!("\nPaper §4: 'with the exception of ResNet-50, in all other MLPerf-0.6");
    println!("models batch size only increases two times or less.'");
}
