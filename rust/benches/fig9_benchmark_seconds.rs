//! Figure 9 reproduction: "MLPerf-0.6 benchmark seconds" — simulated
//! time-to-train for the five models across pod slices with all §2
//! optimizations enabled, plus the paper-scale summary row. Driven by the
//! scenario sweep engine (`scenario::fig9_scenarios`).

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::models::{all_models, model};
use tpu_pod_train::scenario::{fig9_scenarios, run_scenario, ScalingScenario};

fn main() {
    let mut t = Table::new(
        "Fig. 9: benchmark seconds vs TPU-v3 cores (simulated)",
        &["model", "64", "128", "256", "512", "1024", "2048"],
    );
    for s in fig9_scenarios() {
        let m = model(&s.model).unwrap();
        let recs = run_scenario(&s).expect("scenario");
        let mut row = vec![s.model.clone()];
        for r in &recs {
            row.push(if r.cores > m.max_useful_cores() {
                "—".into()
            } else if r.converged {
                format!("{:.0}", r.benchmark_seconds)
            } else {
                "DNF".into()
            });
        }
        t.row(&row);
    }
    t.print();

    let mut t2 = Table::new(
        "Largest-scale summary vs the public MLPerf-0.6 results",
        &["model", "cores", "sim seconds", "public v0.6 (approx)"],
    );
    let mut t3 = Table::new(
        "Pod-scale per-phase attribution (participating groups, ms/step)",
        &["model", "active/cores", "compute", "halo", "gradsum", "update", "eval s/pass"],
    );
    let public = [
        ("resnet50", "67-77"),
        ("ssd", "~73"),
        ("maskrcnn", "~2100"),
        ("transformer", "~51"),
        ("gnmt", "~108"),
    ];
    for (m, (_, pub_s)) in all_models().iter().zip(public) {
        let cores = m.max_useful_cores().min(2048);
        let s = ScalingScenario::submission(m.name, vec![cores / 2])
            .named(format!("fig9-summary-{}", m.name));
        let recs = run_scenario(&s).expect("scenario");
        let r = &recs[0];
        t2.row(&[
            m.name.to_string(),
            cores.to_string(),
            format!("{:.0}", r.benchmark_seconds),
            pub_s.to_string(),
        ]);
        let n_evals = (r.epochs / m.eval_interval_epochs).ceil().max(1.0);
        t3.row(&[
            m.name.to_string(),
            format!("{}/{}", r.participating_cores, r.cores),
            format!("{:.3}", r.compute_seconds * 1e3),
            format!("{:.3}", r.halo_seconds * 1e3),
            format!("{:.3}", r.gradsum_seconds * 1e3),
            format!("{:.3}", r.update_seconds * 1e3),
            format!("{:.2}", r.eval_seconds / n_evals),
        ]);
    }
    t2.print();
    t3.print();
    println!("\n(Absolute agreement is not expected from a simulator; the shape —");
    println!(" who is fastest, where scaling flattens, Mask-RCNN's wall — should hold.");
    println!(" Every phase above is priced over its participating group — surplus");
    println!(" cores, e.g. GNMT's idle half-pod, buy no gradsum/update/eval time.)");
}
