//! Tier-1 perf harness for the sweep engine: run the full 5-model §2
//! ablation grid through the pre-memoization serial reference and the
//! memoized serial/parallel engines, cross-check byte-identity, and
//! record the wall-clocks in `BENCH_sweep.json` at the workspace root so
//! every `cargo test` run refreshes the perf trajectory. Timing
//! assertions are deliberately absent — CI machines are noisy; the
//! recorded numbers are the artifact.

use tpu_pod_train::scenario::{run_sweep_bench, AblationGrid};

#[test]
fn full_grid_bench_records_perf_trajectory() {
    let grid = AblationGrid::full_paper();
    let bench = run_sweep_bench(&grid, 0).expect("sweep bench (byte-identity cross-check)");
    assert_eq!(bench.scenarios, 80);
    assert_eq!(bench.points, 480);
    assert!(bench.baseline_s > 0.0 && bench.serial_s > 0.0 && bench.parallel_s > 0.0);

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sweep.json");
    bench.write(path).unwrap_or_else(|e| panic!("writing {path}: {e}"));

    // Round-trip: the record parses and carries the headline fields.
    let text = std::fs::read_to_string(path).unwrap();
    let j = tpu_pod_train::util::json::Json::parse(&text).unwrap();
    assert_eq!(j.get("points").and_then(|v| v.as_usize()), Some(480));
    let speedup = j.get("speedup_vs_baseline").and_then(|v| v.as_f64()).unwrap();
    assert!(speedup > 0.0, "speedup field must be populated, got {speedup}");
}
