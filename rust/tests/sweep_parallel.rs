//! Determinism contract of the parallel sweep engine: for every preset
//! and for the ablation grid, `SweepRunner::run_jobs(N)` must serialize
//! byte-identically to `run_jobs(1)` — worker count and scheduling order
//! can never leak into a report. Also pins the memoized engine against
//! the uncached single-point evaluator.

use tpu_pod_train::scenario::{
    fig7_scenarios, fig8_scenarios, fig9_scenarios, sweep_point, table1_scenarios, AblationGrid,
    SweepRunner,
};

fn assert_jobs_invariant(runner: &SweepRunner) {
    let serial = runner.run_jobs(1).expect("serial sweep");
    let serial_dump = serial.dump();
    for jobs in [2usize, 3, 8, 0] {
        let parallel = runner.run_jobs(jobs).expect("parallel sweep");
        assert_eq!(
            serial_dump,
            parallel.dump(),
            "jobs={jobs}: parallel report is not byte-identical to serial"
        );
    }
}

#[test]
fn fig7_preset_parallel_is_byte_identical() {
    assert_jobs_invariant(&SweepRunner::new(fig7_scenarios()));
}

#[test]
fn fig8_preset_parallel_is_byte_identical() {
    assert_jobs_invariant(&SweepRunner::new(fig8_scenarios(&[256, 1024, 2048])));
}

#[test]
fn fig9_preset_parallel_is_byte_identical() {
    assert_jobs_invariant(&SweepRunner::new(fig9_scenarios()));
}

#[test]
fn table1_preset_parallel_is_byte_identical() {
    assert_jobs_invariant(&SweepRunner::new(table1_scenarios()));
}

#[test]
fn ablation_grid_parallel_is_byte_identical() {
    // Full axis cross-product; chip ladder trimmed to keep tier-1 fast
    // (the full ladder runs in tests/bench_sweep.rs).
    let mut grid = AblationGrid::full_paper();
    grid.chips = vec![16, 256];
    assert_jobs_invariant(&SweepRunner::new(grid.scenarios()));
}

#[test]
fn memoized_engine_matches_uncached_point_evaluator() {
    // The engine's memoized kernels and hoisted census must be invisible:
    // every record equals what the standalone single-point evaluator
    // (fresh cache per point) produces, byte for byte.
    let scenarios = fig9_scenarios();
    let report = SweepRunner::new(scenarios.clone()).run().expect("sweep");
    let mut i = 0;
    for s in &scenarios {
        let m = s.profile().expect("profile");
        for &chips in &s.chips {
            let reference = sweep_point(s, &m, chips);
            assert_eq!(
                report.records[i].to_json().dump(),
                reference.to_json().dump(),
                "{} @ {chips} chips diverged from the uncached evaluator",
                s.name
            );
            i += 1;
        }
    }
    assert_eq!(i, report.records.len());
}

#[test]
fn validation_failure_reports_before_any_work_in_parallel_mode() {
    let mut grid = AblationGrid::full_paper();
    grid.models = vec!["resnet50".into(), "alexnet".into()];
    grid.chips = vec![16];
    let err = SweepRunner::new(grid.scenarios()).run_jobs(4).unwrap_err();
    assert!(err.contains("alexnet"), "{err}");
}
