//! Tier-1 perf harness for the reference-backend executors: run every
//! proxy family's `train_step` through the naive (pre-tiling scalar
//! baseline), tiled, and tiled+threaded configurations, cross-check
//! bit-identity, and record the wall-clocks in `BENCH_backend.json` at
//! the workspace root so every `cargo test` run refreshes the perf
//! trajectory. Timing assertions are deliberately absent — CI machines
//! are noisy; the recorded numbers (and the ≥4x speedup acceptance) are
//! read from the artifact, not gated here.

use tpu_pod_train::models::proxy::PROXY_FAMILIES;
use tpu_pod_train::scenario::run_backend_bench;
use tpu_pod_train::util::json::Json;

#[test]
fn backend_matrix_records_perf_trajectory() {
    let families: Vec<&str> = PROXY_FAMILIES.iter().map(|d| d.family).collect();
    let bench = run_backend_bench(&families, 20, 0)
        .expect("backend bench (bit-identity cross-check)");
    assert_eq!(bench.cases.len(), families.len());
    assert!(bench.threads >= 1);
    for c in &bench.cases {
        assert!(
            c.naive_step_s > 0.0 && c.tiled_step_s > 0.0 && c.threaded_step_s > 0.0,
            "{}: zero step time recorded",
            c.family
        );
    }

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_backend.json");
    bench.write(path).unwrap_or_else(|e| panic!("writing {path}: {e}"));

    // Round-trip: the record parses and carries the headline fields.
    let text = std::fs::read_to_string(path).unwrap();
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("backend_matrix"));
    assert_eq!(
        j.get("cases").and_then(|v| v.as_arr()).map(|a| a.len()),
        Some(families.len())
    );
    let geomean = j.get("geomean_speedup_threaded").and_then(|v| v.as_f64()).unwrap();
    assert!(geomean > 0.0, "geomean speedup must be populated, got {geomean}");
}
