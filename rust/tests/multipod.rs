//! Cross-layer property tests for the hierarchical multi-pod netsim
//! (referenced from `netsim::topology`'s module docs):
//!
//! 1. **Collapse bit-identity** — any pod spec with `pods = 1` or
//!    inter-pod ratio `1.0` prices bit-identically to the flat 2-D torus
//!    on the paper's 16/64/256/1024 ladder, for both the raw and the
//!    guarded (per-chip payload) entry points.
//! 2. **Fast-path bypass** — a non-uniform payload schedule reports
//!    `fastpath: false` through the pod-aware guarded pricing and the
//!    `SweepCache` schedule key, and costs at least the uniform price.
//! 3. **Concurrent-phase contention** — gradsum and halo injected into
//!    one simulation cost at least either phase priced alone.
//!
//! Plus the grid end-to-end: pod axes declared on an `AblationGrid`
//! arrive in the emitted `SweepRecord`s.

use tpu_pod_train::costs::PodLayout;
use tpu_pod_train::netsim::{
    concurrent_gradsum_halo_makespan, pod_group_gradsum_makespan,
    pod_group_gradsum_makespan_guarded, torus2d_gradsum_makespan, CrossPodStrategy, Message,
    NetParams, NetSim, PodSpec, Torus,
};
use tpu_pod_train::scenario::{AblationGrid, SweepCache, SweepRunner};

const LADDER: [usize; 4] = [16, 64, 256, 1024];

/// Every degenerate pod spec (single pod, or full-rate inter-pod links,
/// under either cross-pod strategy) must collapse to the flat capped
/// torus **bit-for-bit** — multi-pod support cannot perturb the paper's
/// single-pod numbers even in the last ulp.
#[test]
fn collapsing_pod_specs_price_bit_identical_to_the_flat_torus() {
    let p = NetParams::default();
    for &chips in &LADDER {
        let torus = Torus::for_chips_idle(chips, PodLayout::TORUS_MAX_ASPECT).0;
        let flat = torus2d_gradsum_makespan(torus, 1e8, &p);
        for spec in [
            PodSpec::default(),
            PodSpec::new(1, 0.25),
            PodSpec::new(4, 1.0),
            PodSpec::new(1, 1.0).with_strategy(CrossPodStrategy::FlatRing),
            PodSpec::new(8, 1.0).with_strategy(CrossPodStrategy::FlatRing),
        ] {
            let priced =
                pod_group_gradsum_makespan(chips, spec, PodLayout::TORUS_MAX_ASPECT, 1e8, &p);
            assert_eq!(
                priced.to_bits(),
                flat.to_bits(),
                "chips {chips}, spec {spec:?}: {priced} vs flat {flat}"
            );
        }
    }
}

/// The guarded entry point under uniform payloads: collapse specs take
/// the symmetry fast path and reproduce the flat price bit-for-bit on
/// the whole ladder.
#[test]
fn guarded_uniform_collapse_takes_the_fast_path_on_the_ladder() {
    let p = NetParams::default();
    for &chips in &LADDER {
        let torus = Torus::for_chips_idle(chips, PodLayout::TORUS_MAX_ASPECT).0;
        let flat = torus2d_gradsum_makespan(torus, 4e7, &p);
        let payloads = vec![4e7; torus.chips()];
        let g = pod_group_gradsum_makespan_guarded(
            chips,
            PodSpec::default(),
            PodLayout::TORUS_MAX_ASPECT,
            &payloads,
            &p,
        );
        assert!(g.fastpath, "uniform single-pod payloads must take the fast path");
        assert_eq!(g.seconds.to_bits(), flat.to_bits(), "chips {chips}");
    }
}

/// Slower inter-pod links can only cost more, and the cross-pod phase is
/// a real cost on top of each pod's own reduction.
#[test]
fn slower_inter_pod_links_cost_more() {
    let p = NetParams::default();
    for &chips in &[64usize, 256, 1024] {
        let half = pod_group_gradsum_makespan(
            chips,
            PodSpec::new(4, 0.5),
            PodLayout::TORUS_MAX_ASPECT,
            1e8,
            &p,
        );
        let eighth = pod_group_gradsum_makespan(
            chips,
            PodSpec::new(4, 0.125),
            PodLayout::TORUS_MAX_ASPECT,
            1e8,
            &p,
        );
        assert!(eighth > half, "chips {chips}: ratio 1/8 {eighth} vs 1/2 {half}");
        let per_pod = torus2d_gradsum_makespan(
            Torus::for_chips_idle(chips / 4, PodLayout::TORUS_MAX_ASPECT).0,
            1e8,
            &p,
        );
        assert!(half > per_pod, "chips {chips}: the cross-pod phase must cost something");
    }
}

/// Non-uniform payload schedules must bypass the symmetry fast path —
/// through the pod-aware guarded pricing directly, and through the
/// `SweepCache`, whose key carries the full schedule fingerprint (so a
/// skewed schedule can never be served a uniform schedule's cached
/// price) and the pod spec (so multi-pod points never collide with flat
/// ones).
#[test]
fn non_uniform_schedules_bypass_the_fastpath_and_key_the_cache() {
    let p = NetParams::default();
    let chips = 64usize;
    let torus = Torus::for_chips_idle(chips, PodLayout::TORUS_MAX_ASPECT).0;
    let mut payloads = vec![1e7; torus.chips()];
    let uniform = pod_group_gradsum_makespan_guarded(
        chips,
        PodSpec::default(),
        PodLayout::TORUS_MAX_ASPECT,
        &payloads,
        &p,
    );
    assert!(uniform.fastpath);
    payloads[7] *= 3.0;
    let skewed = pod_group_gradsum_makespan_guarded(
        chips,
        PodSpec::default(),
        PodLayout::TORUS_MAX_ASPECT,
        &payloads,
        &p,
    );
    assert!(!skewed.fastpath, "a non-uniform schedule must use the event engine");
    assert!(skewed.seconds > uniform.seconds, "the heavy chip can only slow things down");

    // Same contract through the memoizing cache (the sweep engine's path).
    let cache = SweepCache::default();
    let base = vec![1e7; torus.chips()];
    let c_uniform = cache.scheduled_makespan(&base, chips, PodSpec::default());
    assert!(c_uniform.fastpath);
    assert_eq!(c_uniform.seconds.to_bits(), uniform.seconds.to_bits());
    let c_skewed = cache.scheduled_makespan(&payloads, chips, PodSpec::default());
    assert!(!c_skewed.fastpath);
    assert_eq!(c_skewed.seconds.to_bits(), skewed.seconds.to_bits());
    // A multi-pod spec keys (and prices) separately from the flat torus.
    let c_multi = cache.scheduled_makespan(&payloads, chips, PodSpec::new(2, 0.25));
    assert!(!c_multi.fastpath);
    assert_ne!(c_multi.seconds.to_bits(), c_skewed.seconds.to_bits());
}

/// The halo batch of `concurrent_gradsum_halo_makespan`'s convention:
/// consecutive row-major groups of `group` chips, each chip shipping
/// `bytes` to the next member of its group ring.
fn halo_batch(torus: Torus, group: usize, bytes: f64) -> Vec<Message> {
    let n = torus.chips();
    let mut msgs = Vec::new();
    let mut start = 0;
    while start < n {
        let size = group.min(n - start);
        if size > 1 {
            for off in 0..size {
                msgs.push(Message {
                    src: torus.coord(start + off),
                    dst: torus.coord(start + (off + 1) % size),
                    bytes,
                    ready_at: 0.0,
                });
            }
        }
        start += size;
    }
    msgs
}

/// Concurrent phases share link bandwidth: the joint price is at least
/// the clean gradsum schedule and at least the halo phase alone, for
/// both gradsum schedules, across the ladder's lower rungs.
#[test]
fn concurrent_phases_cost_at_least_each_phase_alone() {
    let p = NetParams::default();
    for &chips in &[16usize, 64, 256] {
        let torus = Torus::for_chips_idle(chips, PodLayout::TORUS_MAX_ASPECT).0;
        let payloads = vec![2e7; torus.chips()];
        let halo_alone = NetSim::new(torus, p.link_bw, p.link_latency)
            .makespan(&halo_batch(torus, 4, 1e6));
        assert!(halo_alone > 0.0);
        for two_d in [true, false] {
            let clean =
                concurrent_gradsum_halo_makespan(torus, &payloads, 4, 0.0, two_d, &p).seconds;
            let joint = concurrent_gradsum_halo_makespan(torus, &payloads, 4, 1e6, two_d, &p);
            assert!(!joint.fastpath, "shared-link pricing is never the fast path");
            assert!(
                joint.seconds >= clean,
                "chips {chips} two_d {two_d}: joint {} vs clean {clean}",
                joint.seconds
            );
            assert!(
                joint.seconds >= halo_alone,
                "chips {chips} two_d {two_d}: joint {} vs halo alone {halo_alone}",
                joint.seconds
            );
        }
    }
}

/// End to end: pod axes declared on the ablation grid arrive in the
/// emitted records — strategy labels, ratio, pod count, and a finite
/// concurrent makespan next to the collective one.
#[test]
fn grid_pod_axes_reach_the_sweep_records() {
    let mut g = AblationGrid::full_paper();
    g.models = vec!["resnet50".to_string()];
    g.chips = vec![16];
    g.pods = vec![2];
    g.inter_pod_ratios = vec![0.25];
    g.cross_pod = vec![CrossPodStrategy::Hierarchical, CrossPodStrategy::FlatRing];
    let report = SweepRunner::new(g.scenarios()).run_jobs(2).expect("grid runs");
    assert!(!report.records.is_empty());
    let mut labels = std::collections::BTreeSet::new();
    for r in &report.records {
        assert_eq!(r.pods, 2, "{}", r.scenario);
        assert_eq!(r.inter_pod_ratio, 0.25, "{}", r.scenario);
        assert!(r.scenario.contains("-pods:2-ipr:0.25-xp:"), "{}", r.scenario);
        assert!(r.collective_makespan_seconds.is_finite());
        assert!(r.concurrent_makespan_seconds.is_finite());
        labels.insert(r.cross_pod_strategy.clone());
    }
    assert_eq!(
        labels.into_iter().collect::<Vec<_>>(),
        vec!["flat-ring".to_string(), "hierarchical".to_string()]
    );
}
