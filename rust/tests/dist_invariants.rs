//! Property-based invariants of the distributed substrates, driven by the
//! in-repo shrinking framework (`tpu_pod_train::testing`).
//!
//! These are the "it must hold for every shape" contracts: collectives
//! compute exact sums for any world size and payload, sharding plans
//! partition exactly, the eval sharder covers each example once, packers
//! round-trip, bf16 error stays bounded.

use tpu_pod_train::collectives::{
    chunk_range, gradsum_pipelined, gradsum_serial, halo_exchange, ring_all_reduce, FlatView,
    Placement,
};
use tpu_pod_train::data::bucket::{batch_bucketized, batch_sequential, total_waste};
use tpu_pod_train::data::synthetic::TranslationTask;
use tpu_pod_train::evaluation::EvalSharding;
use tpu_pod_train::fabric::run_spmd;
use tpu_pod_train::models::{all_models, Layout};
use tpu_pod_train::netsim::{
    payload_uniform, ring_step_makespan, torus2d_gradsum_event_makespan,
    torus2d_gradsum_makespan, torus2d_gradsum_makespan_guarded, ArAlgo, CostModel, Dir, Message,
    NetParams, NetSim, Torus,
};
use tpu_pod_train::scenario::gradsum_contention_makespan;
use tpu_pod_train::simulator::{simulate, SimOptions};
use tpu_pod_train::testing::forall;
use tpu_pod_train::util::bf16::{Bf16, BF16_MAX_REL_ERR};
use tpu_pod_train::util::rng::Rng;
use tpu_pod_train::wus::ShardPlan;

#[test]
fn prop_chunk_ranges_partition_exactly() {
    forall(
        300,
        |rng| (rng.below(10_000) as usize, rng.below(64) as usize + 1),
        |&(len, n)| {
            let mut covered = 0;
            for c in 0..n {
                let r = chunk_range(len, n, c);
                if r.start != covered {
                    return Err(format!("gap at chunk {c}"));
                }
                covered = r.end;
            }
            if covered != len {
                return Err(format!("covered {covered} != len {len}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ring_all_reduce_exact_sums() {
    forall(
        25,
        |rng| {
            let world = 1usize << rng.below(4); // 1..8
            let len = rng.below(200) as usize + 1;
            (world, len)
        },
        |&(world, len)| {
            let out = run_spmd(world, |ep| {
                let group: Vec<usize> = (0..world).collect();
                let mut data: Vec<f32> =
                    (0..len).map(|i| ((ep.rank * 13 + i) % 7) as f32).collect();
                ring_all_reduce(ep, &group, &mut data);
                data
            });
            for i in 0..len {
                let expect: f32 = (0..world).map(|r| ((r * 13 + i) % 7) as f32).sum();
                for (r, row) in out.iter().enumerate() {
                    if (row[i] - expect).abs() > 1e-4 {
                        return Err(format!("rank {r} elt {i}: {} != {expect}", row[i]));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gradsum_modes_agree_and_sum() {
    forall(
        15,
        |rng| {
            let world = 1usize << (rng.below(3) + 1); // 2,4,8
            let ntensors = rng.below(8) as usize + 1;
            let sizes: Vec<usize> =
                (0..ntensors).map(|_| rng.below(40) as usize + 1).collect();
            let quantum = rng.below(64) as usize + 1;
            (world, (sizes, quantum))
        },
        |&(world, (ref sizes, quantum))| {
            let sizes_in = sizes.clone();
            let make = move |rank: usize| -> Vec<Vec<f32>> {
                sizes_in
                    .iter()
                    .enumerate()
                    .map(|(t, &s)| {
                        (0..s).map(|i| ((rank * 3 + t * 5 + i) % 9) as f32 - 4.0).collect()
                    })
                    .collect()
            };
            let out = run_spmd(world, move |ep| {
                let place = Placement::new(world);
                let mut a = make(ep.rank);
                let mut b = make(ep.rank);
                gradsum_serial(ep, &place, &mut a);
                gradsum_pipelined(ep, &place, &mut b, quantum);
                (a, b)
            });
            for (r, (a, b)) in out.iter().enumerate() {
                for (ti, s) in sizes.iter().enumerate() {
                    for i in 0..*s {
                        let expect: f32 = (0..world)
                            .map(|rr| ((rr * 3 + ti * 5 + i) % 9) as f32 - 4.0)
                            .sum();
                        if (a[ti][i] - expect).abs() > 1e-3 {
                            return Err(format!("serial rank {r} t{ti}[{i}]"));
                        }
                        if (b[ti][i] - expect).abs() > 1e-3 {
                            return Err(format!(
                                "pipelined rank {r} t{ti}[{i}]: {} != {expect} (q={quantum})",
                                b[ti][i]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_flatview_pack_unpack_roundtrip() {
    forall(
        200,
        |rng| {
            let sizes: Vec<usize> =
                (0..rng.below(6) + 1).map(|_| rng.below(30) as usize + 1).collect();
            let total: usize = sizes.iter().sum();
            let start = rng.below(total as u64) as usize;
            let end = start + 1 + rng.below((total - start) as u64) as usize;
            (sizes, (start, end))
        },
        |&(ref sizes, (start, end))| {
            if sizes.is_empty() {
                return Ok(());
            }
            let total: usize = sizes.iter().sum();
            if total == 0 || end > total || start >= end {
                return Ok(()); // shrinking may produce degenerate inputs
            }
            let mut tensors: Vec<Vec<f32>> = sizes
                .iter()
                .enumerate()
                .map(|(t, &s)| (0..s).map(|i| (t * 100 + i) as f32).collect())
                .collect();
            let orig = tensors.clone();
            let mut view =
                FlatView::new(tensors.iter_mut().map(|t| t.as_mut_slice()).collect());
            let mut buf = vec![0.0f32; end - start];
            view.pack(start, end, &mut buf);
            // Unpack the packed data back — must be identity.
            view.unpack(start, end, &buf);
            drop(view);
            if tensors != orig {
                return Err("pack/unpack not identity".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shard_plan_partitions_and_balances() {
    forall(
        300,
        |rng| {
            let sizes: Vec<usize> =
                (0..rng.below(12) + 1).map(|_| rng.below(5000) as usize).collect();
            let shards = rng.below(64) as usize + 1;
            (sizes, shards)
        },
        |&(ref sizes, shards)| {
            if shards == 0 {
                return Ok(());
            }
            let plan = ShardPlan::balanced(sizes, shards);
            let total: usize = sizes.iter().sum();
            if plan.total != total {
                return Err("total mismatch".into());
            }
            let mut covered = 0;
            for r in &plan.ranges {
                if r.start != covered {
                    return Err("gap".into());
                }
                covered = r.end;
            }
            if covered != total {
                return Err("incomplete cover".into());
            }
            let max = plan.ranges.iter().map(|r| r.len()).max().unwrap();
            let min = plan.ranges.iter().map(|r| r.len()).min().unwrap();
            if max > min + 1 {
                return Err(format!("imbalance {max} vs {min}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eval_sharding_covers_exactly_once() {
    forall(
        300,
        |rng| {
            (
                rng.below(500) as usize + 1,
                (rng.below(16) as usize + 1, rng.below(16) as usize + 1),
            )
        },
        |&(n, (cores, batch))| {
            if cores == 0 || batch == 0 {
                return Ok(());
            }
            let s = EvalSharding::new(n, cores, batch);
            let mut seen = vec![0u32; n];
            for step in 0..s.steps() {
                for core in 0..cores {
                    let c = s.chunk(core, step);
                    for (i, &g) in c.indices.iter().enumerate() {
                        if c.mask[i] == 1.0 {
                            if g >= n {
                                return Err(format!("index {g} out of range"));
                            }
                            seen[g] += 1;
                        }
                    }
                }
            }
            if seen.iter().any(|&x| x != 1) {
                return Err("coverage not exactly-once".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bf16_error_bounded() {
    forall(
        2000,
        |rng| rng.normal_f32(0.0, 100.0),
        |&x| {
            if x == 0.0 || !x.is_finite() {
                return Ok(());
            }
            let rel = ((Bf16::from_f32(x).to_f32() - x) / x).abs();
            if rel > BF16_MAX_REL_ERR {
                return Err(format!("rel err {rel} for {x}"));
            }
            Ok(())
        },
    );
}

/// The §2 gradient-summation contract at the edges of the quantum axis:
/// pipelined (any pack granularity) ≡ serial ≡ the local reference sum,
/// including the degenerate world of one.
#[test]
fn prop_gradsum_extreme_quanta_match_local_reference() {
    forall(
        10,
        |rng| {
            let world = rng.below(6) as usize + 1; // 1..=6, non-powers-of-two included
            let ntensors = rng.below(5) as usize + 1;
            let sizes: Vec<usize> =
                (0..ntensors).map(|_| rng.below(25) as usize + 1).collect();
            (world, sizes)
        },
        |&(world, ref sizes)| {
            // Shrinking may propose a zero world; skip it so a failure
            // still shrinks cleanly. (Any positive world is valid now —
            // non-powers-of-two run the collectives on a 1-D ring or a
            // near-square torus.)
            if world == 0 {
                return Ok(());
            }
            let total: usize = sizes.iter().sum();
            let sizes_in = sizes.clone();
            let make = move |rank: usize| -> Vec<Vec<f32>> {
                sizes_in
                    .iter()
                    .enumerate()
                    .map(|(t, &s)| {
                        (0..s).map(|i| ((rank * 7 + t * 3 + i) % 11) as f32 - 5.0).collect()
                    })
                    .collect()
            };
            for quantum in [1usize, total.max(1), 4 * total.max(1)] {
                let out = run_spmd(world, {
                    let make = make.clone();
                    move |ep| {
                        let place = Placement::new(world);
                        let mut serial = make(ep.rank);
                        let mut pipelined = make(ep.rank);
                        gradsum_serial(ep, &place, &mut serial);
                        gradsum_pipelined(ep, &place, &mut pipelined, quantum);
                        (serial, pipelined)
                    }
                });
                for (r, (serial, pipelined)) in out.iter().enumerate() {
                    for (ti, &s) in sizes.iter().enumerate() {
                        for i in 0..s {
                            let reference: f32 = (0..world)
                                .map(|rr| ((rr * 7 + ti * 3 + i) % 11) as f32 - 5.0)
                                .sum();
                            if (serial[ti][i] - reference).abs() > 1e-3 {
                                return Err(format!(
                                    "serial rank {r} t{ti}[{i}] != local reference (q={quantum})"
                                ));
                            }
                            if (pipelined[ti][i] - reference).abs() > 1e-3 {
                                return Err(format!(
                                    "pipelined rank {r} t{ti}[{i}] != local reference (q={quantum})"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Tentpole contract of the arbitrary-survivor work: ring gradient
/// summation is **exact** — `== the serial per-element sum`, bit for
/// bit — at non-power-of-two worlds. The payloads are integer-valued
/// f32 (magnitudes ≤ 5, ≤ 96 addends), so every summation order yields
/// the same float; equality here pins exactness, not a tolerance.
#[test]
fn prop_ring_gradsum_equals_serial_sum_at_non_power_of_two_worlds() {
    for world in [3usize, 6, 12, 96] {
        let cases = if world >= 48 { 2 } else { 6 };
        forall(
            cases,
            |rng| {
                let ntensors = rng.below(4) as usize + 1;
                let sizes: Vec<usize> =
                    (0..ntensors).map(|_| rng.below(30) as usize + 1).collect();
                let quantum = rng.below(48) as usize + 1;
                (sizes, quantum)
            },
            |&(ref sizes, quantum)| {
                if sizes.is_empty() || quantum == 0 {
                    return Ok(()); // degenerate shrink proposals
                }
                let sizes_in = sizes.clone();
                let make = move |rank: usize| -> Vec<Vec<f32>> {
                    sizes_in
                        .iter()
                        .enumerate()
                        .map(|(t, &s)| {
                            (0..s).map(|i| ((rank * 7 + t * 3 + i) % 11) as f32 - 5.0).collect()
                        })
                        .collect()
                };
                let out = run_spmd(world, move |ep| {
                    let place = Placement::new(world);
                    let mut serial = make(ep.rank);
                    let mut pipelined = make(ep.rank);
                    gradsum_serial(ep, &place, &mut serial);
                    gradsum_pipelined(ep, &place, &mut pipelined, quantum);
                    (serial, pipelined)
                });
                for (r, (serial, pipelined)) in out.iter().enumerate() {
                    for (ti, &s) in sizes.iter().enumerate() {
                        for i in 0..s {
                            let reference: f32 = (0..world)
                                .map(|rr| ((rr * 7 + ti * 3 + i) % 11) as f32 - 5.0)
                                .sum();
                            if serial[ti][i].to_bits() != reference.to_bits() {
                                return Err(format!(
                                    "world {world} serial rank {r} t{ti}[{i}]: \
                                     {} != serial sum {reference}",
                                    serial[ti][i]
                                ));
                            }
                            if pipelined[ti][i].to_bits() != reference.to_bits() {
                                return Err(format!(
                                    "world {world} pipelined rank {r} t{ti}[{i}] \
                                     (q={quantum}): {} != serial sum {reference}",
                                    pipelined[ti][i]
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

/// WUS checkpoint contract at arbitrary worlds: restoring full-length
/// optimizer slots into per-rank shards (uneven remainder shards at
/// non-power-of-two worlds) and all-gathering them back is the
/// identity, bit for bit — the round-trip the v2 checkpoint resume
/// path depends on.
#[test]
fn prop_shard_state_gather_restore_roundtrip_at_non_power_of_two_worlds() {
    use tpu_pod_train::wus::ShardedSgd;
    for world in [3usize, 6, 12, 96] {
        let cases = if world >= 48 { 2 } else { 6 };
        forall(
            cases,
            |rng| {
                let ntensors = rng.below(6) as usize + 1;
                (0..ntensors).map(|_| rng.below(300) as usize).collect::<Vec<usize>>()
            },
            |sizes: &Vec<usize>| {
                let total: usize = sizes.iter().sum();
                if total == 0 {
                    return Ok(()); // nothing to shard
                }
                let full: Vec<f32> = (0..total).map(|i| (i % 17) as f32 - 8.0).collect();
                let full_in = full.clone();
                let sizes_in = sizes.clone();
                let out = run_spmd(world, move |ep| {
                    let plan = ShardPlan::balanced(&sizes_in, world);
                    let mut opt = ShardedSgd::new(0.9, plan, ep.rank);
                    opt.restore_full_state(&[("velocity".into(), full_in.clone())])
                        .expect("restore_full_state");
                    let group: Vec<usize> = (0..world).collect();
                    opt.gather_full_state(ep, &group)
                });
                for (r, slots) in out.iter().enumerate() {
                    let (name, v) = &slots[0];
                    if name != "velocity" {
                        return Err(format!("world {world} rank {r}: slot {name:?}"));
                    }
                    if v.len() != full.len() {
                        return Err(format!(
                            "world {world} rank {r}: gathered {} of {} elements",
                            v.len(),
                            full.len()
                        ));
                    }
                    for i in 0..v.len() {
                        if v[i].to_bits() != full[i].to_bits() {
                            return Err(format!(
                                "world {world} rank {r} elt {i}: {} != {}",
                                v[i], full[i]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

/// `ShardPlan::balanced` contracts beyond gap-free coverage: the
/// `imbalance()` metric respects the ceil/floor bound, and per tensor the
/// shard overlaps are disjoint, in order, and cover the tensor exactly.
#[test]
fn prop_shard_plan_imbalance_bound_and_overlap_partition() {
    forall(
        200,
        |rng| {
            let sizes: Vec<usize> =
                (0..rng.below(10) + 1).map(|_| rng.below(4000) as usize).collect();
            let shards = rng.below(64) as usize + 1;
            (sizes, shards)
        },
        |&(ref sizes, shards)| {
            // Generated shards are >= 1, but shrinking can propose 0;
            // skip it (the bound below would divide by zero).
            if shards == 0 {
                return Ok(());
            }
            let plan = ShardPlan::balanced(sizes, shards);
            let total: usize = sizes.iter().sum();
            if total >= shards {
                let floor = total / shards;
                let bound = (floor + 1) as f64 / floor as f64;
                if plan.imbalance() > bound + 1e-12 {
                    return Err(format!(
                        "imbalance {} exceeds ceil/floor bound {bound}",
                        plan.imbalance()
                    ));
                }
            }
            for (ti, &size) in sizes.iter().enumerate() {
                let mut covered = 0usize;
                for r in &plan.ranges {
                    if let Some(o) = plan.tensor_overlap(ti, r) {
                        if o.start != covered {
                            return Err(format!(
                                "tensor {ti}: overlap gap at {covered} (got {:?})",
                                o
                            ));
                        }
                        covered = o.end;
                    }
                }
                if covered != size {
                    return Err(format!("tensor {ti}: covered {covered} != size {size}"));
                }
            }
            Ok(())
        },
    );
}

/// Halo-exchange round-trip identity: bouncing the received halos straight
/// back must return every worker's own boundary rows unchanged (the halo
/// protocol is a pure transport — no aliasing, no reordering).
#[test]
fn prop_halo_exchange_roundtrip_identity() {
    forall(
        15,
        |rng| {
            let world = rng.below(4) as usize + 2; // 2..5 stripes
            let rows = rng.below(8) as usize + 1; // halo payload length
            (world, (rows, rng.next_u64()))
        },
        |&(world, (rows, seed))| {
            let out = run_spmd(world, move |ep| {
                let group: Vec<usize> = (0..world).collect();
                let mut rng = Rng::new(seed).fold_in(ep.rank as u64);
                let top: Vec<f32> = (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let bottom: Vec<f32> =
                    (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let pos = ep.rank;
                let (above, below) = halo_exchange(
                    ep,
                    &group,
                    (pos > 0).then_some(&top[..]),
                    (pos + 1 < world).then_some(&bottom[..]),
                    false,
                );
                // Bounce: send the received halos straight back.
                let (above2, below2) =
                    halo_exchange(ep, &group, above.as_deref(), below.as_deref(), false);
                (top, bottom, above2, below2)
            });
            for (r, (top, bottom, above2, below2)) in out.iter().enumerate() {
                if r > 0 && above2.as_ref() != Some(top) {
                    return Err(format!("rank {r}: top rows did not round-trip"));
                }
                if r + 1 < world && below2.as_ref() != Some(bottom) {
                    return Err(format!("rank {r}: bottom rows did not round-trip"));
                }
            }
            Ok(())
        },
    );
}

/// The event-driven 4-phase 2-D gradient-summation schedule must agree
/// with the analytic `CostModel::all_reduce(ArAlgo::Torus2D, ..)`: the
/// analytic model assumes every ring step's bidirectional neighbor
/// transfers overlap perfectly, and the link simulator prices exactly
/// those transfers under contention, so the two may differ only by the
/// analytic model's 4 per-phase software overheads.
///
/// Restricted to chips >= 16 so both torus dimensions are >= 4: on a
/// 2-wide dimension the +/- neighbor is the same chip and the shortest-
/// path router folds both half-chunks onto one link, where they honestly
/// serialize — the analytic bidirectional-bandwidth assumption only
/// holds with distinct +/- links.
#[test]
fn prop_contention_2d_schedule_matches_analytic_all_reduce() {
    forall(
        60,
        |rng| {
            let chips = 1usize << (rng.below(7) + 4); // 16 .. 1024
            let mbytes = rng.below(400) as usize + 1;
            (chips, mbytes)
        },
        |&(chips, mbytes)| {
            // Shrinking may propose non-power-of-two or too-small chip
            // counts; skip those so failures still shrink cleanly.
            if chips < 16 || !chips.is_power_of_two() {
                return Ok(());
            }
            let bytes = mbytes as f64 * 1e6;
            let p = NetParams::default();
            let analytic =
                CostModel::new(Torus::for_chips(chips), p).all_reduce(ArAlgo::Torus2D, bytes);
            let event = gradsum_contention_makespan(bytes, chips, true);
            let expected = analytic - 4.0 * p.phase_overhead;
            let rel = ((event - expected) / expected.abs().max(1e-15)).abs();
            if rel > 1e-3 {
                return Err(format!(
                    "{chips} chips, {mbytes} MB: event {event} vs analytic-minus-overhead \
                     {expected} (rel err {rel})"
                ));
            }
            Ok(())
        },
    );
}

/// Halo traffic under spatial partitioning: the analytic
/// `CostModel::halo_exchange` assumes all neighbor transfers overlap.
/// Drive the link simulator with every chip shipping a halo to all four
/// neighbors simultaneously — the makespan must equal ONE transfer (plus
/// link latency), i.e. the analytic time minus its software overhead.
#[test]
fn contention_confirms_halo_neighbor_overlap() {
    // Dimensions >= 4 so the four neighbor directions use four distinct
    // links (see the 2-D contention property above).
    for (nx, ny) in [(4usize, 4usize), (8, 4), (8, 8)] {
        let torus = Torus::new(nx, ny);
        let p = NetParams::default();
        let bytes = 2e6;
        let mut sim = NetSim::new(torus, p.link_bw, p.link_latency);
        let mut msgs = Vec::new();
        for c in torus.coords() {
            for d in [Dir::XPlus, Dir::XMinus, Dir::YPlus, Dir::YMinus] {
                let dst = torus.step(c, d);
                if dst != c {
                    msgs.push(Message { src: c, dst, bytes, ready_at: 0.0 });
                }
            }
        }
        let event = sim.makespan(&msgs);
        let analytic = CostModel::new(torus, p).halo_exchange(bytes, 4);
        let expected = analytic - p.phase_overhead;
        assert!(
            ((event - expected) / expected).abs() < 1e-9,
            "{nx}x{ny}: event {event} vs analytic-minus-overhead {expected}"
        );
    }
}

/// The netsim symmetry fast-path prices the 4-phase bidirectional 2-D
/// schedule from ONE representative ring row and column; under uniform
/// payloads the torus decomposes into identical rings sharing no links,
/// so the fast path must match the full event-driven simulation (which
/// schedules every ring of every row/column) to within 1e-9 on the
/// 16/64/256/1024-chip tori the sweeps price.
#[test]
fn fastpath_matches_full_event_simulation_on_pod_tori() {
    for chips in [16usize, 64, 256, 1024] {
        for mbytes in [1.0f64, 102.4, 400.0] {
            let bytes = mbytes * 1e6;
            let full = gradsum_contention_makespan(bytes, chips, true);
            let fast =
                torus2d_gradsum_makespan(Torus::for_chips(chips), bytes, &NetParams::default());
            assert!(
                (fast - full).abs() <= 1e-9,
                "{chips} chips, {mbytes} MB: fast {fast} vs full event-driven {full}"
            );
        }
    }
}

/// The fast path is exact ONLY under uniform payloads; the guarded entry
/// point must (a) take the fast path when every chip carries bit-equal
/// bytes, agreeing with the whole-torus event engine to 1e-9, and
/// (b) fall back to the event engine — exactly — the moment one chip's
/// payload differs (a straggler or degraded chip breaks row symmetry,
/// which no single representative ring can express).
#[test]
fn guarded_fastpath_falls_back_on_non_uniform_schedules() {
    let p = NetParams::default();
    for chips in [16usize, 64] {
        let torus = Torus::for_chips(chips);
        let uniform = vec![4e6; torus.chips()];
        assert!(payload_uniform(&uniform));
        let g = torus2d_gradsum_makespan_guarded(torus, &uniform, &p);
        assert!(g.fastpath, "{chips} chips: uniform payloads must take the fast path");
        let event = torus2d_gradsum_event_makespan(torus, &uniform, &p);
        assert!(
            (g.seconds - event).abs() <= 1e-9 * event.max(1.0),
            "{chips} chips uniform: guarded {} vs event {event}",
            g.seconds
        );

        let mut skewed = uniform.clone();
        skewed[torus.chips() / 2] *= 3.0; // one heavy chip
        assert!(!payload_uniform(&skewed));
        let g = torus2d_gradsum_makespan_guarded(torus, &skewed, &p);
        assert!(!g.fastpath, "{chips} chips: a skewed schedule must use the event engine");
        assert_eq!(g.seconds, torus2d_gradsum_event_makespan(torus, &skewed, &p));
        assert!(
            g.seconds >= event - 1e-12,
            "{chips} chips: the heavy chip can only slow the schedule ({} vs {event})",
            g.seconds
        );
    }
}

/// Property form of the symmetry argument: for any pod-slice torus and
/// payload, one representative bidirectional ring step equals the full
/// torus-wide batch of the same steps — and the composed 2-D schedule
/// agrees end to end.
#[test]
fn prop_fastpath_ring_symmetry_exact() {
    forall(
        60,
        |rng| {
            let chips = 1usize << (rng.below(7) + 4); // 16 .. 1024
            let kbytes = rng.below(400_000) as usize + 1;
            (chips, kbytes)
        },
        |&(chips, kbytes)| {
            // Shrinking may propose degenerate inputs; skip them so a
            // failure still shrinks cleanly.
            if chips < 4 || !chips.is_power_of_two() || kbytes == 0 {
                return Ok(());
            }
            let bytes = kbytes as f64 * 1e3;
            let p = NetParams::default();
            let torus = Torus::for_chips(chips);
            // One ring step, X direction, against the full-torus batch.
            let fast_step = ring_step_makespan(torus.nx, bytes, &p);
            let mut sim = NetSim::new(torus, p.link_bw, p.link_latency);
            let msgs: Vec<Message> = torus
                .coords()
                .flat_map(|c| {
                    [
                        Message {
                            src: c,
                            dst: torus.step(c, Dir::XPlus),
                            bytes: bytes / 2.0,
                            ready_at: 0.0,
                        },
                        Message {
                            src: c,
                            dst: torus.step(c, Dir::XMinus),
                            bytes: bytes / 2.0,
                            ready_at: 0.0,
                        },
                    ]
                })
                .collect();
            let full_step = sim.makespan(&msgs);
            if (fast_step - full_step).abs() > 1e-12 {
                return Err(format!(
                    "{chips} chips, {kbytes} kB ring step: fast {fast_step} vs {full_step}"
                ));
            }
            let full = gradsum_contention_makespan(bytes, chips, true);
            let fast = torus2d_gradsum_makespan(torus, bytes, &p);
            if (fast - full).abs() > 1e-9 {
                return Err(format!(
                    "{chips} chips, {kbytes} kB schedule: fast {fast} vs {full}"
                ));
            }
            Ok(())
        },
    );
}

/// The idle-core regression guard for the participation-aware cost layer:
/// with a fixed global batch, adding surplus cores beyond `replicas * mp`
/// must leave every priced phase EXACTLY unchanged (surplus cores hold no
/// replica and do no work).
#[test]
fn prop_idle_cores_leave_phase_pricing_unchanged() {
    let models = all_models();
    forall(
        40,
        |rng| {
            let model_idx = rng.below(models.len() as u64) as usize;
            let replicas = 1usize << (rng.below(6) + 2); // 4 .. 128
            let batch_mult = 1usize << rng.below(5); // 1x .. 16x replicas
            let surplus_mult = 1usize << (rng.below(3) + 1); // 2x .. 8x cores
            (model_idx, (replicas, (batch_mult, surplus_mult)))
        },
        |&(model_idx, (replicas, (batch_mult, surplus_mult)))| {
            let degenerate =
                model_idx >= models.len() || replicas == 0 || batch_mult == 0 || surplus_mult < 2;
            if degenerate {
                return Ok(());
            }
            let m = &models[model_idx];
            let global_batch = replicas * batch_mult;
            let fit = Layout { cores: replicas, mp: 1, replicas, global_batch };
            let surplus =
                Layout { cores: replicas * surplus_mult, mp: 1, replicas, global_batch };
            let opts = |l: Layout| SimOptions { layout_override: Some(l), ..Default::default() };
            let a = simulate(m, fit.cores, &opts(fit));
            let b = simulate(m, surplus.cores, &opts(surplus));
            if b.surplus_cores != surplus.cores - replicas {
                return Err(format!(
                    "{}: surplus {} != {}",
                    m.name,
                    b.surplus_cores,
                    surplus.cores - replicas
                ));
            }
            for (label, x, y) in [
                ("compute", a.compute_seconds, b.compute_seconds),
                ("halo", a.halo_seconds, b.halo_seconds),
                ("gradsum", a.gradsum_seconds, b.gradsum_seconds),
                ("update", a.update_seconds, b.update_seconds),
                ("eval", a.eval_seconds, b.eval_seconds),
                ("step", a.step_seconds, b.step_seconds),
            ] {
                if x != y {
                    return Err(format!(
                        "{} @ {} replicas, batch {global_batch}: {label} {x} != {y} with \
                         {} surplus cores",
                        m.name, replicas, b.surplus_cores
                    ));
                }
            }
            if a.benchmark_seconds.is_finite() != b.benchmark_seconds.is_finite() {
                return Err("convergence changed with surplus cores".into());
            }
            if a.benchmark_seconds.is_finite() && a.benchmark_seconds != b.benchmark_seconds {
                return Err(format!(
                    "benchmark {} != {}",
                    a.benchmark_seconds, b.benchmark_seconds
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bucketization_never_increases_waste() {
    forall(
        20,
        |rng| (rng.below(1000) as usize + 64, rng.below(1_000_000)),
        |&(n, seed)| {
            let task = TranslationTask::default();
            let pairs = task.pairs(&mut Rng::new(seed), n);
            let batch = 16;
            let seq = total_waste(&batch_sequential(pairs.clone(), batch));
            let mut rng = Rng::new(seed ^ 1);
            let buck = total_waste(&batch_bucketized(pairs, batch, 256, &mut rng));
            if buck > seq + 0.02 {
                return Err(format!("bucketized waste {buck} > sequential {seq}"));
            }
            Ok(())
        },
    );
}
