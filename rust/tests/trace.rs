//! End-to-end contract tests for the structured tracing layer:
//!
//! 1. a traced run (evals + checkpoints + an injected fault) is
//!    deterministic modulo timestamps (`Trace::canonical_dump` is
//!    byte-identical across two seeded runs);
//! 2. tracing never perturbs the numerics: disabled-vs-enabled runs are
//!    bit-identical in step losses and final params across SGD/Adam/LARS
//!    and replicated/WUS updates;
//! 3. the JSONL export round-trips losslessly and the accounting
//!    cross-check (`summarize`) passes against the run's own counters;
//! 4. the Chrome export names every phase/track and round-trips within
//!    tolerance;
//! 5. a tampered trace fails the cross-check (the nonzero-exit contract
//!    of `trace summarize`).

use tpu_pod_train::coordinator::{train, OptChoice, TrainConfig};
use tpu_pod_train::metrics::{summarize, Trace, TraceSink, DEFAULT_TOLERANCE};
use tpu_pod_train::optim::{AdamConfig, LarsConfig};
use tpu_pod_train::scenario::{FaultEvent, FaultKind, FaultTrace};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("trace-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The full-surface config: evals, durable checkpoints, and one injected
/// chip death (step 17, after the step-10 checkpoint) so the trace carries
/// eval spans, ckpt write/publish spans, a rollback and two incarnations.
fn faulted_cfg(dir: &std::path::Path, sink: TraceSink) -> TrainConfig {
    let mut cfg = TrainConfig::quick("transformer", 4, 30);
    cfg.eval_every = 10;
    cfg.eval_examples = 64;
    cfg.checkpoint_every = 10;
    cfg.checkpoint_dir = Some(dir.to_path_buf());
    cfg.faults = Some(FaultTrace {
        name: "trace-test".into(),
        ckpt_every_steps: 10,
        restore_seconds: 0.0,
        events: vec![FaultEvent { step: 17, chip: 1, kind: FaultKind::Death }],
    });
    cfg.trace = sink;
    cfg
}

#[test]
fn traced_run_is_deterministic_modulo_timestamps() {
    let d1 = tmpdir("det1");
    let d2 = tmpdir("det2");
    let s1 = TraceSink::enabled();
    train(&faulted_cfg(&d1, s1.clone())).unwrap();
    let s2 = TraceSink::enabled();
    train(&faulted_cfg(&d2, s2.clone())).unwrap();
    let t1 = s1.drain();
    let t2 = s2.drain();
    assert!(!t1.is_empty(), "traced run recorded nothing");
    assert_eq!(
        t1.canonical_dump(),
        t2.canonical_dump(),
        "two seeded runs must produce byte-identical canonical dumps"
    );
    let _ = std::fs::remove_dir_all(&d1);
    let _ = std::fs::remove_dir_all(&d2);
}

#[test]
fn tracing_never_perturbs_numerics() {
    let optimizers: [(&str, OptChoice); 3] = [
        ("sgd", OptChoice::Sgd { lr: 0.05, momentum: 0.9 }),
        ("adam", OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 }),
        ("lars", OptChoice::Lars { cfg: LarsConfig::default(), lr: 1.0 }),
    ];
    for (label, opt) in &optimizers {
        for wus in [false, true] {
            let mk = |sink: TraceSink| {
                let mut c = TrainConfig::quick("transformer", 2, 10);
                c.eval_every = 5;
                c.eval_examples = 64;
                c.opt = opt.clone();
                c.use_wus = wus;
                c.trace = sink;
                c
            };
            let off = train(&mk(TraceSink::disabled())).unwrap();
            let sink = TraceSink::enabled();
            let on = train(&mk(sink.clone())).unwrap();
            assert!(!sink.drain().is_empty(), "{label} wus={wus}: no events recorded");

            assert_eq!(off.step_losses.len(), on.step_losses.len(), "{label} wus={wus}");
            for (a, b) in off.step_losses.iter().zip(&on.step_losses) {
                assert_eq!(a.to_bits(), b.to_bits(), "{label} wus={wus}: losses diverged");
            }
            assert_eq!(off.final_params.len(), on.final_params.len(), "{label} wus={wus}");
            for (pa, pb) in off.final_params.iter().zip(&on.final_params) {
                assert_eq!(pa.len(), pb.len(), "{label} wus={wus}");
                for (x, y) in pa.iter().zip(pb) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{label} wus={wus}: params diverged");
                }
            }
        }
    }
}

#[test]
fn jsonl_round_trip_passes_the_accounting_cross_check() {
    let dir = tmpdir("jsonl");
    let sink = TraceSink::enabled();
    train(&faulted_cfg(&dir, sink.clone())).unwrap();
    let t = sink.drain();

    let path = dir.join("trace.jsonl");
    t.write(&path).unwrap();
    let back = Trace::load(&path).unwrap();
    assert_eq!(back.len(), t.len());
    assert_eq!(back.canonical_dump(), t.canonical_dump(), "JSONL round-trip lost events");

    let s = summarize(&back, DEFAULT_TOLERANCE);
    assert!(!s.checks.is_empty(), "trainer trace must carry report.* counters");
    assert!(s.ok(), "accounting cross-check failed: {:#?}", s.checks);
    // The injected death shows up in the goodput story.
    assert!(s.timeline.iter().any(|l| l.contains("dies")), "{:?}", s.timeline);
    assert!(s.timeline.iter().any(|l| l.contains("rollback")), "{:?}", s.timeline);
    let goodput = s.counters.get("report.goodput").copied().unwrap();
    assert!(goodput < 1.0, "rollback must cost goodput, got {goodput}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chrome_export_names_phases_tracks_and_faults() {
    let dir = tmpdir("chrome");
    let sink = TraceSink::enabled();
    train(&faulted_cfg(&dir, sink.clone())).unwrap();
    let t = sink.drain();

    let text = t.to_chrome();
    for needle in [
        "\"traceEvents\"",
        "\"ph\":\"X\"",
        "trainer.fwd",
        "trainer.bwd",
        "trainer.gradsum",
        "trainer.update",
        "trainer.eval",
        "ckpt.write",
        "ckpt.publish",
        "fault.death",
        "rollback",
        "incarnation.start",
        "rank0-steps",
        "ckpt-writer",
        "coordinator",
    ] {
        assert!(text.contains(needle), "chrome export missing {needle:?}");
    }
    // Round-trips (µs timestamps) and still reconciles with the report.
    let back = Trace::parse(&text).unwrap();
    assert_eq!(back.len(), t.len());
    let s = summarize(&back, DEFAULT_TOLERANCE);
    assert!(s.ok(), "chrome round-trip broke the cross-check: {:#?}", s.checks);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tampered_trace_fails_the_cross_check() {
    let sink = TraceSink::enabled();
    let mut cfg = TrainConfig::quick("transformer", 2, 8);
    cfg.trace = sink.clone();
    train(&cfg).unwrap();
    let mut t = sink.drain();
    assert!(summarize(&t, DEFAULT_TOLERANCE).ok(), "untampered trace must pass");

    // Claim one more step than the spans show: the exact count check trips.
    for ev in t.events.iter_mut() {
        if ev.name == "report.steps" {
            ev.dur_s += 1.0;
        }
    }
    let s = summarize(&t, DEFAULT_TOLERANCE);
    assert!(!s.ok(), "tampered step count must fail the cross-check");
    assert!(s.checks.iter().any(|c| !c.ok && c.name.contains("steps")));
}
