//! Tier-1 perf harness for the tracing layer: run the same seeded
//! reference-trainer job with the sink disabled and enabled, cross-check
//! bit-identity (losses + final params), and record the wall-clocks in
//! `BENCH_trace.json` at the workspace root so every `cargo test` run
//! refreshes the overhead trajectory. The acceptance bound (disabled ~0,
//! enabled <5%) is read from the artifact, not asserted here — CI
//! machines are noisy and the run is short.

use tpu_pod_train::scenario::run_trace_bench;
use tpu_pod_train::util::json::Json;

#[test]
fn trace_overhead_records_perf_trajectory() {
    let bench = run_trace_bench("transformer", 2, 40)
        .expect("trace bench (bit-identity cross-check)");
    assert!(bench.disabled_s > 0.0 && bench.enabled_s > 0.0);
    assert!(bench.events > 0, "enabled run must record events");

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_trace.json");
    bench.write(path).unwrap_or_else(|e| panic!("writing {path}: {e}"));

    // Round-trip: the record parses and carries the headline fields.
    let text = std::fs::read_to_string(path).unwrap();
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("trace_overhead"));
    assert!(j.get("events").and_then(Json::as_usize).unwrap() > 0);
    let pct = j.get("overhead_pct").and_then(Json::as_f64).unwrap();
    assert!(pct.is_finite(), "overhead_pct must be finite, got {pct}");
}
