//! Determinism contract of the threaded reference backend, end to end
//! through the live trainer: `--exec-threads N` must be bit-identical to
//! the serial executor for every optimizer and update-sharding mode, and
//! two seeded threaded runs must be bit-identical to each other. The
//! backend guarantees this by construction — threads own disjoint output
//! row spans and every element keeps its serial reduction order — and
//! these tests pin the guarantee where it matters: final parameters and
//! the full loss curve of real training runs.

use tpu_pod_train::coordinator::{train, OptChoice, TrainConfig, TrainReport};
use tpu_pod_train::optim::{AdamConfig, LarsConfig};

fn run(model: &str, opt: OptChoice, wus: bool, threads: usize, seed: u64) -> TrainReport {
    let mut cfg = TrainConfig::quick(model, 2, 8);
    cfg.opt = opt;
    cfg.use_wus = wus;
    cfg.exec_threads = threads;
    cfg.seed = seed;
    train(&cfg).expect("training run")
}

fn assert_bit_identical(a: &TrainReport, b: &TrainReport, what: &str) {
    assert_eq!(a.step_losses.len(), b.step_losses.len(), "{what}: step count");
    for (i, (x, y)) in a.step_losses.iter().zip(&b.step_losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: loss diverged at step {i}");
    }
    assert_eq!(a.final_params.len(), b.final_params.len(), "{what}: param tensor count");
    for (t, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa.len(), pb.len(), "{what}: tensor {t} length");
        for (i, (x, y)) in pa.iter().zip(pb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: tensor {t} diverged at element {i}");
        }
    }
}

/// Threaded output == serial output, bit for bit, across every optimizer
/// and both weight-update modes (replicated and sharded).
#[test]
fn threaded_trainer_is_bit_identical_to_serial() {
    let optimizers: [(&str, fn() -> OptChoice); 3] = [
        ("sgd", || OptChoice::Sgd { lr: 0.05, momentum: 0.9 }),
        ("adam", || OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 }),
        ("lars", || OptChoice::Lars { cfg: LarsConfig::default(), lr: 0.02 }),
    ];
    for (name, opt) in optimizers {
        for wus in [false, true] {
            let serial = run("gnmt", opt(), wus, 1, 7);
            for threads in [2, 5] {
                let threaded = run("gnmt", opt(), wus, threads, 7);
                assert_bit_identical(
                    &serial,
                    &threaded,
                    &format!("{name} wus={wus} threads={threads}"),
                );
            }
        }
    }
}

/// Two identically-seeded runs at `--exec-threads 4` are bit-identical:
/// thread scheduling never leaks into the numerics.
#[test]
fn seeded_threaded_runs_are_reproducible() {
    for model in ["transformer", "resnet50"] {
        let a = run(model, OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 }, true, 4, 42);
        let b = run(model, OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 }, true, 4, 42);
        assert_bit_identical(&a, &b, &format!("{model} repeat run"));
    }
}

/// The report splits executor time into fwd and bwd, and the split
/// accounts for the whole executor total.
#[test]
fn exec_time_is_split_into_fwd_and_bwd() {
    let rep = run("ssd", OptChoice::Sgd { lr: 0.05, momentum: 0.9 }, false, 2, 0);
    assert!(rep.fwd_s > 0.0, "forward seconds must be timed, got {}", rep.fwd_s);
    assert!(rep.bwd_s > 0.0, "backward seconds must be timed, got {}", rep.bwd_s);
    assert!(rep.exec_s > 0.0);
    let sum = rep.fwd_s + rep.bwd_s;
    assert!(
        (sum - rep.exec_s).abs() <= 1e-9 + rep.exec_s * 1e-6,
        "fwd {} + bwd {} must account for exec {}",
        rep.fwd_s,
        rep.bwd_s,
        rep.exec_s
    );
}
