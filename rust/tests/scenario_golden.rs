//! Golden-trace tests for the scenario sweep engine: one submission sweep
//! point per model (all five MLPerf-0.6 benchmarks) is pinned in
//! tests/fixtures/*.json, and the engine must reproduce every field of
//! the record within tolerance — including the per-phase participation
//! attribution (participating/surplus cores, halo split, per-phase group
//! sizes). Plus strong-scaling monotonicity checks.
//!
//! GNMT and Mask-RCNN pin the idle-core accounting: at 1024 chips their
//! batch-limited layouts occupy only 1024 / 512 of the 2048 cores, so
//! their fixtures prove surplus cores buy no gradsum/update/eval time.
//!
//! Regenerating a fixture after an intentional model change:
//! `cargo run --release -- sweep --model <model> --chips 1024` and paste
//! the record object (the fixture is one record, not a full report), with
//! the "scenario" field set to "golden-<model>".

use tpu_pod_train::scenario::{run_scenario, BatchSchedule, ScalingScenario, SweepRecord};
use tpu_pod_train::util::json::Json;

fn fixture(stem: &str) -> Json {
    let path = format!("tests/fixtures/{stem}.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("parsing {path}: {e}"))
}

/// Relative tolerance for the engine's f64 outputs. The fixtures hold
/// exact expected values; the slack only covers floating-point
/// re-association, so any real model change trips it.
const REL_TOL: f64 = 1e-6;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_TOL * a.abs().max(b.abs()).max(1e-12)
}

fn golden_record(model: &str) -> SweepRecord {
    let scenario =
        ScalingScenario::submission(model, vec![1024]).named(format!("golden-{model}"));
    run_scenario(&scenario).expect("golden scenario").remove(0)
}

fn check_golden(model: &str) {
    let want = fixture(&format!("{model}_chips1024"));
    let got = golden_record(model).to_json();
    let want_obj = match &want {
        Json::Obj(m) => m,
        other => panic!("fixture must be an object, got {other:?}"),
    };
    assert!(!want_obj.is_empty());
    for (key, expected) in want_obj {
        let actual = got
            .get(key)
            .unwrap_or_else(|| panic!("{model}: record missing fixture key {key:?}"));
        match (expected, actual) {
            (Json::Num(a), Json::Num(b)) => {
                assert!(
                    close(*a, *b),
                    "{model}.{key}: fixture {a} vs engine {b} (rel err {})",
                    ((a - b) / a.abs().max(1e-12)).abs()
                );
            }
            (a, b) => {
                assert_eq!(a, b, "{model}.{key} mismatch");
            }
        }
    }
    // And no extra numeric drift hiding in unchecked keys: the record
    // must not have keys the fixture lacks (fixtures are full records).
    if let Json::Obj(got_obj) = &got {
        for key in got_obj.keys() {
            assert!(want_obj.contains_key(key), "{model}: fixture missing key {key:?}");
        }
    }
}

#[test]
fn golden_resnet50_pod_point() {
    check_golden("resnet50");
}

#[test]
fn golden_ssd_pod_point() {
    check_golden("ssd");
}

#[test]
fn golden_transformer_pod_point() {
    check_golden("transformer");
}

#[test]
fn golden_gnmt_pod_point() {
    check_golden("gnmt");
}

#[test]
fn golden_maskrcnn_pod_point() {
    check_golden("maskrcnn");
}

/// Structural anchors that must hold regardless of fixture contents (the
/// paper's §3 layouts at the full pod).
#[test]
fn golden_layouts_match_paper() {
    let rn = golden_record("resnet50");
    assert_eq!((rn.mp, rn.replicas, rn.global_batch), (1, 2048, 32768));
    assert_eq!((rn.participating_cores, rn.surplus_cores), (2048, 0));
    let ssd = golden_record("ssd");
    assert_eq!((ssd.mp, ssd.replicas, ssd.global_batch), (4, 512, 2048));
    assert!(ssd.halo_seconds > 0.0, "SSD mp 4 must pay halo");
    let tf = golden_record("transformer");
    assert_eq!((tf.mp, tf.replicas, tf.global_batch), (1, 2048, 2048));
    assert!(ssd.spatial_speedup > 1.4 && ssd.spatial_speedup < 1.9);
    // GNMT's 1024-replica batch wall leaves half the pod idle; Mask-RCNN's
    // 128-replica x mp-4 layout leaves three quarters idle (paper §3).
    let gnmt = golden_record("gnmt");
    assert_eq!((gnmt.mp, gnmt.replicas, gnmt.global_batch), (1, 1024, 1024));
    assert_eq!((gnmt.participating_cores, gnmt.surplus_cores), (1024, 1024));
    assert_eq!(gnmt.update_shards, 1024);
    let mr = golden_record("maskrcnn");
    assert_eq!((mr.mp, mr.replicas, mr.global_batch), (4, 128, 128));
    assert_eq!((mr.participating_cores, mr.surplus_cores), (512, 1536));
    assert!(mr.converged, "batch 128 is exactly the Mask-RCNN wall");
}

/// The idle-core fix, visible end-to-end: GNMT at 2048 cores prices
/// gradsum/update/eval identically to a hypothetical 1024-core machine
/// with the same layout (the surplus 1024 cores buy nothing).
#[test]
fn golden_gnmt_surplus_cores_price_like_participating_slice() {
    use tpu_pod_train::models::model;
    use tpu_pod_train::simulator::{simulate, SimOptions};
    let m = model("gnmt").unwrap();
    let full_pod = simulate(&m, 2048, &SimOptions::default());
    assert_eq!(full_pod.participating_cores, 1024);
    let l = full_pod.layout;
    let fitted = tpu_pod_train::models::Layout { cores: 1024, ..l };
    let half_pod = simulate(
        &m,
        1024,
        &SimOptions { layout_override: Some(fitted), ..Default::default() },
    );
    assert_eq!(full_pod.gradsum_seconds, half_pod.gradsum_seconds);
    assert_eq!(full_pod.update_seconds, half_pod.update_seconds);
    assert_eq!(full_pod.eval_seconds, half_pod.eval_seconds);
    assert_eq!(full_pod.step_seconds, half_pod.step_seconds);
}

/// Strong scaling: under a fixed global batch, step time must not
/// increase as chips grow, for the compute-dominated models. (The
/// Transformer saturates — its gradsum/update floor is ~constant — so it
/// is deliberately excluded; the submission-schedule benchmark-seconds
/// check below covers it.)
#[test]
fn step_time_non_increasing_under_fixed_global_batch() {
    for (model, batch) in [("resnet50", 32768usize), ("ssd", 2048)] {
        let scenario = ScalingScenario::submission(model, vec![16, 32, 64, 128, 256, 512, 1024])
            .with_batch(BatchSchedule::Fixed(batch))
            .named(format!("monotone-{model}"));
        let recs = run_scenario(&scenario).expect("scenario");
        for w in recs.windows(2) {
            assert!(
                w[1].step_seconds <= w[0].step_seconds * 1.02,
                "{model} fixed batch {batch}: step {}s @ {} chips vs {}s @ {} chips",
                w[1].step_seconds,
                w[1].chips,
                w[0].step_seconds,
                w[0].chips
            );
        }
    }
}

/// Submission schedule: benchmark seconds shrink with scale for every
/// model inside its useful range (the paper's headline).
#[test]
fn benchmark_seconds_monotone_under_submission_schedule() {
    for model in ["resnet50", "ssd", "transformer", "gnmt"] {
        let scenario = ScalingScenario::submission(model, vec![32, 64, 128, 256, 512, 1024])
            .named(format!("headline-{model}"));
        let recs = run_scenario(&scenario).expect("scenario");
        for w in recs.windows(2) {
            assert!(
                w[1].benchmark_seconds < w[0].benchmark_seconds * 1.05,
                "{model}: {}s @ {} chips vs {}s @ {} chips",
                w[1].benchmark_seconds,
                w[1].chips,
                w[0].benchmark_seconds,
                w[0].chips
            );
        }
    }
}
