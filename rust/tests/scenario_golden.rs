//! Golden-trace tests for the scenario sweep engine: one submission sweep
//! point per model (transformer / ResNet-50 / SSD) is pinned in
//! tests/fixtures/*.json, and the engine must reproduce every field of
//! the record within tolerance. Plus strong-scaling monotonicity checks.
//!
//! Regenerating a fixture after an intentional model change:
//! `cargo run --release -- sweep --model <model> --chips 1024` and paste
//! the record object (the fixture is one record, not a full report), with
//! the "scenario" field set to "golden-<model>".

use tpu_pod_train::scenario::{run_scenario, BatchSchedule, ScalingScenario, SweepRecord};
use tpu_pod_train::util::json::Json;

fn fixture(stem: &str) -> Json {
    let path = format!("tests/fixtures/{stem}.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("parsing {path}: {e}"))
}

/// Relative tolerance for the engine's f64 outputs. The fixtures hold
/// exact expected values; the slack only covers floating-point
/// re-association, so any real model change trips it.
const REL_TOL: f64 = 1e-6;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_TOL * a.abs().max(b.abs()).max(1e-12)
}

fn golden_record(model: &str) -> SweepRecord {
    let scenario =
        ScalingScenario::submission(model, vec![1024]).named(format!("golden-{model}"));
    run_scenario(&scenario).expect("golden scenario").remove(0)
}

fn check_golden(model: &str) {
    let want = fixture(&format!("{model}_chips1024"));
    let got = golden_record(model).to_json();
    let want_obj = match &want {
        Json::Obj(m) => m,
        other => panic!("fixture must be an object, got {other:?}"),
    };
    assert!(!want_obj.is_empty());
    for (key, expected) in want_obj {
        let actual = got
            .get(key)
            .unwrap_or_else(|| panic!("{model}: record missing fixture key {key:?}"));
        match (expected, actual) {
            (Json::Num(a), Json::Num(b)) => {
                assert!(
                    close(*a, *b),
                    "{model}.{key}: fixture {a} vs engine {b} (rel err {})",
                    ((a - b) / a.abs().max(1e-12)).abs()
                );
            }
            (a, b) => {
                assert_eq!(a, b, "{model}.{key} mismatch");
            }
        }
    }
    // And no extra numeric drift hiding in unchecked keys: the record
    // must not have keys the fixture lacks (fixtures are full records).
    if let Json::Obj(got_obj) = &got {
        for key in got_obj.keys() {
            assert!(want_obj.contains_key(key), "{model}: fixture missing key {key:?}");
        }
    }
}

#[test]
fn golden_resnet50_pod_point() {
    check_golden("resnet50");
}

#[test]
fn golden_ssd_pod_point() {
    check_golden("ssd");
}

#[test]
fn golden_transformer_pod_point() {
    check_golden("transformer");
}

/// Structural anchors that must hold regardless of fixture contents (the
/// paper's §3 layouts at the full pod).
#[test]
fn golden_layouts_match_paper() {
    let rn = golden_record("resnet50");
    assert_eq!((rn.mp, rn.replicas, rn.global_batch), (1, 2048, 32768));
    let ssd = golden_record("ssd");
    assert_eq!((ssd.mp, ssd.replicas, ssd.global_batch), (4, 512, 2048));
    let tf = golden_record("transformer");
    assert_eq!((tf.mp, tf.replicas, tf.global_batch), (1, 2048, 2048));
    assert!(ssd.spatial_speedup > 1.4 && ssd.spatial_speedup < 1.9);
}

/// Strong scaling: under a fixed global batch, step time must not
/// increase as chips grow, for the compute-dominated models. (The
/// Transformer saturates — its gradsum/update floor is ~constant — so it
/// is deliberately excluded; the submission-schedule benchmark-seconds
/// check below covers it.)
#[test]
fn step_time_non_increasing_under_fixed_global_batch() {
    for (model, batch) in [("resnet50", 32768usize), ("ssd", 2048)] {
        let scenario = ScalingScenario::submission(model, vec![16, 32, 64, 128, 256, 512, 1024])
            .with_batch(BatchSchedule::Fixed(batch))
            .named(format!("monotone-{model}"));
        let recs = run_scenario(&scenario).expect("scenario");
        for w in recs.windows(2) {
            assert!(
                w[1].step_seconds <= w[0].step_seconds * 1.02,
                "{model} fixed batch {batch}: step {}s @ {} chips vs {}s @ {} chips",
                w[1].step_seconds,
                w[1].chips,
                w[0].step_seconds,
                w[0].chips
            );
        }
    }
}

/// Submission schedule: benchmark seconds shrink with scale for every
/// model inside its useful range (the paper's headline).
#[test]
fn benchmark_seconds_monotone_under_submission_schedule() {
    for model in ["resnet50", "ssd", "transformer", "gnmt"] {
        let scenario = ScalingScenario::submission(model, vec![32, 64, 128, 256, 512, 1024])
            .named(format!("headline-{model}"));
        let recs = run_scenario(&scenario).expect("scenario");
        for w in recs.windows(2) {
            assert!(
                w[1].benchmark_seconds < w[0].benchmark_seconds * 1.05,
                "{model}: {}s @ {} chips vs {}s @ {} chips",
                w[1].benchmark_seconds,
                w[1].chips,
                w[0].benchmark_seconds,
                w[0].chips
            );
        }
    }
}
