//! Integration tests for the live trainer and the cross-layer kernel
//! contracts.
//!
//! Two tiers:
//!
//! * **Reference-backend trainer tests** — run unconditionally. The
//!   in-Rust fwd/bwd executor (`runtime::reference`) drives the full step
//!   loop (data pipeline → fwd/bwd → gradient summation → replicated or
//!   sharded weight update → distributed eval) on N simulated cores with
//!   no artifacts. These are tier-1: CI gates trainer behavior here.
//! * **PJRT-only tests** — the trainer over `--backend pjrt` plus the
//!   Rust-vs-Pallas kernel-parity contracts. They need the AOT artifacts
//!   (`python python/compile/aot.py` → `artifacts/`, or `ARTIFACTS_DIR`)
//!   *and* the real `xla` binding in place of the offline stub (see
//!   rust/src/runtime/xla.rs), so they skip with a message naming that
//!   backend when either is missing — they execute the compiled
//!   artifacts themselves.

use tpu_pod_train::collectives::{gradsum_pipelined, gradsum_serial, Placement};
use tpu_pod_train::coordinator::{train, GradSumMode, OptChoice, TrainConfig};
use tpu_pod_train::fabric::run_spmd;
use tpu_pod_train::optim::{
    adam_step, lars_step, AdamConfig, AdamState, LarsConfig, LarsState, LarsVariant,
};
use tpu_pod_train::runtime::{
    Backend, BackendChoice, HostTensor, Precision, ReferenceBackend, Runtime, StepBatch,
};
use tpu_pod_train::util::rng::Rng;

/// True when the AOT artifacts and a working PJRT backend are available.
/// Tests run from the crate root (rust/); artifacts/ lives there. Probed
/// once per test binary (the PJRT client probe is not free).
fn pjrt_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        // The manifest may exist while the PJRT backend is the offline stub.
        std::path::Path::new("artifacts/manifest.json").exists()
            && Runtime::with_dir("artifacts").is_ok()
    })
}

/// Skip the calling test (early-return) when the PJRT backend is
/// unusable, printing why (visible with `cargo test -- --nocapture`).
macro_rules! require_pjrt {
    () => {
        if !pjrt_available() {
            eprintln!(
                "skipping {}: needs the PJRT backend (`--backend pjrt`) — build the AOT \
                 artifacts with `python python/compile/aot.py` (into artifacts/ or \
                 $ARTIFACTS_DIR) and swap the real `xla` binding in for the offline stub \
                 (rust/src/runtime/xla.rs). The reference-backend trainer tests below run \
                 regardless.",
                module_path!()
            );
            return;
        }
    };
}

fn runtime() -> Runtime {
    Runtime::with_dir("artifacts").expect("pjrt_available() said artifacts exist")
}

fn randvec(seed: u64, n: usize) -> Vec<f32> {
    Rng::new(seed).normal_vec(n, 1.0)
}

// ---------------------------------------------------------------------------
// Live trainer on the reference backend (tier-1, no artifacts)
// ---------------------------------------------------------------------------

#[test]
fn trainer_loss_decreases_transformer_reference() {
    let mut cfg = TrainConfig::quick("transformer", 2, 40);
    cfg.opt = OptChoice::Adam { cfg: AdamConfig::default(), lr: 3e-3 };
    let rep = train(&cfg).unwrap();
    assert_eq!(rep.step_losses.len(), 40);
    let first: f32 = rep.step_losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = rep.step_losses[35..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first * 0.5,
        "loss should drop: first {first:.3} last {last:.3}"
    );
    assert!(rep.exec_s > 0.0, "backend execute time should be accounted");
}

#[test]
fn trainer_bf16_backend_also_learns() {
    let mut cfg = TrainConfig::quick("transformer", 2, 40);
    cfg.backend = BackendChoice::ReferenceBf16;
    cfg.opt = OptChoice::Adam { cfg: AdamConfig::default(), lr: 3e-3 };
    let rep = train(&cfg).unwrap();
    let first: f32 = rep.step_losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = rep.step_losses[35..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first * 0.5,
        "bf16 loss should drop: first {first:.3} last {last:.3}"
    );
}

#[test]
fn trainer_wus_matches_replicated_trajectory() {
    // Weight-update sharding is an execution strategy: the loss trajectory
    // must match the replicated optimizer to f32 tolerance.
    let mut base = TrainConfig::quick("transformer", 4, 10);
    base.opt = OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 };
    let mut wus = base.clone();
    wus.use_wus = true;
    let r1 = train(&base).unwrap();
    let r2 = train(&wus).unwrap();
    for (a, b) in r1.step_losses.iter().zip(&r2.step_losses) {
        assert!((a - b).abs() < 5e-3, "replicated {a} vs wus {b}");
    }
}

#[test]
fn trainer_wus_sgd_matches_replicated_trajectory() {
    // The SGD baseline rides the same sharded-update path (ShardedSgd).
    let mut base = TrainConfig::quick("resnet50", 4, 10);
    base.opt = OptChoice::Sgd { lr: 0.05, momentum: 0.9 };
    let mut wus = base.clone();
    wus.use_wus = true;
    let r1 = train(&base).unwrap();
    let r2 = train(&wus).unwrap();
    for (a, b) in r1.step_losses.iter().zip(&r2.step_losses) {
        assert!((a - b).abs() < 5e-3, "replicated {a} vs wus {b}");
    }
}

#[test]
fn trainer_gradsum_modes_agree() {
    let mut serial = TrainConfig::quick("transformer", 4, 8);
    serial.gradsum = GradSumMode::Serial;
    let mut pipe = serial.clone();
    pipe.gradsum = GradSumMode::Pipelined { quantum: 1024 };
    let r1 = train(&serial).unwrap();
    let r2 = train(&pipe).unwrap();
    for (a, b) in r1.step_losses.iter().zip(&r2.step_losses) {
        assert!((a - b).abs() < 5e-3, "serial {a} vs pipelined {b}");
    }
}

#[test]
fn trainer_image_lars_reaches_quality_target() {
    // ResNet-50 proxy on the planted-feature image task with
    // unscaled-momentum LARS: must hit 60% top-1 (10 classes, alpha=2 —
    // easily separable).
    let cfg = TrainConfig {
        steps: 250,
        eval_every: 25,
        eval_examples: 128,
        opt: OptChoice::Lars { cfg: LarsConfig::default(), lr: 1.0 },
        seed: 7,
        task_difficulty: 0.0,
        image_alpha: 2.0,
        quality_target: Some(0.6),
        ..TrainConfig::quick("resnet50", 2, 250)
    };
    let rep = train(&cfg).unwrap();
    assert!(
        rep.converged_at.is_some(),
        "ResNet proxy + LARS failed to reach 60% top-1; evals: {:?}",
        rep.evals
    );
}

#[test]
fn trainer_lars_tolerates_larger_batch_than_sgd_default() {
    // Table 1's premise in miniature: LARS keeps converging when the
    // per-core batch is scaled 4x; SGD converges at the default batch.
    let mut sgd = TrainConfig::quick("resnet50", 2, 40);
    sgd.opt = OptChoice::Sgd { lr: 0.05, momentum: 0.9 };
    let mut lars = TrainConfig::quick("resnet50", 2, 40);
    lars.opt = OptChoice::Lars { cfg: LarsConfig::default(), lr: 1.0 };
    lars.batch_override = Some(32); // 4x the model default of 8
    for (label, cfg) in [("sgd", sgd), ("lars@4x-batch", lars)] {
        let rep = train(&cfg).unwrap();
        let first: f32 = rep.step_losses[..5].iter().sum::<f32>() / 5.0;
        let last: f32 = rep.step_losses[35..].iter().sum::<f32>() / 5.0;
        assert!(
            last < first * 0.7,
            "{label}: loss should drop, first {first:.3} last {last:.3}"
        );
    }
}

#[test]
fn trainer_eval_metrics_independent_of_core_count() {
    // Distributed eval must give the same metrics at any core count
    // (padding/masking invariance) when the model state is identical.
    let mk = |cores| {
        let mut c = TrainConfig::quick("transformer", cores, 1);
        c.eval_every = 1;
        c.eval_examples = 100; // deliberately not a multiple of anything
        c.opt = OptChoice::Sgd { lr: 0.0, momentum: 0.0 }; // freeze weights
        c
    };
    let r1 = train(&mk(1)).unwrap();
    let r4 = train(&mk(4)).unwrap();
    let (e1, e4) = (r1.evals[0], r4.evals[0]);
    assert!((e1.accuracy - e4.accuracy).abs() < 1e-5,
            "acc {} vs {}", e1.accuracy, e4.accuracy);
    assert!((e1.loss - e4.loss).abs() < 1e-4);
}

#[test]
fn trainer_single_core_works() {
    let rep = train(&TrainConfig::quick("transformer", 1, 3)).unwrap();
    assert_eq!(rep.step_losses.len(), 3);
    assert!(rep.params_total > 10_000);
}

#[test]
fn trainer_runs_are_bit_identical() {
    // Seeded determinism: the reference backend + fabric collectives are
    // sequential f32 in a fixed order, so two runs of the same config must
    // produce bit-identical loss curves and eval points.
    let mut cfg = TrainConfig::quick("transformer", 4, 12);
    cfg.eval_every = 4;
    cfg.eval_examples = 64;
    cfg.opt = OptChoice::Adam { cfg: AdamConfig::default(), lr: 3e-3 };
    let r1 = train(&cfg).unwrap();
    let r2 = train(&cfg).unwrap();
    assert_eq!(r1.step_losses.len(), r2.step_losses.len());
    for (a, b) in r1.step_losses.iter().zip(&r2.step_losses) {
        assert_eq!(a.to_bits(), b.to_bits(), "loss curves diverged: {a} vs {b}");
    }
    assert_eq!(r1.evals.len(), r2.evals.len());
    for (a, b) in r1.evals.iter().zip(&r2.evals) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }
}

#[test]
fn reference_gradsum_matches_serial_sum() {
    // Reference-backend gradients summed via collectives::gradsum must
    // equal the serial elementwise sum of every rank's gradients.
    let world = 4;
    let be = ReferenceBackend::new("transformer", Precision::F32).unwrap();
    let params: Vec<Vec<f32>> = be
        .specs()
        .iter()
        .map(|s| Rng::new(17).fold_in(s.numel() as u64).normal_vec(s.numel(), 0.05))
        .collect();
    let grads_for_rank = |rank: usize| -> Vec<Vec<f32>> {
        let dims = *be.dims();
        let mut rng = Rng::new(123).fold_in(rank as u64);
        let n = dims.batch_per_core * dims.seq;
        let tokens: Vec<i32> = (0..n).map(|_| rng.below(dims.vocab as u64) as i32).collect();
        let targets: Vec<i32> =
            tokens.iter().map(|&t| ((5 * t as i64 + 3) % dims.vocab as i64) as i32).collect();
        let batch = StepBatch::Lm { tokens, targets };
        let (_, grads) = be.train_step(&params, &batch).unwrap();
        grads
    };

    // Serial reference: elementwise sum over ranks, one rank at a time.
    let mut expected = grads_for_rank(0);
    for r in 1..world {
        for (acc, g) in expected.iter_mut().zip(grads_for_rank(r)) {
            for (a, x) in acc.iter_mut().zip(g) {
                *a += x;
            }
        }
    }

    for pipelined in [false, true] {
        let out = run_spmd(world, |ep| {
            let place = Placement::new(world);
            let be = ReferenceBackend::new("transformer", Precision::F32).unwrap();
            let dims = *be.dims();
            let mut rng = Rng::new(123).fold_in(ep.rank as u64);
            let n = dims.batch_per_core * dims.seq;
            let tokens: Vec<i32> =
                (0..n).map(|_| rng.below(dims.vocab as u64) as i32).collect();
            let targets: Vec<i32> = tokens
                .iter()
                .map(|&t| ((5 * t as i64 + 3) % dims.vocab as i64) as i32)
                .collect();
            let batch = StepBatch::Lm { tokens, targets };
            let (_, mut grads) = be.train_step(&params, &batch).unwrap();
            if pipelined {
                gradsum_pipelined(ep, &place, &mut grads, 1024);
            } else {
                gradsum_serial(ep, &place, &mut grads);
            }
            grads
        });
        for (rank, got) in out.iter().enumerate() {
            for (ti, (g, e)) in got.iter().zip(&expected).enumerate() {
                for (x, y) in g.iter().zip(e) {
                    assert!(
                        (x - y).abs() < 1e-5 + 1e-4 * y.abs(),
                        "pipelined={pipelined} rank {rank} tensor {ti}: ring {x} vs serial {y}"
                    );
                }
            }
        }
    }
}

#[test]
fn pjrt_backend_without_artifacts_is_a_clean_error() {
    if pjrt_available() {
        return; // real artifacts present: the error path is not reachable
    }
    let mut cfg = TrainConfig::quick("transformer_tiny", 1, 1);
    cfg.backend = BackendChoice::PjRt;
    let err = train(&cfg).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("aot.py") || msg.contains("PJRT") || msg.contains("artifact"),
        "error should name the missing PJRT prerequisites: {msg}"
    );
}

// ---------------------------------------------------------------------------
// PJRT-only: the trainer over the AOT artifacts, and the Rust-optimizer ==
// AOT-compiled-Pallas-kernel cross-layer contract. These execute the
// compiled artifacts, so they cannot run on the reference backend.
// ---------------------------------------------------------------------------

#[test]
fn trainer_pjrt_backend_end_to_end() {
    // Exercises PjRtBackend's train/eval marshalling (params + batch +
    // mask ordering) through the full step loop — the coverage the
    // reference-backend tests cannot provide.
    require_pjrt!();
    let mut cfg = TrainConfig::quick("transformer_tiny", 2, 20);
    cfg.backend = BackendChoice::PjRt;
    cfg.opt = OptChoice::Adam { cfg: AdamConfig::default(), lr: 3e-3 };
    cfg.eval_every = 10;
    cfg.eval_examples = 64;
    let rep = train(&cfg).unwrap();
    assert_eq!(rep.step_losses.len(), 20);
    assert_eq!(rep.evals.len(), 2);
    let first: f32 = rep.step_losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = rep.step_losses[15..].iter().sum::<f32>() / 5.0;
    assert!(last < first, "PJRT trainer should learn: first {first:.3} last {last:.3}");
}

#[test]
fn rust_lars_matches_pallas_artifact_both_variants() {
    require_pjrt!();
    let rt = runtime();
    let n = 16384;
    for (scaled, art) in [(true, "lars_scaled_16384"), (false, "lars_unscaled_16384")] {
        let w0 = randvec(1, n);
        let g = randvec(2, n);
        let v0 = randvec(3, n);
        let (lr, eta, beta, mom) = (0.1f32, 0.01, 1e-4, 0.9);

        // Pallas kernel via PJRT.
        let hp = HostTensor::new(vec![4], vec![lr, eta, beta, mom]);
        let w = HostTensor::new(vec![n], w0.clone());
        let gt = HostTensor::new(vec![n], g.clone());
        let v = HostTensor::new(vec![n], v0.clone());
        let out = rt.execute(art, &[&w, &gt, &v, &hp], &[]).unwrap();

        // Rust implementation.
        let cfg = LarsConfig {
            variant: if scaled { LarsVariant::Scaled } else { LarsVariant::Unscaled },
            eta,
            weight_decay: beta,
            momentum: mom,
            skip_adaptation_for_1d: false,
        };
        let mut w_rust = w0;
        let mut st = LarsState { v: v0 };
        lars_step(&cfg, lr, &mut w_rust, &g, &mut st, false);

        for i in 0..n {
            assert!(
                (out[0].data[i] - w_rust[i]).abs() < 1e-5,
                "{art} w[{i}]: pallas {} vs rust {}",
                out[0].data[i],
                w_rust[i]
            );
            assert!((out[1].data[i] - st.v[i]).abs() < 1e-5, "{art} v[{i}]");
        }
    }
}

#[test]
fn rust_adam_matches_pallas_artifact() {
    require_pjrt!();
    let rt = runtime();
    let n = 16384;
    let w0 = randvec(10, n);
    let g = randvec(11, n);
    let m0: Vec<f32> = randvec(12, n).iter().map(|x| x * 0.1).collect();
    let v0: Vec<f32> = randvec(13, n).iter().map(|x| x * x * 0.01).collect();
    let (lr, b1, b2, eps, step) = (1e-3f32, 0.9, 0.999, 1e-8, 5u64);

    let hp = HostTensor::new(vec![5], vec![lr, b1, b2, eps, step as f32]);
    let out = rt
        .execute(
            "adam_16384",
            &[
                &HostTensor::new(vec![n], w0.clone()),
                &HostTensor::new(vec![n], g.clone()),
                &HostTensor::new(vec![n], m0.clone()),
                &HostTensor::new(vec![n], v0.clone()),
                &hp,
            ],
            &[],
        )
        .unwrap();

    let mut w_rust = w0;
    let mut st = AdamState { m: m0, v: v0 };
    // Rust state tracks steps internally from 1; drive to step 5 by
    // matching the bias-correction exponent: call once with step 5.
    adam_step(&AdamConfig { beta1: b1, beta2: b2, eps }, lr, step, &mut w_rust, &g, &mut st);

    for i in 0..n {
        assert!(
            (out[0].data[i] - w_rust[i]).abs() < 2e-5,
            "w[{i}]: pallas {} vs rust {}",
            out[0].data[i],
            w_rust[i]
        );
    }
}

#[test]
fn attention_artifact_executes() {
    require_pjrt!();
    let rt = runtime();
    let (b, h, s, d) = (8, 4, 64, 32);
    let n = b * h * s * d;
    let q = HostTensor::new(vec![b, h, s, d], randvec(20, n));
    let k = HostTensor::new(vec![b, h, s, d], randvec(21, n));
    let v = HostTensor::new(vec![b, h, s, d], randvec(22, n));
    let out = rt.execute("attention_b8h4s64d32", &[&q, &k, &v], &[]).unwrap();
    assert_eq!(out[0].shape, vec![b, h, s, d]);
    // Causal attention of row 0 attends only to position 0: out[0] == v[0].
    for di in 0..d {
        assert!((out[0].data[di] - v.data[di]).abs() < 1e-5);
    }
}

#[test]
fn lstm_artifact_state_bounded() {
    require_pjrt!();
    let rt = runtime();
    let (b, h) = (8, 128);
    let xp = HostTensor::new(vec![b, 4 * h], randvec(30, b * 4 * h));
    let hh = HostTensor::new(vec![b, h], randvec(31, b * h));
    let cc = HostTensor::new(vec![b, h], randvec(32, b * h));
    let wh = HostTensor::new(vec![h, 4 * h], randvec(33, h * 4 * h));
    let bias = HostTensor::new(vec![4 * h], vec![0.0; 4 * h]);
    let out = rt.execute("lstm_cell_b8h128", &[&xp, &hh, &cc, &wh, &bias], &[]).unwrap();
    assert!(out[0].data.iter().all(|x| x.abs() <= 1.0 + 1e-5), "|h'| must be ≤ 1");
}
