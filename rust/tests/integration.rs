//! Integration tests over the real AOT artifacts: the Rust⇄Pallas⇄ref
//! three-way loop, and the full trainer (PJRT + collectives + optimizers +
//! distributed eval) on the in-process pod.
//!
//! Requires `make artifacts` (the Makefile runs it before `cargo test`).
//! On a clean checkout without `artifacts/` (or in the offline build,
//! where the PJRT backend is a stub) every test here skips with a message
//! instead of failing — the artifact-independent suites (unit tests,
//! dist_invariants, scenario_golden) are the tier-1 signal.

use tpu_pod_train::coordinator::{train, GradSumMode, OptChoice, TrainConfig};
use tpu_pod_train::optim::{
    adam_step, lars_step, AdamConfig, AdamState, LarsConfig, LarsState, LarsVariant,
};
use tpu_pod_train::runtime::{HostTensor, Runtime};
use tpu_pod_train::util::rng::Rng;

/// True when the AOT artifacts and a working PJRT backend are available.
/// Tests run from the crate root (rust/); artifacts/ lives there. Probed
/// once per test binary (the PJRT client probe is not free).
fn artifacts_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        // The manifest may exist while the PJRT backend is the offline stub.
        std::path::Path::new("artifacts/manifest.json").exists()
            && Runtime::with_dir("artifacts").is_ok()
    })
}

/// Skip the calling test (early-return) when artifacts are unusable,
/// printing why (visible with `cargo test -- --nocapture`).
macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!(
                "skipping {}: artifacts/ absent or PJRT unavailable (run `make artifacts` \
                 with the real xla binding to enable)",
                module_path!()
            );
            return;
        }
    };
}

fn runtime() -> Runtime {
    Runtime::with_dir("artifacts").expect("run `make artifacts` first")
}

fn randvec(seed: u64, n: usize) -> Vec<f32> {
    Rng::new(seed).normal_vec(n, 1.0)
}

// ---------------------------------------------------------------------------
// Rust optimizer == AOT-compiled Pallas kernel (the cross-layer contract)
// ---------------------------------------------------------------------------

#[test]
fn rust_lars_matches_pallas_artifact_both_variants() {
    require_artifacts!();
    let rt = runtime();
    let n = 16384;
    for (scaled, art) in [(true, "lars_scaled_16384"), (false, "lars_unscaled_16384")] {
        let w0 = randvec(1, n);
        let g = randvec(2, n);
        let v0 = randvec(3, n);
        let (lr, eta, beta, mom) = (0.1f32, 0.01, 1e-4, 0.9);

        // Pallas kernel via PJRT.
        let hp = HostTensor::new(vec![4], vec![lr, eta, beta, mom]);
        let w = HostTensor::new(vec![n], w0.clone());
        let gt = HostTensor::new(vec![n], g.clone());
        let v = HostTensor::new(vec![n], v0.clone());
        let out = rt.execute(art, &[&w, &gt, &v, &hp], &[]).unwrap();

        // Rust implementation.
        let cfg = LarsConfig {
            variant: if scaled { LarsVariant::Scaled } else { LarsVariant::Unscaled },
            eta,
            weight_decay: beta,
            momentum: mom,
            skip_adaptation_for_1d: false,
        };
        let mut w_rust = w0;
        let mut st = LarsState { v: v0 };
        lars_step(&cfg, lr, &mut w_rust, &g, &mut st, false);

        for i in 0..n {
            assert!(
                (out[0].data[i] - w_rust[i]).abs() < 1e-5,
                "{art} w[{i}]: pallas {} vs rust {}",
                out[0].data[i],
                w_rust[i]
            );
            assert!((out[1].data[i] - st.v[i]).abs() < 1e-5, "{art} v[{i}]");
        }
    }
}

#[test]
fn rust_adam_matches_pallas_artifact() {
    require_artifacts!();
    let rt = runtime();
    let n = 16384;
    let w0 = randvec(10, n);
    let g = randvec(11, n);
    let m0: Vec<f32> = randvec(12, n).iter().map(|x| x * 0.1).collect();
    let v0: Vec<f32> = randvec(13, n).iter().map(|x| x * x * 0.01).collect();
    let (lr, b1, b2, eps, step) = (1e-3f32, 0.9, 0.999, 1e-8, 5u64);

    let hp = HostTensor::new(vec![5], vec![lr, b1, b2, eps, step as f32]);
    let out = rt
        .execute(
            "adam_16384",
            &[
                &HostTensor::new(vec![n], w0.clone()),
                &HostTensor::new(vec![n], g.clone()),
                &HostTensor::new(vec![n], m0.clone()),
                &HostTensor::new(vec![n], v0.clone()),
                &hp,
            ],
            &[],
        )
        .unwrap();

    let mut w_rust = w0;
    let mut st = AdamState { m: m0, v: v0 };
    // Rust state tracks steps internally from 1; drive to step 5 by
    // matching the bias-correction exponent: call once with step 5.
    adam_step(&AdamConfig { beta1: b1, beta2: b2, eps }, lr, step, &mut w_rust, &g, &mut st);

    for i in 0..n {
        assert!(
            (out[0].data[i] - w_rust[i]).abs() < 2e-5,
            "w[{i}]: pallas {} vs rust {}",
            out[0].data[i],
            w_rust[i]
        );
    }
}

#[test]
fn attention_artifact_executes() {
    require_artifacts!();
    let rt = runtime();
    let (b, h, s, d) = (8, 4, 64, 32);
    let n = b * h * s * d;
    let q = HostTensor::new(vec![b, h, s, d], randvec(20, n));
    let k = HostTensor::new(vec![b, h, s, d], randvec(21, n));
    let v = HostTensor::new(vec![b, h, s, d], randvec(22, n));
    let out = rt.execute("attention_b8h4s64d32", &[&q, &k, &v], &[]).unwrap();
    assert_eq!(out[0].shape, vec![b, h, s, d]);
    // Causal attention of row 0 attends only to position 0: out[0] == v[0].
    for di in 0..d {
        assert!((out[0].data[di] - v.data[di]).abs() < 1e-5);
    }
}

#[test]
fn lstm_artifact_state_bounded() {
    require_artifacts!();
    let rt = runtime();
    let (b, h) = (8, 128);
    let xp = HostTensor::new(vec![b, 4 * h], randvec(30, b * 4 * h));
    let hh = HostTensor::new(vec![b, h], randvec(31, b * h));
    let cc = HostTensor::new(vec![b, h], randvec(32, b * h));
    let wh = HostTensor::new(vec![h, 4 * h], randvec(33, h * 4 * h));
    let bias = HostTensor::new(vec![4 * h], vec![0.0; 4 * h]);
    let out = rt.execute("lstm_cell_b8h128", &[&xp, &hh, &cc, &wh, &bias], &[]).unwrap();
    assert!(out[0].data.iter().all(|x| x.abs() <= 1.0 + 1e-5), "|h'| must be ≤ 1");
}

// ---------------------------------------------------------------------------
// Full trainer
// ---------------------------------------------------------------------------

#[test]
fn trainer_loss_decreases_tiny_transformer() {
    require_artifacts!();
    let mut cfg = TrainConfig::quick("transformer_tiny", 2, 40);
    cfg.opt = OptChoice::Adam { cfg: AdamConfig::default(), lr: 3e-3 };
    let rep = train(&cfg).unwrap();
    assert_eq!(rep.step_losses.len(), 40);
    let first: f32 = rep.step_losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = rep.step_losses[35..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first * 0.8,
        "loss should drop: first {first:.3} last {last:.3}"
    );
}

#[test]
fn trainer_wus_matches_replicated_trajectory() {
    require_artifacts!();
    // Weight-update sharding is an execution strategy: the loss trajectory
    // must match the replicated optimizer to f32 tolerance.
    let mut base = TrainConfig::quick("transformer_tiny", 4, 10);
    base.opt = OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 };
    let mut wus = base.clone();
    wus.use_wus = true;
    let r1 = train(&base).unwrap();
    let r2 = train(&wus).unwrap();
    for (a, b) in r1.step_losses.iter().zip(&r2.step_losses) {
        assert!((a - b).abs() < 5e-3, "replicated {a} vs wus {b}");
    }
}

#[test]
fn trainer_gradsum_modes_agree() {
    require_artifacts!();
    let mut serial = TrainConfig::quick("transformer_tiny", 4, 8);
    serial.gradsum = GradSumMode::Serial;
    let mut pipe = serial.clone();
    pipe.gradsum = GradSumMode::Pipelined { quantum: 1024 };
    let r1 = train(&serial).unwrap();
    let r2 = train(&pipe).unwrap();
    for (a, b) in r1.step_losses.iter().zip(&r2.step_losses) {
        assert!((a - b).abs() < 5e-3, "serial {a} vs pipelined {b}");
    }
}

#[test]
fn trainer_cnn_lars_reaches_quality_target() {
    require_artifacts!();
    // Mini-CNN on the planted-feature image task with unscaled-momentum
    // LARS: must hit 60% top-1 (10 classes, alpha=2 — easily separable).
    let cfg = TrainConfig {
        model: "cnn_mini".into(),
        cores: 2,
        steps: 120,
        eval_every: 20,
        eval_examples: 128,
        opt: OptChoice::Lars { cfg: LarsConfig::default(), lr: 0.2 },
        use_wus: false,
        gradsum: GradSumMode::Pipelined { quantum: 4096 },
        seed: 7,
        task_difficulty: 0.0,
        image_alpha: 2.0,
        quality_target: Some(0.6),
        ..TrainConfig::quick("cnn_mini", 2, 120)
    };
    let rep = train(&cfg).unwrap();
    assert!(
        rep.converged_at.is_some(),
        "CNN+LARS failed to reach 60% top-1; evals: {:?}",
        rep.evals
    );
}

#[test]
fn trainer_eval_metrics_independent_of_core_count() {
    require_artifacts!();
    // Distributed eval must give the same metrics at any core count
    // (padding/masking invariance) when the model state is identical.
    let mk = |cores| {
        let mut c = TrainConfig::quick("transformer_tiny", cores, 1);
        c.eval_every = 1;
        c.eval_examples = 100; // deliberately not a multiple of anything
        c.opt = OptChoice::Sgd { lr: 0.0, momentum: 0.0 }; // freeze weights
        c
    };
    let r1 = train(&mk(1)).unwrap();
    let r4 = train(&mk(4)).unwrap();
    let (e1, e4) = (r1.evals[0], r4.evals[0]);
    assert!((e1.accuracy - e4.accuracy).abs() < 1e-5,
            "acc {} vs {}", e1.accuracy, e4.accuracy);
    assert!((e1.loss - e4.loss).abs() < 1e-4);
}

#[test]
fn trainer_single_core_works() {
    require_artifacts!();
    let rep = train(&TrainConfig::quick("transformer_tiny", 1, 3)).unwrap();
    assert_eq!(rep.step_losses.len(), 3);
    assert!(rep.params_total > 100_000);
}
