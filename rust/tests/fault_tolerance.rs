//! Fault-tolerant elastic training, end to end on the reference backend
//! (tier-1, no artifacts):
//!
//! * kill-and-resume is **bit-identical** for SGD, Adam and LARS, both
//!   replicated and weight-update-sharded — the v2 checkpoint carries
//!   params, optimizer accumulators and every rank's data-RNG state, so
//!   an interrupted run replays to exactly the uninterrupted weights;
//! * the same bit-identity holds on a **non-power-of-two world** (3
//!   workers) — arbitrary survivor sets are first-class;
//! * an injected chip death rolls back to the newest durable checkpoint
//!   and restarts elastically on **exactly the survivors** (world − 1,
//!   power of two or not), with the lost work reported as goodput;
//! * a torn async write (a crash mid-`.tmp`) never corrupts the
//!   previous durable checkpoint;
//! * stragglers stretch steps but never kill the run;
//! * the sweep engine's fault axis: an empty trace leaves every
//!   `SweepRecord` byte-identical (goodput exactly 1.0), a real trace
//!   prices goodput below 1.0.

use std::path::PathBuf;

use tpu_pod_train::coordinator::{checkpoint_path, train, OptChoice, TrainConfig};
use tpu_pod_train::optim::{AdamConfig, LarsConfig};
use tpu_pod_train::scenario::{
    FaultEvent, FaultKind, FaultTrace, ScalingScenario, SweepRunner,
};

/// Fresh scratch dir under the system temp dir (tests run in parallel in
/// one process, so the tag must be unique per call site).
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tpt_ft_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn death_at(step: u64, chip: usize) -> FaultTrace {
    FaultTrace {
        name: format!("death-{step}-{chip}"),
        ckpt_every_steps: 0,
        restore_seconds: 0.0,
        events: vec![FaultEvent { step, chip, kind: FaultKind::Death }],
    }
}

/// Kill-and-resume bit-identity at a given world size, across every
/// optimizer, replicated and weight-update-sharded. `cores` may be any
/// positive count — non-power-of-two worlds shard unevenly (remainder
/// shards) and must still round-trip exactly.
fn assert_kill_resume_bit_identical(cores: usize) {
    let opts: [(&str, OptChoice); 3] = [
        ("sgd", OptChoice::Sgd { lr: 0.05, momentum: 0.9 }),
        ("adam", OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 }),
        ("lars", OptChoice::Lars { cfg: LarsConfig::default(), lr: 0.5 }),
    ];
    for (name, opt) in opts {
        for wus in [false, true] {
            let tag = format!("resume_{cores}c_{name}_{}", if wus { "wus" } else { "rep" });

            // Uninterrupted run, checkpointing as it goes.
            let full_dir = scratch_dir(&format!("{tag}_full"));
            let mut cfg = TrainConfig::quick("transformer", cores, 12);
            cfg.opt = opt;
            cfg.use_wus = wus;
            cfg.checkpoint_every = 4;
            cfg.checkpoint_dir = Some(full_dir.clone());
            let full = train(&cfg).unwrap();
            assert_eq!(full.step_losses.len(), 12, "{tag}");
            assert_eq!(full.checkpoints, vec![4, 8, 12], "{tag}");
            assert_eq!(full.goodput, 1.0, "{tag}");

            // The same run killed after step 7 (simulated by truncating
            // `steps`), then resumed from its last durable checkpoint.
            let cut_dir = scratch_dir(&format!("{tag}_cut"));
            let mut cut = cfg.clone();
            cut.steps = 7;
            cut.checkpoint_dir = Some(cut_dir.clone());
            let interrupted = train(&cut).unwrap();
            assert_eq!(interrupted.checkpoints, vec![4], "{tag}");

            let mut res = cfg.clone();
            res.checkpoint_dir = Some(cut_dir.clone());
            res.resume = Some(checkpoint_path(&cut_dir, 4));
            let resumed = train(&res).unwrap();
            assert_eq!(resumed.resumed_from, 4, "{tag}");
            assert_eq!(resumed.step_losses.len(), 8, "{tag}");
            assert_eq!(resumed.checkpoints, vec![8, 12], "{tag}");
            assert_eq!(resumed.goodput, 1.0, "{tag}");

            // Bit-identical: every tensor, every element, exact f32 bits.
            assert_eq!(full.final_params.len(), resumed.final_params.len(), "{tag}");
            for (a, b) in full.final_params.iter().zip(&resumed.final_params) {
                let same = a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "{tag}: resumed params diverged from the uninterrupted run");
            }
            // The losses replayed after the checkpoint must match too.
            assert_eq!(&full.step_losses[4..], &resumed.step_losses[..], "{tag}");

            let _ = std::fs::remove_dir_all(&full_dir);
            let _ = std::fs::remove_dir_all(&cut_dir);
        }
    }
}

#[test]
fn kill_and_resume_is_bit_identical_for_every_optimizer() {
    assert_kill_resume_bit_identical(4);
}

#[test]
fn kill_and_resume_is_bit_identical_on_a_non_power_of_two_world() {
    // Three workers: the world size the old power-of-two stack rejected
    // outright. WUS shards unevenly here (remainder shards), and the
    // resume must still reproduce the uninterrupted run bit for bit.
    assert_kill_resume_bit_identical(3);
}

#[test]
fn chip_death_triggers_elastic_restart_on_the_survivors() {
    let dir = scratch_dir("death");
    let mut cfg = TrainConfig::quick("transformer", 4, 10);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.faults = Some(death_at(6, 1));
    let rep = train(&cfg).unwrap();

    // Incarnation 1 runs steps 1..=5 (the death strikes mid-step 6),
    // rolls back to the step-4 checkpoint, and incarnation 2 replays
    // 5..=10 on exactly the 3 survivors — not a power-of-two halving:
    // 11 executed steps, 10 useful, 1 lost.
    assert_eq!(rep.restores, 1);
    assert_eq!(rep.lost_steps, 1);
    assert_eq!(rep.final_cores, 3);
    assert_eq!(rep.step_losses.len(), 11);
    assert!((rep.goodput - 10.0 / 11.0).abs() < 1e-12, "goodput {}", rep.goodput);
    // Checkpoints: steps 2, 4 before the death; 6, 8, 10 after.
    assert_eq!(rep.checkpoints, vec![2, 4, 6, 8, 10]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn consecutive_deaths_walk_the_world_down_one_survivor_at_a_time() {
    // 5 workers, two deaths: 5 → 4 → 3. Every intermediate world is a
    // valid world; nothing rounds to a power of two.
    let dir = scratch_dir("ladder");
    let mut cfg = TrainConfig::quick("transformer", 5, 12);
    cfg.checkpoint_every = 3;
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.faults = Some(FaultTrace {
        name: "two-deaths".into(),
        ckpt_every_steps: 0,
        restore_seconds: 0.0,
        events: vec![
            FaultEvent { step: 5, chip: 4, kind: FaultKind::Death },
            FaultEvent { step: 9, chip: 3, kind: FaultKind::Death },
        ],
    });
    let rep = train(&cfg).unwrap();
    assert_eq!(rep.restores, 2);
    assert_eq!(rep.final_cores, 3);
    // Death mid-step 5 rolls back to step 3 (1 lost), mid-step 9 rolls
    // back to step 6 (2 lost): 12 useful + 3 replayed = 15 executed.
    assert_eq!(rep.lost_steps, 3);
    assert_eq!(rep.step_losses.len(), 15);
    assert!((rep.goodput - 12.0 / 15.0).abs() < 1e-12, "goodput {}", rep.goodput);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn death_without_any_checkpoint_replays_from_scratch() {
    let mut cfg = TrainConfig::quick("transformer", 4, 6);
    cfg.faults = Some(death_at(4, 0));
    let rep = train(&cfg).unwrap();
    // 3 steps lost (no durable checkpoint existed), full replay on the
    // 3 survivors from a fresh init: 3 + 6 executed, 6 useful.
    assert_eq!(rep.restores, 1);
    assert_eq!(rep.lost_steps, 3);
    assert_eq!(rep.final_cores, 3);
    assert_eq!(rep.step_losses.len(), 9);
    assert!((rep.goodput - 6.0 / 9.0).abs() < 1e-12, "goodput {}", rep.goodput);
}

#[test]
fn torn_async_write_never_corrupts_the_durable_checkpoint() {
    use tpu_pod_train::checkpoint;
    use tpu_pod_train::models::proxy::proxy_dims;
    use tpu_pod_train::runtime::param_specs_for;

    let dir = scratch_dir("torn");
    let mut cfg = TrainConfig::quick("transformer", 3, 8);
    cfg.checkpoint_every = 4;
    cfg.checkpoint_dir = Some(dir.clone());
    let rep = train(&cfg).unwrap();
    assert_eq!(rep.checkpoints, vec![4, 8]);

    // The async writer publishes via tmp-file + atomic rename: a clean
    // run leaves no `.tmp` litter behind.
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        assert!(
            p.extension().map(|e| e != "tmp").unwrap_or(true),
            "leftover tmp file {p:?} — publish must be tmp+rename"
        );
    }

    // Simulate a crash mid-write of the *next* save: a truncated `.tmp`
    // sitting beside the durable file, exactly what a torn write leaves.
    let durable = checkpoint_path(&dir, 8);
    let bytes = std::fs::read(&durable).unwrap();
    let torn = checkpoint::tmp_path(&durable);
    std::fs::write(&torn, &bytes[..bytes.len() / 2]).unwrap();

    // The durable checkpoint is untouched by the torn write…
    let specs = param_specs_for(&proxy_dims("transformer").unwrap());
    assert_eq!(checkpoint::peek_step(&durable).unwrap(), 8);
    let st = checkpoint::load(&durable, &specs).unwrap();
    assert_eq!(st.step, 8);
    // …and the torn half-file itself is detectably invalid, so nothing
    // can mistake it for a checkpoint.
    assert!(
        checkpoint::load(&torn, &specs).is_err(),
        "a truncated tmp file must never load as a valid checkpoint"
    );

    // Resuming from the durable file still works with the torn tmp
    // sitting in the directory.
    let mut res = cfg.clone();
    res.steps = 10;
    res.resume = Some(durable);
    let resumed = train(&res).unwrap();
    assert_eq!(resumed.resumed_from, 8);
    assert_eq!(resumed.step_losses.len(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn straggler_is_counted_but_never_fatal() {
    let mut cfg = TrainConfig::quick("transformer", 2, 8);
    cfg.faults = Some(FaultTrace {
        name: "slow".into(),
        ckpt_every_steps: 0,
        restore_seconds: 0.0,
        events: vec![FaultEvent {
            step: 3,
            chip: 0,
            kind: FaultKind::Slowdown { factor: 2.5, steps: 2 },
        }],
    });
    let rep = train(&cfg).unwrap();
    assert_eq!(rep.step_losses.len(), 8);
    assert_eq!(rep.straggled_steps, 2); // steps 3 and 4
    assert_eq!(rep.restores, 0);
    assert_eq!(rep.lost_steps, 0);
    assert_eq!(rep.goodput, 1.0);
    assert_eq!(rep.final_cores, 2);
}

#[test]
fn empty_fault_trace_keeps_sweep_records_byte_identical() {
    let base = ScalingScenario::submission("resnet50", vec![16, 256]);
    let faulted = base.clone().with_faults(FaultTrace::empty("nothing-happens"));
    let clean = SweepRunner::new(vec![base]).run().unwrap();
    let with_trace = SweepRunner::new(vec![faulted]).run().unwrap();
    assert_eq!(clean.dump(), with_trace.dump(), "empty trace must be a byte-level no-op");
    for rec in &with_trace.records {
        assert_eq!(rec.goodput, 1.0, "goodput must be exactly 1.0 under an empty trace");
        assert_eq!(rec.fault_events, 0);
        assert_eq!(rec.lost_steps, 0.0);
        assert_eq!(rec.restore_seconds, 0.0);
    }
}

#[test]
fn sweep_fault_trace_prices_goodput_below_one() {
    let trace = FaultTrace {
        name: "one-death".into(),
        ckpt_every_steps: 100,
        restore_seconds: 30.0,
        events: vec![FaultEvent { step: 500, chip: 0, kind: FaultKind::Death }],
    };
    let clean = SweepRunner::new(vec![ScalingScenario::submission("resnet50", vec![64])])
        .run()
        .unwrap();
    let faulted = SweepRunner::new(vec![
        ScalingScenario::submission("resnet50", vec![64]).with_faults(trace)
    ])
    .run()
    .unwrap();
    let (c, f) = (&clean.records[0], &faulted.records[0]);
    assert_eq!(f.fault_events, 1);
    assert!(f.goodput < 1.0, "goodput {}", f.goodput);
    assert!(f.lost_steps > 0.0);
    assert_eq!(f.restore_seconds, 30.0);
    assert!(f.final_cores < c.final_cores, "death must shrink the slice");
    assert!(
        f.benchmark_seconds > c.benchmark_seconds,
        "lost work must stretch the benchmark clock"
    );
}
