//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment ships no crates.io closure, so this vendored
//! shim provides the API subset the workspace actually uses:
//!
//! * [`Error`] — a context-chain error (outermost context first),
//! * [`Result`] with the `Error` default,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`] / [`bail!`] macros.
//!
//! Semantics mirror the real crate where this repo depends on them:
//! `{e}` prints the outermost message, `{e:#}` prints the whole chain
//! joined by `": "`, and `?` converts any `std::error::Error` (capturing
//! its `source()` chain). Like the real crate, `Error` deliberately does
//! NOT implement `std::error::Error` (that is what makes the blanket
//! `From` impl coherent).

use std::fmt;

/// A context-chain error. `chain[0]` is the outermost (most recently
/// attached) context; the root cause is last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what [`anyhow!`] expands to).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — plain `Result` with the chain error as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn fails() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert!(fails().is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i64> {
            let n: i64 = "not a number".parse()?;
            Ok(n)
        }
        let e = parse().unwrap_err();
        assert!(format!("{e}").contains("invalid digit"), "{e}");
    }

    #[test]
    fn context_stacks_outermost_first() {
        let e = anyhow!("root").context("mid").context("outer");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}
