//! Criterion-lite bench harness (no criterion crate in the offline build).
//!
//! `cargo bench` targets use `harness = false` and drive this: named
//! benchmarks with warmup, adaptive iteration counts, mean/p50/p99 output,
//! plus a table printer for the paper-reproduction benches (each bench
//! regenerates one paper table/figure as rows on stdout).

use crate::util::timer::{percentile, Stats, Timer};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p99_s)
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with warmup and a time budget.
pub struct Bench {
    pub warmup_s: f64,
    pub budget_s: f64,
    pub min_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Bench {
        Bench { warmup_s: 0.3, budget_s: 1.5, min_iters: 5, results: Vec::new() }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup_s: 0.05, budget_s: 0.3, min_iters: 3, results: Vec::new() }
    }

    /// Time `f` repeatedly; prints and records the result.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w = Timer::start();
        while w.secs() < self.warmup_s {
            f();
        }
        // Measure.
        let mut samples = Vec::new();
        let mut stats = Stats::new();
        let budget = Timer::start();
        while budget.secs() < self.budget_s || (samples.len() as u64) < self.min_iters {
            let t = Timer::start();
            f();
            let dt = t.secs();
            samples.push(dt);
            stats.push(dt);
            if samples.len() > 100_000 {
                break;
            }
        }
        let r = BenchResult {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean_s: stats.mean(),
            p50_s: percentile(&samples, 50.0),
            p99_s: percentile(&samples, 99.0),
            std_s: stats.std(),
        };
        println!("{}", r.line());
        self.results.push(r.clone());
        r
    }
}

/// Fixed-width table printer for paper-table reproductions.
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|h| h.len().max(10)).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers, &self.widths));
        let total: usize = self.widths.iter().sum::<usize>() + 2 * (self.widths.len() - 1);
        println!("{}", "-".repeat(total));
        for r in &self.rows {
            println!("{}", fmt_row(r, &self.widths));
        }
    }
}

/// Helper: `x.yz` formatting for speedups/ratios.
pub fn fmt_ratio(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let mut b = Bench { warmup_s: 0.0, budget_s: 0.05, min_iters: 3, results: vec![] };
        let r = b.run("sleep-1ms", || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert!(r.mean_s >= 0.9e-3, "{}", r.mean_s);
        assert!(r.iters >= 3);
    }

    #[test]
    fn table_rendering_is_aligned() {
        let mut t = Table::new("Table 1", &["optimizer", "epochs", "seconds"]);
        t.row(&["scaled".into(), "72.8".into(), "76.9".into()]);
        t.row(&["unscaled-long-name".into(), "64".into(), "67.1".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(2.5e-3), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 us");
        assert_eq!(fmt_time(2.5e-9), "2.5 ns");
    }
}
