//! TPU-v3 device roofline model (paper Fig. 1: 420 TFLOPS and 128 GB HBM
//! per 4-chip device → 105 TF/chip, 52.5 TF/core; 32 GB HBM/chip).
//!
//! Used by the pod simulator to estimate per-step compute time and the
//! optimizer weight-update overhead that motivates weight-update sharding
//! (§2: LARS ≈6% of step @2048 cores on ResNet-50; Adam ≈45% on
//! Transformer). End-to-end pricing goes through `costs::CostStack`
//! (`ComputePhase` / `WeightUpdatePhase` wrap this roofline over the
//! participating core set); the raw helpers here take an explicit torus
//! and shard count for micro-studies.

use crate::netsim::{ArAlgo, CostModel};

/// Per-core device constants.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    /// Peak bf16 FLOP/s per core.
    pub peak_flops: f64,
    /// HBM bytes/s per core.
    pub hbm_bw: f64,
    /// Achievable fraction of peak on dense conv/matmul workloads.
    pub mxu_efficiency: f64,
}

pub const TPU_V3: Device = Device {
    peak_flops: 52.5e12,
    hbm_bw: 450e9,
    mxu_efficiency: 0.55,
};

/// Per-core batch at which MXU utilization reaches half its dense-batch
/// ceiling (small per-core batches starve the systolic array — the regime
/// the paper's model-parallel techniques fight).
pub const BATCH_HALF_UTIL: f64 = 16.0;

impl Device {
    /// MXU efficiency at a given per-core example count.
    pub fn efficiency_at(&self, examples_per_core: f64) -> f64 {
        self.mxu_efficiency * examples_per_core / (examples_per_core + BATCH_HALF_UTIL)
    }

    /// Compute time for one example-batch on one core: roofline of MXU
    /// FLOPs against HBM traffic.
    pub fn compute_time(&self, flops: f64, hbm_bytes: f64) -> f64 {
        let t_flops = flops / (self.peak_flops * self.mxu_efficiency);
        let t_mem = hbm_bytes / self.hbm_bw;
        t_flops.max(t_mem)
    }

    /// Compute time with batch-dependent utilization.
    pub fn compute_time_batched(&self, flops: f64, hbm_bytes: f64, examples_per_core: f64) -> f64 {
        let t_flops = flops / (self.peak_flops * self.efficiency_at(examples_per_core));
        let t_mem = hbm_bytes / self.hbm_bw;
        t_flops.max(t_mem)
    }

    /// Optimizer update time for `params` parameters with `bytes_per_param`
    /// HBM traffic per parameter (LARS: w,g,v read + w,v write ≈ 20 B;
    /// Adam: w,g,m,v read + w,m,v write ≈ 28 B). Elementwise → memory
    /// bound.
    pub fn update_time(&self, params: f64, bytes_per_param: f64) -> f64 {
        params * bytes_per_param / self.hbm_bw
    }

    /// A [`TPU_V3`]-shaped device whose dense-batch compute coefficient is
    /// the given achieved forward-GFLOP/s (the `fitted_gflops` a live
    /// calibration reports: forward FLOPs over full fwd+bwd seconds, the
    /// 3x forward-FLOPs convention of `costs::ComputePhase` folded in).
    /// The batch-starvation curve ([`Device::efficiency_at`]) and the HBM
    /// roofline keep their TPU-v3 shape — only the dense compute ceiling
    /// is rescaled, so `with_compute_gflops` of TPU-v3's own dense
    /// coefficient reproduces [`TPU_V3`] exactly.
    pub fn with_compute_gflops(gflops: f64) -> Device {
        Device { peak_flops: 3.0 * gflops * 1e9 / TPU_V3.mxu_efficiency, ..TPU_V3 }
    }

    /// Dense-limit achieved forward-GFLOP/s of this device (the inverse of
    /// [`Device::with_compute_gflops`]).
    pub fn dense_fwd_gflops(&self) -> f64 {
        self.peak_flops * self.mxu_efficiency / 3.0 / 1e9
    }
}

/// Optimizer HBM traffic per parameter (f32 state).
pub const LARS_BYTES_PER_PARAM: f64 = 20.0;
pub const ADAM_BYTES_PER_PARAM: f64 = 28.0;

/// Weight-update strategy cost (paper §2 / Fig. 4).
#[derive(Clone, Copy, Debug)]
pub struct UpdateCost {
    pub replicated: f64,
    pub sharded: f64,
}

/// Cost of the weight update replicated vs sharded across `cores`, where
/// the sharded path adds the all-gather of fresh weights on the torus.
pub fn weight_update_cost(
    dev: &Device,
    net: &CostModel,
    params: f64,
    bytes_per_param: f64,
    cores: usize,
) -> UpdateCost {
    let replicated = dev.update_time(params, bytes_per_param);
    let shard_compute = dev.update_time(params / cores as f64, bytes_per_param);
    let gather = net.all_gather(params * 4.0); // weights broadcast in f32
    UpdateCost { replicated, sharded: shard_compute + gather }
}

/// Full device-step model: compute + gradient summation + weight update.
#[derive(Clone, Copy, Debug)]
pub struct StepModel {
    pub compute: f64,
    pub gradsum: f64,
    pub update: f64,
}

impl StepModel {
    pub fn total(&self) -> f64 {
        self.compute + self.gradsum + self.update
    }

    /// Update share of the total step time — the quantity behind the
    /// paper's "about 6% of the total device step time" (ResNet-50 LARS)
    /// and "about 45% of the step time" (Transformer Adam).
    pub fn update_fraction(&self) -> f64 {
        self.update / self.total()
    }
}

/// Estimate one synchronous training step.
#[allow(clippy::too_many_arguments)]
pub fn step_model(
    dev: &Device,
    net: &CostModel,
    flops_per_example: f64,
    hbm_bytes_per_example: f64,
    examples_per_core: f64,
    // util_units_per_example: 1 for an image classifier (parallelism
    // saturates within one image), ~tokens/sentence for sequence models
    // whose matmul row count is batch x tokens.
    util_units_per_example: f64,
    params: f64,
    bytes_per_param: f64,
    use_wus: bool,
) -> StepModel {
    // fwd + bwd ≈ 3x fwd FLOPs; MXU utilization degrades at small
    // per-core batch.
    let compute = dev.compute_time_batched(
        3.0 * flops_per_example * examples_per_core,
        hbm_bytes_per_example * examples_per_core,
        examples_per_core * util_units_per_example,
    );
    let gradsum = net.all_reduce(ArAlgo::Torus2D, params * 4.0);
    let cores = net.torus.chips() * 2; // 2 cores per chip
    let uc = weight_update_cost(dev, net, params, bytes_per_param, cores);
    let update = if use_wus { uc.sharded } else { uc.replicated };
    StepModel { compute, gradsum, update }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{NetParams, Torus};

    fn pod(chips: usize) -> CostModel {
        CostModel::new(Torus::for_chips(chips), NetParams::default())
    }

    #[test]
    fn compute_time_roofline() {
        // 1 TFLOP of dense work ≈ 34.6 ms at 55% of 52.5 TF.
        let t = TPU_V3.compute_time(1e12, 1e6);
        assert!((t - 1e12 / (52.5e12 * 0.55)).abs() < 1e-9);
        // Memory-bound case.
        let t = TPU_V3.compute_time(1e6, 45e9);
        assert!((t - 0.1).abs() < 1e-6);
    }

    /// `with_compute_gflops` built from TPU-v3's own dense coefficient is
    /// TPU-v3 again: the fitted-GFLOP/s preset only rescales the compute
    /// ceiling, it never warps the starvation curve or the HBM roofline.
    #[test]
    fn fitted_gflops_preset_roundtrip() {
        let d = Device::with_compute_gflops(TPU_V3.dense_fwd_gflops());
        assert!((d.peak_flops - TPU_V3.peak_flops).abs() / TPU_V3.peak_flops < 1e-12);
        assert_eq!(d.hbm_bw, TPU_V3.hbm_bw);
        assert_eq!(d.mxu_efficiency, TPU_V3.mxu_efficiency);
        // Halving the fitted coefficient exactly doubles dense compute time.
        let half = Device::with_compute_gflops(TPU_V3.dense_fwd_gflops() / 2.0);
        let t1 = TPU_V3.compute_time(1e12, 0.0);
        let t2 = half.compute_time(1e12, 0.0);
        assert!((t2 / t1 - 2.0).abs() < 1e-9, "{t2} vs {t1}");
    }

    /// Paper §2: ResNet-50 LARS weight update ≈ 6% of step @ 2048 cores,
    /// batch 32K (16 examples/core).
    #[test]
    fn resnet_lars_update_overhead_matches_paper() {
        let net = pod(1024); // 2048 cores
        let params = 25.6e6;
        let step = step_model(
            &TPU_V3,
            &net,
            3.9e9,  // ResNet-50 fwd GFLOPs/image
            50e6,   // activation traffic/image (approx)
            16.0,   // 32768 / 2048 cores
            1.0,    // image models: 1 util unit per example
            params,
            LARS_BYTES_PER_PARAM,
            false, // replicated update (the overhead being measured)
        );
        let frac = step.update_fraction();
        assert!((0.03..0.10).contains(&frac), "LARS update fraction {frac}");
    }

    /// Paper §2: Transformer Adam update ≈ 45% of step time (batch 1/core).
    #[test]
    fn transformer_adam_update_overhead_matches_paper() {
        let net = pod(1024);
        let params = 210e6; // MLPerf Transformer (big)
        let step = step_model(
            &TPU_V3,
            &net,
            2.0e9 * 33.0, // fwd FLOPs for one 33-token-avg sentence ≈ 2*P*L
            60e6,
            1.0,  // batch 1 per core (paper: global 2048 on 2048 cores)
            33.0, // ~33 matmul rows (tokens) per sentence
            params,
            ADAM_BYTES_PER_PARAM,
            false,
        );
        let frac = step.update_fraction();
        assert!((0.30..0.60).contains(&frac), "Adam update fraction {frac}");
    }

    #[test]
    fn wus_removes_most_update_cost_at_scale() {
        let net = pod(1024);
        let uc = weight_update_cost(&TPU_V3, &net, 210e6, ADAM_BYTES_PER_PARAM, 2048);
        assert!(
            uc.sharded < uc.replicated * 0.55,
            "sharded {} vs replicated {}",
            uc.sharded,
            uc.replicated
        );
    }

    #[test]
    fn wus_pointless_on_few_cores() {
        // On 4 chips the all-gather costs more than the saved update time
        // for a small model — matching why WUS is a *scale* optimization.
        let net = pod(4);
        let uc = weight_update_cost(&TPU_V3, &net, 25.6e6, LARS_BYTES_PER_PARAM, 8);
        assert!(uc.sharded > uc.replicated * 0.5);
    }

    #[test]
    fn step_model_totals() {
        let net = pod(64);
        let s = step_model(&TPU_V3, &net, 3.9e9, 50e6, 32.0, 1.0, 25.6e6,
                           LARS_BYTES_PER_PARAM, true);
        assert!(s.total() > 0.0);
        assert!(s.compute > s.update, "compute should dominate at batch 32");
    }
}
