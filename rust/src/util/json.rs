//! Minimal JSON parser + emitter (no serde in the offline build).
//!
//! Parses the `artifacts/manifest.json` the AOT pipeline writes and emits
//! structured results for the bench harness. Supports the full JSON value
//! model; numbers are kept as f64 (manifest only contains shapes and small
//! ints, well inside f64's exact-integer range).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_nested() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"shape": [8, 64], "name": "tokens"}"#).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("tokens"));
        let shape: Vec<usize> =
            v.get("shape").unwrap().as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![8, 64]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse(r#"{"a": }"#).is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        // Mirror of the structure aot.py writes.
        let src = r#"{"artifacts": [{"name": "t", "file": "t.hlo.txt",
            "inputs": [{"name": "w", "dtype": "f32", "shape": [4]}],
            "outputs": [], "meta": {"kind": "train_step"}}],
            "params": {"m": [{"name": "embed", "shape": [256, 128]}]},
            "configs": {}}"#;
        let v = Json::parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("t"));
    }
}
