//! Shared substrate utilities: PRNG, JSON, bfloat16, CLI parsing, timing.
//!
//! Everything here is written in-repo because the offline build environment
//! only ships the `xla` crate closure (see DESIGN.md "Offline-dependency
//! note").

pub mod bf16;
pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;
