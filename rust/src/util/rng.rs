//! Deterministic PRNG for data generation, initialization and tests.
//!
//! xoshiro256++ seeded via SplitMix64 — fast, well-distributed, and entirely
//! reproducible across runs (no external `rand` crate in the offline build).
//! Every worker derives an independent stream with [`Rng::fold_in`], the
//! same idiom as `jax.random.fold_in`, so data-parallel cores see decorrelated
//! but reproducible data.

/// SplitMix64 step — used for seeding and stream splitting.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    gauss_spare: Option<f64>,
}

/// Serializable snapshot of the full generator state ([`Rng::state`] /
/// [`Rng::restore`]). Includes the cached Box-Muller sample, so a restored
/// generator continues the exact stream — checkpoint format v2 persists one
/// of these per worker as the data-pipeline cursor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RngState {
    pub s: [u64; 4],
    /// `f64::to_bits` of the cached Box-Muller spare, if present.
    pub spare: Option<u64>,
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (reproducible per-worker data).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mixed = self.s[0] ^ self.s[3].rotate_left(17) ^ data.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Rng::new(mixed)
    }

    /// Snapshot the complete generator state (checkpoint format v2).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare: self.gauss_spare.map(f64::to_bits) }
    }

    /// Rebuild a generator from a snapshot; the restored generator
    /// continues the original stream bit-exactly.
    pub fn restore(state: &RngState) -> Rng {
        Rng { s: state.s, gauss_spare: state.spare.map(f64::from_bits) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire rejection-free for our purposes).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// f32 normal with mean/std.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a vector with standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(0.0, std)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from a categorical distribution given (unnormalised) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fold_in_decorrelates() {
        let base = Rng::new(7);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_restore_continues_stream_exactly() {
        let mut r = Rng::new(99);
        // Leave a cached Box-Muller spare pending so the snapshot must
        // carry it (an odd number of normal draws).
        for _ in 0..7 {
            r.normal();
        }
        let snap = r.state();
        assert!(snap.spare.is_some(), "odd normal draws must cache a spare");
        let mut restored = Rng::restore(&snap);
        for _ in 0..100 {
            assert_eq!(r.normal().to_bits(), restored.normal().to_bits());
            assert_eq!(r.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn state_round_trips_without_spare() {
        let mut r = Rng::new(123);
        r.next_u64();
        let snap = r.state();
        assert_eq!(snap.spare, None);
        let mut restored = Rng::restore(&snap);
        for _ in 0..10 {
            assert_eq!(r.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
        assert!((hits[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }
}
