//! Wall-clock timing helpers + simple streaming statistics, shared by the
//! metrics layer and the bench harness.

use std::time::Instant;

/// Scope timer: `let _t = Timer::start(); ...; let secs = _t.secs();`
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Streaming mean/min/max/stddev (Welford) without storing samples.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Stats {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a sample vector (nearest-rank; sorts a copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
    s[rank.min(s.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_match_closed_form() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 51.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.millis() >= 4.0);
    }
}
