//! Software bfloat16 (paper §2: "We use mixed precision with the bfloat16
//! precision in all our benchmark runs").
//!
//! bf16 is the top 16 bits of an IEEE-754 f32 (8-bit exponent, 7-bit
//! mantissa). The conversion uses round-to-nearest-even, matching TPU
//! hardware. Gradient *summation* follows the paper's rule: bf16 payloads on
//! the wire, f32 accumulation ("all non-convolutional operations (e.g. ...
//! gradient summation) use 32-bit floating point numbers" — we expose both a
//! bf16-payload mode for wire-volume modelling and f32 accumulate for math).

/// A bfloat16 value, stored as its raw 16-bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);

    /// Convert from f32 with round-to-nearest-even (TPU semantics).
    #[inline]
    pub fn from_f32(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            // Quiet the NaN, keep the payload's top bits.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round to nearest even on the truncated 16 bits.
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(round_bit - 1 + lsb);
        Bf16((rounded >> 16) as u16)
    }

    /// Truncation conversion (no rounding) — what naive ports do; kept for
    /// the precision-loss tests.
    #[inline]
    pub fn from_f32_truncate(x: f32) -> Bf16 {
        Bf16((x.to_bits() >> 16) as u16)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

/// Round-trip an f32 slice through bf16 in place (wire emulation).
pub fn round_slice_bf16(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = Bf16::from_f32(*x).to_f32();
    }
}

/// Pack an f32 slice into bf16 wire format (2 bytes/element).
pub fn pack_bf16(xs: &[f32]) -> Vec<Bf16> {
    xs.iter().map(|&x| Bf16::from_f32(x)).collect()
}

/// Unpack bf16 wire data, accumulating into an f32 buffer
/// (`acc += unpacked`) — the paper's f32-accumulate summation rule.
pub fn accumulate_bf16(acc: &mut [f32], wire: &[Bf16]) {
    assert_eq!(acc.len(), wire.len());
    for (a, w) in acc.iter_mut().zip(wire) {
        *a += w.to_f32();
    }
}

/// Max relative error introduced by one bf16 rounding (2^-8 mantissa ulp).
pub const BF16_MAX_REL_ERR: f32 = 1.0 / 256.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_round_trip() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0, f32::INFINITY] {
            assert_eq!(Bf16::from_f32(x).to_f32(), x);
        }
    }

    #[test]
    fn nan_stays_nan() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn rounding_error_bounded() {
        let mut worst = 0.0f32;
        for i in 0..10_000 {
            let x = (i as f32 - 5000.0) * 0.001_237 + 0.000_413;
            if x == 0.0 {
                continue;
            }
            let rel = ((Bf16::from_f32(x).to_f32() - x) / x).abs();
            worst = worst.max(rel);
        }
        assert!(worst <= BF16_MAX_REL_ERR, "worst={worst}");
    }

    #[test]
    fn round_nearest_even_beats_truncation() {
        // Statistical check: RNE has ~zero mean error; truncation biases
        // toward zero magnitude.
        let mut sum_rne = 0.0f64;
        let mut sum_trunc = 0.0f64;
        for i in 1..20_000 {
            let x = i as f32 * 0.000_777 + 1.0;
            sum_rne += (Bf16::from_f32(x).to_f32() - x) as f64;
            sum_trunc += (Bf16::from_f32_truncate(x).to_f32() - x) as f64;
        }
        assert!(sum_rne.abs() < sum_trunc.abs() / 10.0,
                "rne={sum_rne} trunc={sum_trunc}");
    }

    #[test]
    fn rne_ties_to_even() {
        // 1.0 + 2^-8 is exactly halfway between two bf16 values; RNE picks
        // the even mantissa (which here is 1.0).
        let x = f32::from_bits(0x3f80_8000);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0);
        // While anything above the tie rounds up.
        let y = f32::from_bits(0x3f80_8001);
        assert!(Bf16::from_f32(y).to_f32() > 1.0);
    }

    #[test]
    fn accumulate_in_f32_is_exact_for_wire_values() {
        let xs = vec![1.5f32, -2.25, 0.125];
        let wire = pack_bf16(&xs);
        let mut acc = vec![10.0f32; 3];
        accumulate_bf16(&mut acc, &wire);
        assert_eq!(acc, vec![11.5, 7.75, 10.125]);
    }

    #[test]
    fn wire_is_half_the_bytes() {
        assert_eq!(std::mem::size_of::<Bf16>(), 2);
    }
}
