//! Tiny declarative CLI argument parser (no clap in the offline build).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args
//! and subcommands; generates usage text. The launcher (`main.rs`) and every
//! example binary share this.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Cli {
        Cli { name, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Cli {
        self.specs.push(ArgSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Cli {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let d = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<24} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse a raw token list (without argv[0]).
    pub fn parse_tokens(&self, tokens: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag, takes no value"));
                    }
                    args.flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| format!("--{key} requires a value"))?,
                    };
                    args.values.insert(key.to_string(), val);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse from the process environment; prints usage and exits on error.
    pub fn parse(&self) -> Args {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_tokens(&tokens) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test", "t")
            .opt("cores", "8", "core count")
            .opt("lr", "0.1", "learning rate")
            .flag("verbose", "chatty")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse_tokens(&[]).unwrap();
        assert_eq!(a.get_usize("cores", 0), 8);
        assert_eq!(a.get_f64("lr", 0.0), 0.1);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cli().parse_tokens(&toks(&["--cores", "16", "--lr=0.5"])).unwrap();
        assert_eq!(a.get_usize("cores", 0), 16);
        assert_eq!(a.get_f64("lr", 0.0), 0.5);
    }

    #[test]
    fn flags_and_positionals() {
        let a = cli().parse_tokens(&toks(&["train", "--verbose", "extra"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["train", "extra"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse_tokens(&toks(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse_tokens(&toks(&["--cores"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cli().parse_tokens(&toks(&["--verbose=1"])).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = cli().usage();
        assert!(u.contains("--cores") && u.contains("default: 8"));
    }
}
