//! Config system: a TOML-subset parser + typed accessors + CLI overrides.
//!
//! Supports the launcher's needs: `[section.sub]` tables, string / integer /
//! float / boolean / string-array values, `#` comments, and dotted-path
//! overrides from the command line (`--set train.lr=0.5`).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    fn parse(raw: &str) -> Result<Value, String> {
        let raw = raw.trim();
        if raw.starts_with('"') && raw.ends_with('"') && raw.len() >= 2 {
            return Ok(Value::Str(raw[1..raw.len() - 1].to_string()));
        }
        if raw == "true" {
            return Ok(Value::Bool(true));
        }
        if raw == "false" {
            return Ok(Value::Bool(false));
        }
        if raw.starts_with('[') && raw.ends_with(']') {
            let inner = &raw[1..raw.len() - 1];
            let mut items = Vec::new();
            if !inner.trim().is_empty() {
                for part in split_top_level(inner) {
                    items.push(Value::parse(&part)?);
                }
            }
            return Ok(Value::Arr(items));
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(format!("cannot parse value: {raw:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Split "a, b, [c, d]" at top-level commas.
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

/// Flat dotted-key config ("train.lr" → value).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub entries: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = strip_comment(raw_line).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section", lineno + 1));
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value = Value::parse(val)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            cfg.entries.insert(full_key, value);
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    /// Apply a `key.path=value` CLI override.
    pub fn set_override(&mut self, spec: &str) -> Result<(), String> {
        let (key, val) = spec
            .split_once('=')
            .ok_or_else(|| format!("override must be key=value: {spec:?}"))?;
        self.entries.insert(key.trim().to_string(), Value::parse(val)?);
        Ok(())
    }

    /// Merge `other` on top of `self` (other wins).
    pub fn merge(&mut self, other: Config) {
        self.entries.extend(other.entries);
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.i64_or(key, default as i64) as usize
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// All keys below a dotted prefix.
    pub fn section(&self, prefix: &str) -> Vec<(&str, &Value)> {
        let p = format!("{prefix}.");
        self.entries
            .iter()
            .filter(|(k, _)| k.starts_with(&p))
            .map(|(k, v)| (&k[p.len()..], v))
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# pod config
name = "resnet50"

[pod]
chips = 1024            # full pod
cores_per_chip = 2

[train]
lr = 31.2
warmup_epochs = 25
use_wus = true
presets = ["tiny", "small"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "resnet50");
        assert_eq!(c.usize_or("pod.chips", 0), 1024);
        assert_eq!(c.f64_or("train.lr", 0.0), 31.2);
        assert!(c.bool_or("train.use_wus", false));
        match c.get("train.presets").unwrap() {
            Value::Arr(items) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[0].as_str(), Some("tiny"));
            }
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn int_promotes_to_float() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn comments_stripped_but_not_in_strings() {
        let c = Config::parse(r##"s = "a#b" # comment"##).unwrap();
        assert_eq!(c.str_or("s", ""), "a#b");
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set_override("train.lr=29.0").unwrap();
        c.set_override("pod.chips=64").unwrap();
        assert_eq!(c.f64_or("train.lr", 0.0), 29.0);
        assert_eq!(c.usize_or("pod.chips", 0), 64);
    }

    #[test]
    fn section_listing() {
        let c = Config::parse(SAMPLE).unwrap();
        let keys: Vec<&str> = c.section("train").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["lr", "presets", "use_wus", "warmup_epochs"]);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Config::parse("x == 1\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = Config::parse("\n\nbad").unwrap_err();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn merge_prefers_other() {
        let mut a = Config::parse("x = 1\ny = 2").unwrap();
        let b = Config::parse("y = 3\nz = 4").unwrap();
        a.merge(b);
        assert_eq!(a.i64_or("x", 0), 1);
        assert_eq!(a.i64_or("y", 0), 3);
        assert_eq!(a.i64_or("z", 0), 4);
    }

    #[test]
    fn nested_arrays() {
        let c = Config::parse("m = [[1, 2], [3]]").unwrap();
        match c.get("m").unwrap() {
            Value::Arr(rows) => assert_eq!(rows.len(), 2),
            _ => panic!(),
        }
    }
}
