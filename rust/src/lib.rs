//! # tpu-pod-train
//!
//! Reproduction of *"Scale MLPerf-0.6 models on Google TPU-v3 Pods"*
//! (Kumar et al., 2019) as a three-layer Rust + JAX + Pallas
//! distributed-training framework. See DESIGN.md for the system inventory
//! and EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * L3 (this crate) — coordinator: data-parallel trainer, 2-D torus
//!   gradient summation, weight-update sharding, spatial partitioning,
//!   distributed evaluation, pod simulator.
//! * Executors — the trainer drives a [`runtime::Backend`]: the in-Rust
//!   reference fwd/bwd ([`runtime::reference`], exact analytic gradients
//!   over the [`models::proxy`] dense proxies; no artifacts, tier-1) or
//!   PJRT over the AOT artifacts ([`runtime::PjRtBackend`]). The
//!   reference executor runs blocked/tiled kernels
//!   ([`runtime::kernels`]) with per-step workspace reuse and an
//!   optional intra-core threaded split (`--exec-threads`), bit-identical
//!   to the serial scalar baseline by construction; `BENCH_backend.json`
//!   tracks the naive/tiled/threaded step-time matrix.
//! * L2/L1 (python/, build-time only) — JAX model fwd/bwd + Pallas kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt` and executed via PJRT from
//!   [`runtime`] when `--backend pjrt` is selected.
//!
//! # Cost attribution, scenario sweeps & test matrix
//!
//! All pricing flows through the participation-aware [`costs`] layer:
//! [`costs::PodLayout`] derives the participating core set from a layout
//! (`replicas × mp`; surplus cores idle), and a [`costs::CostStack`] of
//! per-phase [`costs::StepCostModel`]s prices compute, halo, gradient
//! summation, the weight update, eval and infra each over its own group —
//! backed by [`devicesim`], [`netsim`], [`wus`], [`evaluation`] and
//! [`spatial`]. No phase is priced over raw machine cores, so
//! fixed-batch strong-scaling sweeps cannot overstate scaling.
//!
//! The network layer is hierarchical: [`netsim::TopologySpec`] places a
//! chip count onto a flat 2-D torus or a [`netsim::PodSpec`] pod group
//! (N intra-pod tori joined by slower inter-pod links), and cross-pod
//! gradient summation prices either reduce-then-broadcast
//! ([`netsim::CrossPodStrategy::Hierarchical`]) or one flat ring over
//! the boundary links. Single-pod specs collapse bit-identically to the
//! flat torus, non-uniform payload schedules route around the
//! `netsim::fastpath` symmetry shortcut through the event-driven
//! simulator, and concurrent phases (gradsum + halo) can share link
//! bandwidth in one simulation
//! ([`netsim::concurrent_gradsum_halo_makespan`]);
//! `rust/tests/multipod.rs` pins all three properties.
//!
//! The paper's actual experiment is a *sweep*: each MLPerf model across
//! pod slices (16 → 1024 chips) with weight-update sharding, spatial
//! partitioning, gradient-summation schedule and optimizer co-tuned per
//! point. The [`scenario`] module is that experiment driver:
//! [`scenario::ScalingScenario`] declares a sweep, an
//! [`scenario::AblationGrid`] expands every §2 on/off axis into labeled
//! scenarios (the scenario × SimOptions cross-product behind
//! `sweep --grid`), and a [`scenario::SweepRunner`] executes the grid —
//! serially or over a worker pool (`run_jobs` / `--jobs N`) with
//! memoized contention/imbalance kernels and the `netsim::fastpath`
//! ring-symmetry shortcut, byte-identical to the serial run. Each
//! point's [`scenario::SweepRecord`] carries the layout, participating
//! vs surplus cores, the per-phase step-time attribution (with each
//! phase's group size), shard imbalance, a contention-checked collective
//! time and the predicted benchmark seconds. `tpu-pod-train sweep` emits
//! the JSON report and `sweep --compare baseline.json` diffs it against
//! a prior run (nonzero exit on regression); `BENCH_sweep.json` tracks
//! the engine's own throughput; `rust/src/scenario/README.md` maps
//! sweeps to the paper's figures and documents the attribution and grid
//! naming schemas. `sweep --live` closes the loop between the two
//! engines: the [`calibrate`] module runs a micro-grid of real training
//! points on the live reference trainer, records measured per-phase
//! wall-clock next to the simulator's attribution, gates on trend
//! agreement (batch-scaling monotonicity, cross-family ordering; nonzero
//! exit on disagreement) and fits the live compute coefficient a
//! measured `StepCostModel` would use; `sweep --costs-from` feeds that
//! fitted GFLOP/s back into the simulator's compute pricing, and
//! `sweep --grid --marginals` reduces a grid report to the per-axis
//! marginal-speedup table (what each §2 toggle bought at each scale).
//!
//! # Observability
//!
//! Every timed phase records into one structured tracing layer
//! ([`metrics::TraceSink`]): the trainer step loop (input/compute/
//! fwd/bwd/gradsum/update/eval spans per step, rank 0), the async
//! checkpoint writer (write/publish spans), fault handling
//! (incarnation/death/preemption/rollback instants), the sweep worker
//! pool (per-point spans with queue-wait attribution + cache-hit
//! counters) and `sweep --live` calibration points. `--trace FILE` on
//! `train` and `sweep` exports JSON-lines or Chrome trace-event format
//! (load at ui.perfetto.dev), and `trace summarize` reduces a trace to
//! per-phase p50/p99 tables *and cross-checks it against the run's own
//! `TrainReport` accounting* (nonzero exit on disagreement). Tracing
//! off is bit-identical to the layer not existing; traced runs are
//! deterministic modulo timestamps. See `rust/src/metrics/README.md`
//! for the schema and span taxonomy.
//!
//! The test matrix:
//! * unit tests inside every module (the substrate contracts),
//! * `rust/tests/dist_invariants.rs` — property-based distributed
//!   invariants with shrinking (collective sums, shard-plan partitioning,
//!   halo round-trips) via [`testing::forall`],
//! * `rust/tests/scenario_golden.rs` — golden-trace fixtures pinning one
//!   sweep point per model plus strong-scaling monotonicity checks,
//! * `rust/tests/integration.rs` — the real-trainer loop on the reference
//!   backend (always runs: convergence, WUS/gradsum equivalences, seeded
//!   bit-identical determinism); the Pallas kernel-parity tests skip
//!   unless the PJRT backend is available (`python python/compile/aot.py`
//!   + the real `xla` binding, see `rust/src/runtime/README.md`),
//! * `rust/tests/fault_tolerance.rs` — the [`checkpoint`] +
//!   [`scenario::FaultTrace`] layer: kill-and-resume bit-identity for
//!   every optimizer (replicated and WUS), elastic halving restarts on
//!   chip death, and the sweep engine's goodput accounting (an empty
//!   trace is a byte-level no-op),
//! * `rust/tests/exec_threads.rs` — the threaded executor's determinism
//!   contract end to end: `--exec-threads N` bit-identical to serial for
//!   every optimizer (replicated and WUS), seeded threaded runs
//!   reproducible, executor time split into fwd/bwd,
//! * `rust/tests/trace.rs` — the tracing layer's contracts end to end:
//!   traced faulted runs deterministic modulo timestamps
//!   (`canonical_dump` byte-identity), tracing never perturbs the
//!   numerics (disabled vs enabled bit-identical for every optimizer,
//!   replicated and WUS), JSONL/Chrome round-trips pass the
//!   `summarize` accounting cross-check, tampered traces fail it,
//! * `rust/tests/bench_backend.rs` + `rust/tests/bench_sweep.rs` +
//!   `rust/tests/bench_trace.rs` — the perf trajectory: regenerate
//!   `BENCH_backend.json` (naive/tiled/threaded executor matrix,
//!   bit-identity cross-checked), `BENCH_sweep.json`, and
//!   `BENCH_trace.json` (tracing-overhead pair, bit-identity
//!   cross-checked) on every `cargo test` run.

pub mod benchkit;
pub mod calibrate;
pub mod checkpoint;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod costs;
pub mod data;
pub mod devicesim;
pub mod evaluation;
pub mod fabric;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod netsim;
pub mod runtime;
pub mod scenario;
pub mod simulator;
pub mod spatial;
pub mod testing;
pub mod util;
pub mod wus;
