//! # tpu-pod-train
//!
//! Reproduction of *"Scale MLPerf-0.6 models on Google TPU-v3 Pods"*
//! (Kumar et al., 2019) as a three-layer Rust + JAX + Pallas
//! distributed-training framework. See DESIGN.md for the system inventory
//! and EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * L3 (this crate) — coordinator: data-parallel trainer, 2-D torus
//!   gradient summation, weight-update sharding, spatial partitioning,
//!   distributed evaluation, pod simulator.
//! * L2/L1 (python/, build-time only) — JAX model fwd/bwd + Pallas kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt` and executed via PJRT from
//!   [`runtime`].

pub mod benchkit;
pub mod checkpoint;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod devicesim;
pub mod evaluation;
pub mod fabric;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod netsim;
pub mod runtime;
pub mod simulator;
pub mod spatial;
pub mod testing;
pub mod util;
pub mod wus;
