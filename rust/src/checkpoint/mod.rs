//! Checkpointing: save/restore model parameters + optimizer step counter.
//!
//! MLPerf's timing rules make initialization (including checkpoint
//! restore) free, so production runs restore the pre-trained backbone
//! (e.g. SSD's ResNet-34) before `run_start`. Format: a JSON header
//! (tensor names/shapes/offsets, fletcher checksum) followed by raw
//! little-endian f32 data — readable with one pass, no serde.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::ParamSpec;
use crate::util::json::{obj, Json};

/// Fletcher-64 style checksum over the raw f32 bytes.
fn checksum(data: &[f32]) -> u64 {
    let mut a: u64 = 1;
    let mut b: u64 = 0;
    for &x in data {
        a = (a + x.to_bits() as u64) % 0xFFFF_FFFB;
        b = (b + a) % 0xFFFF_FFFB;
    }
    (b << 32) | a
}

/// Save parameters (+ step) to `path`.
pub fn save(
    path: impl AsRef<Path>,
    specs: &[ParamSpec],
    params: &[Vec<f32>],
    step: u64,
) -> Result<()> {
    assert_eq!(specs.len(), params.len());
    let mut tensors = Vec::new();
    let mut offset = 0usize;
    for (s, p) in specs.iter().zip(params) {
        if s.numel() != p.len() {
            bail!("{}: spec {} elems, data {}", s.name, s.numel(), p.len());
        }
        tensors.push(obj(vec![
            ("name", Json::Str(s.name.clone())),
            ("shape", Json::Arr(s.shape.iter().map(|&d| Json::from(d)).collect())),
            ("offset", Json::from(offset)),
        ]));
        offset += p.len();
    }
    let total_sum: u64 = params.iter().map(|p| checksum(p)).fold(0, u64::wrapping_add);
    let header = obj(vec![
        ("format", Json::Str("tpu-pod-train-ckpt-v1".into())),
        ("step", Json::from(step as usize)),
        ("total_elems", Json::from(offset)),
        ("checksum", Json::Str(format!("{total_sum:016x}"))),
        ("tensors", Json::Arr(tensors)),
    ])
    .dump();

    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for p in params {
        // Safe little-endian serialization.
        let mut buf = Vec::with_capacity(p.len() * 4);
        for &x in p {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

/// Restore a checkpoint; returns (params, step). Validates names, shapes
/// and checksum against `specs`.
pub fn load(path: impl AsRef<Path>, specs: &[ParamSpec]) -> Result<(Vec<Vec<f32>>, u64)> {
    let mut f = std::fs::File::open(&path)
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    if hlen > 64 << 20 {
        bail!("implausible header length {hlen}");
    }
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)
        .map_err(|e| anyhow::anyhow!("header parse: {e}"))?;
    if header.get("format").and_then(Json::as_str) != Some("tpu-pod-train-ckpt-v1") {
        bail!("unknown checkpoint format");
    }
    let step = header.get("step").and_then(Json::as_usize).unwrap_or(0) as u64;
    let tensors = header
        .get("tensors")
        .and_then(Json::as_arr)
        .context("header missing tensors")?;
    if tensors.len() != specs.len() {
        bail!("checkpoint has {} tensors, model needs {}", tensors.len(), specs.len());
    }
    let mut params = Vec::with_capacity(specs.len());
    for (t, s) in tensors.iter().zip(specs) {
        let name = t.get("name").and_then(Json::as_str).unwrap_or("");
        if name != s.name {
            bail!("tensor order mismatch: checkpoint {name:?} vs model {:?}", s.name);
        }
        let shape: Vec<usize> = t
            .get("shape")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        if shape != s.shape {
            bail!("{name}: shape {shape:?} vs model {:?}", s.shape);
        }
        let n = s.numel();
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        params.push(data);
    }
    let want = header.get("checksum").and_then(Json::as_str).unwrap_or("");
    let got: u64 = params.iter().map(|p| checksum(p)).fold(0, u64::wrapping_add);
    if format!("{got:016x}") != want {
        bail!("checksum mismatch: corrupt checkpoint");
    }
    Ok((params, step))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "embed".into(), shape: vec![16, 8] },
            ParamSpec { name: "layer0.w".into(), shape: vec![8, 8] },
            ParamSpec { name: "bias".into(), shape: vec![8] },
        ]
    }

    fn make_params(seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        specs().iter().map(|s| rng.normal_vec(s.numel(), 1.0)).collect()
    }

    #[test]
    fn round_trip_exact() {
        let dir = std::env::temp_dir().join("tpt_ckpt_rt.bin");
        let params = make_params(1);
        save(&dir, &specs(), &params, 42).unwrap();
        let (restored, step) = load(&dir, &specs()).unwrap();
        assert_eq!(step, 42);
        assert_eq!(restored, params); // bit-exact
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join("tpt_ckpt_shape.bin");
        save(&dir, &specs(), &make_params(2), 0).unwrap();
        let mut wrong = specs();
        wrong[1].shape = vec![4, 16];
        assert!(load(&dir, &wrong).is_err());
    }

    #[test]
    fn name_mismatch_rejected() {
        let dir = std::env::temp_dir().join("tpt_ckpt_name.bin");
        save(&dir, &specs(), &make_params(3), 0).unwrap();
        let mut wrong = specs();
        wrong[0].name = "other".into();
        assert!(load(&dir, &wrong).is_err());
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("tpt_ckpt_corrupt.bin");
        save(&dir, &specs(), &make_params(4), 0).unwrap();
        // Flip a payload byte near the end.
        let mut bytes = std::fs::read(&dir).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&dir, bytes).unwrap();
        let err = load(&dir, &specs()).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
    }

    #[test]
    fn missing_file_is_error() {
        assert!(load("/nonexistent/ckpt.bin", &specs()).is_err());
    }
}
