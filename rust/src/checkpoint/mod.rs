//! Checkpointing: save/restore model parameters, optimizer state, RNG
//! streams and the step counter.
//!
//! MLPerf's timing rules make initialization (including checkpoint
//! restore) free, so production runs restore the pre-trained backbone
//! (e.g. SSD's ResNet-34) before `run_start`. Format v2: a JSON header
//! (tensor names/shapes/offsets, optimizer slot directory, per-worker RNG
//! snapshots, chained fletcher checksum) followed by raw little-endian
//! f32 data — readable with one pass, no serde. See `README.md` in this
//! directory for the byte-level layout and the resume guarantees.
//!
//! Format v1 (params + step only, order-invariant checksum) is still
//! readable with a warning; its optimizer state is reported as absent so
//! the trainer re-initializes accumulators — v1 resumes are therefore NOT
//! bit-identical, which is exactly the bug v2 fixes.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::ParamSpec;
use crate::util::json::{obj, Json};
use crate::util::rng::RngState;

const FORMAT_V1: &str = "tpu-pod-train-ckpt-v1";
const FORMAT_V2: &str = "tpu-pod-train-ckpt-v2";

/// Fletcher-64 style checksum, chained across the full payload stream.
///
/// Unlike the v1 scheme (per-tensor sums folded with `wrapping_add`, which
/// is order-invariant — swapping two same-shaped tensors' payloads passed
/// verification), the stream carries its running state across tensor
/// boundaries, so the total depends on byte order end to end.
pub struct ChecksumStream {
    a: u64,
    b: u64,
}

impl ChecksumStream {
    pub fn new() -> ChecksumStream {
        ChecksumStream { a: 1, b: 0 }
    }

    pub fn update(&mut self, data: &[f32]) {
        for &x in data {
            self.a = (self.a + x.to_bits() as u64) % 0xFFFF_FFFB;
            self.b = (self.b + self.a) % 0xFFFF_FFFB;
        }
    }

    pub fn total(&self) -> u64 {
        (self.b << 32) | self.a
    }
}

impl Default for ChecksumStream {
    fn default() -> Self {
        ChecksumStream::new()
    }
}

/// v1 per-tensor checksum (kept to validate legacy checkpoints).
fn checksum_v1(data: &[f32]) -> u64 {
    let mut s = ChecksumStream::new();
    s.update(data);
    s.total()
}

/// Optimizer state carried by a v2 checkpoint.
///
/// `slots` are named full-length (unsharded) accumulator vectors in a fixed
/// order: SGD/LARS store `velocity`, Adam stores `m` then `v`. Momentum
/// vectors that the replicated optimizers had not lazily allocated yet are
/// saved as explicit zeros so the restore side never guesses.
#[derive(Clone, Debug, PartialEq)]
pub struct OptSnapshot {
    /// One of "none", "sgd", "adam", "lars".
    pub kind: String,
    /// Adam's bias-correction step counter (0 for other optimizers).
    pub adam_step: u64,
    pub slots: Vec<(String, Vec<f32>)>,
}

impl OptSnapshot {
    pub fn none() -> OptSnapshot {
        OptSnapshot { kind: "none".into(), adam_step: 0, slots: Vec::new() }
    }
}

/// Everything needed to resume training bit-identically on the reference
/// backend: parameters, optimizer accumulators, and each worker's data RNG
/// snapshot (the RNG *is* the synthetic data-pipeline cursor, so restoring
/// it resumes the input stream at the exact batch the run left off).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    pub step: u64,
    pub params: Vec<Vec<f32>>,
    pub opt: OptSnapshot,
    /// Per-rank data RNG states, indexed by rank; empty for v1 checkpoints.
    pub rng: Vec<RngState>,
    /// World size the checkpoint was taken at (0 for v1 checkpoints).
    pub world: usize,
}

fn rng_state_json(st: &RngState) -> Json {
    obj(vec![
        (
            "s",
            Json::Arr(st.s.iter().map(|&w| Json::Str(format!("{w:016x}"))).collect()),
        ),
        (
            "spare",
            match st.spare {
                Some(w) => Json::Str(format!("{w:016x}")),
                None => Json::Null,
            },
        ),
    ])
}

fn parse_hex_u64(j: &Json) -> Result<u64> {
    let s = j.as_str().context("expected hex string")?;
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 {s:?}"))
}

fn rng_state_from_json(j: &Json) -> Result<RngState> {
    let words = j.get("s").and_then(Json::as_arr).context("rng missing s")?;
    if words.len() != 4 {
        bail!("rng state needs 4 words, got {}", words.len());
    }
    let mut s = [0u64; 4];
    for (i, w) in words.iter().enumerate() {
        s[i] = parse_hex_u64(w)?;
    }
    let spare = match j.get("spare") {
        Some(Json::Null) | None => None,
        Some(v) => Some(parse_hex_u64(v)?),
    };
    Ok(RngState { s, spare })
}

fn write_f32s(f: &mut std::fs::File, data: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(data.len() * 4);
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

fn read_f32s(f: &mut std::fs::File, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save a full training state to `path` (format v2).
pub fn save(path: impl AsRef<Path>, specs: &[ParamSpec], state: &TrainState) -> Result<()> {
    assert_eq!(specs.len(), state.params.len());
    let mut tensors = Vec::new();
    let mut offset = 0usize;
    for (s, p) in specs.iter().zip(&state.params) {
        if s.numel() != p.len() {
            bail!("{}: spec {} elems, data {}", s.name, s.numel(), p.len());
        }
        tensors.push(obj(vec![
            ("name", Json::Str(s.name.clone())),
            ("shape", Json::Arr(s.shape.iter().map(|&d| Json::from(d)).collect())),
            ("offset", Json::from(offset)),
        ]));
        offset += p.len();
    }
    let mut slot_dir = Vec::new();
    for (name, data) in &state.opt.slots {
        slot_dir.push(obj(vec![
            ("name", Json::Str(name.clone())),
            ("len", Json::from(data.len())),
            ("offset", Json::from(offset)),
        ]));
        offset += data.len();
    }

    // Chained checksum over the entire payload stream: params in spec
    // order, then optimizer slots in directory order.
    let mut stream = ChecksumStream::new();
    for p in &state.params {
        stream.update(p);
    }
    for (_, data) in &state.opt.slots {
        stream.update(data);
    }
    let total_sum = stream.total();

    let header = obj(vec![
        ("format", Json::Str(FORMAT_V2.into())),
        ("step", Json::from(state.step as usize)),
        ("world", Json::from(state.world)),
        ("total_elems", Json::from(offset)),
        ("checksum", Json::Str(format!("{total_sum:016x}"))),
        ("tensors", Json::Arr(tensors)),
        (
            "opt",
            obj(vec![
                ("kind", Json::Str(state.opt.kind.clone())),
                ("adam_step", Json::from(state.opt.adam_step as usize)),
                ("slots", Json::Arr(slot_dir)),
            ]),
        ),
        ("rng", Json::Arr(state.rng.iter().map(rng_state_json).collect())),
    ])
    .dump();

    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for p in &state.params {
        write_f32s(&mut f, p)?;
    }
    for (_, data) in &state.opt.slots {
        write_f32s(&mut f, data)?;
    }
    Ok(())
}

/// The scratch name [`save_atomic`] streams into before the rename.
/// Readers ([`load`], `latest_checkpoint`) never look at `.tmp` files, so
/// a torn one is inert garbage, not a corrupt checkpoint.
pub fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    std::path::PathBuf::from(os)
}

/// Durable save with a crash-safe publish: stream the full file into
/// `<path>.tmp`, then atomically rename it over the final name. A crash at
/// any point mid-write leaves either no file or a stale `.tmp` — the
/// previously published checkpoint at `path` (if any) stays valid.
pub fn save_atomic(path: impl AsRef<Path>, specs: &[ParamSpec], state: &TrainState) -> Result<()> {
    let path = path.as_ref();
    let tmp = tmp_path(path);
    save(&tmp, specs, state)?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Double-buffered background checkpoint writer (rank 0 only).
///
/// The training loop hands a fully materialized [`TrainState`] snapshot to
/// [`enqueue`](Self::enqueue) and keeps stepping while a writer thread
/// streams it to disk via [`save_atomic`]; the snapshot being an owned
/// second buffer is what makes the overlap safe. At most one save is in
/// flight: enqueueing the next checkpoint first drains the previous write
/// (propagating its error), so a slow disk back-pressures the step loop
/// instead of queueing unbounded snapshots. Call [`drain`](Self::drain)
/// before exiting — including crash-injection exits — so the last queued
/// checkpoint is durable.
///
/// With a trace sink ([`AsyncWriter::with_trace`]) the writer thread
/// records `ckpt.write` (streaming into `<path>.tmp`) and `ckpt.publish`
/// (the atomic rename) spans on the checkpoint track — the window between
/// them is exactly the crash window where only the `.tmp` exists.
#[derive(Default)]
pub struct AsyncWriter {
    inflight: Option<std::thread::JoinHandle<Result<()>>>,
    trace: crate::metrics::TraceSink,
    epoch: u32,
    saves: u32,
}

impl AsyncWriter {
    pub fn new() -> AsyncWriter {
        AsyncWriter::with_trace(crate::metrics::TraceSink::disabled(), 0)
    }

    /// A writer whose saves are recorded on the trace's checkpoint track;
    /// `epoch` is the trainer incarnation index (the trace epoch).
    pub fn with_trace(trace: crate::metrics::TraceSink, epoch: u32) -> AsyncWriter {
        AsyncWriter { inflight: None, trace, epoch, saves: 0 }
    }

    /// Queue one durable save; blocks only if the previous one is still
    /// being written.
    pub fn enqueue(
        &mut self,
        path: std::path::PathBuf,
        specs: Vec<ParamSpec>,
        state: TrainState,
    ) -> Result<()> {
        self.drain()?;
        // Each save gets a short-lived local on the checkpoint track with a
        // per-save sequence base, so events from successive writer threads
        // order by save index regardless of merge timing.
        let mut tl = self.trace.local_from(crate::metrics::TRACK_CKPT, self.epoch, self.saves * 8);
        self.saves += 1;
        self.inflight = Some(std::thread::spawn(move || {
            use crate::metrics::AttrVal;
            let step = state.step;
            let file = path
                .file_name()
                .and_then(|f| f.to_str())
                .unwrap_or("ckpt")
                .to_string();
            let tmp = tmp_path(&path);
            let t0 = tl.start();
            save(&tmp, &specs, &state)?;
            tl.span("ckpt.write", t0, || {
                vec![("step", AttrVal::from(step)), ("file", AttrVal::from(file.clone()))]
            });
            let t1 = tl.start();
            std::fs::rename(&tmp, &path)
                .with_context(|| format!("publishing {tmp:?} -> {path:?}"))?;
            tl.span("ckpt.publish", t1, || {
                vec![("step", AttrVal::from(step)), ("file", AttrVal::from(file))]
            });
            Ok(())
        }));
        Ok(())
    }

    /// Wait for the in-flight save (if any) to be published, surfacing its
    /// error. Idempotent.
    pub fn drain(&mut self) -> Result<()> {
        match self.inflight.take() {
            Some(h) => {
                h.join().map_err(|_| anyhow::anyhow!("checkpoint writer thread panicked"))?
            }
            None => Ok(()),
        }
    }
}

/// Save parameters (+ step) in the legacy v1 format. Kept for
/// compatibility tests and for interop with pre-v2 tooling; new code
/// should use [`save`].
pub fn save_v1(
    path: impl AsRef<Path>,
    specs: &[ParamSpec],
    params: &[Vec<f32>],
    step: u64,
) -> Result<()> {
    assert_eq!(specs.len(), params.len());
    let mut tensors = Vec::new();
    let mut offset = 0usize;
    for (s, p) in specs.iter().zip(params) {
        if s.numel() != p.len() {
            bail!("{}: spec {} elems, data {}", s.name, s.numel(), p.len());
        }
        tensors.push(obj(vec![
            ("name", Json::Str(s.name.clone())),
            ("shape", Json::Arr(s.shape.iter().map(|&d| Json::from(d)).collect())),
            ("offset", Json::from(offset)),
        ]));
        offset += p.len();
    }
    // v1 bug preserved on purpose: per-tensor checksums folded with an
    // order-invariant sum. Readers treat this as weak verification.
    let total_sum: u64 = params.iter().map(|p| checksum_v1(p)).fold(0, u64::wrapping_add);
    let header = obj(vec![
        ("format", Json::Str(FORMAT_V1.into())),
        ("step", Json::from(step as usize)),
        ("total_elems", Json::from(offset)),
        ("checksum", Json::Str(format!("{total_sum:016x}"))),
        ("tensors", Json::Arr(tensors)),
    ])
    .dump();

    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for p in params {
        write_f32s(&mut f, p)?;
    }
    Ok(())
}

fn read_header(f: &mut std::fs::File, path: &Path) -> Result<Json> {
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    if hlen > 64 << 20 {
        bail!("implausible header length {hlen}");
    }
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    Json::parse(std::str::from_utf8(&hbuf)?)
        .map_err(|e| anyhow::anyhow!("header parse ({path:?}): {e}"))
}

fn read_params(
    f: &mut std::fs::File,
    header: &Json,
    specs: &[ParamSpec],
    stream: &mut ChecksumStream,
) -> Result<Vec<Vec<f32>>> {
    let tensors = header
        .get("tensors")
        .and_then(Json::as_arr)
        .context("header missing tensors")?;
    if tensors.len() != specs.len() {
        bail!("checkpoint has {} tensors, model needs {}", tensors.len(), specs.len());
    }
    let mut params = Vec::with_capacity(specs.len());
    for (t, s) in tensors.iter().zip(specs) {
        let name = t.get("name").and_then(Json::as_str).unwrap_or("");
        if name != s.name {
            bail!("tensor order mismatch: checkpoint {name:?} vs model {:?}", s.name);
        }
        let shape: Vec<usize> = t
            .get("shape")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        if shape != s.shape {
            bail!("{name}: shape {shape:?} vs model {:?}", s.shape);
        }
        let data = read_f32s(f, s.numel())?;
        stream.update(&data);
        params.push(data);
    }
    Ok(params)
}

/// Restore a checkpoint (v2 or, with a warning, legacy v1). Validates
/// names, shapes and checksum against `specs`.
pub fn load(path: impl AsRef<Path>, specs: &[ParamSpec]) -> Result<TrainState> {
    let path = path.as_ref();
    let mut f =
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let header = read_header(&mut f, path)?;
    let format = header.get("format").and_then(Json::as_str).unwrap_or("");
    match format {
        FORMAT_V2 => load_v2(&mut f, &header, specs),
        FORMAT_V1 => {
            eprintln!(
                "warning: {path:?} is a legacy v1 checkpoint (no optimizer/RNG state, \
                 order-invariant checksum); resume will NOT be bit-identical"
            );
            load_v1(&mut f, &header, specs)
        }
        other => bail!("unknown checkpoint format {other:?}"),
    }
}

fn load_v2(f: &mut std::fs::File, header: &Json, specs: &[ParamSpec]) -> Result<TrainState> {
    let step = header.get("step").and_then(Json::as_usize).unwrap_or(0) as u64;
    let world = header.get("world").and_then(Json::as_usize).unwrap_or(0);
    let mut stream = ChecksumStream::new();
    let params = read_params(f, header, specs, &mut stream)?;

    let opt_h = header.get("opt").context("v2 header missing opt")?;
    let kind = opt_h.get("kind").and_then(Json::as_str).unwrap_or("none").to_string();
    let adam_step = opt_h.get("adam_step").and_then(Json::as_usize).unwrap_or(0) as u64;
    let mut slots = Vec::new();
    for slot in opt_h.get("slots").and_then(Json::as_arr).unwrap_or(&[]) {
        let name = slot.get("name").and_then(Json::as_str).unwrap_or("").to_string();
        let len = slot.get("len").and_then(Json::as_usize).context("slot missing len")?;
        let data = read_f32s(f, len)?;
        stream.update(&data);
        slots.push((name, data));
    }

    let want = header.get("checksum").and_then(Json::as_str).unwrap_or("");
    if format!("{:016x}", stream.total()) != want {
        bail!("checksum mismatch: corrupt checkpoint");
    }

    let mut rng = Vec::new();
    for r in header.get("rng").and_then(Json::as_arr).unwrap_or(&[]) {
        rng.push(rng_state_from_json(r)?);
    }
    Ok(TrainState {
        step,
        params,
        opt: OptSnapshot { kind, adam_step, slots },
        rng,
        world,
    })
}

fn load_v1(f: &mut std::fs::File, header: &Json, specs: &[ParamSpec]) -> Result<TrainState> {
    let step = header.get("step").and_then(Json::as_usize).unwrap_or(0) as u64;
    let mut stream = ChecksumStream::new();
    let params = read_params(f, header, specs, &mut stream)?;
    let want = header.get("checksum").and_then(Json::as_str).unwrap_or("");
    // v1's documented (buggy) verification: order-invariant fold.
    let got: u64 = params.iter().map(|p| checksum_v1(p)).fold(0, u64::wrapping_add);
    if format!("{got:016x}") != want {
        bail!("checksum mismatch: corrupt checkpoint");
    }
    Ok(TrainState {
        step,
        params,
        opt: OptSnapshot::none(),
        rng: Vec::new(),
        world: 0,
    })
}

/// Read only the step counter from a checkpoint header (either format).
/// Cheap: never touches the payload.
pub fn peek_step(path: impl AsRef<Path>) -> Result<u64> {
    let path = path.as_ref();
    let mut f =
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let header = read_header(&mut f, path)?;
    let format = header.get("format").and_then(Json::as_str).unwrap_or("");
    if format != FORMAT_V1 && format != FORMAT_V2 {
        bail!("unknown checkpoint format {format:?}");
    }
    Ok(header.get("step").and_then(Json::as_usize).unwrap_or(0) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "embed".into(), shape: vec![16, 8] },
            ParamSpec { name: "layer0.w".into(), shape: vec![8, 8] },
            ParamSpec { name: "bias".into(), shape: vec![8] },
        ]
    }

    fn make_params(seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        specs().iter().map(|s| rng.normal_vec(s.numel(), 1.0)).collect()
    }

    fn make_state(seed: u64, step: u64) -> TrainState {
        let params = make_params(seed);
        let total: usize = params.iter().map(Vec::len).sum();
        let mut rng = Rng::new(seed ^ 0xabcd);
        let m = rng.normal_vec(total, 0.1);
        let v = rng.normal_vec(total, 0.01);
        let mut r0 = Rng::new(77);
        r0.normal(); // leave a Box-Muller spare cached
        TrainState {
            step,
            params,
            opt: OptSnapshot {
                kind: "adam".into(),
                adam_step: step,
                slots: vec![("m".into(), m), ("v".into(), v)],
            },
            rng: vec![r0.state(), Rng::new(78).state()],
            world: 2,
        }
    }

    #[test]
    fn round_trip_exact_with_opt_and_rng() {
        let dir = std::env::temp_dir().join("tpt_ckpt_rt_v2.bin");
        let state = make_state(1, 42);
        save(&dir, &specs(), &state).unwrap();
        let restored = load(&dir, &specs()).unwrap();
        assert_eq!(restored, state); // bit-exact, incl. opt slots + rng
        assert_eq!(peek_step(&dir).unwrap(), 42);
    }

    #[test]
    fn v1_still_loads_without_opt_state() {
        let dir = std::env::temp_dir().join("tpt_ckpt_v1_compat.bin");
        let params = make_params(9);
        save_v1(&dir, &specs(), &params, 17).unwrap();
        let st = load(&dir, &specs()).unwrap();
        assert_eq!(st.step, 17);
        assert_eq!(st.params, params);
        assert_eq!(st.opt, OptSnapshot::none());
        assert!(st.rng.is_empty());
        assert_eq!(st.world, 0);
        assert_eq!(peek_step(&dir).unwrap(), 17);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join("tpt_ckpt_shape.bin");
        save(&dir, &specs(), &make_state(2, 0)).unwrap();
        let mut wrong = specs();
        wrong[1].shape = vec![4, 16];
        assert!(load(&dir, &wrong).is_err());
    }

    #[test]
    fn name_mismatch_rejected() {
        let dir = std::env::temp_dir().join("tpt_ckpt_name.bin");
        save(&dir, &specs(), &make_state(3, 0)).unwrap();
        let mut wrong = specs();
        wrong[0].name = "other".into();
        assert!(load(&dir, &wrong).is_err());
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("tpt_ckpt_corrupt.bin");
        save(&dir, &specs(), &make_state(4, 0)).unwrap();
        // Flip a payload byte near the end.
        let mut bytes = std::fs::read(&dir).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&dir, bytes).unwrap();
        let err = load(&dir, &specs()).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
    }

    /// The v1 checksum folded per-tensor sums order-invariantly, so
    /// swapping two same-shaped tensors' payloads passed verification.
    /// v2 chains the checksum across the stream and must reject the swap.
    #[test]
    fn swapped_same_shape_tensors_rejected_by_v2() {
        let two = vec![
            ParamSpec { name: "a".into(), shape: vec![8, 8] },
            ParamSpec { name: "b".into(), shape: vec![8, 8] },
        ];
        let mut rng = Rng::new(5);
        let params = vec![rng.normal_vec(64, 1.0), rng.normal_vec(64, 1.0)];

        let swap_payload = |path: &std::path::Path| {
            let mut bytes = std::fs::read(path).unwrap();
            let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
            let h = 8 + hlen;
            let first = bytes[h..h + 256].to_vec();
            let second = bytes[h + 256..h + 512].to_vec();
            bytes[h..h + 256].copy_from_slice(&second);
            bytes[h + 256..h + 512].copy_from_slice(&first);
            std::fs::write(path, bytes).unwrap();
        };

        // v2 rejects the swap.
        let p2 = std::env::temp_dir().join("tpt_ckpt_swap_v2.bin");
        let state = TrainState {
            step: 0,
            params: params.clone(),
            opt: OptSnapshot::none(),
            rng: Vec::new(),
            world: 1,
        };
        save(&p2, &two, &state).unwrap();
        swap_payload(&p2);
        let err = load(&p2, &two).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");

        // Pin the original bug: v1 accepts the same swap (wrong data!).
        let p1 = std::env::temp_dir().join("tpt_ckpt_swap_v1.bin");
        save_v1(&p1, &two, &params, 0).unwrap();
        swap_payload(&p1);
        let st = load(&p1, &two).unwrap();
        assert_eq!(st.params[0], params[1], "v1 swap silently accepted");
    }

    #[test]
    fn checksum_stream_is_order_sensitive() {
        let xs = vec![1.0f32, 2.0, 3.0];
        let ys = vec![4.0f32, 5.0];
        let mut ab = ChecksumStream::new();
        ab.update(&xs);
        ab.update(&ys);
        let mut ba = ChecksumStream::new();
        ba.update(&ys);
        ba.update(&xs);
        assert_ne!(ab.total(), ba.total());
        // But the v1 fold of per-chunk sums is NOT order sensitive.
        let fold = |a: &[f32], b: &[f32]| {
            [a, b].iter().map(|c| checksum_v1(c)).fold(0u64, u64::wrapping_add)
        };
        assert_eq!(fold(&xs, &ys), fold(&ys, &xs));
    }

    #[test]
    fn missing_file_is_error() {
        assert!(load("/nonexistent/ckpt.bin", &specs()).is_err());
        assert!(peek_step("/nonexistent/ckpt.bin").is_err());
    }
}
