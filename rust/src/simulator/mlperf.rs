//! End-to-end time-to-train simulation (paper Fig. 9 "MLPerf-0.6 benchmark
//! seconds" + the §2 optimization ablations).
//!
//! benchmark_seconds = train_steps x step_time + evals x eval_time + infra,
//! with every §2 technique toggleable so the benches can ablate:
//! * 2-D vs 1-D gradient summation, pipelined vs serial gathers,
//! * weight-update sharding on/off,
//! * distributed in-loop eval vs side-card eval,
//! * spatial partitioning (per the model's layout policy).

use crate::devicesim::{step_model, weight_update_cost, Device, TPU_V3};
use crate::models::registry::{Layout, ModelProfile};
use crate::netsim::{ArAlgo, CostModel, GradSumModel, NetParams, Torus};
use crate::spatial::plan::{maskrcnn_stage1_layers, plan, ssd_layers};

/// Optimization toggles (all true = the Google submission config).
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    pub gradsum_2d: bool,
    pub gradsum_pipelined: bool,
    pub weight_update_sharding: bool,
    pub distributed_eval: bool,
    pub spatial_partitioning: bool,
    /// Override the convergence-curve epochs (Table 1 optimizer study).
    pub epochs_override: Option<f64>,
    /// Override the submission layout policy (scenario sweeps with a fixed
    /// global batch use this for strong-scaling studies).
    pub layout_override: Option<Layout>,
}

impl Default for SimOptions {
    fn default() -> SimOptions {
        SimOptions {
            gradsum_2d: true,
            gradsum_pipelined: true,
            weight_update_sharding: true,
            distributed_eval: true,
            spatial_partitioning: true,
            epochs_override: None,
            layout_override: None,
        }
    }
}

/// Simulation output for one (model, core-count) point.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub model: &'static str,
    pub cores: usize,
    pub layout: Layout,
    pub epochs: f64,
    pub steps: f64,
    pub step_seconds: f64,
    pub compute_seconds: f64,
    pub gradsum_seconds: f64,
    pub update_seconds: f64,
    pub eval_seconds: f64,
    pub infra_seconds: f64,
    /// The headline: MLPerf benchmark seconds (init excluded).
    pub benchmark_seconds: f64,
    pub converged: bool,
    /// Spatial-partition speedup of the chosen mp degree (1.0 = pure DP).
    pub spatial_speedup: f64,
}

/// Fixed infrastructure overhead per eval in the in-loop scheme (loop
/// switch) and per eval in the side-card scheme (checkpoint transfer) —
/// the "infrastructure overheads [that] dominate" (§3 Transformer).
const INLOOP_EVAL_OVERHEAD_S: f64 = 0.35;
const SIDECARD_EVAL_OVERHEAD_S: f64 = 6.0;
/// Cores of the fixed side-card eval slice in the baseline scheme.
const SIDECARD_CORES: f64 = 16.0;

/// Spatial-partitioning speedup for a model at partition degree mp
/// (public: the scenario sweep engine and the Fig. 10 bench reuse it).
pub fn spatial_speedup(model: &ModelProfile, mp: usize) -> f64 {
    if mp <= 1 {
        return 1.0;
    }
    let dev = TPU_V3;
    // Halo cost uses a small local neighborhood model.
    let net = CostModel::new(Torus::new(2, 2), NetParams::default());
    let layers = match model.name {
        "ssd" => ssd_layers(),
        "maskrcnn" => maskrcnn_stage1_layers(),
        _ => return 1.0,
    };
    plan(&layers, mp, &dev, &net).speedup()
}

/// Simulate one model at `cores` TPU-v3 cores (2 cores/chip).
pub fn simulate(model: &ModelProfile, cores: usize, opts: &SimOptions) -> SimResult {
    let chips = (cores / 2).max(1);
    let net = CostModel::new(Torus::for_chips(chips.next_power_of_two()), NetParams::default());
    let dev: Device = TPU_V3;

    let mut layout = model.layout(cores);
    if !opts.spatial_partitioning {
        // Without MP the model cannot exceed its batch-limited replica
        // count; surplus cores idle.
        let replicas = (cores).min(model.max_batch);
        layout = Layout { cores, mp: 1, replicas, global_batch: layout.global_batch };
    }
    if let Some(l) = opts.layout_override {
        layout = l;
    }

    let epochs = opts
        .epochs_override
        .or_else(|| model.epochs.epochs(layout.global_batch))
        .unwrap_or(f64::INFINITY);
    let converged = epochs.is_finite();
    let steps = (model.train_examples as f64 / layout.global_batch as f64).ceil() * epochs;

    // ---- step time -------------------------------------------------------
    let examples_per_replica = layout.per_replica_batch();
    let mp_speed = if opts.spatial_partitioning { spatial_speedup(model, layout.mp) } else { 1.0 };
    let base = step_model(
        &dev,
        &net,
        model.fwd_flops_per_example,
        model.hbm_bytes_per_example,
        examples_per_replica,
        model.util_units_per_example,
        model.params,
        model.optimizer.bytes_per_param(),
        false,
    );
    // Model parallelism accelerates the per-replica compute.
    let compute = base.compute / mp_speed;

    // Gradient summation: schedule choice.
    let algo = if opts.gradsum_2d { ArAlgo::Torus2D } else { ArAlgo::Ring1D };
    let gs = GradSumModel { cost: &net, algo };
    let tensors = model.gradient_bytes();
    let gradsum =
        if opts.gradsum_pipelined { gs.pipelined(&tensors) } else { gs.serial(&tensors) };

    // Weight update: replicated vs sharded.
    let uc = weight_update_cost(&dev, &net, model.params, model.optimizer.bytes_per_param(),
                                cores);
    let update = if opts.weight_update_sharding { uc.sharded.min(uc.replicated) }
                 else { uc.replicated };

    let step_seconds = compute + gradsum + update;
    let train_seconds = steps * step_seconds;

    // ---- evaluation ------------------------------------------------------
    let n_evals = (epochs / model.eval_interval_epochs).ceil().max(1.0);
    let eval_flops = model.eval_examples as f64 * model.fwd_flops_per_example;
    let eval_one = if opts.distributed_eval {
        // All cores share the eval work (padding overhead ≤ one stride).
        eval_flops / (cores as f64 * dev.peak_flops * dev.mxu_efficiency)
            + INLOOP_EVAL_OVERHEAD_S
    } else {
        // Side-card: fixed small slice + checkpoint shipping, serialized
        // into the convergence path (the Amdahl bottleneck of §2).
        eval_flops / (SIDECARD_CORES * dev.peak_flops * dev.mxu_efficiency)
            + SIDECARD_EVAL_OVERHEAD_S
    };
    let eval_seconds = if converged { n_evals * eval_one } else { 0.0 };

    // Fixed per-run infrastructure inside the measured window.
    let infra_seconds = 3.0;

    let benchmark_seconds = if converged {
        train_seconds + eval_seconds + infra_seconds
    } else {
        f64::INFINITY
    };

    SimResult {
        model: model.name,
        cores,
        layout,
        epochs,
        steps,
        step_seconds,
        compute_seconds: compute,
        gradsum_seconds: gradsum,
        update_seconds: update,
        eval_seconds,
        infra_seconds,
        benchmark_seconds,
        converged,
        spatial_speedup: mp_speed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::registry::all_models;

    fn m(name: &str) -> ModelProfile {
        crate::models::registry::model(name).unwrap()
    }

    #[test]
    fn resnet_pod_benchmark_seconds_order_of_magnitude() {
        // Paper Table 1 / Fig. 9: ResNet-50 at 2048 cores ≈ 67-77 s.
        let r = simulate(&m("resnet50"), 2048, &SimOptions::default());
        assert!(r.converged);
        assert!(
            (30.0..200.0).contains(&r.benchmark_seconds),
            "resnet50@2048: {:.1}s",
            r.benchmark_seconds
        );
    }

    #[test]
    fn all_optimizations_help_at_pod_scale() {
        for model in all_models() {
            let cores = model.max_useful_cores().min(2048);
            let full = simulate(&model, cores, &SimOptions::default());
            if !full.converged {
                continue;
            }
            for (label, opts) in [
                ("serial gradsum",
                 SimOptions { gradsum_pipelined: false, ..Default::default() }),
                ("1-D gradsum", SimOptions { gradsum_2d: false, ..Default::default() }),
                ("no WUS",
                 SimOptions { weight_update_sharding: false, ..Default::default() }),
                ("side-card eval",
                 SimOptions { distributed_eval: false, ..Default::default() }),
            ] {
                let ablated = simulate(&model, cores, &opts);
                assert!(
                    ablated.benchmark_seconds >= full.benchmark_seconds - 1e-9,
                    "{} @ {cores}: {label} should not be faster ({} vs {})",
                    model.name,
                    ablated.benchmark_seconds,
                    full.benchmark_seconds
                );
            }
        }
    }

    #[test]
    fn strong_scaling_monotone_until_model_limit() {
        // More cores → less time (the paper's headline), within each
        // model's useful range.
        for model in all_models() {
            let mut prev = f64::INFINITY;
            for cores in [64, 128, 256, 512, 1024, 2048] {
                if cores > model.max_useful_cores() {
                    break;
                }
                let r = simulate(&model, cores, &SimOptions::default());
                if !r.converged {
                    continue;
                }
                assert!(
                    r.benchmark_seconds < prev * 1.05,
                    "{} @ {cores}: {:.1}s vs prev {:.1}s",
                    model.name,
                    r.benchmark_seconds,
                    prev
                );
                prev = r.benchmark_seconds;
            }
        }
    }

    #[test]
    fn scaling_is_sublinear_at_the_far_end() {
        // Fig. 9's diminishing returns: 2x cores buys <2x speedup at pod
        // scale (epochs grow with batch + fixed overheads).
        let a = simulate(&m("resnet50"), 1024, &SimOptions::default());
        let b = simulate(&m("resnet50"), 2048, &SimOptions::default());
        let speedup = a.benchmark_seconds / b.benchmark_seconds;
        assert!(speedup > 1.0 && speedup < 2.0, "speedup {speedup}");
    }

    #[test]
    fn maskrcnn_dnf_past_its_batch_wall_without_mp() {
        let model = m("maskrcnn");
        let no_mp = SimOptions { spatial_partitioning: false, ..Default::default() };
        let with_mp = simulate(&model, 256, &SimOptions::default());
        let without = simulate(&model, 256, &no_mp);
        assert!(with_mp.converged);
        // Without MP the extra cores idle: slower than with MP.
        assert!(without.benchmark_seconds > with_mp.benchmark_seconds);
    }

    #[test]
    fn transformer_eval_overhead_dominates_at_scale_without_distribution() {
        // §3: "the eval and infrastructure overheads dominate the
        // end-to-end convergence time" — visible as the side-card ablation
        // hurting Transformer badly at pod scale.
        let model = m("transformer");
        let full = simulate(&model, 2048, &SimOptions::default());
        let side = simulate(
            &model,
            2048,
            &SimOptions { distributed_eval: false, ..Default::default() },
        );
        let penalty = side.benchmark_seconds / full.benchmark_seconds;
        assert!(penalty > 1.10, "side-card eval penalty {penalty}");
    }

    #[test]
    fn update_share_shrinks_with_wus() {
        let model = m("transformer");
        let full = simulate(&model, 2048, &SimOptions::default());
        let no_wus = simulate(
            &model,
            2048,
            &SimOptions { weight_update_sharding: false, ..Default::default() },
        );
        assert!(full.update_seconds < no_wus.update_seconds * 0.6);
    }
}
