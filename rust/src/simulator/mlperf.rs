//! End-to-end time-to-train simulation (paper Fig. 9 "MLPerf-0.6 benchmark
//! seconds" + the §2 optimization ablations).
//!
//! benchmark_seconds = train_steps x step_time + evals x eval_time + infra,
//! with every §2 technique toggleable so the benches can ablate:
//! * 2-D vs 1-D gradient summation, pipelined vs serial gathers,
//! * weight-update sharding on/off,
//! * distributed in-loop eval vs side-card eval,
//! * spatial partitioning (per the model's layout policy).
//!
//! All pricing goes through the participation-aware [`crate::costs`]
//! layer: a [`PodLayout`] derives the participating core set from the
//! layout, and a [`CostStack`] of [`crate::costs::StepCostModel`]s prices
//! each phase over its own group — surplus cores (fixed-batch strong
//! scaling, the no-spatial ablation) no longer shrink gradsum, weight
//! update or eval time.

use crate::costs::{spatial_factors, CostConfig, CostStack, Phase, PhaseCost, PodLayout};
use crate::devicesim::{Device, TPU_V3};
use crate::models::registry::{Layout, ModelProfile};
use crate::netsim::{ArAlgo, CrossPodStrategy, PodSpec};

/// Optimization toggles (all true = the Google submission config).
///
/// Construct with the builder — [`SimOptions::submission()`] is the
/// all-optimizations default, and each method peels one technique off or
/// extends the topology:
///
/// ```ignore
/// let opts = SimOptions::submission().without_wus().pods(4, 0.25);
/// ```
///
/// Plain `Default` construction and direct field access keep working;
/// the builder only exists so adding fields (like the multi-pod spec)
/// doesn't churn every call site again.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    pub gradsum_2d: bool,
    pub gradsum_pipelined: bool,
    pub weight_update_sharding: bool,
    pub distributed_eval: bool,
    pub spatial_partitioning: bool,
    /// Override the convergence-curve epochs (Table 1 optimizer study).
    pub epochs_override: Option<f64>,
    /// Override the submission layout policy (scenario sweeps with a fixed
    /// global batch use this for strong-scaling studies).
    pub layout_override: Option<Layout>,
    /// Live-calibrated compute coefficient (`sweep --costs-from`): price
    /// compute with [`Device::with_compute_gflops`] instead of the TPU-v3
    /// datasheet roofline. `None` = the stock [`TPU_V3`] device.
    pub compute_gflops: Option<f64>,
    /// Multi-pod topology (pod count, inter-pod bandwidth ratio, cross-pod
    /// gradsum strategy). The default single-pod spec prices bit-identically
    /// to the pre-hierarchy simulator.
    pub pods: PodSpec,
}

impl Default for SimOptions {
    fn default() -> SimOptions {
        SimOptions {
            gradsum_2d: true,
            gradsum_pipelined: true,
            weight_update_sharding: true,
            distributed_eval: true,
            spatial_partitioning: true,
            epochs_override: None,
            layout_override: None,
            compute_gflops: None,
            pods: PodSpec::default(),
        }
    }
}

impl SimOptions {
    /// Builder entry point: the Google submission config (all §2
    /// optimizations on, single pod).
    pub fn submission() -> SimOptions {
        SimOptions::default()
    }

    /// Disable weight-update sharding.
    pub fn without_wus(mut self) -> SimOptions {
        self.weight_update_sharding = false;
        self
    }

    /// Disable spatial partitioning (pure data parallelism).
    pub fn without_spatial(mut self) -> SimOptions {
        self.spatial_partitioning = false;
        self
    }

    /// Side-card eval instead of distributed in-loop eval.
    pub fn without_distributed_eval(mut self) -> SimOptions {
        self.distributed_eval = false;
        self
    }

    /// Serial fused gradient summation instead of the pipelined schedule.
    pub fn serial_gradsum(mut self) -> SimOptions {
        self.gradsum_pipelined = false;
        self
    }

    /// 1-D ring gradient summation instead of the 2-D torus schedule.
    pub fn ring_gradsum(mut self) -> SimOptions {
        self.gradsum_2d = false;
        self
    }

    /// Span `pods` pods joined by links at `inter_pod_ratio` of the torus
    /// link bandwidth (keeps the current cross-pod strategy).
    pub fn pods(mut self, pods: usize, inter_pod_ratio: f64) -> SimOptions {
        self.pods = PodSpec { pods, inter_pod_ratio, ..self.pods };
        self
    }

    /// Pick the cross-pod gradient-summation strategy.
    pub fn cross_pod(mut self, strategy: CrossPodStrategy) -> SimOptions {
        self.pods.strategy = strategy;
        self
    }

    /// Override the convergence-curve epochs.
    pub fn epochs(mut self, epochs: f64) -> SimOptions {
        self.epochs_override = Some(epochs);
        self
    }

    /// Override the submission layout policy.
    pub fn layout(mut self, layout: Layout) -> SimOptions {
        self.layout_override = Some(layout);
        self
    }

    /// Price compute at a live-calibrated GFLOP/s coefficient.
    pub fn with_compute_gflops(mut self, gflops: f64) -> SimOptions {
        self.compute_gflops = Some(gflops);
        self
    }

    /// The cost-layer configuration these toggles select.
    pub fn cost_config(&self) -> CostConfig {
        CostConfig {
            dev: match self.compute_gflops {
                Some(g) => Device::with_compute_gflops(g),
                None => TPU_V3,
            },
            gradsum_algo: if self.gradsum_2d { ArAlgo::Torus2D } else { ArAlgo::Ring1D },
            gradsum_pipelined: self.gradsum_pipelined,
            weight_update_sharding: self.weight_update_sharding,
            distributed_eval: self.distributed_eval,
            spatial_partitioning: self.spatial_partitioning,
            ..CostConfig::default()
        }
    }
}

/// Simulation output for one (model, core-count) point.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub model: &'static str,
    pub cores: usize,
    pub layout: Layout,
    /// Cores that hold a replica shard (surplus cores idle).
    pub participating_cores: usize,
    pub surplus_cores: usize,
    pub epochs: f64,
    pub steps: f64,
    pub step_seconds: f64,
    pub compute_seconds: f64,
    /// Spatial-partition halo + distributed-BN communication per step.
    pub halo_seconds: f64,
    pub gradsum_seconds: f64,
    pub update_seconds: f64,
    pub eval_seconds: f64,
    pub infra_seconds: f64,
    /// The headline: MLPerf benchmark seconds (init excluded).
    pub benchmark_seconds: f64,
    pub converged: bool,
    /// Spatial-partition speedup of the chosen mp degree (1.0 = pure DP).
    pub spatial_speedup: f64,
    /// The full per-phase price list (per-group attribution).
    pub phases: Vec<PhaseCost>,
}

impl SimResult {
    /// Cores the given phase was priced over (0 if the phase is absent).
    pub fn phase_cores(&self, phase: Phase) -> usize {
        self.phases.iter().find(|c| c.phase == phase).map(|c| c.cores).unwrap_or(0)
    }
}

/// Spatial-partitioning speedup for a model at partition degree mp
/// (public: the scenario sweep engine and the Fig. 10 bench reuse it).
pub fn spatial_speedup(model: &ModelProfile, mp: usize) -> f64 {
    spatial_factors(model, mp, &TPU_V3).speedup
}

/// Simulate one model at `cores` TPU-v3 cores (2 cores/chip).
pub fn simulate(model: &ModelProfile, cores: usize, opts: &SimOptions) -> SimResult {
    let mut layout = model.layout(cores);
    if !opts.spatial_partitioning {
        // Without MP the model cannot exceed its batch-limited replica
        // count; surplus cores idle.
        let replicas = (cores).min(model.max_batch);
        layout = Layout { cores, mp: 1, replicas, global_batch: layout.global_batch };
    }
    if let Some(l) = opts.layout_override {
        layout = l;
    }
    let pod = PodLayout::from_layout(&layout).with_pods(opts.pods);

    let epochs = opts
        .epochs_override
        .or_else(|| model.epochs.epochs(layout.global_batch))
        .unwrap_or(f64::INFINITY);
    let converged = epochs.is_finite();
    let steps = (model.train_examples as f64 / layout.global_batch as f64).ceil() * epochs;

    // ---- the single pricing path: the §2 cost stack ----------------------
    let stack = CostStack::standard(&opts.cost_config());
    let bd = stack.breakdown(model, &pod);
    let step_seconds = bd.step_seconds();
    let train_seconds = steps * step_seconds;

    let n_evals = (epochs / model.eval_interval_epochs).ceil().max(1.0);
    let eval_seconds = if converged { n_evals * bd.seconds(Phase::Eval) } else { 0.0 };
    let infra_seconds = bd.seconds(Phase::Infra);

    let benchmark_seconds = if converged {
        train_seconds + eval_seconds + infra_seconds
    } else {
        f64::INFINITY
    };

    let mp_speed = if opts.spatial_partitioning {
        spatial_factors(model, layout.mp, &TPU_V3).speedup
    } else {
        1.0
    };

    SimResult {
        model: model.name,
        cores,
        layout,
        participating_cores: pod.participating_cores(),
        surplus_cores: pod.surplus_cores(),
        epochs,
        steps,
        step_seconds,
        compute_seconds: bd.seconds(Phase::Compute),
        halo_seconds: bd.seconds(Phase::Halo),
        gradsum_seconds: bd.seconds(Phase::GradSum),
        update_seconds: bd.seconds(Phase::WeightUpdate),
        eval_seconds,
        infra_seconds,
        benchmark_seconds,
        converged,
        spatial_speedup: mp_speed,
        phases: bd.phases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::registry::all_models;

    fn m(name: &str) -> ModelProfile {
        crate::models::registry::model(name).unwrap()
    }

    #[test]
    fn resnet_pod_benchmark_seconds_order_of_magnitude() {
        // Paper Table 1 / Fig. 9: ResNet-50 at 2048 cores ≈ 67-77 s.
        let r = simulate(&m("resnet50"), 2048, &SimOptions::default());
        assert!(r.converged);
        assert!(
            (30.0..200.0).contains(&r.benchmark_seconds),
            "resnet50@2048: {:.1}s",
            r.benchmark_seconds
        );
    }

    #[test]
    fn step_decomposition_sums_to_step_seconds() {
        for model in all_models() {
            let cores = model.max_useful_cores().min(2048);
            let r = simulate(&model, cores, &SimOptions::default());
            let sum =
                r.compute_seconds + r.halo_seconds + r.gradsum_seconds + r.update_seconds;
            assert!(
                (r.step_seconds - sum).abs() < 1e-12,
                "{}: step {} != phase sum {sum}",
                model.name,
                r.step_seconds
            );
        }
    }

    #[test]
    fn all_optimizations_help_at_pod_scale() {
        for model in all_models() {
            let cores = model.max_useful_cores().min(2048);
            let full = simulate(&model, cores, &SimOptions::default());
            if !full.converged {
                continue;
            }
            for (label, opts) in [
                ("serial gradsum",
                 SimOptions { gradsum_pipelined: false, ..Default::default() }),
                ("1-D gradsum", SimOptions { gradsum_2d: false, ..Default::default() }),
                ("no WUS",
                 SimOptions { weight_update_sharding: false, ..Default::default() }),
                ("side-card eval",
                 SimOptions { distributed_eval: false, ..Default::default() }),
            ] {
                let ablated = simulate(&model, cores, &opts);
                assert!(
                    ablated.benchmark_seconds >= full.benchmark_seconds - 1e-9,
                    "{} @ {cores}: {label} should not be faster ({} vs {})",
                    model.name,
                    ablated.benchmark_seconds,
                    full.benchmark_seconds
                );
            }
        }
    }

    #[test]
    fn strong_scaling_monotone_until_model_limit() {
        // More cores → less time (the paper's headline), within each
        // model's useful range.
        for model in all_models() {
            let mut prev = f64::INFINITY;
            for cores in [64, 128, 256, 512, 1024, 2048] {
                if cores > model.max_useful_cores() {
                    break;
                }
                let r = simulate(&model, cores, &SimOptions::default());
                if !r.converged {
                    continue;
                }
                assert!(
                    r.benchmark_seconds < prev * 1.05,
                    "{} @ {cores}: {:.1}s vs prev {:.1}s",
                    model.name,
                    r.benchmark_seconds,
                    prev
                );
                prev = r.benchmark_seconds;
            }
        }
    }

    #[test]
    fn scaling_is_sublinear_at_the_far_end() {
        // Fig. 9's diminishing returns: 2x cores buys <2x speedup at pod
        // scale (epochs grow with batch + fixed overheads).
        let a = simulate(&m("resnet50"), 1024, &SimOptions::default());
        let b = simulate(&m("resnet50"), 2048, &SimOptions::default());
        let speedup = a.benchmark_seconds / b.benchmark_seconds;
        assert!(speedup > 1.0 && speedup < 2.0, "speedup {speedup}");
    }

    #[test]
    fn maskrcnn_dnf_past_its_batch_wall_without_mp() {
        let model = m("maskrcnn");
        let no_mp = SimOptions { spatial_partitioning: false, ..Default::default() };
        let with_mp = simulate(&model, 256, &SimOptions::default());
        let without = simulate(&model, 256, &no_mp);
        assert!(with_mp.converged);
        // Without MP the extra cores idle: slower than with MP.
        assert!(without.benchmark_seconds > with_mp.benchmark_seconds);
        assert!(without.surplus_cores > 0, "idle cores must be visible");
        assert_eq!(with_mp.surplus_cores, 0);
    }

    #[test]
    fn transformer_eval_overhead_dominates_at_scale_without_distribution() {
        // §3: "the eval and infrastructure overheads dominate the
        // end-to-end convergence time" — visible as the side-card ablation
        // hurting Transformer badly at pod scale.
        let model = m("transformer");
        let full = simulate(&model, 2048, &SimOptions::default());
        let side = simulate(
            &model,
            2048,
            &SimOptions { distributed_eval: false, ..Default::default() },
        );
        let penalty = side.benchmark_seconds / full.benchmark_seconds;
        assert!(penalty > 1.10, "side-card eval penalty {penalty}");
    }

    #[test]
    fn update_share_shrinks_with_wus() {
        let model = m("transformer");
        let full = simulate(&model, 2048, &SimOptions::default());
        let no_wus = simulate(
            &model,
            2048,
            &SimOptions { weight_update_sharding: false, ..Default::default() },
        );
        assert!(full.update_seconds < no_wus.update_seconds * 0.6);
    }

    #[test]
    fn surplus_cores_do_not_buy_time_under_fixed_batch() {
        // The tentpole regression guard in unit form: a fixed-batch layout
        // with 4x the cores (all idle) must price every phase identically.
        let model = m("resnet50");
        let fit = Layout { cores: 512, mp: 1, replicas: 512, global_batch: 8192 };
        let surplus = Layout { cores: 2048, ..fit };
        let a = simulate(
            &model,
            512,
            &SimOptions { layout_override: Some(fit), ..Default::default() },
        );
        let b = simulate(
            &model,
            2048,
            &SimOptions { layout_override: Some(surplus), ..Default::default() },
        );
        assert_eq!(a.step_seconds, b.step_seconds);
        assert_eq!(a.gradsum_seconds, b.gradsum_seconds);
        assert_eq!(a.update_seconds, b.update_seconds);
        assert_eq!(a.eval_seconds, b.eval_seconds);
        assert_eq!(a.benchmark_seconds, b.benchmark_seconds);
        assert_eq!(b.surplus_cores, 1536);
    }

    #[test]
    fn builder_matches_literal_construction() {
        let built = SimOptions::submission()
            .without_wus()
            .without_distributed_eval()
            .serial_gradsum()
            .ring_gradsum()
            .without_spatial();
        let literal = SimOptions {
            gradsum_2d: false,
            gradsum_pipelined: false,
            weight_update_sharding: false,
            distributed_eval: false,
            spatial_partitioning: false,
            ..Default::default()
        };
        let r_built = simulate(&m("resnet50"), 1024, &built);
        let r_literal = simulate(&m("resnet50"), 1024, &literal);
        assert_eq!(r_built.benchmark_seconds.to_bits(), r_literal.benchmark_seconds.to_bits());
        assert_eq!(built.pods, PodSpec::default());
    }

    #[test]
    fn multi_pod_options_price_the_hierarchy() {
        // pods(n, 1.0) collapses: bit-identical to the single-pod default.
        let single = simulate(&m("resnet50"), 2048, &SimOptions::default());
        let collapsed = simulate(&m("resnet50"), 2048, &SimOptions::submission().pods(2, 1.0));
        assert_eq!(single.benchmark_seconds.to_bits(), collapsed.benchmark_seconds.to_bits());
        // A real hierarchy reprices gradsum only; slower links cost more.
        let hier = simulate(&m("resnet50"), 2048, &SimOptions::submission().pods(2, 0.25));
        let slower = simulate(&m("resnet50"), 2048, &SimOptions::submission().pods(2, 0.05));
        assert_eq!(single.compute_seconds.to_bits(), hier.compute_seconds.to_bits());
        assert_eq!(single.update_seconds.to_bits(), hier.update_seconds.to_bits());
        assert!(slower.gradsum_seconds > hier.gradsum_seconds);
        let flat = simulate(
            &m("resnet50"),
            2048,
            &SimOptions::submission().pods(2, 0.25).cross_pod(CrossPodStrategy::FlatRing),
        );
        assert!(flat.gradsum_seconds > hier.gradsum_seconds);
    }

    #[test]
    fn halo_phase_appears_only_with_spatial_partitioning() {
        let ssd = m("ssd");
        let full = simulate(&ssd, 2048, &SimOptions::default());
        assert!(full.layout.mp > 1);
        assert!(full.halo_seconds > 0.0, "mp > 1 must pay halo");
        assert_eq!(full.phase_cores(Phase::Halo), full.layout.mp);
        let no_mp = simulate(
            &ssd,
            2048,
            &SimOptions { spatial_partitioning: false, ..Default::default() },
        );
        assert_eq!(no_mp.halo_seconds, 0.0);
    }
}
