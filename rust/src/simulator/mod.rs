//! MLPerf-0.6 pod simulator: combines the model inventories, the TPU-v3
//! roofline, the torus collective model and the convergence curves into
//! end-to-end time-to-train estimates — the generator behind Figs. 7-9 and
//! Table 1.

pub mod mlperf;

pub use mlperf::{simulate, spatial_speedup, SimOptions, SimResult};
