//! In-process "pod" fabric: N SPMD worker threads connected by mailbox
//! channels, playing the role of TPU cores on the torus.
//!
//! The collectives in `crate::collectives` run *real math on real buffers*
//! over this fabric — the same reduce-scatter/all-gather schedules the paper
//! runs on ICI links — so their correctness (and the pipelining structure of
//! the gradient summation) is exercised for real, while TPU-scale *timing*
//! comes from `crate::netsim`.
//!
//! Semantics are MPI-flavored: `send(to, tag, payload)` is async buffered,
//! `recv(from, tag)` blocks and stashes out-of-order arrivals, `try_recv`
//! polls (the pipelined gradsum packs gradient fragments while polling —
//! genuine overlap in a single thread).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::bf16::Bf16;

/// Message payload: f32 math values or bf16 wire format (halo exchanges of
/// activations may ride bf16 per the paper's mixed-precision rule; gradient
/// summation stays f32).
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    Bf16(Vec<Bf16>),
}

impl Payload {
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::F32(v) => v.len() * 4,
            Payload::Bf16(v) => v.len() * 2,
        }
    }

    /// Materialize as f32 (bf16 upconverts losslessly).
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Payload::F32(v) => v,
            Payload::Bf16(v) => v.into_iter().map(|b| b.to_f32()).collect(),
        }
    }
}

struct Envelope {
    from: usize,
    tag: u64,
    payload: Payload,
}

/// Shared traffic accounting across the fabric (wire-volume assertions).
#[derive(Default)]
pub struct Traffic {
    pub bytes_sent: AtomicU64,
    pub messages: AtomicU64,
}

/// One worker's communication endpoint. Move into the worker thread.
pub struct Endpoint {
    pub rank: usize,
    pub world: usize,
    senders: Vec<Sender<Envelope>>,
    inbox: Receiver<Envelope>,
    /// Out-of-order stash: (from, tag) → payloads in arrival order.
    stash: HashMap<(usize, u64), Vec<Payload>>,
    pub traffic: Arc<Traffic>,
    /// SPMD-deterministic tag allocator (see [`Endpoint::fresh_tags`]).
    tag_counter: u64,
}

impl Endpoint {
    /// Reserve a block of `n` tags. Because every rank executes the same
    /// SPMD program order, counters agree across ranks without any
    /// coordination — consecutive collectives can never alias even when one
    /// rank runs ahead.
    pub fn fresh_tags(&mut self, n: u64) -> u64 {
        let base = self.tag_counter;
        self.tag_counter += n;
        base
    }

    /// Asynchronous buffered send.
    pub fn send(&self, to: usize, tag: u64, payload: Payload) {
        self.traffic.bytes_sent.fetch_add(payload.wire_bytes() as u64, Ordering::Relaxed);
        self.traffic.messages.fetch_add(1, Ordering::Relaxed);
        self.senders[to]
            .send(Envelope { from: self.rank, tag, payload })
            .expect("fabric peer hung up");
    }

    /// Blocking matched receive.
    pub fn recv(&mut self, from: usize, tag: u64) -> Payload {
        if let Some(p) = self.take_stashed(from, tag) {
            return p;
        }
        loop {
            let env = self.inbox.recv().expect("fabric closed");
            if env.from == from && env.tag == tag {
                return env.payload;
            }
            self.stash.entry((env.from, env.tag)).or_default().push(env.payload);
        }
    }

    /// Non-blocking matched receive (used by the pipelined gradsum to
    /// overlap packing with network waits).
    pub fn try_recv(&mut self, from: usize, tag: u64) -> Option<Payload> {
        if let Some(p) = self.take_stashed(from, tag) {
            return Some(p);
        }
        loop {
            match self.inbox.try_recv() {
                Ok(env) => {
                    if env.from == from && env.tag == tag {
                        return Some(env.payload);
                    }
                    self.stash.entry((env.from, env.tag)).or_default().push(env.payload);
                }
                Err(TryRecvError::Empty) => return None,
                Err(TryRecvError::Disconnected) => panic!("fabric closed"),
            }
        }
    }

    fn take_stashed(&mut self, from: usize, tag: u64) -> Option<Payload> {
        if let Some(q) = self.stash.get_mut(&(from, tag)) {
            if !q.is_empty() {
                return Some(q.remove(0));
            }
        }
        None
    }
}

/// Build a fully-connected fabric of `world` endpoints.
pub fn fabric(world: usize) -> Vec<Endpoint> {
    let traffic = Arc::new(Traffic::default());
    let mut senders = Vec::with_capacity(world);
    let mut inboxes = Vec::with_capacity(world);
    for _ in 0..world {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(rx);
    }
    inboxes
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Endpoint {
            rank,
            world,
            senders: senders.clone(),
            inbox,
            stash: HashMap::new(),
            traffic: traffic.clone(),
            tag_counter: 0,
        })
        .collect()
}

/// Run one SPMD closure per endpoint on its own OS thread; returns the
/// per-rank results in rank order. Panics propagate.
pub fn run_spmd<T, F>(world: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&mut Endpoint) -> T + Sync,
{
    let endpoints = fabric(world);
    std::thread::scope(|scope| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|mut ep| {
                let f = &f;
                scope.spawn(move || f(&mut ep))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong() {
        let out = run_spmd(2, |ep| {
            if ep.rank == 0 {
                ep.send(1, 7, Payload::F32(vec![1.0, 2.0]));
                ep.recv(1, 8).into_f32()
            } else {
                let got = ep.recv(0, 7).into_f32();
                ep.send(0, 8, Payload::F32(vec![got[0] + got[1]]));
                got
            }
        });
        assert_eq!(out[0], vec![3.0]);
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let out = run_spmd(2, |ep| {
            if ep.rank == 0 {
                ep.send(1, 1, Payload::F32(vec![1.0]));
                ep.send(1, 2, Payload::F32(vec![2.0]));
                vec![]
            } else {
                // Receive in reverse tag order.
                let b = ep.recv(0, 2).into_f32();
                let a = ep.recv(0, 1).into_f32();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn same_tag_fifo_order() {
        let out = run_spmd(2, |ep| {
            if ep.rank == 0 {
                for i in 0..5 {
                    ep.send(1, 0, Payload::F32(vec![i as f32]));
                }
                vec![]
            } else {
                (0..5).map(|_| ep.recv(0, 0).into_f32()[0]).collect()
            }
        });
        assert_eq!(out[1], vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bf16_payload_halves_wire_bytes() {
        let eps = fabric(2);
        let t = eps[0].traffic.clone();
        let out = run_spmd(2, |ep| {
            if ep.rank == 0 {
                ep.send(1, 0, Payload::F32(vec![1.5; 100]));
                ep.send(1, 1, Payload::Bf16(vec![Bf16::from_f32(1.5); 100]));
                0.0
            } else {
                let a = ep.recv(0, 0).into_f32();
                let b = ep.recv(0, 1).into_f32();
                a[0] + b[0]
            }
        });
        assert_eq!(out[1], 3.0);
        drop(t); // traffic accounting checked in the dedicated test below
    }

    #[test]
    fn traffic_accounting() {
        let results = run_spmd(3, |ep| {
            if ep.rank == 0 {
                ep.send(1, 0, Payload::F32(vec![0.0; 10])); // 40 bytes
                ep.send(2, 0, Payload::Bf16(vec![Bf16::ZERO; 10])); // 20 bytes
            } else {
                ep.recv(0, 0);
            }
            ep.traffic.bytes_sent.load(Ordering::SeqCst)
        });
        // Total fabric traffic is global (shared counter): 60 bytes.
        assert!(results.iter().all(|&b| b == 60));
    }

    #[test]
    fn try_recv_polls() {
        let out = run_spmd(2, |ep| {
            if ep.rank == 0 {
                std::thread::sleep(std::time::Duration::from_millis(10));
                ep.send(1, 0, Payload::F32(vec![42.0]));
                0
            } else {
                let mut polls = 0u64;
                loop {
                    if let Some(p) = ep.try_recv(0, 0) {
                        assert_eq!(p.into_f32(), vec![42.0]);
                        break;
                    }
                    polls += 1;
                }
                polls
            }
        });
        assert!(out[1] > 0, "receiver should have polled while waiting");
    }
}
