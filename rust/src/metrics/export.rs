//! Trace serialization: JSON-lines (stable documented schema) and Chrome
//! trace-event format (loadable in Perfetto / `chrome://tracing`), plus the
//! auto-detecting parser the `trace summarize` CLI uses.
//!
//! ## JSON-lines schema (`tpu-pod-train-trace-v1`)
//!
//! First line is a header: `{"format":"tpu-pod-train-trace-v1"}`. Every
//! following line is one event object:
//!
//! ```text
//! {"kind":"span","name":"trainer.compute","track":0,"epoch":0,"seq":2,
//!  "t_s":0.00121,"dur_s":0.00034,"attrs":{"step":0}}
//! {"kind":"instant","name":"fault.death","track":1000,"epoch":0,"seq":3,
//!  "t_s":0.5,"attrs":{"chip":2,"step":5}}
//! {"kind":"counter","name":"report.steps","track":1000,"epoch":0,"seq":9,
//!  "t_s":0.9,"value":8}
//! ```
//!
//! `track`/`epoch`/`seq` are the deterministic ordering key (see
//! [`super::trace`]); `t_s`/`dur_s` are f64 seconds since the sink origin
//! and round-trip exactly (Rust's f64 `Display` is shortest-round-trip).
//! Spans carry `dur_s`, counters carry `value`, instants carry neither.
//!
//! ## Chrome trace-event format
//!
//! `{"traceEvents":[...],"displayTimeUnit":"ms"}` with `ph:"X"` complete
//! events (µs timestamps, fractional), `ph:"i"` thread-scoped instants,
//! `ph:"C"` counters, and `thread_name` metadata naming each track. The
//! ordering key is preserved in `args` as `trace_epoch`/`trace_seq` so the
//! format parses back losslessly (tid = track).
//!
//! [`Trace::write`] picks the format by extension — `.jsonl` writes
//! JSON-lines, anything else (the `--trace t.json` default) writes Chrome
//! format. [`Trace::parse`] detects the format from content.

use super::trace::{track_name, AttrVal, EventKind, Trace, TraceEvent};
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// JSONL header tag; bump on schema change.
pub const TRACE_FORMAT: &str = "tpu-pod-train-trace-v1";

/// Chrome `args` keys that carry the ordering key rather than user attrs.
const RESERVED_ARGS: [&str; 2] = ["trace_epoch", "trace_seq"];

fn attr_to_json(v: &AttrVal) -> Json {
    match v {
        AttrVal::Int(x) => Json::Num(*x as f64),
        AttrVal::Num(x) => Json::Num(*x),
        AttrVal::Str(s) => Json::Str(s.clone()),
    }
}

fn attr_from_json(v: &Json) -> Option<AttrVal> {
    match v {
        Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => Some(AttrVal::Int(*x as i64)),
        Json::Num(x) => Some(AttrVal::Num(*x)),
        Json::Str(s) => Some(AttrVal::Str(s.clone())),
        _ => None,
    }
}

fn attrs_obj(attrs: &[(String, AttrVal)]) -> Json {
    Json::Obj(attrs.iter().map(|(k, v)| (k.clone(), attr_to_json(v))).collect())
}

impl Trace {
    /// Serialize as JSON-lines (`tpu-pod-train-trace-v1`, schema above).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        out.push_str(&obj(vec![("format", Json::from(TRACE_FORMAT))]).dump());
        out.push('\n');
        for ev in &self.events {
            let mut pairs = vec![
                ("kind", Json::from(ev.kind.label())),
                ("name", Json::Str(ev.name.clone())),
                ("track", Json::from(ev.track as usize)),
                ("epoch", Json::from(ev.epoch as usize)),
                ("seq", Json::from(ev.seq as usize)),
                ("t_s", Json::Num(ev.t_s)),
            ];
            match ev.kind {
                EventKind::Span => pairs.push(("dur_s", Json::Num(ev.dur_s))),
                EventKind::Counter => pairs.push(("value", Json::Num(ev.dur_s))),
                EventKind::Instant => {}
            }
            if !ev.attrs.is_empty() {
                pairs.push(("attrs", attrs_obj(&ev.attrs)));
            }
            out.push_str(&obj(pairs).dump());
            out.push('\n');
        }
        out
    }

    /// Serialize in Chrome trace-event format (Perfetto, `chrome://tracing`).
    pub fn to_chrome(&self) -> String {
        let mut events: Vec<Json> = Vec::with_capacity(self.events.len() + 8);
        events.push(obj(vec![
            ("ph", Json::from("M")),
            ("pid", Json::from(0usize)),
            ("tid", Json::from(0usize)),
            ("name", Json::from("process_name")),
            ("args", obj(vec![("name", Json::from("tpu-pod-train"))])),
        ]));
        let tracks: std::collections::BTreeSet<u32> =
            self.events.iter().map(|e| e.track).collect();
        for t in &tracks {
            events.push(obj(vec![
                ("ph", Json::from("M")),
                ("pid", Json::from(0usize)),
                ("tid", Json::from(*t as usize)),
                ("name", Json::from("thread_name")),
                ("args", obj(vec![("name", Json::Str(track_name(*t)))])),
            ]));
        }
        for ev in &self.events {
            let cat = ev.name.split('.').next().unwrap_or("trace").to_string();
            let mut args: BTreeMap<String, Json> = ev
                .attrs
                .iter()
                .map(|(k, v)| (k.clone(), attr_to_json(v)))
                .collect();
            args.insert("trace_epoch".to_string(), Json::from(ev.epoch as usize));
            args.insert("trace_seq".to_string(), Json::from(ev.seq as usize));
            let mut pairs = vec![
                ("pid", Json::from(0usize)),
                ("tid", Json::from(ev.track as usize)),
                ("name", Json::Str(ev.name.clone())),
                ("cat", Json::Str(cat)),
                ("ts", Json::Num(ev.t_s * 1e6)),
            ];
            match ev.kind {
                EventKind::Span => {
                    pairs.push(("ph", Json::from("X")));
                    pairs.push(("dur", Json::Num(ev.dur_s * 1e6)));
                }
                EventKind::Instant => {
                    pairs.push(("ph", Json::from("i")));
                    pairs.push(("s", Json::from("t")));
                }
                EventKind::Counter => {
                    pairs.push(("ph", Json::from("C")));
                    args.insert("value".to_string(), Json::Num(ev.dur_s));
                }
            }
            pairs.push(("args", Json::Obj(args)));
            events.push(obj(pairs));
        }
        obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::from("ms")),
        ])
        .dump()
    }

    /// Parse either export format, auto-detected from content.
    pub fn parse(text: &str) -> Result<Trace, String> {
        if let Ok(v) = Json::parse(text) {
            if v.get("traceEvents").is_some() {
                return parse_chrome(&v);
            }
        }
        parse_jsonl(text)
    }

    /// Write `path`, format chosen by extension (`.jsonl` → JSON-lines,
    /// anything else → Chrome trace-event format).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        let jsonl = path.extension().and_then(|e| e.to_str()) == Some("jsonl");
        let text = if jsonl { self.to_jsonl() } else { self.to_chrome() };
        std::fs::write(path, text)
    }

    pub fn load(path: &Path) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Trace::parse(&text)
    }
}

fn parse_jsonl(text: &str) -> Result<Trace, String> {
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    let (_, header) = lines.next().ok_or("empty trace file")?;
    let h = Json::parse(header).map_err(|e| format!("trace header: {e}"))?;
    match h.get("format").and_then(|f| f.as_str()) {
        Some(TRACE_FORMAT) => {}
        Some(other) => return Err(format!("unknown trace format {other:?}")),
        None => return Err("not a trace file (missing format header)".to_string()),
    }
    let mut events = Vec::new();
    for (i, line) in lines {
        let v = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        events.push(event_from_jsonl(&v).map_err(|e| format!("line {}: {e}", i + 1))?);
    }
    Ok(Trace { events })
}

fn event_from_jsonl(v: &Json) -> Result<TraceEvent, String> {
    let kind = match v.get("kind").and_then(|k| k.as_str()) {
        Some("span") => EventKind::Span,
        Some("instant") => EventKind::Instant,
        Some("counter") => EventKind::Counter,
        other => return Err(format!("bad event kind {other:?}")),
    };
    let name = v.get("name").and_then(|n| n.as_str()).ok_or("missing name")?.to_string();
    let num = |key: &str| v.get(key).and_then(|x| x.as_f64());
    let dur_s = match kind {
        EventKind::Span => num("dur_s").ok_or("span missing dur_s")?,
        EventKind::Counter => num("value").ok_or("counter missing value")?,
        EventKind::Instant => 0.0,
    };
    let mut attrs = Vec::new();
    if let Some(Json::Obj(m)) = v.get("attrs") {
        for (k, av) in m {
            attrs.push((k.clone(), attr_from_json(av).ok_or("bad attr value")?));
        }
    }
    Ok(TraceEvent {
        track: num("track").ok_or("missing track")? as u32,
        epoch: num("epoch").unwrap_or(0.0) as u32,
        seq: num("seq").unwrap_or(0.0) as u32,
        t_s: num("t_s").ok_or("missing t_s")?,
        kind,
        name,
        dur_s,
        attrs,
    })
}

fn parse_chrome(v: &Json) -> Result<Trace, String> {
    let evs = v
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("traceEvents is not an array")?;
    let mut events = Vec::new();
    for ev in evs {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        let kind = match ph {
            "X" => EventKind::Span,
            "i" | "I" => EventKind::Instant,
            "C" => EventKind::Counter,
            _ => continue, // metadata and anything we did not emit
        };
        let name = ev.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
        let track = ev.get("tid").and_then(|t| t.as_f64()).unwrap_or(0.0) as u32;
        let t_s = ev.get("ts").and_then(|t| t.as_f64()).unwrap_or(0.0) / 1e6;
        let mut epoch = 0;
        let mut seq = 0;
        let mut value = 0.0;
        let mut attrs = Vec::new();
        if let Some(Json::Obj(m)) = ev.get("args") {
            for (k, av) in m {
                match k.as_str() {
                    "trace_epoch" => epoch = av.as_f64().unwrap_or(0.0) as u32,
                    "trace_seq" => seq = av.as_f64().unwrap_or(0.0) as u32,
                    "value" if kind == EventKind::Counter => {
                        value = av.as_f64().unwrap_or(0.0);
                    }
                    _ => {
                        if let Some(a) = attr_from_json(av) {
                            attrs.push((k.clone(), a));
                        }
                    }
                }
            }
        }
        let dur_s = match kind {
            EventKind::Span => ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0) / 1e6,
            EventKind::Counter => value,
            EventKind::Instant => 0.0,
        };
        events.push(TraceEvent { track, epoch, seq, t_s, kind, name, dur_s, attrs });
    }
    // Chrome args are unordered; restore the deterministic order key.
    events.sort_by(|a, b| (a.track, a.epoch, a.seq).cmp(&(b.track, b.epoch, b.seq)));
    Ok(Trace { events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::trace::{TraceSink, TRACK_COORD, TRACK_STEP};

    fn sample() -> Trace {
        let sink = TraceSink::enabled();
        let mut tr = sink.local(TRACK_STEP, 0);
        let t0 = tr.start();
        tr.span_at("trainer.compute", t0, 0.25, || {
            vec![("step", AttrVal::from(0usize)), ("exec_fwd_s", AttrVal::from(0.125))]
        });
        tr.instant("fault.death", || {
            vec![("chip", AttrVal::from(2usize)), ("kind", AttrVal::from("death"))]
        });
        tr.counter("report.steps", 8.0);
        drop(tr);
        let mut co = sink.local(TRACK_COORD, 1);
        co.instant("incarnation.start", || vec![("world", AttrVal::from(3usize))]);
        drop(co);
        sink.drain()
    }

    #[test]
    fn jsonl_round_trips() {
        let t = sample();
        let text = t.to_jsonl();
        assert!(text.starts_with(&format!("{{\"format\":\"{TRACE_FORMAT}\"}}\n")));
        let back = Trace::parse(&text).unwrap();
        assert_eq!(back.len(), t.len());
        // Serialization is a fixed point after one pass.
        assert_eq!(back.to_jsonl(), text);
        assert_eq!(back.canonical_dump(), t.canonical_dump());
        // Exact f64 round-trip.
        for (a, b) in t.events.iter().zip(back.events.iter()) {
            assert_eq!(a.t_s.to_bits(), b.t_s.to_bits());
            assert_eq!(a.dur_s.to_bits(), b.dur_s.to_bits());
        }
    }

    #[test]
    fn chrome_round_trips_semantics() {
        let t = sample();
        let text = t.to_chrome();
        let v = Json::parse(&text).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        // process_name + 2 thread_name metadata + 4 events
        assert_eq!(evs.len(), 3 + t.len());
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"ph\":\"i\""));
        assert!(text.contains("\"ph\":\"C\""));
        assert!(text.contains("\"thread_name\""));
        let back = Trace::parse(&text).unwrap();
        assert_eq!(back.len(), t.len());
        assert_eq!(back.canonical_dump(), t.canonical_dump());
    }

    #[test]
    fn write_picks_format_by_extension(){
        let dir = std::env::temp_dir().join(format!("trace-ext-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let t = sample();
        let chrome = dir.join("t.json");
        let jsonl = dir.join("t.jsonl");
        t.write(&chrome).unwrap();
        t.write(&jsonl).unwrap();
        assert!(std::fs::read_to_string(&chrome).unwrap().contains("traceEvents"));
        assert!(std::fs::read_to_string(&jsonl).unwrap().starts_with("{\"format\""));
        assert_eq!(Trace::load(&chrome).unwrap().len(), t.len());
        assert_eq!(Trace::load(&jsonl).unwrap().len(), t.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_garbage_and_wrong_format() {
        assert!(Trace::parse("").is_err());
        assert!(Trace::parse("not json").is_err());
        assert!(Trace::parse("{\"format\":\"other-v9\"}\n").is_err());
        assert!(Trace::parse("{\"report\":\"live_calibration\"}").is_err());
    }
}
