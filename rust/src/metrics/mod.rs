//! MLPerf-style structured run logging, timing rules — and the structured
//! trace subsystem.
//!
//! MLPerf time-to-train measures from `run_start` (after initialization —
//! the v0.6 rules added "a time budget allowing for large scale systems to
//! initialize") to the eval that first reaches the quality target. This
//! module implements that clock plus simple counters the trainer and
//! benches report.
//!
//! The [`trace`] / [`export`] / [`report`] submodules are the unified
//! tracing layer: [`TraceSink`] records per-phase spans, instants, and
//! counters across the trainer step loop, the checkpoint `AsyncWriter`,
//! the sweep worker pool, and `calibrate` live runs; exporters emit
//! JSON-lines or Chrome trace-event format (Perfetto); `trace summarize`
//! reduces a trace and cross-checks it against `TrainReport` accounting.
//! See `rust/src/metrics/README.md` for the schema and span taxonomy.

pub mod export;
pub mod report;
pub mod trace;

pub use report::{summarize, TraceSummary, DEFAULT_TOLERANCE};
pub use trace::{
    track_name, AttrVal, EventKind, Trace, TraceEvent, TraceLocal, TraceSink, TRACK_CALIBRATE,
    TRACK_CKPT, TRACK_COORD, TRACK_STEP, TRACK_SWEEP_BASE,
};

use std::time::Instant;

use crate::util::json::{obj, Json};

/// One structured log event (mirrors the MLPerf compliance log).
#[derive(Clone, Debug)]
pub struct Event {
    pub t: f64,
    pub key: String,
    pub value: Json,
}

/// Run phases per the MLPerf timing rules.
#[derive(Clone, Copy, PartialEq, Debug)]
enum Phase {
    Init,
    Running,
    Stopped,
}

/// MLPerf run logger + clock.
pub struct RunLog {
    origin: Instant,
    run_start: Option<f64>,
    run_stop: Option<f64>,
    target_hit_at: Option<f64>,
    phase: Phase,
    pub events: Vec<Event>,
    /// Quality target (e.g. top-1 0.759 for ResNet-50 in v0.6).
    pub quality_target: f64,
}

impl RunLog {
    pub fn new(quality_target: f64) -> RunLog {
        RunLog {
            origin: Instant::now(),
            run_start: None,
            run_stop: None,
            target_hit_at: None,
            phase: Phase::Init,
            events: Vec::new(),
            quality_target,
        }
    }

    fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    pub fn log(&mut self, key: &str, value: Json) {
        self.events.push(Event { t: self.now(), key: key.to_string(), value });
    }

    /// End of initialization (compile, weight broadcast): the MLPerf clock
    /// starts here.
    pub fn run_start(&mut self) {
        assert_eq!(self.phase, Phase::Init, "run_start called twice");
        self.phase = Phase::Running;
        let t = self.now();
        self.run_start = Some(t);
        self.log("run_start", Json::Null);
    }

    /// Record an evaluation result; trips the quality clock on first pass.
    pub fn eval_result(&mut self, epoch: f64, accuracy: f64) {
        assert_eq!(self.phase, Phase::Running, "eval outside run");
        self.log(
            "eval_accuracy",
            obj(vec![("epoch", Json::Num(epoch)), ("value", Json::Num(accuracy))]),
        );
        if accuracy >= self.quality_target && self.target_hit_at.is_none() {
            self.target_hit_at = Some(self.now());
            self.log("quality_target_reached", Json::Num(accuracy));
        }
    }

    pub fn run_stop(&mut self) {
        assert_eq!(self.phase, Phase::Running);
        self.phase = Phase::Stopped;
        self.run_stop = Some(self.now());
        self.log("run_stop", Json::Null);
    }

    /// Whether the target was reached.
    pub fn converged(&self) -> bool {
        self.target_hit_at.is_some()
    }

    /// MLPerf benchmark seconds: run_start → quality target. None if the
    /// target was never reached (a DNF submission).
    pub fn benchmark_seconds(&self) -> Option<f64> {
        Some(self.target_hit_at? - self.run_start?)
    }

    /// Initialization seconds excluded from the benchmark time.
    pub fn init_seconds(&self) -> Option<f64> {
        self.run_start
    }

    /// Serialize the event log as JSON lines.
    pub fn dump(&self) -> String {
        self.events
            .iter()
            .map(|e| {
                obj(vec![
                    ("t", Json::Num(e.t)),
                    ("key", Json::Str(e.key.clone())),
                    ("value", e.value.clone()),
                ])
                .dump()
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Step-time decomposition accumulator (device step = compute + gradsum +
/// weight update; the paper's §2 overhead percentages come from exactly
/// this breakdown).
#[derive(Clone, Debug, Default)]
pub struct StepBreakdown {
    pub compute_s: f64,
    pub gradsum_s: f64,
    pub update_s: f64,
    pub input_s: f64,
    pub steps: u64,
}

impl StepBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_s + self.gradsum_s + self.update_s + self.input_s
    }

    /// Fraction of step time spent in the optimizer update (the quantity
    /// weight-update sharding attacks: 6% ResNet-50 LARS, 45% Transformer
    /// Adam in the paper).
    pub fn update_fraction(&self) -> f64 {
        if self.total() == 0.0 {
            0.0
        } else {
            self.update_s / self.total()
        }
    }

    pub fn report(&self) -> String {
        let t = self.total().max(1e-12);
        format!(
            "steps={} total={:.3}s compute={:.1}% gradsum={:.1}% update={:.1}% input={:.1}%",
            self.steps,
            self.total(),
            100.0 * self.compute_s / t,
            100.0 * self.gradsum_s / t,
            100.0 * self.update_s / t,
            100.0 * self.input_s / t,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn benchmark_clock_excludes_init() {
        let mut log = RunLog::new(0.75);
        std::thread::sleep(Duration::from_millis(20)); // "compilation"
        log.run_start();
        std::thread::sleep(Duration::from_millis(10));
        log.eval_result(4.0, 0.5);
        std::thread::sleep(Duration::from_millis(10));
        log.eval_result(8.0, 0.76);
        log.run_stop();
        let bench = log.benchmark_seconds().unwrap();
        assert!(bench >= 0.015 && bench < 0.5, "bench={bench}");
        assert!(log.init_seconds().unwrap() >= 0.015);
        assert!(log.converged());
    }

    #[test]
    fn dnf_when_target_missed() {
        let mut log = RunLog::new(0.99);
        log.run_start();
        log.eval_result(1.0, 0.5);
        log.run_stop();
        assert!(!log.converged());
        assert_eq!(log.benchmark_seconds(), None);
    }

    #[test]
    fn first_passing_eval_stops_the_clock() {
        let mut log = RunLog::new(0.7);
        log.run_start();
        log.eval_result(1.0, 0.71);
        let t1 = log.benchmark_seconds().unwrap();
        std::thread::sleep(Duration::from_millis(10));
        log.eval_result(2.0, 0.9); // later, better eval must not move it
        assert_eq!(log.benchmark_seconds().unwrap(), t1);
    }

    #[test]
    fn event_log_is_json_lines() {
        let mut log = RunLog::new(0.5);
        log.run_start();
        log.eval_result(1.0, 0.6);
        log.run_stop();
        for line in log.dump().lines() {
            assert!(crate::util::json::Json::parse(line).is_ok(), "{line}");
        }
    }

    #[test]
    fn breakdown_percentages() {
        let b = StepBreakdown {
            compute_s: 0.90,
            gradsum_s: 0.04,
            update_s: 0.06,
            input_s: 0.0,
            steps: 100,
        };
        assert!((b.update_fraction() - 0.06).abs() < 1e-12);
        assert!(b.report().contains("update=6.0%"));
    }

    #[test]
    #[should_panic(expected = "run_start called twice")]
    fn double_start_panics() {
        let mut log = RunLog::new(0.5);
        log.run_start();
        log.run_start();
    }
}
