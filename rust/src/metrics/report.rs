//! Trace reduction: per-phase p50/p99/total tables, the goodput timeline,
//! cache-hit rates — and the accounting cross-check that makes a trace a
//! correctness oracle.
//!
//! The trainer emits its final [`TrainReport`](crate::coordinator::TrainReport)
//! accounting as `report.*` counters on the coordinator track, recorded
//! from the *same* `Timer` values that produced the per-phase spans. So in
//! a well-formed trace the span durations must re-derive the report:
//!
//! - `count(trainer.step) == count(trainer.update) == report.steps` (exact)
//! - `sum(trainer.input|compute|gradsum|update) == report.*_s`
//! - `sum(trainer.fwd) + Σ eval exec_fwd_s == report.fwd_s` (eval runs the
//!   same backend pass, so eval-time executor seconds are attributed on
//!   the `trainer.eval` span), same for bwd
//! - `fwd + bwd == report.exec_s`
//! - `count(ckpt.publish) == report.checkpoints`
//!
//! [`summarize`] evaluates these with a tiny tolerance (phase sums are
//! bit-identical within one incarnation; fault restarts and the Chrome
//! µs round-trip perturb at ~1e-15 relative) and `trace summarize` exits
//! nonzero when any check fails. Traces without `report.*` counters
//! (sweep/calibrate traces) skip the cross-check.

use std::collections::BTreeMap;

use super::trace::{AttrVal, EventKind, Trace, TRACK_COORD};
use crate::benchkit::{fmt_time, Table};
use crate::util::timer::percentile;

/// Relative tolerance for the accounting cross-check. Span sums re-add the
/// exact f64 durations the report added, but in a different association
/// across incarnations, and the Chrome export round-trips through µs.
pub const DEFAULT_TOLERANCE: f64 = 1e-9;
const ABS_TOLERANCE: f64 = 1e-12;

#[derive(Clone, Debug)]
pub struct PhaseStat {
    pub name: String,
    pub count: usize,
    pub total_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

#[derive(Clone, Debug)]
pub struct Check {
    pub name: String,
    pub ok: bool,
    pub detail: String,
}

#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    pub events: usize,
    pub phases: Vec<PhaseStat>,
    /// Final value of every counter (last sample wins).
    pub counters: BTreeMap<String, f64>,
    /// Human-readable incarnation/fault/rollback history, in event order.
    pub timeline: Vec<String>,
    /// `(cache name, hit rate)` derived from `*_hits`/`*_misses` counters.
    pub cache_rates: Vec<(String, f64)>,
    pub checks: Vec<Check>,
}

fn attr<'a>(attrs: &'a [(String, AttrVal)], key: &str) -> Option<&'a AttrVal> {
    attrs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

fn attr_f64(attrs: &[(String, AttrVal)], key: &str) -> Option<f64> {
    attr(attrs, key).and_then(|v| v.as_f64())
}

fn close(a: f64, b: f64, rel: f64) -> bool {
    (a - b).abs() <= ABS_TOLERANCE + rel * a.abs().max(b.abs())
}

/// Reduce a trace. `tolerance` is the relative tolerance for the
/// accounting cross-check ([`DEFAULT_TOLERANCE`] for the CLI default).
pub fn summarize(trace: &Trace, tolerance: f64) -> TraceSummary {
    let mut sum = TraceSummary { events: trace.len(), ..Default::default() };

    // Per-phase duration samples, grouped by span name (event order, which
    // drain() made deterministic).
    let mut durs: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for ev in &trace.events {
        match ev.kind {
            EventKind::Span => durs.entry(ev.name.as_str()).or_default().push(ev.dur_s),
            EventKind::Counter => {
                sum.counters.insert(ev.name.clone(), ev.dur_s);
            }
            EventKind::Instant => {}
        }
    }
    for (name, ds) in &durs {
        sum.phases.push(PhaseStat {
            name: name.to_string(),
            count: ds.len(),
            total_s: ds.iter().sum(),
            p50_s: percentile(ds, 50.0),
            p99_s: percentile(ds, 99.0),
            max_s: ds.iter().cloned().fold(0.0, f64::max),
        });
    }
    sum.phases.sort_by(|a, b| b.total_s.total_cmp(&a.total_s));

    // Goodput timeline: coordinator-track instants in order.
    for ev in &trace.events {
        if ev.track != TRACK_COORD || ev.kind != EventKind::Instant {
            continue;
        }
        let geti = |k: &str| attr_f64(&ev.attrs, k).map(|x| x as i64).unwrap_or(-1);
        let line = match ev.name.as_str() {
            "incarnation.start" => format!(
                "incarnation {} starts at step {} on {} cores",
                geti("incarnation"),
                geti("start_step"),
                geti("world")
            ),
            "fault.death" => {
                format!("chip {} dies before step {}", geti("chip"), geti("step"))
            }
            "fault.preemption" => {
                format!("chip {} preempted before step {}", geti("chip"), geti("step"))
            }
            "rollback" => format!(
                "rollback to step {} ({} steps of work lost)",
                geti("to_step"),
                geti("lost_steps")
            ),
            _ => format!("{} at t={:.3}s", ev.name, ev.t_s),
        };
        sum.timeline.push(line);
    }

    // Cache-hit rates from paired `<name>_hits` / `<name>_misses` counters.
    let hit_keys: Vec<String> = sum
        .counters
        .keys()
        .filter(|k| k.ends_with("_hits"))
        .map(|k| k[..k.len() - 5].to_string())
        .collect();
    for base in hit_keys {
        let hits = sum.counters[&format!("{base}_hits")];
        let misses = sum.counters.get(&format!("{base}_misses")).copied().unwrap_or(0.0);
        if hits + misses > 0.0 {
            sum.cache_rates.push((base, hits / (hits + misses)));
        }
    }

    // ---- accounting cross-check (trainer traces only) --------------------
    if let Some(&steps) = sum.counters.get("report.steps") {
        let span_total = |name: &str| durs.get(name).map(|d| d.iter().sum()).unwrap_or(0.0);
        let span_count = |name: &str| durs.get(name).map(|d| d.len()).unwrap_or(0);
        let counter = |k: &str| sum.counters.get(k).copied().unwrap_or(0.0);
        let mut check_eq = |name: &str, got: f64, want: f64, exact: bool| {
            let ok = if exact { got == want } else { close(got, want, tolerance) };
            sum.checks.push(Check {
                name: name.to_string(),
                ok,
                detail: format!("trace {got} vs report {want}"),
            });
        };

        check_eq("steps == trainer.step spans", span_count("trainer.step") as f64, steps, true);
        check_eq("steps == trainer.update spans", span_count("trainer.update") as f64, steps, true);
        for phase in ["input", "compute", "gradsum", "update"] {
            check_eq(
                &format!("{phase} span sum == report.{phase}_s"),
                span_total(&format!("trainer.{phase}")),
                counter(&format!("report.{phase}_s")),
                false,
            );
        }
        // Eval runs the same executor: its fwd/bwd seconds are carried as
        // span attributes, not sub-spans, and count toward the totals.
        let eval_fwd: f64 = trace
            .events
            .iter()
            .filter(|e| e.kind == EventKind::Span && e.name == "trainer.eval")
            .filter_map(|e| attr_f64(&e.attrs, "exec_fwd_s"))
            .sum();
        let eval_bwd: f64 = trace
            .events
            .iter()
            .filter(|e| e.kind == EventKind::Span && e.name == "trainer.eval")
            .filter_map(|e| attr_f64(&e.attrs, "exec_bwd_s"))
            .sum();
        check_eq(
            "fwd spans + eval fwd == report.fwd_s",
            span_total("trainer.fwd") + eval_fwd,
            counter("report.fwd_s"),
            false,
        );
        check_eq(
            "bwd spans + eval bwd == report.bwd_s",
            span_total("trainer.bwd") + eval_bwd,
            counter("report.bwd_s"),
            false,
        );
        check_eq(
            "fwd_s + bwd_s == report.exec_s",
            counter("report.fwd_s") + counter("report.bwd_s"),
            counter("report.exec_s"),
            false,
        );
        check_eq(
            "ckpt.publish spans == report.checkpoints",
            span_count("ckpt.publish") as f64,
            counter("report.checkpoints"),
            true,
        );
    }
    sum
}

impl TraceSummary {
    /// True when every accounting check passed (vacuously true for traces
    /// without `report.*` counters).
    pub fn ok(&self) -> bool {
        self.checks.iter().all(|c| c.ok)
    }

    pub fn print(&self) {
        let mut t = Table::new(
            &format!("trace summary ({} events)", self.events),
            &["phase", "count", "total", "p50", "p99", "max"],
        );
        for p in &self.phases {
            t.row(&[
                p.name.clone(),
                p.count.to_string(),
                fmt_time(p.total_s),
                fmt_time(p.p50_s),
                fmt_time(p.p99_s),
                fmt_time(p.max_s),
            ]);
        }
        t.print();

        if !self.timeline.is_empty() || self.counters.contains_key("report.goodput") {
            println!("\n=== goodput timeline ===");
            for line in &self.timeline {
                println!("  {line}");
            }
            if let Some(g) = self.counters.get("report.goodput") {
                println!(
                    "  goodput {:.4} ({} steps lost, {} restores)",
                    g,
                    self.counters.get("report.lost_steps").copied().unwrap_or(0.0),
                    self.counters.get("report.restores").copied().unwrap_or(0.0),
                );
            }
        }

        if !self.cache_rates.is_empty() {
            println!("\n=== cache hit rates ===");
            for (name, rate) in &self.cache_rates {
                println!("  {name}: {:.1}%", rate * 100.0);
            }
        }

        if self.checks.is_empty() {
            println!("\naccounting cross-check: skipped (no report.* counters in trace)");
        } else {
            println!("\n=== accounting cross-check ===");
            for c in &self.checks {
                let mark = if c.ok { "ok  " } else { "FAIL" };
                println!("  [{mark}] {} ({})", c.name, c.detail);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::trace::{TraceSink, TRACK_CKPT, TRACK_STEP};

    /// Hand-build a consistent 2-step trainer trace.
    fn consistent_trace() -> Trace {
        let sink = TraceSink::enabled();
        let mut tr = sink.local(TRACK_STEP, 0);
        for step in 1usize..=2 {
            let t0 = tr.start();
            tr.span_at("trainer.input", t0, 0.01, || vec![("step", AttrVal::from(step))]);
            tr.span_at("trainer.compute", t0, 0.1, || vec![("step", AttrVal::from(step))]);
            tr.span_at("trainer.fwd", t0, 0.06, Vec::new);
            tr.span_at("trainer.bwd", t0, 0.03, Vec::new);
            tr.span_at("trainer.gradsum", t0, 0.02, || vec![("step", AttrVal::from(step))]);
            tr.span_at("trainer.update", t0, 0.005, || vec![("step", AttrVal::from(step))]);
            tr.span_at("trainer.step", t0, 0.14, || vec![("step", AttrVal::from(step))]);
        }
        // One eval contributing executor time outside the fwd/bwd spans.
        let t0 = tr.start();
        tr.span_at("trainer.eval", t0, 0.05, || {
            vec![("exec_fwd_s", AttrVal::from(0.04)), ("exec_bwd_s", AttrVal::from(0.0))]
        });
        drop(tr);
        let mut ck = sink.local(TRACK_CKPT, 0);
        ck.span_at("ckpt.write", 0.0, 0.02, Vec::new);
        ck.span_at("ckpt.publish", 0.02, 0.001, Vec::new);
        drop(ck);
        let mut co = sink.local(super::TRACK_COORD, 0);
        co.counter("report.steps", 2.0);
        co.counter("report.input_s", 0.02);
        co.counter("report.compute_s", 0.2);
        co.counter("report.gradsum_s", 0.04);
        co.counter("report.update_s", 0.01);
        co.counter("report.fwd_s", 0.06 + 0.06 + 0.04);
        co.counter("report.bwd_s", 0.06);
        co.counter("report.exec_s", 0.16 + 0.06);
        co.counter("report.checkpoints", 1.0);
        co.counter("report.goodput", 1.0);
        drop(co);
        sink.drain()
    }

    #[test]
    fn consistent_trace_passes_checks() {
        let s = summarize(&consistent_trace(), DEFAULT_TOLERANCE);
        assert!(!s.checks.is_empty());
        assert!(s.ok(), "{:#?}", s.checks);
        let step = s.phases.iter().find(|p| p.name == "trainer.step").unwrap();
        assert_eq!(step.count, 2);
        assert!((step.total_s - 0.28).abs() < 1e-12);
        assert!(step.p50_s > 0.0 && step.p99_s >= step.p50_s);
    }

    #[test]
    fn tampered_counter_fails_checks() {
        let mut t = consistent_trace();
        for ev in t.events.iter_mut() {
            if ev.name == "report.fwd_s" {
                ev.dur_s *= 1.5;
            }
        }
        let s = summarize(&t, DEFAULT_TOLERANCE);
        assert!(!s.ok());
        assert!(s.checks.iter().any(|c| !c.ok && c.name.contains("fwd")));
    }

    #[test]
    fn dropped_span_fails_step_count() {
        let mut t = consistent_trace();
        let idx = t.events.iter().position(|e| e.name == "trainer.step").unwrap();
        t.events.remove(idx);
        let s = summarize(&t, DEFAULT_TOLERANCE);
        assert!(s.checks.iter().any(|c| !c.ok && c.name.contains("trainer.step")));
    }

    #[test]
    fn sweep_trace_skips_cross_check_and_reports_cache_rates() {
        let sink = TraceSink::enabled();
        let mut w = sink.local(crate::metrics::trace::TRACK_SWEEP_BASE, 0);
        let t0 = w.start();
        w.span_at("sweep.point", t0, 0.5, || vec![("chips", AttrVal::from(16usize))]);
        w.counter("sweep.cache.makespan_hits", 30.0);
        w.counter("sweep.cache.makespan_misses", 10.0);
        drop(w);
        let s = summarize(&sink.drain(), DEFAULT_TOLERANCE);
        assert!(s.checks.is_empty());
        assert!(s.ok());
        assert_eq!(s.cache_rates.len(), 1);
        assert!((s.cache_rates[0].1 - 0.75).abs() < 1e-12);
        s.print(); // should not panic
    }
}
