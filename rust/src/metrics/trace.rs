//! Structured trace recorder: spans, instants and counters on named tracks.
//!
//! The paper's argument is an attribution story — which phase eats the step
//! at which scale — and `SweepRecord`/`TrainReport` already carry the
//! *totals*. This module records *when* everything happened: per-step phase
//! spans in the trainer, checkpoint `AsyncWriter` write/publish windows,
//! incarnation boundaries and rollbacks under faults, per-job spans in the
//! sweep pool. Export formats live in [`super::export`], the reduction /
//! cross-check engine in [`super::report`].
//!
//! Design constraints, in order:
//!
//! 1. **Disabled tracing is free.** `TraceSink` is an `Option<Arc<..>>`;
//!    every recording method is `#[inline]` and early-outs on `None`
//!    without touching its attribute closure, so a disabled sink performs
//!    no allocation and no clock read. The trainer's numerics never depend
//!    on the sink, so a traced run is bit-identical to an untraced one.
//! 2. **Deterministic event order.** Events are recorded into per-thread
//!    [`TraceLocal`] buffers (no lock on the hot path) and merged into the
//!    shared sink when the local is flushed/dropped. Each event carries a
//!    `(track, epoch, seq)` key — track = logical timeline, epoch =
//!    incarnation index (so a restarted rank-0 loop does not collide with
//!    its predecessor), seq = position in the local buffer — and
//!    [`TraceSink::drain`] sorts by that key. The resulting event
//!    *sequence* is independent of thread scheduling and lock order;
//!    only the wall-clock fields (`t_s`, `dur_s`, attrs/counters whose
//!    name ends in `_s`) vary between runs. [`Trace::canonical_dump`]
//!    strips exactly those fields, and the seeded determinism test pins
//!    the dump byte-identical across runs.
//! 3. **No dependencies.** Timestamps are `f64` seconds since the sink's
//!    origin `Instant`; serialization goes through `util::json`.

use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Rank-0 step loop (per-step phase spans live here).
pub const TRACK_STEP: u32 = 0;
/// Coordinator (incarnation boundaries, fault/rollback instants, report counters).
pub const TRACK_COORD: u32 = 1000;
/// Checkpoint `AsyncWriter` thread (write/publish spans — the crash window).
pub const TRACK_CKPT: u32 = 1001;
/// `sweep --live` calibration points.
pub const TRACK_CALIBRATE: u32 = 1002;
/// Sweep pool worker `i` records on `TRACK_SWEEP_BASE + i`.
pub const TRACK_SWEEP_BASE: u32 = 2000;

/// Human-readable track name (Perfetto thread names, summary tables).
pub fn track_name(track: u32) -> String {
    match track {
        TRACK_STEP => "rank0-steps".to_string(),
        TRACK_COORD => "coordinator".to_string(),
        TRACK_CKPT => "ckpt-writer".to_string(),
        TRACK_CALIBRATE => "calibrate".to_string(),
        t if t >= TRACK_SWEEP_BASE => format!("sweep-worker-{}", t - TRACK_SWEEP_BASE),
        t => format!("track-{t}"),
    }
}

/// Attribute value. Time-valued attributes use the `_s`-suffix naming
/// convention (`queue_wait_s`, `exec_fwd_s`) so canonicalization and the
/// summary engine can tell wall-clock values from deterministic ones.
#[derive(Clone, Debug, PartialEq)]
pub enum AttrVal {
    Int(i64),
    Num(f64),
    Str(String),
}

impl From<i64> for AttrVal {
    fn from(x: i64) -> AttrVal {
        AttrVal::Int(x)
    }
}
impl From<usize> for AttrVal {
    fn from(x: usize) -> AttrVal {
        AttrVal::Int(x as i64)
    }
}
impl From<u64> for AttrVal {
    fn from(x: u64) -> AttrVal {
        AttrVal::Int(x as i64)
    }
}
impl From<f64> for AttrVal {
    fn from(x: f64) -> AttrVal {
        AttrVal::Num(x)
    }
}
impl From<&str> for AttrVal {
    fn from(s: &str) -> AttrVal {
        AttrVal::Str(s.to_string())
    }
}
impl From<String> for AttrVal {
    fn from(s: String) -> AttrVal {
        AttrVal::Str(s)
    }
}

impl AttrVal {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            AttrVal::Int(x) => Some(*x as f64),
            AttrVal::Num(x) => Some(*x),
            AttrVal::Str(_) => None,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Interval: `t_s .. t_s + dur_s`.
    Span,
    /// Point event (`dur_s` unused).
    Instant,
    /// Monotonic counter sample: value in `dur_s`.
    Counter,
}

impl EventKind {
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Span => "span",
            EventKind::Instant => "instant",
            EventKind::Counter => "counter",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub track: u32,
    /// Incarnation index for trainer tracks; 0 elsewhere.
    pub epoch: u32,
    /// Position within the `(track, epoch)` local buffer (plus its seq base).
    pub seq: u32,
    /// Seconds since the sink origin.
    pub t_s: f64,
    pub kind: EventKind,
    pub name: String,
    /// Span duration in seconds, or the counter value ([`EventKind::Counter`]).
    pub dur_s: f64,
    pub attrs: Vec<(String, AttrVal)>,
}

struct Shared {
    origin: Instant,
    events: Mutex<Vec<TraceEvent>>,
}

/// Handle to a trace being recorded. Cheap to clone; `disabled()` is the
/// no-op sink (all recording paths early-out, nothing is allocated).
#[derive(Clone, Default)]
pub struct TraceSink(Option<Arc<Shared>>);

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceSink({})", if self.0.is_some() { "enabled" } else { "disabled" })
    }
}

impl TraceSink {
    pub fn disabled() -> TraceSink {
        TraceSink(None)
    }

    pub fn enabled() -> TraceSink {
        TraceSink(Some(Arc::new(Shared {
            origin: Instant::now(),
            events: Mutex::new(Vec::new()),
        })))
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Per-thread recording buffer for one `(track, epoch)` timeline.
    /// Sequence numbers start at 0; use [`TraceSink::local_from`] when
    /// several short-lived locals share a timeline (checkpoint saves).
    pub fn local(&self, track: u32, epoch: u32) -> TraceLocal {
        self.local_from(track, epoch, 0)
    }

    /// Like [`TraceSink::local`] with an explicit sequence base, so events
    /// from successive locals on the same `(track, epoch)` sort in creation
    /// order rather than colliding at seq 0.
    pub fn local_from(&self, track: u32, epoch: u32, seq_base: u32) -> TraceLocal {
        TraceLocal { shared: self.0.clone(), track, epoch, seq: seq_base, buf: Vec::new() }
    }

    /// Take every recorded event, sorted by `(track, epoch, seq)` — an
    /// order independent of thread scheduling. Locals still alive keep
    /// appending to the (now empty) shared buffer.
    pub fn drain(&self) -> Trace {
        match &self.0 {
            None => Trace { events: Vec::new() },
            Some(sh) => {
                let mut events = std::mem::take(&mut *sh.events.lock().unwrap());
                events.sort_by(|a, b| {
                    (a.track, a.epoch, a.seq).cmp(&(b.track, b.epoch, b.seq))
                });
                Trace { events }
            }
        }
    }
}

/// Per-thread event buffer. Recording never takes the shared lock; events
/// are moved into the sink by [`TraceLocal::flush`] (also called on drop).
pub struct TraceLocal {
    shared: Option<Arc<Shared>>,
    track: u32,
    epoch: u32,
    seq: u32,
    buf: Vec<TraceEvent>,
}

impl TraceLocal {
    /// A local that records nothing (non-rank-0 workers).
    pub fn disabled() -> TraceLocal {
        TraceLocal { shared: None, track: 0, epoch: 0, seq: 0, buf: Vec::new() }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.shared.is_some()
    }

    /// Current time in seconds since the sink origin (0.0 when disabled).
    /// Pair with [`TraceLocal::span`]: `let t0 = tr.start(); ...; tr.span(..)`.
    #[inline]
    pub fn start(&self) -> f64 {
        match &self.shared {
            None => 0.0,
            Some(sh) => sh.origin.elapsed().as_secs_f64(),
        }
    }

    /// Close a span opened at `t0` (from [`TraceLocal::start`]), timed now.
    /// `attrs` is only invoked when the sink is enabled.
    #[inline]
    pub fn span<F>(&mut self, name: &'static str, t0: f64, attrs: F)
    where
        F: FnOnce() -> Vec<(&'static str, AttrVal)>,
    {
        if self.shared.is_some() {
            let dur = self.start() - t0;
            self.push(EventKind::Span, name, t0, dur, attrs());
        }
    }

    /// Record a span with an externally measured duration — used to reuse
    /// the exact `Timer` values that feed `StepBreakdown`, so span sums in
    /// a trace reproduce the report's accounting bit-for-bit, and to place
    /// synthetic sub-spans (fwd/bwd inside the compute span).
    #[inline]
    pub fn span_at<F>(&mut self, name: &'static str, t0: f64, dur_s: f64, attrs: F)
    where
        F: FnOnce() -> Vec<(&'static str, AttrVal)>,
    {
        if self.shared.is_some() {
            self.push(EventKind::Span, name, t0, dur_s, attrs());
        }
    }

    /// Point event, timed now.
    #[inline]
    pub fn instant<F>(&mut self, name: &'static str, attrs: F)
    where
        F: FnOnce() -> Vec<(&'static str, AttrVal)>,
    {
        if self.shared.is_some() {
            let t = self.start();
            self.push(EventKind::Instant, name, t, 0.0, attrs());
        }
    }

    /// Counter sample, timed now. Counters whose name ends in `_s` carry
    /// wall-clock values and are excluded from the canonical dump.
    #[inline]
    pub fn counter(&mut self, name: &'static str, value: f64) {
        if self.shared.is_some() {
            let t = self.start();
            self.push(EventKind::Counter, name, t, value, Vec::new());
        }
    }

    fn push(
        &mut self,
        kind: EventKind,
        name: &'static str,
        t_s: f64,
        dur_s: f64,
        attrs: Vec<(&'static str, AttrVal)>,
    ) {
        self.buf.push(TraceEvent {
            track: self.track,
            epoch: self.epoch,
            seq: self.seq,
            t_s,
            kind,
            name: name.to_string(),
            dur_s,
            attrs: attrs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        });
        self.seq += 1;
    }

    /// Move buffered events into the sink. Also runs on drop.
    pub fn flush(&mut self) {
        if let Some(sh) = &self.shared {
            if !self.buf.is_empty() {
                sh.events.lock().unwrap().append(&mut self.buf);
            }
        }
    }
}

impl Drop for TraceLocal {
    fn drop(&mut self) {
        self.flush();
    }
}

/// A drained trace: events in deterministic `(track, epoch, seq)` order.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Timestamp-stripped dump: one line per event with kind, track, epoch,
    /// seq, name, counter value (unless the name ends in `_s`) and attrs
    /// (values of `_s`-suffixed keys replaced by `·`). Two seeded runs of
    /// the same config produce byte-identical canonical dumps — this is the
    /// determinism-modulo-timestamps oracle.
    pub fn canonical_dump(&self) -> String {
        let mut out = String::new();
        for ev in &self.events {
            out.push_str(&format!(
                "{} {}/{}/{} {}",
                ev.kind.label(),
                ev.track,
                ev.epoch,
                ev.seq,
                ev.name
            ));
            if ev.kind == EventKind::Counter && !ev.name.ends_with("_s") {
                out.push_str(&format!(" ={}", fmt_num(ev.dur_s)));
            }
            for (k, v) in &ev.attrs {
                if k.ends_with("_s") {
                    out.push_str(&format!(" {k}=·"));
                } else {
                    match v {
                        AttrVal::Int(x) => out.push_str(&format!(" {k}={x}")),
                        AttrVal::Num(x) => out.push_str(&format!(" {k}={}", fmt_num(*x))),
                        AttrVal::Str(s) => out.push_str(&format!(" {k}={s}")),
                    }
                }
            }
            out.push('\n');
        }
        out
    }
}

fn fmt_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_records_nothing_and_skips_attr_closures() {
        let sink = TraceSink::disabled();
        assert!(!sink.is_enabled());
        let mut tr = sink.local(TRACK_STEP, 0);
        let t0 = tr.start();
        assert_eq!(t0, 0.0);
        tr.span("x", t0, || panic!("attr closure must not run when disabled"));
        tr.instant("y", || panic!("attr closure must not run when disabled"));
        tr.counter("c", 1.0);
        drop(tr);
        assert!(sink.drain().is_empty());
    }

    #[test]
    fn drain_orders_by_track_epoch_seq_not_merge_order() {
        let sink = TraceSink::enabled();
        // Merge a later track first, then an earlier one, then a second
        // epoch on the first track: drain must still sort deterministically.
        let mut b = sink.local(TRACK_COORD, 0);
        b.instant("coord.ev", Vec::new);
        drop(b);
        let mut a = sink.local(TRACK_STEP, 0);
        a.counter("steps", 2.0);
        a.instant("step.ev", Vec::new);
        drop(a);
        let mut a2 = sink.local(TRACK_STEP, 1);
        a2.instant("restarted.ev", Vec::new);
        drop(a2);
        let tr = sink.drain();
        let names: Vec<&str> = tr.events.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["steps", "step.ev", "restarted.ev", "coord.ev"]);
        assert_eq!(tr.events[0].seq, 0);
        assert_eq!(tr.events[1].seq, 1);
        assert_eq!(tr.events[2].epoch, 1);
    }

    #[test]
    fn seq_base_orders_successive_locals() {
        let sink = TraceSink::enabled();
        let mut second = sink.local_from(TRACK_CKPT, 0, 16);
        second.instant("save.1", Vec::new);
        drop(second);
        let mut first = sink.local_from(TRACK_CKPT, 0, 0);
        first.instant("save.0", Vec::new);
        drop(first);
        let tr = sink.drain();
        let names: Vec<&str> = tr.events.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["save.0", "save.1"]);
    }

    #[test]
    fn canonical_dump_strips_wall_clock_only() {
        let sink = TraceSink::enabled();
        let mut tr = sink.local(TRACK_STEP, 0);
        let t0 = tr.start();
        tr.span_at("trainer.compute", t0, 0.123, || {
            vec![("step", AttrVal::from(3usize)), ("exec_fwd_s", AttrVal::from(0.1))]
        });
        tr.counter("report.steps", 8.0);
        tr.counter("report.compute_s", 0.456);
        drop(tr);
        let dump = sink.drain().canonical_dump();
        assert!(dump.contains("span 0/0/0 trainer.compute step=3 exec_fwd_s=·"), "{dump}");
        assert!(dump.contains("counter 0/0/1 report.steps =8"), "{dump}");
        // Wall-clock counter keeps its name, loses its value.
        assert!(dump.contains("counter 0/0/2 report.compute_s\n"), "{dump}");
        assert!(!dump.contains("0.123"), "{dump}");
        assert!(!dump.contains("0.456"), "{dump}");
    }

    #[test]
    fn span_measures_nonnegative_duration() {
        let sink = TraceSink::enabled();
        let mut tr = sink.local(TRACK_STEP, 0);
        let t0 = tr.start();
        tr.span("w", t0, Vec::new);
        drop(tr);
        let trace = sink.drain();
        assert_eq!(trace.len(), 1);
        assert!(trace.events[0].dur_s >= 0.0);
        assert!(trace.events[0].t_s >= 0.0);
    }

    #[test]
    fn track_names() {
        assert_eq!(track_name(TRACK_STEP), "rank0-steps");
        assert_eq!(track_name(TRACK_SWEEP_BASE + 3), "sweep-worker-3");
    }
}
