//! Shared-trace goodput audit (`sweep --faults TRACE --live`).
//!
//! PR 6 gave the repo two independent goodput models: the live trainer's
//! incarnation loop (`coordinator::train` — real rollbacks to real
//! checkpoint files, elastic restart on exactly the survivors) and the
//! simulator's [`price_fault_trace`] (an analytic walk over the same
//! event timeline). Nothing ever checked them against each other. This
//! module replays **one shared [`FaultTrace`] through both** and gates on
//! agreement:
//!
//! * the fatal events are replayed as a severity ladder — the empty
//!   prefix, then one fatal event, then two, … — so each rung adds
//!   exactly one rollback to both models;
//! * **lost steps must match exactly** per rung: both sides roll back to
//!   the same `floor((step-1)/every)*every` durable frontier, so any gap
//!   means one of the two rollback models drifted;
//! * **goodput must agree** per rung within an absolute tolerance, in the
//!   one currency both sides share: *steps*. The trainer reports
//!   `useful / executed` steps directly; the simulator's lost-step count
//!   converts to the same ratio (`steps / (steps + lost)`), and that pair
//!   is gated. The simulator's native wall-clock goodput (repriced
//!   seconds) is reported alongside but **not** gap-gated — after a death
//!   the survivors run every remaining step slower, so seconds-domain
//!   goodput degrades faster than steps-domain by construction, and the
//!   gap between the two grows with the step horizon. Both goodputs must
//!   still be non-increasing along the ladder — more faults can never
//!   mean more goodput;
//! * **survivor sets must match**: after `d` deaths the trainer must be
//!   on `cores − d` workers and the simulator's degraded layout on the
//!   matching chip count — the arbitrary-survivor policy, not a
//!   power-of-two halving.
//!
//! Slowdown events are excluded from the replay: the live trainer models
//! a straggler as a stretched (but useful) step while the simulator
//! charges wall-clock, so they move the two goodput definitions in
//! structurally different ways. The audit is about the *lost-work* model.
//!
//! `sweep --faults TRACE --live` prints the comparison JSON and exits
//! nonzero on any disagreement — the CI gate that keeps the simulator's
//! elasticity model honest against the thing it claims to predict.

use anyhow::{anyhow, Result};

use crate::coordinator::{train, TrainConfig};
use crate::scenario::{price_fault_trace, FaultEvent, FaultKind, FaultTrace, ScalingScenario};
use crate::simulator::simulate;
use crate::util::json::{obj, Json};

/// Audit configuration (CLI: `--live-*` / `--audit-*` flags).
#[derive(Clone, Debug)]
pub struct FaultAuditOptions {
    /// Registry family for the live runs (and the simulated scenario).
    pub model: String,
    /// Live worker count; one trace `chip` = one worker = one simulated
    /// chip. Any positive count — non-power-of-two worlds are the point.
    pub cores: usize,
    /// Total steps of the audited run (both sides share this horizon).
    pub steps: usize,
    /// Durable-checkpoint cadence used by both rollback models.
    pub checkpoint_every: usize,
    /// Absolute goodput slack per rung (goodput is in [0, 1]).
    pub tolerance: f64,
    /// Cap on ladder length (fatal events replayed), to bound audit cost.
    pub max_fatal_events: usize,
    pub seed: u64,
    /// Scratch directory for the live runs' checkpoints.
    pub workdir: std::path::PathBuf,
}

impl Default for FaultAuditOptions {
    fn default() -> FaultAuditOptions {
        FaultAuditOptions {
            model: "transformer".into(),
            cores: 4,
            steps: 24,
            checkpoint_every: 4,
            tolerance: 0.15,
            max_fatal_events: 3,
            seed: 0,
            workdir: std::env::temp_dir().join(format!("tpu-fault-audit-{}", std::process::id())),
        }
    }
}

/// One severity rung: the same fatal-event prefix through both models.
#[derive(Clone, Debug)]
pub struct AuditPoint {
    /// Fatal events replayed at this rung (ladder position).
    pub fatal_events: usize,
    /// Death events among them (each shrinks both worlds by one).
    pub deaths: usize,
    pub live_goodput: f64,
    pub live_lost_steps: u64,
    pub live_restores: usize,
    /// Live worker count at the end of the run.
    pub live_final_cores: usize,
    /// Simulator wall-clock goodput (base seconds / repriced seconds).
    /// Reported and trend-checked, but not gap-gated — see module doc.
    pub sim_goodput: f64,
    pub sim_lost_steps: f64,
    /// Simulator goodput in the trainer's currency:
    /// `steps / (steps + lost_steps)`. This is what the gap gate compares
    /// against `live_goodput`.
    pub sim_step_goodput: f64,
    /// Participating cores of the simulator's final (degraded) layout.
    pub sim_final_cores: usize,
}

impl AuditPoint {
    fn to_json(&self) -> Json {
        obj(vec![
            ("fatal_events", Json::from(self.fatal_events)),
            ("deaths", Json::from(self.deaths)),
            ("live_goodput", Json::from(self.live_goodput)),
            ("live_lost_steps", Json::from(self.live_lost_steps as usize)),
            ("live_restores", Json::from(self.live_restores)),
            ("live_final_cores", Json::from(self.live_final_cores)),
            ("sim_goodput", Json::from(self.sim_goodput)),
            ("sim_lost_steps", Json::from(self.sim_lost_steps)),
            ("sim_step_goodput", Json::from(self.sim_step_goodput)),
            ("sim_final_cores", Json::from(self.sim_final_cores)),
        ])
    }
}

/// The full audit record (`sweep --faults --live` output).
#[derive(Clone, Debug)]
pub struct FaultAuditReport {
    pub trace_name: String,
    pub model: String,
    pub cores: usize,
    pub steps: usize,
    pub checkpoint_every: usize,
    pub tolerance: f64,
    pub points: Vec<AuditPoint>,
    /// Human-readable agreement failures (empty = the two goodput models
    /// describe the same degraded machine).
    pub disagreements: Vec<String>,
}

impl FaultAuditReport {
    pub fn agrees(&self) -> bool {
        self.disagreements.is_empty()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("report", Json::from("fault_goodput_audit")),
            ("trace", Json::from(self.trace_name.as_str())),
            ("model", Json::from(self.model.as_str())),
            ("cores", Json::from(self.cores)),
            ("steps", Json::from(self.steps)),
            ("checkpoint_every", Json::from(self.checkpoint_every)),
            ("tolerance", Json::from(self.tolerance)),
            ("points", Json::Arr(self.points.iter().map(AuditPoint::to_json).collect())),
            (
                "disagreements",
                Json::Arr(self.disagreements.iter().map(|s| Json::from(s.as_str())).collect()),
            ),
            ("agrees", Json::Bool(self.agrees())),
        ])
    }

    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())
    }
}

/// The agreement checks, pure over the collected rungs (unit-testable
/// with fabricated data). `cores` is the starting world size and
/// `base_participating`/`base_cores` describe the fault-free simulated
/// layout (the survivor check on the sim side only fires when the base
/// layout fully occupies its slice — a batch-limited layout has idle
/// cores whose loss costs nothing).
pub fn audit_disagreements(
    points: &[AuditPoint],
    cores: usize,
    base_participating: usize,
    base_cores: usize,
    tolerance: f64,
) -> Vec<String> {
    let tol = tolerance.max(0.0);
    let mut out = Vec::new();
    for p in points {
        let k = p.fatal_events;
        if (p.live_lost_steps as f64 - p.sim_lost_steps).abs() > 1e-9 {
            out.push(format!(
                "rung {k}: lost steps disagree — trainer rolled back {} steps, \
                 simulator priced {} (both must land on the same checkpoint frontier)",
                p.live_lost_steps, p.sim_lost_steps
            ));
        }
        if (p.live_goodput - p.sim_step_goodput).abs() > tol {
            out.push(format!(
                "rung {k}: goodput gap {:.3} (trainer) vs {:.3} (simulator, steps domain) \
                 exceeds tolerance {tol}",
                p.live_goodput, p.sim_step_goodput
            ));
        }
        if p.live_final_cores != cores - p.deaths {
            out.push(format!(
                "rung {k}: trainer finished on {} workers, expected exactly the {} survivors \
                 of {cores} after {} death(s)",
                p.live_final_cores,
                cores - p.deaths,
                p.deaths
            ));
        }
        if base_participating == base_cores
            && p.sim_final_cores != base_participating - 2 * p.deaths
        {
            out.push(format!(
                "rung {k}: simulator's final layout has {} participating cores, expected \
                 {} ({} minus {} dead chips)",
                p.sim_final_cores,
                base_participating - 2 * p.deaths,
                base_participating,
                p.deaths
            ));
        }
    }
    for w in points.windows(2) {
        let (a, b) = (&w[0], &w[1]);
        for (side, ga, gb) in [
            ("trainer", a.live_goodput, b.live_goodput),
            ("simulator", a.sim_goodput, b.sim_goodput),
        ] {
            if gb > ga + tol {
                out.push(format!(
                    "{side} goodput rose {ga:.3} -> {gb:.3} from rung {} to rung {} — \
                     more faults can never mean more goodput",
                    a.fatal_events, b.fatal_events
                ));
            }
        }
    }
    out
}

/// Replay `trace` through the live trainer and the simulator and assemble
/// the comparison report. Every live run writes (and cleans up) real
/// checkpoints under `opts.workdir`.
pub fn run_fault_audit(opts: &FaultAuditOptions, trace: &FaultTrace) -> Result<FaultAuditReport> {
    if opts.cores < 2 {
        return Err(anyhow!("the audit needs at least 2 workers (a death must leave survivors)"));
    }
    if opts.checkpoint_every == 0 || opts.steps == 0 {
        return Err(anyhow!("the audit needs a positive step count and checkpoint cadence"));
    }
    trace
        .validate_in_context(opts.steps as u64, opts.cores)
        .map_err(|e| anyhow!("fault trace fails strict validation: {e}"))?;

    let fatal: Vec<FaultEvent> = trace
        .events
        .iter()
        .filter(|ev| !matches!(ev.kind, FaultKind::Slowdown { .. }))
        .copied()
        .collect();
    if fatal.is_empty() {
        return Err(anyhow!(
            "trace {:?} has no death/preemption events — nothing to audit",
            trace.name
        ));
    }
    let rungs = fatal.len().min(opts.max_fatal_events.max(1));

    // The simulated twin: one chip per live worker, the same step horizon.
    // The base point is simulated once; each rung reprices it under its
    // event prefix via `price_fault_trace`.
    let scenario = ScalingScenario::submission(&opts.model, vec![opts.cores]);
    let profile = scenario.profile().map_err(|e| anyhow!("audit scenario: {e}"))?;
    let sim_cores = opts.cores * 2;
    let mut base = simulate(&profile, sim_cores, &scenario.sim_options(sim_cores));
    base.steps = opts.steps as f64;
    base.converged = true; // the audit horizon is fixed-step, not to-quality

    let mut points = Vec::new();
    for k in 0..=rungs {
        let prefix = FaultTrace {
            name: format!("{}-rung{k}", trace.name),
            ckpt_every_steps: opts.checkpoint_every as u64,
            restore_seconds: trace.restore_seconds,
            events: fatal[..k].to_vec(),
        };
        let deaths =
            prefix.events.iter().filter(|ev| ev.kind == FaultKind::Death).count();

        let sim = price_fault_trace(&scenario, &profile, &base, &prefix);

        let ckpt_dir = opts.workdir.join(format!("rung{k}"));
        let mut cfg = TrainConfig::quick(&opts.model, opts.cores, opts.steps);
        cfg.seed = opts.seed;
        cfg.checkpoint_every = opts.checkpoint_every;
        cfg.checkpoint_dir = Some(ckpt_dir.clone());
        cfg.faults = Some(prefix);
        let live = train(&cfg)?;
        let _ = std::fs::remove_dir_all(&ckpt_dir);

        points.push(AuditPoint {
            fatal_events: k,
            deaths,
            live_goodput: live.goodput,
            live_lost_steps: live.lost_steps,
            live_restores: live.restores,
            live_final_cores: live.final_cores,
            sim_goodput: sim.goodput,
            sim_lost_steps: sim.lost_steps,
            sim_step_goodput: opts.steps as f64 / (opts.steps as f64 + sim.lost_steps),
            sim_final_cores: sim.final_cores,
        });
    }

    let disagreements = audit_disagreements(
        &points,
        opts.cores,
        base.participating_cores,
        base.cores,
        opts.tolerance,
    );
    Ok(FaultAuditReport {
        trace_name: trace.name.clone(),
        model: opts.model.clone(),
        cores: opts.cores,
        steps: opts.steps,
        checkpoint_every: opts.checkpoint_every,
        tolerance: opts.tolerance,
        points,
        disagreements,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rung(
        k: usize,
        deaths: usize,
        live_g: f64,
        sim_g: f64,
        lost: u64,
        cores: usize,
    ) -> AuditPoint {
        AuditPoint {
            fatal_events: k,
            deaths,
            live_goodput: live_g,
            live_lost_steps: lost,
            live_restores: k,
            live_final_cores: cores - deaths,
            sim_goodput: sim_g,
            sim_lost_steps: lost as f64,
            // An agreeing simulator prices the same lost work, so its
            // steps-domain goodput lands exactly on the trainer's.
            sim_step_goodput: live_g,
            sim_final_cores: 2 * (cores - deaths),
        }
    }

    #[test]
    fn agreeing_rungs_produce_no_disagreements() {
        let pts = vec![
            rung(0, 0, 1.0, 1.0, 0, 4),
            rung(1, 1, 0.9, 0.88, 3, 4),
            rung(2, 2, 0.8, 0.77, 6, 4),
        ];
        assert_eq!(audit_disagreements(&pts, 4, 8, 8, 0.15), Vec::<String>::new());
    }

    #[test]
    fn lost_step_mismatch_is_flagged() {
        let mut pts = vec![rung(0, 0, 1.0, 1.0, 0, 4), rung(1, 1, 0.9, 0.9, 3, 4)];
        pts[1].sim_lost_steps = 5.0;
        let d = audit_disagreements(&pts, 4, 8, 8, 0.15);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("lost steps disagree"), "{}", d[0]);
    }

    #[test]
    fn goodput_gap_and_rise_are_flagged() {
        let mut pts = vec![rung(0, 0, 1.0, 0.5, 0, 4), rung(1, 1, 0.4, 0.9, 3, 4)];
        // Simulator claims far less lost work than the trainer saw…
        pts[1].sim_step_goodput = 0.9;
        let d = audit_disagreements(&pts, 4, 8, 8, 0.15);
        assert!(d.iter().any(|m| m.contains("goodput gap")), "{d:?}");
        // …and its wall-clock goodput rose along the ladder (0.5 → 0.9).
        assert!(d.iter().any(|m| m.contains("never mean more goodput")), "{d:?}");
    }

    #[test]
    fn wrong_survivor_sets_are_flagged() {
        // Trainer halved instead of continuing on the survivors.
        let mut pts = vec![rung(1, 1, 0.9, 0.9, 3, 6)];
        pts[0].live_final_cores = 3;
        let d = audit_disagreements(&pts, 6, 12, 12, 0.15);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("exactly the 5 survivors"), "{}", d[0]);

        // Simulator halved its layout instead of dropping one chip.
        let mut pts = vec![rung(1, 1, 0.9, 0.9, 3, 6)];
        pts[0].sim_final_cores = 6;
        let d = audit_disagreements(&pts, 6, 12, 12, 0.15);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("dead chips"), "{}", d[0]);
    }

    #[test]
    fn audit_rejects_traces_without_fatal_events() {
        let opts = FaultAuditOptions::default();
        let mut t = FaultTrace::empty("slow-only");
        t.events = vec![FaultEvent {
            step: 2,
            chip: 0,
            kind: FaultKind::Slowdown { factor: 2.0, steps: 2 },
        }];
        let err = run_fault_audit(&opts, &t).unwrap_err().to_string();
        assert!(err.contains("no death/preemption"), "{err}");
    }

    /// End-to-end on a non-power-of-two world: 3 workers, one death, both
    /// models must agree rung for rung. This is the in-process twin of the
    /// CI `sweep --faults --live` gate.
    #[test]
    fn live_and_sim_agree_on_a_three_worker_death() {
        let opts = FaultAuditOptions {
            cores: 3,
            steps: 8,
            checkpoint_every: 2,
            max_fatal_events: 1,
            workdir: std::env::temp_dir()
                .join(format!("tpu-audit-test-{}", std::process::id())),
            ..Default::default()
        };
        let mut trace = FaultTrace::empty("one-death");
        trace.events = vec![FaultEvent { step: 6, chip: 1, kind: FaultKind::Death }];
        let rep = run_fault_audit(&opts, &trace).unwrap();
        assert_eq!(rep.points.len(), 2);
        assert_eq!(rep.disagreements, Vec::<String>::new());
        let p = &rep.points[1];
        // Died entering step 6: 5 done, frontier at 4, one step lost.
        assert_eq!(p.live_lost_steps, 1);
        assert_eq!(p.live_final_cores, 2, "3 workers minus 1 death");
        assert!(p.live_goodput < 1.0 && p.sim_goodput < 1.0);
        // Same lost work → identical steps-domain goodput (8 useful of 9
        // executed); the wall-clock goodput additionally prices the
        // survivors' slower remaining steps, so it may sit anywhere below 1.
        assert!((p.live_goodput - p.sim_step_goodput).abs() < 1e-12);
        let j = Json::parse(&rep.to_json().dump()).unwrap();
        assert_eq!(j.get("report").and_then(Json::as_str), Some("fault_goodput_audit"));
        assert_eq!(j.get("agrees").and_then(Json::as_bool), Some(true));
    }
}
