//! Live-trainer calibration of the pod simulator (`sweep --live`).
//!
//! The simulator prices compute through `costs::ComputePhase` — a roofline
//! [`crate::devicesim::Device`] coefficient set nobody has checked against
//! an executor this repo can actually run. This module closes that loop on
//! the reference backend: it runs a micro-grid of live training points
//! (each registry family × a per-core batch ladder) on
//! [`crate::coordinator::train`], records the measured per-phase
//! wall-clock (fwd/bwd exec, gradsum, update) next to the simulator's
//! per-phase attribution for the same per-replica batch, and then checks
//! that the *trends* agree:
//!
//! * **Batch scaling** — the simulator's compute attribution grows
//!   monotonically (and at most linearly) with per-replica batch; the
//!   live executor's fwd+bwd seconds must do the same, within a relative
//!   tolerance. A flat or superlinear live curve means the executor and
//!   the cost model no longer describe the same machine.
//! * **Cross-family ordering** — the proxy dims are sized so per-step
//!   compute load follows the registry's Table-1 ordering
//!   ([`ProxyDims::flops_per_step`], pinned statically in
//!   `models::proxy`); the measured live step times must reproduce that
//!   ordering within tolerance.
//!
//! Absolute seconds are *not* gated — a laptop is not a TPU core. What the
//! grid fits instead is the compute coefficient a live-calibrated
//! `StepCostModel` would use: each family's achieved FLOP/s on the live
//! executor, the median across families (`fitted_gflops`), and the
//! per-family live→simulated scale factor (`live_to_sim_alpha`).
//!
//! `tpu-pod-train sweep --live` prints the JSON report and exits nonzero
//! when any trend check fails — the CI gate that keeps the simulator's
//! shape honest as the kernels underneath it change.

pub mod audit;

pub use audit::{run_fault_audit, AuditPoint, FaultAuditOptions, FaultAuditReport};

use anyhow::{anyhow, Result};

use crate::coordinator::{train, TrainConfig};
use crate::metrics::{AttrVal, TraceSink, TRACK_CALIBRATE};
use crate::models::proxy::{proxy_dims, ProxyDims};
use crate::models::registry::{model, Layout};
use crate::simulator::{simulate, SimOptions};
use crate::util::json::{obj, Json};

/// The micro-grid specification.
#[derive(Clone, Debug)]
pub struct LiveGridOptions {
    /// Registry families to calibrate (default: all five).
    pub models: Vec<String>,
    /// Data-parallel worker threads per live point (any positive count).
    pub cores: usize,
    /// Training steps per live point (timed; no eval, no checkpoints).
    pub steps: usize,
    /// `--exec-threads` of the live backend (1 = serial kernels).
    pub exec_threads: usize,
    /// Per-core batch ladder as multipliers of each family's default.
    pub batch_mults: Vec<usize>,
    /// Relative slack for every trend comparison (0.35 = 35%).
    pub tolerance: f64,
    pub seed: u64,
    /// Trace sink for per-point `calibrate.*` spans (disabled = no-op).
    pub trace: TraceSink,
}

impl Default for LiveGridOptions {
    fn default() -> LiveGridOptions {
        LiveGridOptions {
            models: ["resnet50", "ssd", "maskrcnn", "transformer", "gnmt"]
                .map(String::from)
                .to_vec(),
            cores: 2,
            steps: 12,
            exec_threads: 1,
            batch_mults: vec![1, 2, 4],
            tolerance: 0.35,
            seed: 0,
            trace: TraceSink::disabled(),
        }
    }
}

/// One grid point: live measurements next to the simulator's attribution
/// for the same per-replica batch.
#[derive(Clone, Debug)]
pub struct LivePoint {
    pub family: String,
    pub batch_per_core: usize,
    /// Measured fwd+bwd executor seconds per step (rank 0; the minimum of
    /// two runs, so a one-off scheduler stall cannot fake a trend).
    pub live_step_s: f64,
    pub live_fwd_s: f64,
    pub live_bwd_s: f64,
    /// Measured gradient-summation / weight-update wall-clock per step.
    pub live_gradsum_s: f64,
    pub live_update_s: f64,
    /// Simulator per-step attribution at `per_replica_batch ==
    /// batch_per_core` (layout override, pure data parallel).
    pub sim_compute_s: f64,
    pub sim_gradsum_s: f64,
    pub sim_update_s: f64,
    pub sim_step_s: f64,
}

impl LivePoint {
    fn to_json(&self) -> Json {
        obj(vec![
            ("family", Json::from(self.family.as_str())),
            ("batch_per_core", Json::from(self.batch_per_core)),
            ("live_step_seconds", Json::from(self.live_step_s)),
            ("live_fwd_seconds", Json::from(self.live_fwd_s)),
            ("live_bwd_seconds", Json::from(self.live_bwd_s)),
            ("live_gradsum_seconds", Json::from(self.live_gradsum_s)),
            ("live_update_seconds", Json::from(self.live_update_s)),
            ("sim_compute_seconds", Json::from(self.sim_compute_s)),
            ("sim_gradsum_seconds", Json::from(self.sim_gradsum_s)),
            ("sim_update_seconds", Json::from(self.sim_update_s)),
            ("sim_step_seconds", Json::from(self.sim_step_s)),
        ])
    }
}

/// One family's fitted compute coefficients (base-batch point).
#[derive(Clone, Debug)]
pub struct FamilyFit {
    pub family: String,
    pub live_s_per_example: f64,
    /// Proxy forward FLOPs per example ([`ProxyDims::flops_per_example`]).
    pub flops_per_example: f64,
    /// Achieved forward-FLOP/s of the live executor (forward load over
    /// full fwd+bwd seconds — the convention `ComputePhase` uses with its
    /// 3x forward-FLOPs factor folded into the coefficient).
    pub implied_gflops: f64,
    /// sim_compute / live_exec at the base point: the scale factor between
    /// the proxy on this host and the modeled TPU-v3 core.
    pub live_to_sim_alpha: f64,
}

impl FamilyFit {
    fn to_json(&self) -> Json {
        obj(vec![
            ("family", Json::from(self.family.as_str())),
            ("live_seconds_per_example", Json::from(self.live_s_per_example)),
            ("proxy_fwd_flops_per_example", Json::from(self.flops_per_example)),
            ("implied_gflops", Json::from(self.implied_gflops)),
            ("live_to_sim_alpha", Json::from(self.live_to_sim_alpha)),
        ])
    }
}

/// The full calibration record (`sweep --live` output).
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    pub cores: usize,
    pub steps: usize,
    pub exec_threads: usize,
    pub tolerance: f64,
    pub points: Vec<LivePoint>,
    pub fits: Vec<FamilyFit>,
    /// Median achieved GFLOP/s across families — the fitted compute
    /// coefficient for a live-backed `StepCostModel`.
    pub fitted_gflops: f64,
    /// Human-readable trend-check failures (empty = live and simulated
    /// attributions agree).
    pub disagreements: Vec<String>,
}

impl CalibrationReport {
    pub fn agrees(&self) -> bool {
        self.disagreements.is_empty()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("report", Json::from("live_calibration")),
            ("cores", Json::from(self.cores)),
            ("steps", Json::from(self.steps)),
            ("exec_threads", Json::from(self.exec_threads)),
            ("tolerance", Json::from(self.tolerance)),
            ("points", Json::Arr(self.points.iter().map(LivePoint::to_json).collect())),
            ("fits", Json::Arr(self.fits.iter().map(FamilyFit::to_json).collect())),
            ("fitted_gflops", Json::from(self.fitted_gflops)),
            (
                "disagreements",
                Json::Arr(self.disagreements.iter().map(|s| Json::from(s.as_str())).collect()),
            ),
            ("agrees", Json::Bool(self.agrees())),
        ])
    }

    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())
    }
}

/// Run one live point and return mean per-step `(exec, fwd, bwd, gradsum,
/// update)` seconds on rank 0.
fn live_point(
    opts: &LiveGridOptions,
    family: &str,
    batch: usize,
) -> Result<(f64, f64, f64, f64, f64)> {
    let mut cfg = TrainConfig::quick(family, opts.cores, opts.steps);
    cfg.batch_override = Some(batch);
    cfg.eval_every = 0;
    cfg.exec_threads = opts.exec_threads;
    cfg.seed = opts.seed;
    let rep = train(&cfg)?;
    let n = rep.breakdown.steps.max(1) as f64;
    Ok((
        rep.exec_s / n,
        rep.fwd_s / n,
        rep.bwd_s / n,
        rep.breakdown.gradsum_s / n,
        rep.breakdown.update_s / n,
    ))
}

/// Simulate the same per-replica batch on the modeled pod (pure data
/// parallel so the compute attribution is the plain roofline).
fn sim_point(family: &str, cores: usize, batch: usize) -> Result<(f64, f64, f64, f64)> {
    let profile =
        model(family).ok_or_else(|| anyhow!("no registry profile for family {family:?}"))?;
    let layout =
        Layout { cores, mp: 1, replicas: cores, global_batch: cores * batch };
    let options = SimOptions::submission().layout(layout);
    let r = simulate(&profile, cores, &options);
    Ok((r.compute_seconds, r.gradsum_seconds, r.update_seconds, r.step_seconds))
}

/// The trend checks, pure over the collected points (unit-testable with
/// fabricated data). `base_order` is the expected fastest-to-slowest
/// family order at the base batch (proxy per-step compute load).
pub fn trend_disagreements(
    points: &[LivePoint],
    base_order: &[(String, usize)],
    tolerance: f64,
) -> Vec<String> {
    let tol = tolerance.max(0.0);
    let mut out = Vec::new();

    // Batch scaling per family: both live exec and sim compute must be
    // monotone nondecreasing and at-most-linear in per-core batch.
    for (family, _) in base_order {
        let ladder: Vec<&LivePoint> =
            points.iter().filter(|p| &p.family == family).collect();
        for w in ladder.windows(2) {
            let (a, b) = (w[0], w[1]);
            let growth = b.batch_per_core as f64 / a.batch_per_core as f64;
            for (side, ta, tb) in [
                ("live exec", a.live_step_s, b.live_step_s),
                ("sim compute", a.sim_compute_s, b.sim_compute_s),
            ] {
                if tb < ta * (1.0 - tol) {
                    out.push(format!(
                        "{family}: {side} fell {ta:.3e}s -> {tb:.3e}s when per-core batch \
                         grew {} -> {} (expected monotone within {:.0}%)",
                        a.batch_per_core,
                        b.batch_per_core,
                        tol * 100.0
                    ));
                }
                if tb > ta * growth * (1.0 + tol) {
                    out.push(format!(
                        "{family}: {side} grew superlinearly {ta:.3e}s -> {tb:.3e}s over a \
                         {growth}x batch increase (tolerance {:.0}%)",
                        tol * 100.0
                    ));
                }
            }
        }
    }

    // Cross-family ordering at the base batch: live step times must
    // follow the proxy compute-load ordering (the Table-1 stand-in).
    let base: Vec<(&str, f64)> = base_order
        .iter()
        .filter_map(|(family, batch)| {
            points
                .iter()
                .find(|p| &p.family == family && p.batch_per_core == *batch)
                .map(|p| (family.as_str(), p.live_step_s))
        })
        .collect();
    for w in base.windows(2) {
        let ((fast, ta), (slow, tb)) = (w[0], w[1]);
        if ta > tb * (1.0 + tol) {
            out.push(format!(
                "ordering: {fast} measured {ta:.3e}s/step but {slow} only {tb:.3e}s/step — \
                 live ratios do not follow the proxy compute ordering (tolerance {:.0}%)",
                tol * 100.0
            ));
        }
    }
    out
}

/// Load the fitted compute coefficient from a `sweep --live` calibration
/// report on disk (`sweep --costs-from FILE`). Errors name the file and
/// what was wrong: not JSON, not a live-calibration report, or a missing
/// or non-positive `fitted_gflops`.
pub fn fitted_gflops_from_file(path: &str) -> Result<f64> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read calibration file {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{path} is not JSON: {e}"))?;
    match j.get("report").and_then(Json::as_str) {
        Some("live_calibration") => {}
        _ => {
            return Err(anyhow!(
                "{path} is not a live-calibration report (expected report=\"live_calibration\")"
            ))
        }
    }
    let g = j
        .get("fitted_gflops")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("{path} has no fitted_gflops field"))?;
    if !g.is_finite() || g <= 0.0 {
        return Err(anyhow!("{path}: fitted_gflops {g} is not a positive finite coefficient"));
    }
    Ok(g)
}

fn median(mut xs: Vec<f64>) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    xs[xs.len() / 2]
}

/// Run the live micro-grid and assemble the calibration report.
pub fn run_live_calibration(opts: &LiveGridOptions) -> Result<CalibrationReport> {
    if opts.models.is_empty() {
        return Err(anyhow!("live calibration needs at least one model family"));
    }
    if opts.batch_mults.is_empty()
        || opts.batch_mults[0] == 0
        || opts.batch_mults.windows(2).any(|w| w[1] <= w[0])
    {
        return Err(anyhow!("batch multipliers must be nonempty, positive, strictly increasing"));
    }

    // Families ordered by proxy per-step compute load (the expected live
    // step-time ordering), paired with their base per-core batch.
    let mut dims: Vec<(String, ProxyDims)> = Vec::new();
    for name in &opts.models {
        let d = proxy_dims(name)
            .ok_or_else(|| anyhow!("no reference proxy for family {name:?}"))?;
        dims.push((name.clone(), d));
    }
    dims.sort_by(|a, b| {
        a.1.flops_per_step().partial_cmp(&b.1.flops_per_step()).expect("finite flops")
    });
    let base_order: Vec<(String, usize)> =
        dims.iter().map(|(n, d)| (n.clone(), d.batch_per_core)).collect();

    let mut tl = opts.trace.local(TRACK_CALIBRATE, 0);
    let mut points = Vec::new();
    let mut fits = Vec::new();
    for (name, d) in &dims {
        for &mult in &opts.batch_mults {
            let batch = d.batch_per_core * mult;
            // Two runs, keep the faster: a one-off host stall in either
            // run cannot manufacture a trend violation.
            let t_live = tl.start();
            let a = live_point(opts, name, batch)?;
            let b = live_point(opts, name, batch)?;
            let live = if a.0 <= b.0 { a } else { b };
            tl.span("calibrate.live_point", t_live, || {
                vec![
                    ("family", AttrVal::Str(name.clone())),
                    ("batch_per_core", AttrVal::from(batch)),
                    ("live_step_s", AttrVal::Num(live.0)),
                ]
            });
            let t_sim = tl.start();
            let (sim_compute, sim_gradsum, sim_update, sim_step) =
                sim_point(name, opts.cores, batch)?;
            tl.span("calibrate.sim_point", t_sim, || {
                vec![
                    ("family", AttrVal::Str(name.clone())),
                    ("batch_per_core", AttrVal::from(batch)),
                    ("sim_step_s", AttrVal::Num(sim_step)),
                ]
            });
            points.push(LivePoint {
                family: name.clone(),
                batch_per_core: batch,
                live_step_s: live.0,
                live_fwd_s: live.1,
                live_bwd_s: live.2,
                live_gradsum_s: live.3,
                live_update_s: live.4,
                sim_compute_s: sim_compute,
                sim_gradsum_s: sim_gradsum,
                sim_update_s: sim_update,
                sim_step_s: sim_step,
            });
        }
        let base = points
            .iter()
            .find(|p| &p.family == name && p.batch_per_core == d.batch_per_core)
            .expect("base point just pushed");
        let per_example = base.live_step_s / d.batch_per_core as f64;
        fits.push(FamilyFit {
            family: name.clone(),
            live_s_per_example: per_example,
            flops_per_example: d.flops_per_example(),
            implied_gflops: d.flops_per_example() / per_example.max(1e-12) / 1e9,
            live_to_sim_alpha: base.sim_compute_s / base.live_step_s.max(1e-12),
        });
    }

    let fitted_gflops = median(fits.iter().map(|f| f.implied_gflops).collect());
    let disagreements = trend_disagreements(&points, &base_order, opts.tolerance);
    Ok(CalibrationReport {
        cores: opts.cores,
        steps: opts.steps,
        exec_threads: opts.exec_threads,
        tolerance: opts.tolerance,
        points,
        fits,
        fitted_gflops,
        disagreements,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(family: &str, batch: usize, live: f64, sim: f64) -> LivePoint {
        LivePoint {
            family: family.to_string(),
            batch_per_core: batch,
            live_step_s: live,
            live_fwd_s: live * 0.4,
            live_bwd_s: live * 0.6,
            live_gradsum_s: 1e-5,
            live_update_s: 1e-5,
            sim_compute_s: sim,
            sim_gradsum_s: 1e-4,
            sim_update_s: 1e-4,
            sim_step_s: sim + 2e-4,
        }
    }

    fn order() -> Vec<(String, usize)> {
        vec![("resnet50".to_string(), 8), ("maskrcnn".to_string(), 8)]
    }

    #[test]
    fn agreeing_trends_produce_no_disagreements() {
        let points = vec![
            point("resnet50", 8, 1e-4, 1e-2),
            point("resnet50", 16, 1.9e-4, 1.7e-2),
            point("maskrcnn", 8, 9e-4, 1.3),
            point("maskrcnn", 16, 1.8e-3, 2.4),
        ];
        assert_eq!(trend_disagreements(&points, &order(), 0.35), Vec::<String>::new());
    }

    #[test]
    fn falling_live_time_is_a_disagreement() {
        let points = vec![
            point("resnet50", 8, 2e-4, 1e-2),
            point("resnet50", 16, 0.5e-4, 1.7e-2), // live fell 4x on 2x batch
        ];
        let d = trend_disagreements(&points, &order()[..1].to_vec(), 0.35);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("live exec fell"), "{}", d[0]);
    }

    #[test]
    fn superlinear_growth_is_a_disagreement() {
        let points = vec![
            point("resnet50", 8, 1e-4, 1e-2),
            point("resnet50", 16, 9e-4, 1.7e-2), // 9x live time on 2x batch
        ];
        let d = trend_disagreements(&points, &order()[..1].to_vec(), 0.35);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("superlinearly"), "{}", d[0]);
    }

    #[test]
    fn inverted_family_ordering_is_a_disagreement() {
        let points = vec![
            point("resnet50", 8, 5e-3, 1e-2), // "light" family measured slow
            point("maskrcnn", 8, 1e-4, 1.3),
        ];
        let d = trend_disagreements(&points, &order(), 0.35);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].contains("ordering"), "{}", d[0]);
    }

    #[test]
    fn costs_from_file_roundtrip_and_rejections() {
        let dir = std::env::temp_dir().join(format!("tpt-costs-from-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("cal.json");
        std::fs::write(
            &good,
            obj(vec![
                ("report", Json::from("live_calibration")),
                ("fitted_gflops", Json::from(12.5)),
            ])
            .dump(),
        )
        .unwrap();
        let g = fitted_gflops_from_file(good.to_str().unwrap()).unwrap();
        assert_eq!(g, 12.5);

        let missing = dir.join("absent.json");
        assert!(fitted_gflops_from_file(missing.to_str().unwrap()).is_err());
        let wrong = dir.join("wrong.json");
        std::fs::write(&wrong, obj(vec![("report", Json::from("sweep"))]).dump()).unwrap();
        assert!(fitted_gflops_from_file(wrong.to_str().unwrap()).is_err());
        let bad = dir.join("bad.json");
        std::fs::write(
            &bad,
            obj(vec![
                ("report", Json::from("live_calibration")),
                ("fitted_gflops", Json::from(0.0)),
            ])
            .dump(),
        )
        .unwrap();
        assert!(fitted_gflops_from_file(bad.to_str().unwrap()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_grid_options_rejected() {
        let mut o = LiveGridOptions::default();
        o.models.clear();
        assert!(run_live_calibration(&o).is_err());
        let mut o = LiveGridOptions { batch_mults: vec![2, 2], ..Default::default() };
        assert!(run_live_calibration(&o).is_err());
        o.batch_mults = vec![4, 1];
        assert!(run_live_calibration(&o).is_err());
    }

    /// End-to-end on the two lightest families: the report is structurally
    /// complete and round-trips through JSON. Agreement itself is gated in
    /// CI (`sweep --live`), not here — unit-test machines are too noisy to
    /// pin wall-clock trends.
    #[test]
    fn micro_grid_produces_a_complete_report() {
        let opts = LiveGridOptions {
            models: vec!["resnet50".to_string(), "gnmt".to_string()],
            cores: 2,
            steps: 3,
            batch_mults: vec![1, 2],
            ..Default::default()
        };
        let rep = run_live_calibration(&opts).unwrap();
        assert_eq!(rep.points.len(), 4);
        assert_eq!(rep.fits.len(), 2);
        assert!(rep.fitted_gflops > 0.0);
        for p in &rep.points {
            assert!(p.live_step_s > 0.0, "{}: zero live step time", p.family);
            assert!(p.sim_compute_s > 0.0);
            assert!(
                (p.live_fwd_s + p.live_bwd_s - p.live_step_s).abs() <= 1e-9 + p.live_step_s * 1e-6,
                "{}: fwd+bwd must account for the exec time",
                p.family
            );
        }
        let j = Json::parse(&rep.to_json().dump()).unwrap();
        assert_eq!(j.get("report").and_then(Json::as_str), Some("live_calibration"));
        assert_eq!(j.get("points").and_then(Json::as_arr).map(|a| a.len()), Some(4));
        assert!(j.get("agrees").and_then(Json::as_bool).is_some());
    }
}
