//! Ring collectives on real buffers over the fabric: the building blocks
//! both the 1-D baseline and the paper's 2-D torus schedule compose.
//!
//! All functions are SPMD: every rank in `group` calls the same function
//! with the same `group` slice; `group[i]` is the fabric rank at ring
//! position i. Tags are allocated from the endpoint's deterministic
//! allocator so back-to-back collectives never alias.

use crate::fabric::{Endpoint, Payload};

/// Balanced chunk boundaries: chunk `c` of `n` over `len` elements.
pub fn chunk_range(len: usize, n: usize, c: usize) -> std::ops::Range<usize> {
    let base = len / n;
    let rem = len % n;
    let start = c * base + c.min(rem);
    let size = base + usize::from(c < rem);
    start..start + size
}

/// Ring position of this endpoint within `group` (panics if absent).
fn my_pos(ep: &Endpoint, group: &[usize]) -> usize {
    group.iter().position(|&r| r == ep.rank).expect("rank not in group")
}

/// After [`ring_reduce_scatter`], ring position `pos` owns this chunk index.
pub fn owned_chunk(pos: usize, n: usize) -> usize {
    (pos + 1) % n
}

/// Ring reduce-scatter: on return, each rank's `data[chunk_range(owned)]`
/// holds the group sum of that chunk; other regions are partial garbage.
pub fn ring_reduce_scatter(ep: &mut Endpoint, group: &[usize], data: &mut [f32]) {
    let n = group.len();
    if n <= 1 {
        return;
    }
    let pos = my_pos(ep, group);
    let next = group[(pos + 1) % n];
    let prev = group[(pos + n - 1) % n];
    let tags = ep.fresh_tags(n as u64);
    for step in 0..n - 1 {
        let send_c = (pos + n - step) % n;
        let recv_c = (pos + n - step - 1) % n;
        let sr = chunk_range(data.len(), n, send_c);
        ep.send(next, tags + step as u64, Payload::F32(data[sr].to_vec()));
        let incoming = ep.recv(prev, tags + step as u64).into_f32();
        let rr = chunk_range(data.len(), n, recv_c);
        // f32 accumulation (paper: gradient summation in 32-bit).
        for (d, x) in data[rr].iter_mut().zip(incoming) {
            *d += x;
        }
    }
}

/// Ring all-gather assuming each rank's owned chunk (per [`owned_chunk`])
/// is valid; on return every rank holds all chunks.
pub fn ring_all_gather(ep: &mut Endpoint, group: &[usize], data: &mut [f32]) {
    let n = group.len();
    if n <= 1 {
        return;
    }
    let pos = my_pos(ep, group);
    let next = group[(pos + 1) % n];
    let prev = group[(pos + n - 1) % n];
    let tags = ep.fresh_tags(n as u64);
    for step in 0..n - 1 {
        let send_c = (pos + 1 + n - step) % n;
        let recv_c = (pos + n - step) % n;
        let sr = chunk_range(data.len(), n, send_c);
        ep.send(next, tags + step as u64, Payload::F32(data[sr].to_vec()));
        let incoming = ep.recv(prev, tags + step as u64).into_f32();
        let rr = chunk_range(data.len(), n, recv_c);
        data[rr].copy_from_slice(&incoming);
    }
}

/// Full ring all-reduce (reduce-scatter + all-gather).
pub fn ring_all_reduce(ep: &mut Endpoint, group: &[usize], data: &mut [f32]) {
    ring_reduce_scatter(ep, group, data);
    ring_all_gather(ep, group, data);
}

/// All-gather of variable-size parts: every rank contributes `mine`; the
/// return value is the concatenation in ring-position order. Used by
/// weight-update sharding to broadcast freshly-updated weight shards
/// (paper §2, Fig. 4 "optimized all-gather").
pub fn all_gather_concat(ep: &mut Endpoint, group: &[usize], mine: &[f32]) -> Vec<f32> {
    let n = group.len();
    let pos = my_pos(ep, group);
    let tags = ep.fresh_tags(n as u64);
    if n == 1 {
        return mine.to_vec();
    }
    let next = group[(pos + 1) % n];
    let prev = group[(pos + n - 1) % n];
    // Pipelined ring: forward my part, then keep forwarding what arrives.
    let mut parts: Vec<Option<Vec<f32>>> = vec![None; n];
    parts[pos] = Some(mine.to_vec());
    let mut cur = mine.to_vec();
    let mut cur_owner = pos;
    for step in 0..n - 1 {
        ep.send(next, tags + step as u64, Payload::F32(cur));
        let incoming = ep.recv(prev, tags + step as u64).into_f32();
        cur_owner = (cur_owner + n - 1) % n;
        parts[cur_owner] = Some(incoming.clone());
        cur = incoming;
    }
    parts.into_iter().flat_map(|p| p.expect("missing part")).collect()
}

/// Root broadcast (weight init / restored checkpoints).
pub fn broadcast(ep: &mut Endpoint, group: &[usize], root_pos: usize, data: &mut Vec<f32>) {
    let n = group.len();
    if n <= 1 {
        return;
    }
    let pos = my_pos(ep, group);
    let tags = ep.fresh_tags(1);
    // Simple ring pipeline from the root.
    let rel = (pos + n - root_pos) % n;
    if rel != 0 {
        let prev = group[(pos + n - 1) % n];
        *data = ep.recv(prev, tags).into_f32();
    }
    if rel != n - 1 {
        let next = group[(pos + 1) % n];
        ep.send(next, tags, Payload::F32(data.clone()));
    }
}

/// All-reduce a small vector of scalars (eval metrics, BN statistics).
pub fn all_reduce_scalars(ep: &mut Endpoint, group: &[usize], vals: &mut [f32]) {
    let mut buf = vals.to_vec();
    // Scalars are far smaller than a chunk per rank; gather-to-all directly.
    let n = group.len();
    if n <= 1 {
        return;
    }
    let tags = ep.fresh_tags(1);
    for &peer in group {
        if peer != ep.rank {
            ep.send(peer, tags, Payload::F32(buf.clone()));
        }
    }
    for &peer in group {
        if peer != ep.rank {
            let theirs = ep.recv(peer, tags).into_f32();
            for (b, x) in buf.iter_mut().zip(theirs) {
                *b += x;
            }
        }
    }
    vals.copy_from_slice(&buf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::run_spmd;

    #[test]
    fn chunk_ranges_partition() {
        for (len, n) in [(10, 3), (7, 7), (5, 8), (100, 4)] {
            let mut covered = 0;
            for c in 0..n {
                let r = chunk_range(len, n, c);
                assert_eq!(r.start, covered);
                covered = r.end;
            }
            assert_eq!(covered, len);
        }
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        let world = 4;
        let len = 37;
        let out = run_spmd(world, |ep| {
            let group: Vec<usize> = (0..world).collect();
            let mut data: Vec<f32> = (0..len).map(|i| (ep.rank * 100 + i) as f32).collect();
            ring_all_reduce(ep, &group, &mut data);
            data
        });
        for i in 0..len {
            let expect: f32 = (0..world).map(|r| (r * 100 + i) as f32).sum();
            for r in 0..world {
                assert_eq!(out[r][i], expect, "elt {i} rank {r}");
            }
        }
    }

    #[test]
    fn reduce_scatter_owned_chunks_correct() {
        let world = 3;
        let len = 11;
        let out = run_spmd(world, |ep| {
            let group: Vec<usize> = (0..world).collect();
            let mut data: Vec<f32> = (0..len).map(|i| (ep.rank + 1) as f32 * i as f32).collect();
            ring_reduce_scatter(ep, &group, &mut data);
            let own = owned_chunk(ep.rank, world);
            let r = chunk_range(len, world, own);
            (own, data[r].to_vec())
        });
        let total: f32 = (1..=world).map(|x| x as f32).sum();
        for (own, chunk) in out {
            let r = chunk_range(len, world, own);
            for (j, &v) in chunk.iter().enumerate() {
                assert_eq!(v, total * (r.start + j) as f32);
            }
        }
    }

    #[test]
    fn all_gather_concat_orders_parts() {
        let world = 5;
        let out = run_spmd(world, |ep| {
            let group: Vec<usize> = (0..world).collect();
            let mine = vec![ep.rank as f32; ep.rank + 1]; // variable sizes
            all_gather_concat(ep, &group, &mine)
        });
        let expect: Vec<f32> =
            (0..world).flat_map(|r| std::iter::repeat(r as f32).take(r + 1)).collect();
        for r in 0..world {
            assert_eq!(out[r], expect, "rank {r}");
        }
    }

    #[test]
    fn broadcast_from_nonzero_root() {
        let world = 4;
        let out = run_spmd(world, |ep| {
            let group: Vec<usize> = (0..world).collect();
            let mut data = if ep.rank == 2 { vec![3.25, -1.5] } else { vec![0.0, 0.0] };
            broadcast(ep, &group, 2, &mut data);
            data
        });
        for r in 0..world {
            assert_eq!(out[r], vec![3.25, -1.5]);
        }
    }

    #[test]
    fn scalar_all_reduce() {
        let world = 6;
        let out = run_spmd(world, |ep| {
            let group: Vec<usize> = (0..world).collect();
            let mut vals = [1.0, ep.rank as f32];
            all_reduce_scalars(ep, &group, &mut vals);
            vals
        });
        for r in 0..world {
            assert_eq!(out[r][0], world as f32);
            assert_eq!(out[r][1], (0..world).sum::<usize>() as f32);
        }
    }

    #[test]
    fn subgroup_collectives_dont_cross() {
        // Two disjoint groups all-reduce concurrently; sums stay in-group.
        let out = run_spmd(4, |ep| {
            let group: Vec<usize> =
                if ep.rank < 2 { vec![0, 1] } else { vec![2, 3] };
            let mut data = vec![ep.rank as f32 + 1.0];
            ring_all_reduce(ep, &group, &mut data);
            data[0]
        });
        assert_eq!(out, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn back_to_back_collectives_no_alias() {
        // Tag allocator must keep consecutive all-reduces separate even
        // when ranks race ahead.
        let out = run_spmd(3, |ep| {
            let group: Vec<usize> = (0..3).collect();
            let mut a = vec![1.0f32];
            let mut b = vec![10.0f32];
            ring_all_reduce(ep, &group, &mut a);
            ring_all_reduce(ep, &group, &mut b);
            (a[0], b[0])
        });
        for (a, b) in out {
            assert_eq!((a, b), (3.0, 30.0));
        }
    }
}
