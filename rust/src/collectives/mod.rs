//! Collectives on real buffers over the in-process fabric: ring primitives,
//! the paper's 2-D torus all-reduce, pipelined non-contiguous gradient
//! summation (§2), and halo exchange for spatial partitioning.

pub mod gradsum;
pub mod halo;
pub mod ring;
pub mod torus2d;

pub use gradsum::{gradsum_pipelined, gradsum_pipelined_ws, gradsum_serial, FlatView, GradSumWorkspace};
pub use halo::halo_exchange;
pub use ring::{
    all_gather_concat, all_reduce_scalars, broadcast, chunk_range, owned_chunk,
    ring_all_gather, ring_all_reduce, ring_reduce_scatter,
};
pub use torus2d::{torus2d_all_reduce, Placement};
