//! Halo exchange for spatial partitioning (paper §2, Fig. 3: "Halo exchange
//! communication operations are added to synchronize TPU-v3 cores that
//! execute spatially partitioned workloads").
//!
//! 1-D stripe partitioning of the image height: worker i holds rows
//! [r_i, r_{i+1}); a K×K convolution needs K/2 rows of halo from each
//! spatial neighbor. Halos may ride bf16 (activations are matmul/conv
//! operands under the paper's mixed-precision rule).

use crate::fabric::{Endpoint, Payload};
use crate::util::bf16::pack_bf16;

/// Exchange halo rows with stripe neighbors.
///
/// * `group` — fabric ranks of the spatial partition, in stripe order.
/// * `top`/`bottom` — this worker's boundary rows to send (its first/last
///   `halo` rows); `None` at the partition edges.
/// * Returns `(halo_from_above, halo_from_below)` as f32.
pub fn halo_exchange(
    ep: &mut Endpoint,
    group: &[usize],
    top_rows: Option<&[f32]>,
    bottom_rows: Option<&[f32]>,
    bf16_wire: bool,
) -> (Option<Vec<f32>>, Option<Vec<f32>>) {
    let pos = group.iter().position(|&r| r == ep.rank).expect("rank not in group");
    let tags = ep.fresh_tags(2);
    let up_tag = tags; // messages travelling toward lower indices
    let down_tag = tags + 1;

    let wrap = |data: &[f32]| -> Payload {
        if bf16_wire {
            Payload::Bf16(pack_bf16(data))
        } else {
            Payload::F32(data.to_vec())
        }
    };

    // Send my top boundary up, my bottom boundary down.
    if pos > 0 {
        let rows = top_rows.expect("interior worker must provide top rows");
        ep.send(group[pos - 1], up_tag, wrap(rows));
    }
    if pos + 1 < group.len() {
        let rows = bottom_rows.expect("interior worker must provide bottom rows");
        ep.send(group[pos + 1], down_tag, wrap(rows));
    }

    // Receive the matching halos.
    let from_above =
        (pos > 0).then(|| ep.recv(group[pos - 1], down_tag).into_f32());
    let from_below =
        (pos + 1 < group.len()).then(|| ep.recv(group[pos + 1], up_tag).into_f32());
    (from_above, from_below)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::run_spmd;

    #[test]
    fn three_way_stripe_exchange() {
        let out = run_spmd(3, |ep| {
            let group = [0, 1, 2];
            let mine = vec![ep.rank as f32 * 10.0; 4];
            let (above, below) = halo_exchange(
                ep,
                &group,
                (ep.rank > 0).then_some(&mine[..]),
                (ep.rank < 2).then_some(&mine[..]),
                false,
            );
            (above, below)
        });
        // rank 0: nothing above, rank1's rows below.
        assert_eq!(out[0].0, None);
        assert_eq!(out[0].1, Some(vec![10.0; 4]));
        assert_eq!(out[1].0, Some(vec![0.0; 4]));
        assert_eq!(out[1].1, Some(vec![20.0; 4]));
        assert_eq!(out[2].0, Some(vec![10.0; 4]));
        assert_eq!(out[2].1, None);
    }

    #[test]
    fn bf16_wire_round_trips_representable_values() {
        let out = run_spmd(2, |ep| {
            let group = [0, 1];
            let mine = vec![1.5f32, -0.25, 8.0];
            let (above, below) = halo_exchange(
                ep,
                &group,
                (ep.rank == 1).then_some(&mine[..]),
                (ep.rank == 0).then_some(&mine[..]),
                true,
            );
            (above, below)
        });
        assert_eq!(out[0].1, Some(vec![1.5, -0.25, 8.0]));
        assert_eq!(out[1].0, Some(vec![1.5, -0.25, 8.0]));
    }

    #[test]
    fn single_worker_no_exchange() {
        let out = run_spmd(1, |ep| halo_exchange(ep, &[0], None, None, false));
        assert_eq!(out[0], (None, None));
    }
}
