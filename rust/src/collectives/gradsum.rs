//! Gradient summation over the model's (non-contiguous) gradient tensors —
//! the paper's §2 "Optimize gradient summation":
//!
//! > "We observed MLPerf TensorFlow benchmarks with non-contiguous gradient
//! > tensors had limited gradient summation throughput. We optimized the
//! > 2-D scheme by pipelining gathers from non-contiguous tensors from HBM
//! > to on device memory with summation of network packets in the reduction
//! > operation. In the broadcast phase the scatters of the result buffers to
//! > non-contiguous storage is pipelined with data transfer on the network.
//! > This aggressive pipelining ... results in over 1.5x speedup."
//!
//! Two real implementations over the fabric:
//!
//! * [`gradsum_serial`] — the baseline: each gradient tensor is gathered
//!   into contiguous staging, all-reduced with the 2-D schedule, and
//!   scattered back, one tensor at a time. Many small tensors ⇒ many small
//!   ring messages ⇒ latency-bound.
//! * [`gradsum_pipelined`] — the paper's scheme: one logical flat buffer
//!   spanning all tensors; gathers (packs) run while the ring waits on
//!   incoming packets (`try_recv` polling), and scatters (unpacks) overlap
//!   the all-gather phase the same way.

use crate::fabric::{Endpoint, Payload};

use super::ring::{chunk_range, owned_chunk};
use super::torus2d::{torus2d_all_reduce, Placement};

/// Flat view over a list of non-contiguous tensors.
pub struct FlatView<'a> {
    tensors: Vec<&'a mut [f32]>,
    /// Flat offset where each tensor starts; last entry = total length.
    offsets: Vec<usize>,
}

impl<'a> FlatView<'a> {
    pub fn new(tensors: Vec<&'a mut [f32]>) -> FlatView<'a> {
        let mut offsets = Vec::with_capacity(tensors.len() + 1);
        let mut total = 0;
        for t in &tensors {
            offsets.push(total);
            total += t.len();
        }
        offsets.push(total);
        FlatView { tensors, offsets }
    }

    pub fn len(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy flat range [start, end) из tensors into `dst` (the "gather").
    pub fn pack(&self, start: usize, end: usize, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), end - start);
        let mut ti = match self.offsets.binary_search(&start) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        let mut flat = start;
        while flat < end {
            while self.offsets[ti + 1] <= flat {
                ti += 1;
            }
            let t_start = flat - self.offsets[ti];
            let take = (end - flat).min(self.tensors[ti].len() - t_start);
            dst[flat - start..flat - start + take]
                .copy_from_slice(&self.tensors[ti][t_start..t_start + take]);
            flat += take;
        }
    }

    /// Copy `src` back into the tensors at flat range [start, end)
    /// (the "scatter").
    pub fn unpack(&mut self, start: usize, end: usize, src: &[f32]) {
        debug_assert_eq!(src.len(), end - start);
        let mut ti = match self.offsets.binary_search(&start) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        let mut flat = start;
        while flat < end {
            while self.offsets[ti + 1] <= flat {
                ti += 1;
            }
            let t_start = flat - self.offsets[ti];
            let take = (end - flat).min(self.tensors[ti].len() - t_start);
            self.tensors[ti][t_start..t_start + take]
                .copy_from_slice(&src[flat - start..flat - start + take]);
            flat += take;
        }
    }
}

/// Baseline: per-tensor gather → 2-D all-reduce → scatter, no overlap.
pub fn gradsum_serial(ep: &mut Endpoint, place: &Placement, tensors: &mut [Vec<f32>]) {
    for t in tensors.iter_mut() {
        let mut staging = t.clone(); // gather from "HBM"
        torus2d_all_reduce(ep, place, &mut staging);
        t.copy_from_slice(&staging); // scatter back
    }
}

/// Incremental packer: advances through the flat range as polling slack
/// allows; `ensure(end)` forces progress when a send needs the data now.
struct Packer<'a, 'b> {
    view: &'b FlatView<'a>,
    staging: &'b mut [f32],
    cursor: usize,
    /// Elements to pack per opportunistic slice (keeps poll loops live).
    quantum: usize,
}

impl<'a, 'b> Packer<'a, 'b> {
    fn step(&mut self) -> bool {
        if self.cursor >= self.view.len() {
            return false;
        }
        let end = (self.cursor + self.quantum).min(self.view.len());
        self.view.pack(self.cursor, end, &mut self.staging[self.cursor..end]);
        self.cursor = end;
        true
    }

    fn ensure(&mut self, end: usize) {
        while self.cursor < end {
            self.step();
        }
    }
}

/// Blocking matched recv that packs/unpacks while polling.
fn recv_overlapping(
    ep: &mut Endpoint,
    from: usize,
    tag: u64,
    mut work: impl FnMut() -> bool,
) -> Vec<f32> {
    loop {
        if let Some(p) = ep.try_recv(from, tag) {
            return p.into_f32();
        }
        if !work() {
            // No overlap work left: block.
            return ep.recv(from, tag).into_f32();
        }
    }
}

/// Reusable staging buffer for [`gradsum_pipelined_ws`] — on TPU this is
/// the fixed on-device staging area; reusing it across steps avoids paying
/// page-fault zeroing on every call.
#[derive(Default)]
pub struct GradSumWorkspace {
    staging: Vec<f32>,
}

/// The paper's pipelined non-contiguous gradient summation (2-D schedule).
///
/// `quantum` controls the gather/scatter granularity that is interleaved
/// with network waits (≈ the DMA burst size on TPU).
pub fn gradsum_pipelined(
    ep: &mut Endpoint,
    place: &Placement,
    tensors: &mut [Vec<f32>],
    quantum: usize,
) {
    let mut ws = GradSumWorkspace::default();
    gradsum_pipelined_ws(ep, place, tensors, quantum, &mut ws);
}

/// [`gradsum_pipelined`] with a caller-owned workspace (the hot-path form).
pub fn gradsum_pipelined_ws(
    ep: &mut Endpoint,
    place: &Placement,
    tensors: &mut [Vec<f32>],
    quantum: usize,
    ws: &mut GradSumWorkspace,
) {
    let mut view = FlatView::new(tensors.iter_mut().map(|t| t.as_mut_slice()).collect());
    let total = view.len();
    if total == 0 {
        return;
    }
    let world = place.torus.chips();
    if world <= 1 {
        return;
    }
    if ws.staging.len() < total {
        ws.staging.resize(total, 0.0);
    }
    let staging = &mut ws.staging[..total];

    let row = place.row_group(ep.rank);
    let col = place.col_group(ep.rank);
    let nx = row.len();

    // Opportunistic pack/unpack during network waits only pays off when
    // worker threads have real parallel hardware underneath; on a 1-CPU
    // host the poll loop just steals cycles from the peer that is trying
    // to send. The *fused schedule* (one logical all-reduce over the flat
    // buffer instead of one per tensor) is beneficial either way.
    let overlap = std::thread::available_parallelism().map(|n| n.get() > 1).unwrap_or(false);

    // ---- Phase 1: row reduce-scatter with packing overlapped -------------
    {
        let mut packer = Packer { view: &view, staging, cursor: 0, quantum };
        if !overlap {
            packer.ensure(total);
        }
        if nx > 1 {
            let pos = row.iter().position(|&r| r == ep.rank).unwrap();
            let next = row[(pos + 1) % nx];
            let prev = row[(pos + nx - 1) % nx];
            let tags = ep.fresh_tags(nx as u64);
            for step in 0..nx - 1 {
                let send_c = (pos + nx - step) % nx;
                let recv_c = (pos + nx - step - 1) % nx;
                let sr = chunk_range(total, nx, send_c);
                packer.ensure(sr.end); // gather just-in-time for the send
                let chunk = packer.staging[sr].to_vec();
                ep.send(next, tags + step as u64, Payload::F32(chunk));
                let incoming = if overlap {
                    // Poll for the packet; pack forward while waiting (the
                    // paper's gather/summation overlap).
                    loop {
                        if let Some(p) = ep.try_recv(prev, tags + step as u64) {
                            break p.into_f32();
                        }
                        if !packer.step() {
                            break ep.recv(prev, tags + step as u64).into_f32();
                        }
                    }
                } else {
                    ep.recv(prev, tags + step as u64).into_f32()
                };
                let rr = chunk_range(total, nx, recv_c);
                packer.ensure(rr.end);
                for (d, x) in packer.staging[rr].iter_mut().zip(incoming) {
                    *d += x;
                }
            }
        }
        packer.ensure(total);
    }

    // ---- Phase 2: column all-reduce of my owned row-chunk ----------------
    let my_x = row.iter().position(|&r| r == ep.rank).unwrap();
    let row_range = if nx > 1 {
        chunk_range(total, nx, owned_chunk(my_x, nx))
    } else {
        0..total
    };
    if col.len() > 1 {
        // (column ring; the chunk is contiguous in staging already)
        super::ring::ring_all_reduce(ep, &col, &mut staging[row_range]);
    }

    // ---- Phase 3: row all-gather with scattering overlapped --------------
    if nx > 1 {
        let pos = my_x;
        let next = row[(pos + 1) % nx];
        let prev = row[(pos + nx - 1) % nx];
        let tags = ep.fresh_tags(nx as u64);
        // Track which chunks are final so we can unpack them during waits.
        let mut pending_unpack: Vec<usize> = vec![owned_chunk(pos, nx)];
        for step in 0..nx - 1 {
            let send_c = (pos + 1 + nx - step) % nx;
            let recv_c = (pos + nx - step) % nx;
            let sr = chunk_range(total, nx, send_c);
            ep.send(next, tags + step as u64, Payload::F32(staging[sr].to_vec()));
            let incoming = if overlap {
                recv_overlapping(ep, prev, tags + step as u64, || {
                    if let Some(c) = pending_unpack.pop() {
                        let r = chunk_range(total, nx, c);
                        view.unpack(r.start, r.end, &staging[r]);
                        true
                    } else {
                        false
                    }
                })
            } else {
                ep.recv(prev, tags + step as u64).into_f32()
            };
            let rr = chunk_range(total, nx, recv_c);
            staging[rr.clone()].copy_from_slice(&incoming);
            view.unpack(rr.start, rr.end, &staging[rr.clone()]);
        }
        // Unpack anything the poll loop never got to.
        for c in pending_unpack {
            let r = chunk_range(total, nx, c);
            view.unpack(r.start, r.end, &staging[r]);
        }
    } else {
        view.unpack(0, total, &staging);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::run_spmd;

    fn make_tensors(rank: usize, sizes: &[usize]) -> Vec<Vec<f32>> {
        sizes
            .iter()
            .enumerate()
            .map(|(ti, &s)| {
                (0..s).map(|i| ((rank * 7 + ti * 3 + i) % 11) as f32 - 5.0).collect()
            })
            .collect()
    }

    fn expected(world: usize, sizes: &[usize]) -> Vec<Vec<f32>> {
        sizes
            .iter()
            .enumerate()
            .map(|(ti, &s)| {
                (0..s)
                    .map(|i| {
                        (0..world)
                            .map(|r| ((r * 7 + ti * 3 + i) % 11) as f32 - 5.0)
                            .sum()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn flatview_pack_unpack_round_trip() {
        let mut a = vec![1.0, 2.0, 3.0];
        let mut b = vec![4.0];
        let mut c = vec![5.0, 6.0];
        let mut view =
            FlatView::new(vec![a.as_mut_slice(), b.as_mut_slice(), c.as_mut_slice()]);
        let mut buf = vec![0.0; 4];
        view.pack(1, 5, &mut buf);
        assert_eq!(buf, vec![2.0, 3.0, 4.0, 5.0]);
        view.unpack(1, 5, &[20.0, 30.0, 40.0, 50.0]);
        drop(view);
        assert_eq!(a, vec![1.0, 20.0, 30.0]);
        assert_eq!(b, vec![40.0]);
        assert_eq!(c, vec![50.0, 6.0]);
    }

    #[test]
    fn serial_and_pipelined_agree_with_sum() {
        let world = 4;
        let sizes = vec![5, 1, 17, 2, 33, 8];
        let want = expected(world, &sizes);
        for pipelined in [false, true] {
            let out = run_spmd(world, |ep| {
                let place = Placement::new(world);
                let mut tensors = make_tensors(ep.rank, &sizes);
                if pipelined {
                    gradsum_pipelined(ep, &place, &mut tensors, 4);
                } else {
                    gradsum_serial(ep, &place, &mut tensors);
                }
                tensors
            });
            for r in 0..world {
                for (t, w) in out[r].iter().zip(&want) {
                    for (x, y) in t.iter().zip(w) {
                        assert!((x - y).abs() < 1e-4, "pipelined={pipelined} rank={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn pipelined_handles_single_tensor() {
        let world = 2;
        let sizes = vec![64];
        let want = expected(world, &sizes);
        let out = run_spmd(world, |ep| {
            let place = Placement::new(world);
            let mut tensors = make_tensors(ep.rank, &sizes);
            gradsum_pipelined(ep, &place, &mut tensors, 16);
            tensors
        });
        for r in 0..world {
            assert_eq!(out[r][0], want[0], "rank {r}");
        }
    }

    #[test]
    fn pipelined_handles_tensors_smaller_than_world() {
        // Chunks span tensor boundaries; tiny tensors must still sum.
        let world = 8;
        let sizes = vec![1, 1, 1, 2, 1];
        let want = expected(world, &sizes);
        let out = run_spmd(world, |ep| {
            let place = Placement::new(world);
            let mut tensors = make_tensors(ep.rank, &sizes);
            gradsum_pipelined(ep, &place, &mut tensors, 2);
            tensors
        });
        for r in 0..world {
            for (t, w) in out[r].iter().zip(&want) {
                assert_eq!(t, w, "rank {r}");
            }
        }
    }

    #[test]
    fn pipelined_quantum_one() {
        let world = 4;
        let sizes = vec![3, 9, 2];
        let want = expected(world, &sizes);
        let out = run_spmd(world, |ep| {
            let place = Placement::new(world);
            let mut tensors = make_tensors(ep.rank, &sizes);
            gradsum_pipelined(ep, &place, &mut tensors, 1);
            tensors
        });
        for r in 0..world {
            for (t, w) in out[r].iter().zip(&want) {
                for (x, y) in t.iter().zip(w) {
                    assert!((x - y).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn empty_tensor_list_is_noop() {
        run_spmd(2, |ep| {
            let place = Placement::new(2);
            let mut tensors: Vec<Vec<f32>> = vec![];
            gradsum_pipelined(ep, &place, &mut tensors, 8);
        });
    }
}
