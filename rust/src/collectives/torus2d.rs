//! The paper's 2-D gradient-summation schedule (§2, technique from [19]):
//! reduce-scatter along X rings, reduce-scatter the surviving shard along Y
//! rings, then the matching all-gathers in reverse — so both torus
//! dimensions' links carry traffic and the latency term scales with
//! nx + ny instead of nx * ny.
//!
//! Runs on real buffers over the fabric; the math must be bit-identical in
//! structure to a flat all-reduce (same f32 additions, different order —
//! tolerance 1e-5 in tests).

use crate::fabric::Endpoint;
use crate::netsim::Torus;

use super::ring::{chunk_range, owned_chunk, ring_all_gather, ring_all_reduce, ring_reduce_scatter};

/// Logical placement of a fabric rank on a (nx x ny) torus, row-major.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub torus: Torus,
}

impl Placement {
    /// Any world size: ranks are laid out on the near-square exact
    /// factorization of `world` (primes degenerate to a 1-D ring, which
    /// `torus2d_all_reduce` handles with a plain ring all-reduce).
    pub fn new(world: usize) -> Placement {
        assert!(world >= 1, "world must be at least 1");
        Placement { torus: Torus::for_chips(world) }
    }

    /// Fabric ranks in this rank's X ring (its row), in ring order.
    pub fn row_group(&self, rank: usize) -> Vec<usize> {
        let c = self.torus.coord(rank);
        (0..self.torus.nx).map(|x| c.y * self.torus.nx + x).collect()
    }

    /// Fabric ranks in this rank's Y ring (its column), in ring order.
    pub fn col_group(&self, rank: usize) -> Vec<usize> {
        let c = self.torus.coord(rank);
        (0..self.torus.ny).map(|y| y * self.torus.nx + c.x).collect()
    }
}

/// 2-D all-reduce of `data` across the whole fabric arranged per `place`.
pub fn torus2d_all_reduce(ep: &mut Endpoint, place: &Placement, data: &mut [f32]) {
    let nx = place.torus.nx;
    let ny = place.torus.ny;
    if nx * ny <= 1 {
        return;
    }
    let row = place.row_group(ep.rank);
    let col = place.col_group(ep.rank);
    if nx == 1 {
        ring_all_reduce(ep, &col, data);
        return;
    }
    if ny == 1 {
        ring_all_reduce(ep, &row, data);
        return;
    }

    // Phase 1: reduce-scatter along the row; I own row-chunk `rc`.
    ring_reduce_scatter(ep, &row, data);
    let my_x = row.iter().position(|&r| r == ep.rank).unwrap();
    let rc = owned_chunk(my_x, nx);
    let row_range = chunk_range(data.len(), nx, rc);

    // Phase 2+3: all-reduce my row-chunk along the column (RS+AG fused —
    // after this the whole row-chunk is globally reduced on every member
    // of my column).
    ring_all_reduce(ep, &col, &mut data[row_range]);

    // Phase 4: all-gather the row-chunks back along the row.
    ring_all_gather(ep, &row, data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::run_spmd;

    fn check_allreduce(world: usize, len: usize) {
        let out = run_spmd(world, |ep| {
            let place = Placement::new(world);
            let mut data: Vec<f32> =
                (0..len).map(|i| ((ep.rank * 31 + i * 7) % 13) as f32 - 6.0).collect();
            torus2d_all_reduce(ep, &place, &mut data);
            data
        });
        for i in 0..len {
            let expect: f32 =
                (0..world).map(|r| ((r * 31 + i * 7) % 13) as f32 - 6.0).sum();
            for r in 0..world {
                assert!(
                    (out[r][i] - expect).abs() < 1e-4,
                    "world={world} elt {i} rank {r}: {} vs {expect}",
                    out[r][i]
                );
            }
        }
    }

    #[test]
    fn matches_flat_sum_square_torus() {
        check_allreduce(16, 103); // 4x4
    }

    #[test]
    fn matches_flat_sum_rect_torus() {
        check_allreduce(8, 57); // 4x2
    }

    #[test]
    fn matches_flat_sum_two_ranks() {
        check_allreduce(2, 9);
    }

    #[test]
    fn matches_flat_sum_non_power_of_two() {
        check_allreduce(3, 17); // 3x1 ring
        check_allreduce(6, 29); // 3x2
        check_allreduce(12, 53); // 4x3
    }

    #[test]
    fn single_rank_noop() {
        let out = run_spmd(1, |ep| {
            let place = Placement::new(1);
            let mut data = vec![5.0f32, -1.0];
            torus2d_all_reduce(ep, &place, &mut data);
            data
        });
        assert_eq!(out[0], vec![5.0, -1.0]);
    }

    #[test]
    fn placement_groups_are_rings() {
        let p = Placement::new(16); // 4x4
        assert_eq!(p.row_group(5), vec![4, 5, 6, 7]);
        assert_eq!(p.col_group(5), vec![1, 5, 9, 13]);
    }

    #[test]
    fn agrees_with_1d_ring() {
        // Both schedules must produce the same sums (modulo f32 order).
        let world = 8;
        let len = 41;
        let out = run_spmd(world, |ep| {
            let group: Vec<usize> = (0..world).collect();
            let place = Placement::new(world);
            let mut a: Vec<f32> = (0..len).map(|i| (ep.rank + i) as f32).collect();
            let mut b = a.clone();
            ring_all_reduce(ep, &group, &mut a);
            torus2d_all_reduce(ep, &place, &mut b);
            (a, b)
        });
        for (a, b) in out {
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4);
            }
        }
    }
}
