//! `tpu-pod-train` launcher.
//!
//! Subcommands:
//! * `train`    — run the real data-parallel trainer on the in-process pod
//!                (`--backend reference` needs no artifacts and is the
//!                CI-gated default; `--backend pjrt` executes AOT
//!                artifacts built by `python python/compile/aot.py`).
//! * `simulate` — TPU-v3 pod time-to-train simulation for one MLPerf model.
//! * `sweep`    — scenario sweep engine: models × pod slices, JSON report
//!                (the Figs. 7-10 / Table 1 experiment driver); `--grid`
//!                runs the §2 ablation cross-product over `--jobs` workers;
//!                `--live` calibrates the simulator against the live
//!                reference trainer (nonzero exit on trend disagreement).
//! * `submit`   — full simulated MLPerf-0.6 submission (all five models,
//!                Fig. 9-style table).
//! * `faults`   — generate a seeded fault/straggler trace for `train
//!                --faults` and `sweep --faults` (goodput reporting).
//! * `trace`    — summarize a `--trace` file: per-phase p50/p99 tables,
//!                goodput timeline, cache-hit rates, and the accounting
//!                cross-check against the TrainReport counters (nonzero
//!                exit on disagreement).
//! * `info`     — list artifacts, models and device constants.

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::calibrate::{
    fitted_gflops_from_file, run_fault_audit, run_live_calibration, FaultAuditOptions,
    LiveGridOptions,
};
use tpu_pod_train::config::Config;
use tpu_pod_train::coordinator::{train, GradSumMode, OptChoice, TrainConfig};
use tpu_pod_train::metrics::{summarize, Trace, TraceSink, DEFAULT_TOLERANCE};
use tpu_pod_train::models::{all_models, model};
use tpu_pod_train::netsim::CrossPodStrategy;
use tpu_pod_train::optim::{AdamConfig, LarsConfig, LarsVariant};
use tpu_pod_train::runtime::{BackendChoice, Manifest};
use tpu_pod_train::scenario::{
    compare_reports, grid_marginals, AblationGrid, BatchSchedule, FaultTrace, GradSumChoice,
    ScalingScenario, SweepReport, SweepRunner,
};
use tpu_pod_train::simulator::{simulate, SimOptions};
use tpu_pod_train::util::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { vec![] } else { args[1..].to_vec() };
    let code = match cmd {
        "train" => cmd_train(&rest),
        "simulate" => cmd_simulate(&rest),
        "sweep" => cmd_sweep(&rest),
        "submit" => cmd_submit(&rest),
        "faults" => cmd_faults(&rest),
        "trace" => cmd_trace(&rest),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "tpu-pod-train — MLPerf-0.6 TPU-v3 pod reproduction\n\n\
                 Usage: tpu-pod-train <train|simulate|sweep|submit|faults|trace|info> [options]\n\
                 Run a subcommand with --help for its options."
            );
            2
        }
    };
    std::process::exit(code);
}

fn cmd_train(tokens: &[String]) -> i32 {
    let cli = Cli::new("train", "run the real trainer on the in-process pod")
        .opt("config", "", "TOML config file (CLI flags override)")
        .opt("model", "transformer", "model family (reference) or manifest key (pjrt)")
        .opt("backend", "reference", "fwd/bwd executor: reference | reference-bf16 | pjrt")
        .opt("cores", "4", "data-parallel workers (any positive count)")
        .opt("steps", "100", "training steps")
        .opt("batch-per-core", "0", "per-core batch override (reference backend; 0 = default)")
        .opt("eval-every", "25", "eval cadence in steps (0 = never)")
        .opt("eval-examples", "256", "evaluation set size")
        .opt("optimizer", "adam", "adam | lars | lars-scaled | sgd")
        .opt("lr", "0.001", "learning rate")
        .opt("momentum", "0.9", "momentum (sgd/lars)")
        .opt("target", "0", "quality target accuracy (0 = none)")
        .opt("seed", "0", "rng seed")
        .opt("checkpoint-every", "0", "write a durable checkpoint every N steps (0 = never)")
        .opt("checkpoint-dir", "", "directory for ckpt-step*.ckpt files")
        .opt("resume", "", "checkpoint file to resume from")
        .opt("faults", "", "fault/straggler trace JSON (chip = worker rank)")
        .opt(
            "trace",
            "",
            "write a structured trace here (.jsonl = JSON-lines, else Chrome/Perfetto format)",
        )
        .opt("kill-at", "0", "abort the process (exit 3) after this step (CI smoke; 0 = never)")
        .opt(
            "exec-threads",
            "1",
            "intra-core executor threads, reference backend (0 = all host threads)",
        )
        .flag("wus", "shard the weight update across cores (paper §2)")
        .flag("serial-gradsum", "disable the pipelined gradient summation")
        .flag("check-improved", "exit 1 unless the final loss beats the seeded-start loss (CI)");
    let a = match cli.parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let mut file_cfg = Config::default();
    let cfg_path = a.get_or("config", "");
    if !cfg_path.is_empty() {
        match Config::from_file(&cfg_path) {
            Ok(c) => file_cfg = c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    }
    let get_s = |k: &str, d: &str| {
        a.get(k).map(|v| v.to_string()).unwrap_or_else(|| file_cfg.str_or(&format!("train.{k}"), d))
    };
    let lr = a.get_f64("lr", file_cfg.f64_or("train.lr", 1e-3)) as f32;
    let momentum = a.get_f64("momentum", 0.9) as f32;
    let opt = match get_s("optimizer", "adam").as_str() {
        "adam" => OptChoice::Adam { cfg: AdamConfig::default(), lr },
        "lars" => OptChoice::Lars { cfg: LarsConfig::default(), lr },
        "lars-scaled" => OptChoice::Lars {
            cfg: LarsConfig { variant: LarsVariant::Scaled, momentum, ..Default::default() },
            lr,
        },
        "sgd" => OptChoice::Sgd { lr, momentum },
        other => {
            eprintln!("unknown optimizer {other:?}");
            return 2;
        }
    };
    let backend = match BackendChoice::parse(&get_s("backend", "reference")) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let batch_per_core = a.get_usize("batch-per-core", 0);
    let target = a.get_f64("target", 0.0);
    let ckpt_dir = get_s("checkpoint-dir", "");
    let resume = get_s("resume", "");
    let faults_path = get_s("faults", "");
    let faults = if faults_path.is_empty() {
        None
    } else {
        match FaultTrace::load(&faults_path) {
            Ok(t) => Some(t),
            Err(e) => {
                eprintln!("fault trace error: {e}");
                return 2;
            }
        }
    };
    let trace_path = get_s("trace", "");
    let trace_sink =
        if trace_path.is_empty() { TraceSink::disabled() } else { TraceSink::enabled() };
    let cfg = TrainConfig {
        model: get_s("model", "transformer"),
        cores: a.get_usize("cores", file_cfg.usize_or("train.cores", 4)),
        steps: a.get_usize("steps", file_cfg.usize_or("train.steps", 100)),
        eval_every: a.get_usize("eval-every", 25),
        eval_examples: a.get_usize("eval-examples", 256),
        opt,
        use_wus: a.flag("wus") || file_cfg.bool_or("train.use_wus", false),
        gradsum: if a.flag("serial-gradsum") {
            GradSumMode::Serial
        } else {
            GradSumMode::Pipelined { quantum: 4096 }
        },
        backend,
        batch_override: (batch_per_core > 0).then_some(batch_per_core),
        seed: a.get_usize("seed", 0) as u64,
        task_difficulty: 0.05,
        image_alpha: 2.0,
        quality_target: (target > 0.0).then_some(target),
        warmup_steps: 0,
        checkpoint_every: a.get_usize("checkpoint-every", 0),
        checkpoint_dir: (!ckpt_dir.is_empty()).then(|| std::path::PathBuf::from(&ckpt_dir)),
        resume: (!resume.is_empty()).then(|| std::path::PathBuf::from(&resume)),
        faults,
        kill_at: a.get_usize("kill-at", 0),
        exec_threads: a.get_usize("exec-threads", 1),
        trace: trace_sink.clone(),
    };
    if cfg.cores == 0 {
        eprintln!("--cores must be at least 1 (any positive count; no power-of-two requirement)");
        return 2;
    }
    if cfg.steps == 0 {
        eprintln!("--steps must be at least 1");
        return 2;
    }
    println!(
        "training {} on {} cores, {} steps (backend={}, wus={}, gradsum={:?})",
        cfg.model,
        cfg.cores,
        cfg.steps,
        cfg.backend.label(),
        cfg.use_wus,
        cfg.gradsum
    );
    let result = train(&cfg);
    // Export the trace even when training failed: a partial trace of a
    // crashed run is exactly what the postmortem needs.
    if !trace_path.is_empty() {
        let t = trace_sink.drain();
        match t.write(std::path::Path::new(&trace_path)) {
            Ok(()) => eprintln!("trace written to {trace_path} ({} events)", t.len()),
            Err(e) => {
                eprintln!("writing trace {trace_path}: {e}");
                return 1;
            }
        }
    }
    match result {
        Ok(rep) => {
            println!(
                "init {:.1}s, train wall {:.1}s, exec {:.1}s (fwd {:.1}s, bwd {:.1}s), params {}",
                rep.init_s, rep.wallclock_s, rep.exec_s, rep.fwd_s, rep.bwd_s, rep.params_total
            );
            println!("{}", rep.breakdown.report());
            if rep.resumed_from > 0 {
                println!("resumed from step {}", rep.resumed_from);
            }
            if !rep.checkpoints.is_empty() {
                println!("checkpoints written at steps {:?}", rep.checkpoints);
            }
            if rep.restores > 0 || rep.straggled_steps > 0 {
                println!(
                    "faults: goodput {:.3}, {} restore(s), {} lost step(s), \
                     {} straggled step(s), final cores {}",
                    rep.goodput, rep.restores, rep.lost_steps, rep.straggled_steps,
                    rep.final_cores
                );
            }
            let n = rep.step_losses.len();
            let stride = (n / 10).max(1);
            for (i, chunk) in rep.step_losses.chunks(stride).enumerate() {
                let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
                println!("  steps {:>4}..: loss {:.4}", i * stride + 1, mean);
            }
            for e in &rep.evals {
                println!("  eval @ step {:>4}: loss {:.4} acc {:.3}", e.step, e.loss, e.accuracy);
            }
            if let Some(s) = rep.converged_at {
                println!("quality target reached at step {s}");
            }
            // Seeded-start vs final loss (the CI live-trainer gate).
            if !rep.step_losses.is_empty() {
                let k = rep.step_losses.len().min(5);
                let first: f32 = rep.step_losses[..k].iter().sum::<f32>() / k as f32;
                let last: f32 =
                    rep.step_losses[rep.step_losses.len() - k..].iter().sum::<f32>() / k as f32;
                let improved = last < first;
                println!(
                    "loss start {first:.4} → final {last:.4} ({})",
                    if improved { "improved" } else { "NOT improved" }
                );
                if a.flag("check-improved") && !improved {
                    eprintln!("--check-improved: final loss did not beat the seeded-start loss");
                    return 1;
                }
            } else if a.flag("check-improved") {
                eprintln!("--check-improved: no steps ran");
                return 1;
            }
            0
        }
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

fn cmd_simulate(tokens: &[String]) -> i32 {
    let cli = Cli::new("simulate", "TPU-v3 pod time-to-train simulation")
        .opt("model", "resnet50", "resnet50|ssd|maskrcnn|transformer|gnmt")
        .opt("cores", "2048", "TPU-v3 cores")
        .opt("pods", "1", "pods in the group (hierarchical multi-pod topology)")
        .opt("inter-pod-ratio", "1", "inter-pod : intra-pod link bandwidth ratio, in (0, 1]")
        .opt("cross-pod", "hierarchical", "cross-pod gradsum strategy: hierarchical|flat-ring")
        .flag("no-wus", "disable weight-update sharding")
        .flag("no-pipelining", "disable pipelined gradient summation")
        .flag("no-2d", "use 1-D ring gradient summation")
        .flag("no-dist-eval", "use side-card evaluation")
        .flag("no-spatial", "disable spatial partitioning");
    let a = match cli.parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let name = a.get_or("model", "resnet50");
    let Some(m) = model(&name) else {
        eprintln!("unknown model {name}");
        return 2;
    };
    let xp_arg = a.get_or("cross-pod", "hierarchical");
    let Some(xp) = CrossPodStrategy::parse(&xp_arg) else {
        eprintln!("bad --cross-pod value {xp_arg:?} (expected hierarchical or flat-ring)");
        return 2;
    };
    let mut opts = SimOptions::submission()
        .pods(a.get_usize("pods", 1), a.get_f64("inter-pod-ratio", 1.0))
        .cross_pod(xp);
    if let Err(e) = opts.pods.validate() {
        eprintln!("simulate: {e}");
        return 2;
    }
    if a.flag("no-2d") {
        opts = opts.ring_gradsum();
    }
    if a.flag("no-pipelining") {
        opts = opts.serial_gradsum();
    }
    if a.flag("no-wus") {
        opts = opts.without_wus();
    }
    if a.flag("no-dist-eval") {
        opts = opts.without_distributed_eval();
    }
    if a.flag("no-spatial") {
        opts = opts.without_spatial();
    }
    let r = simulate(&m, a.get_usize("cores", 2048), &opts);
    println!("{name} @ {} cores: layout {:?}", r.cores, r.layout);
    if !opts.pods.collapses() {
        println!(
            "  pod group: {} pods @ inter-pod bandwidth ratio {}, {} cross-pod gradsum",
            opts.pods.pods, opts.pods.inter_pod_ratio, opts.pods.strategy.label()
        );
    }
    println!(
        "  participating {} cores ({} surplus/idle)",
        r.participating_cores, r.surplus_cores
    );
    println!(
        "  epochs {:.1}, steps {:.0}, step {:.2} ms \
         (compute {:.2} / halo {:.2} / gradsum {:.2} / update {:.2})",
        r.epochs,
        r.steps,
        r.step_seconds * 1e3,
        r.compute_seconds * 1e3,
        r.halo_seconds * 1e3,
        r.gradsum_seconds * 1e3,
        r.update_seconds * 1e3
    );
    println!("  per-phase groups:");
    for c in &r.phases {
        println!(
            "    {:<8} {:>12.4} ms over {} cores",
            c.phase.label(),
            c.seconds * 1e3,
            c.cores
        );
    }
    println!(
        "  eval {:.1}s, infra {:.1}s → benchmark {:.1}s",
        r.eval_seconds, r.infra_seconds, r.benchmark_seconds
    );
    0
}

/// `sweep --faults TRACE --live`: the shared-trace goodput audit.
/// Replays the trace's fatal-event ladder through the live reference
/// trainer and the simulator's `price_fault_trace`, prints the
/// comparison JSON, and exits 1 on any trend disagreement.
fn cmd_fault_audit(a: &tpu_pod_train::util::cli::Args) -> i32 {
    let defaults = FaultAuditOptions::default();
    let model_arg = a.get_or("model", "");
    if model_arg.contains(',') || model_arg == "all" {
        eprintln!("the fault audit replays one model family, got --model {model_arg}");
        return 2;
    }
    let opts = FaultAuditOptions {
        model: if model_arg.is_empty() { defaults.model.clone() } else { model_arg },
        cores: a.get_usize("live-cores", defaults.cores),
        steps: a.get_usize("live-steps", defaults.steps),
        checkpoint_every: a.get_usize("audit-ckpt-every", defaults.checkpoint_every),
        tolerance: a.get_f64("live-tolerance", defaults.tolerance),
        max_fatal_events: a.get_usize("audit-max-events", defaults.max_fatal_events),
        seed: a.get_usize("audit-seed", defaults.seed as usize) as u64,
        ..defaults
    };
    let faults_path = a.get_or("faults", "");
    let trace = match FaultTrace::load(&faults_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("loading fault trace {faults_path}: {e}");
            return 2;
        }
    };
    eprintln!(
        "fault audit: {} on {} workers, {} steps, checkpoint every {}, trace {:?}",
        opts.model, opts.cores, opts.steps, opts.checkpoint_every, trace.name
    );
    let rep = match run_fault_audit(&opts, &trace) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fault audit error: {e:#}");
            return 2;
        }
    };
    println!("{}", rep.to_json().dump());
    let out = a.get_or("out", "");
    if !out.is_empty() {
        if let Err(e) = rep.write(&out) {
            eprintln!("writing {out}: {e}");
            return 1;
        }
        eprintln!("report written to {out}");
    }
    if !rep.agrees() {
        for d in &rep.disagreements {
            eprintln!("fault-audit disagreement: {d}");
        }
        return 1;
    }
    eprintln!(
        "live/simulated goodput agree over {} ladder rung(s) (|gap| <= {:.2})",
        rep.points.len(),
        rep.tolerance
    );
    0
}

fn cmd_sweep(tokens: &[String]) -> i32 {
    let cli = Cli::new("sweep", "pod-scale scenario sweep (Figs. 7-10 / Table 1 engine)")
        .opt("model", "", "resnet50|ssd|maskrcnn|transformer|gnmt|all (all with --grid)")
        .opt("chips", "", "TPU-v3 chip counts (default 16,64,256,1024; paper ladder with --grid)")
        .opt("batch", "0", "fixed global batch (0 = submission layout policy)")
        .opt("pods", "1", "pods in the group; a comma list with --grid adds a grid axis")
        .opt(
            "inter-pod-ratio",
            "1",
            "inter-pod : intra-pod bandwidth ratio in (0, 1]; comma list with --grid",
        )
        .opt(
            "cross-pod",
            "hierarchical",
            "cross-pod gradsum: hierarchical|flat-ring; comma list with --grid",
        )
        .opt("jobs", "1", "point-execution workers (0 = one per core; output matches --jobs 1)")
        .opt("out", "", "also write the JSON report to this file")
        .opt("compare", "", "baseline SweepReport JSON to diff against (exit 1 on regression)")
        .opt("tolerance", "0.02", "relative benchmark-seconds regression tolerance for --compare")
        .opt("faults", "", "fault trace JSON: reprice every point under failures, report goodput")
        .opt(
            "costs-from",
            "",
            "live calibration JSON (sweep --live --out): price compute at its fitted_gflops",
        )
        .opt(
            "trace",
            "",
            "write a structured trace here (.jsonl = JSON-lines, else Chrome/Perfetto format)",
        )
        .opt("live-steps", "12", "training steps per live calibration point (--live)")
        .opt("live-cores", "2", "data-parallel workers per live point, any positive count (--live)")
        .opt("live-threads", "1", "executor threads for --live (0 = all host threads)")
        .opt("live-tolerance", "0.35", "relative slack for the --live trend checks")
        .opt("audit-ckpt-every", "4", "checkpoint cadence for the fault audit (--faults --live)")
        .opt("audit-max-events", "3", "fatal-event ladder cap for the fault audit")
        .opt("audit-seed", "0", "data/init seed for the fault audit's live runs")
        .flag("live", "calibrate: run the grid on the live trainer; exit 1 on trend disagreement")
        .flag("grid", "run the §2 ablation grid (spatial/WUS x gradsum schedule x LARS/SGD)")
        .flag("serial-gradsum", "expose the non-contiguous gathers (no pipelining)")
        .flag("no-2d", "use the 1-D ring gradient-summation schedule")
        .flag("no-wus", "disable weight-update sharding")
        .flag("no-dist-eval", "use side-card evaluation")
        .flag("no-spatial", "disable spatial partitioning")
        .flag("marginals", "with --grid: print the per-axis marginal speedup table")
        .flag("table", "print a human-readable table before the JSON report");
    let a = match cli.parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    if a.flag("live") {
        // Live calibration is a different engine (coordinator::train +
        // simulator attribution, see `calibrate`); the sweep axes do not
        // apply to it.
        for f in
            ["grid", "serial-gradsum", "no-2d", "no-wus", "no-dist-eval", "no-spatial", "marginals"]
        {
            if a.flag(f) {
                eprintln!("--{f} conflicts with --live (the live grid runs the reference trainer)");
                return 2;
            }
        }
        if !a.get_or("compare", "").is_empty() {
            eprintln!("--compare conflicts with --live");
            return 2;
        }
        for (name, default) in
            [("pods", "1"), ("inter-pod-ratio", "1"), ("cross-pod", "hierarchical")]
        {
            if a.get_or(name, default) != default {
                eprintln!(
                    "--{name} conflicts with --live (the live grid runs the reference trainer)"
                );
                return 2;
            }
        }
        if !a.get_or("costs-from", "").is_empty() {
            eprintln!("--costs-from conflicts with --live (--live *produces* the calibration)");
            return 2;
        }
        if !a.get_or("faults", "").is_empty() {
            // `--faults TRACE --live` is the shared-trace goodput audit:
            // replay the same trace through the live trainer and the
            // simulator's price_fault_trace, gate on agreement.
            if !a.get_or("trace", "").is_empty() {
                eprintln!("--trace is not supported with the --faults --live audit");
                return 2;
            }
            return cmd_fault_audit(&a);
        }
        let defaults = LiveGridOptions::default();
        let model_arg = a.get_or("model", "");
        let models: Vec<String> = if model_arg.is_empty() || model_arg == "all" {
            defaults.models.clone()
        } else {
            model_arg.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
        };
        let trace_path = a.get_or("trace", "");
        let sink =
            if trace_path.is_empty() { TraceSink::disabled() } else { TraceSink::enabled() };
        let opts = LiveGridOptions {
            models,
            cores: a.get_usize("live-cores", defaults.cores),
            steps: a.get_usize("live-steps", defaults.steps),
            exec_threads: a.get_usize("live-threads", defaults.exec_threads),
            tolerance: a.get_f64("live-tolerance", defaults.tolerance),
            trace: sink.clone(),
            ..defaults
        };
        if opts.cores == 0 {
            eprintln!("--live-cores must be at least 1");
            return 2;
        }
        if opts.steps == 0 {
            eprintln!("--live-steps must be positive");
            return 2;
        }
        eprintln!(
            "live calibration: {} families x {:?} batch multipliers, {} cores, {} steps/point",
            opts.models.len(),
            opts.batch_mults,
            opts.cores,
            opts.steps
        );
        let result = run_live_calibration(&opts);
        // Written even when calibration fails: a partial trace of a crashed
        // run is exactly the postmortem artifact.
        if !trace_path.is_empty() {
            let t = sink.drain();
            match t.write(std::path::Path::new(&trace_path)) {
                Ok(()) => eprintln!("trace written to {trace_path} ({} events)", t.len()),
                Err(e) => {
                    eprintln!("writing trace {trace_path}: {e}");
                    return 1;
                }
            }
        }
        let rep = match result {
            Ok(r) => r,
            Err(e) => {
                eprintln!("live calibration error: {e:#}");
                return 2;
            }
        };
        println!("{}", rep.to_json().dump());
        let out = a.get_or("out", "");
        if !out.is_empty() {
            if let Err(e) = rep.write(&out) {
                eprintln!("writing {out}: {e}");
                return 1;
            }
            eprintln!("report written to {out}");
        }
        if !rep.agrees() {
            for d in &rep.disagreements {
                eprintln!("trend disagreement: {d}");
            }
            return 1;
        }
        eprintln!(
            "live/simulated trends agree within {:.0}% (fitted compute {:.2} GFLOP/s)",
            100.0 * rep.tolerance,
            rep.fitted_gflops
        );
        return 0;
    }
    let grid_mode = a.flag("grid");
    if a.flag("marginals") && !grid_mode {
        eprintln!("--marginals requires --grid (marginals pair points across the ablation grid)");
        return 2;
    }
    let mut chips = Vec::new();
    for tok in a.get_or("chips", "").split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        match tok.parse::<usize>() {
            Ok(c) => chips.push(c),
            Err(_) => {
                eprintln!("bad chip count {tok:?} (expected e.g. --chips 16,64,256,1024)");
                return 2;
            }
        }
    }
    let model_arg = a.get_or("model", "");
    let model_arg = if model_arg.is_empty() {
        if grid_mode {
            "all".to_string()
        } else {
            "resnet50".to_string()
        }
    } else {
        model_arg
    };
    let names: Vec<String> = if model_arg == "all" {
        all_models().iter().map(|m| m.name.to_string()).collect()
    } else {
        vec![model_arg]
    };
    let jobs_raw = a.get_or("jobs", "1");
    let jobs: usize = match jobs_raw.trim().parse() {
        Ok(j) => j,
        Err(_) => {
            eprintln!("bad --jobs value {jobs_raw:?} (expected a nonnegative integer)");
            return 2;
        }
    };
    let batch_raw = a.get_or("batch", "0");
    let batch: usize = match batch_raw.trim().parse() {
        Ok(b) => b,
        Err(_) => {
            eprintln!("bad --batch value {batch_raw:?} (expected a nonnegative integer)");
            return 2;
        }
    };
    let mut pods_axis = Vec::new();
    for tok in a.get_or("pods", "1").split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        match tok.parse::<usize>() {
            Ok(p) if p >= 1 => pods_axis.push(p),
            _ => {
                eprintln!(
                    "bad --pods value {tok:?} (expected positive integers, e.g. --pods 1,2,4)"
                );
                return 2;
            }
        }
    }
    if pods_axis.is_empty() {
        pods_axis.push(1);
    }
    let mut ratio_axis = Vec::new();
    for tok in a.get_or("inter-pod-ratio", "1").split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        match tok.parse::<f64>() {
            Ok(r) if r > 0.0 && r <= 1.0 => ratio_axis.push(r),
            _ => {
                eprintln!("bad --inter-pod-ratio value {tok:?} (expected ratios in (0, 1])");
                return 2;
            }
        }
    }
    if ratio_axis.is_empty() {
        ratio_axis.push(1.0);
    }
    let mut xp_axis = Vec::new();
    for tok in a.get_or("cross-pod", "hierarchical").split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        match CrossPodStrategy::parse(tok) {
            Some(s) => xp_axis.push(s),
            None => {
                eprintln!("bad --cross-pod value {tok:?} (expected hierarchical or flat-ring)");
                return 2;
            }
        }
    }
    if xp_axis.is_empty() {
        xp_axis.push(CrossPodStrategy::Hierarchical);
    }
    let scenarios: Vec<ScalingScenario> = if grid_mode {
        // The §2 cross-product; --model/--chips narrow it, the per-axis
        // flags are meaningless here (the grid sweeps both settings).
        for f in ["serial-gradsum", "no-2d", "no-wus", "no-spatial"] {
            if a.flag(f) {
                eprintln!("--{f} conflicts with --grid (the grid sweeps that axis)");
                return 2;
            }
        }
        if a.flag("no-dist-eval") {
            eprintln!("--no-dist-eval conflicts with --grid (grid scenarios pin it on)");
            return 2;
        }
        if batch > 0 {
            eprintln!("--batch conflicts with --grid (the grid uses the submission batches)");
            return 2;
        }
        let mut g = AblationGrid::full_paper();
        g.models = names;
        if !chips.is_empty() {
            g.chips = chips;
        }
        g.pods = pods_axis;
        g.inter_pod_ratios = ratio_axis;
        g.cross_pod = xp_axis;
        let workers = tpu_pod_train::scenario::pool_workers(jobs, g.point_count());
        eprintln!(
            "ablation grid: {} scenarios x {} chip counts = {} points ({} workers)",
            g.scenario_count(),
            g.chips.len(),
            g.point_count(),
            workers
        );
        g.scenarios()
    } else {
        if chips.is_empty() {
            chips = vec![16, 64, 256, 1024];
        }
        if pods_axis.len() > 1 || ratio_axis.len() > 1 || xp_axis.len() > 1 {
            eprintln!(
                "comma lists for --pods/--inter-pod-ratio/--cross-pod need --grid \
                 (a plain sweep takes one value per axis)"
            );
            return 2;
        }
        let (pods_one, ratio_one, xp_one) = (pods_axis[0], ratio_axis[0], xp_axis[0]);
        let gradsum = match (!a.flag("no-2d"), !a.flag("serial-gradsum")) {
            (true, true) => GradSumChoice::Pipelined2D,
            (true, false) => GradSumChoice::Serial2D,
            (false, true) => GradSumChoice::Pipelined1D,
            (false, false) => GradSumChoice::Serial1D,
        };
        names
            .iter()
            .map(|name| {
                let mut s = ScalingScenario::submission(name, chips.clone())
                    .named(format!("sweep-{name}"))
                    .with_pods(pods_one, ratio_one)
                    .with_cross_pod(xp_one);
                if batch > 0 {
                    s = s.with_batch(BatchSchedule::Fixed(batch));
                }
                s.gradsum = gradsum;
                s.weight_update_sharding = !a.flag("no-wus");
                s.distributed_eval = !a.flag("no-dist-eval");
                s.spatial_partitioning = !a.flag("no-spatial");
                s
            })
            .collect()
    };
    let faults_path = a.get_or("faults", "");
    let scenarios: Vec<ScalingScenario> = if faults_path.is_empty() {
        scenarios
    } else {
        let trace = match FaultTrace::load(&faults_path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("fault trace error: {e}");
                return 2;
            }
        };
        eprintln!(
            "fault trace {:?}: {} event(s), ckpt every {} steps",
            trace.name,
            trace.events.len(),
            trace.ckpt_every_steps
        );
        scenarios.into_iter().map(|s| s.with_faults(trace.clone())).collect()
    };
    let costs_path = a.get_or("costs-from", "");
    let scenarios: Vec<ScalingScenario> = if costs_path.is_empty() {
        scenarios
    } else {
        let gflops = match fitted_gflops_from_file(&costs_path) {
            Ok(g) => g,
            Err(e) => {
                eprintln!("costs-from error: {e:#}");
                return 2;
            }
        };
        eprintln!("pricing compute at the live-fitted {gflops:.2} GFLOP/s (from {costs_path})");
        scenarios.into_iter().map(|s| s.with_compute_gflops(gflops)).collect()
    };
    let trace_path = a.get_or("trace", "");
    let sink = if trace_path.is_empty() { TraceSink::disabled() } else { TraceSink::enabled() };
    let result = SweepRunner::new(scenarios).run_jobs_traced(jobs, &sink);
    if !trace_path.is_empty() {
        let t = sink.drain();
        match t.write(std::path::Path::new(&trace_path)) {
            Ok(()) => eprintln!("trace written to {trace_path} ({} events)", t.len()),
            Err(e) => {
                eprintln!("writing trace {trace_path}: {e}");
                return 1;
            }
        }
    }
    let report = match result {
        Ok(r) => r,
        Err(e) => {
            eprintln!("sweep error: {e}");
            return 2;
        }
    };
    if a.flag("table") {
        report.table("Scenario sweep").print();
        println!();
    }
    println!("{}", report.dump());
    let out = a.get_or("out", "");
    if !out.is_empty() {
        if let Err(e) = report.write(&out) {
            eprintln!("writing {out}: {e}");
            return 1;
        }
        eprintln!("report written to {out}");
    }
    if a.flag("marginals") {
        match grid_marginals(&report) {
            Ok(m) => {
                println!();
                m.print();
            }
            Err(e) => {
                eprintln!("marginals error: {e}");
                return 2;
            }
        }
    }
    let baseline_path = a.get_or("compare", "");
    if !baseline_path.is_empty() {
        let baseline = match SweepReport::load(&baseline_path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("loading baseline: {e}");
                return 2;
            }
        };
        let tolerance = a.get_f64("tolerance", 0.02);
        let cmp = compare_reports(&baseline, &report, tolerance);
        cmp.table().print();
        if cmp.only_in_base + cmp.only_in_new > 0 {
            eprintln!(
                "note: {} baseline point(s) unmatched, {} new point(s) unmatched",
                cmp.only_in_base, cmp.only_in_new
            );
        }
        let regressions = cmp.regressions();
        if regressions > 0 {
            eprintln!(
                "{regressions} point(s) regressed beyond {:.1}% tolerance",
                100.0 * tolerance
            );
            return 1;
        }
        eprintln!("no regressions beyond {:.1}% tolerance", 100.0 * tolerance);
    }
    0
}

fn cmd_faults(tokens: &[String]) -> i32 {
    let cli = Cli::new("faults", "generate or validate a seeded fault/straggler trace")
        .opt(
            "validate",
            "",
            "validate an existing trace JSON against --steps/--chips instead of generating",
        )
        .opt("name", "trace", "trace name (recorded in the JSON)")
        .opt("seed", "0", "rng seed (traces are deterministic given the seed)")
        .opt("steps", "1000", "training steps the trace covers")
        .opt("chips", "16", "failure domains per pod (simulator chips / trainer ranks)")
        .opt("pods", "1", "pods in the group: traces cover the global chips x pods slice")
        .opt("ckpt-every", "100", "simulator-side durable checkpoint cadence in steps")
        .opt("restore-seconds", "30", "wall-clock cost of one checkpoint restore")
        .opt("slowdown-rate", "0.001", "per-chip-step probability of a straggler window")
        .opt("death-rate", "0.0002", "per-chip-step probability of a chip death")
        .opt("preempt-rate", "0.0001", "per-chip-step probability of a slice preemption")
        .opt("out", "", "also write the trace JSON to this file");
    let a = match cli.parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let pods = a.get_usize("pods", 1);
    if pods == 0 {
        eprintln!("--pods must be at least 1");
        return 2;
    }
    // Multi-pod jobs address chips globally, so both generation and
    // validation work on the whole pod group, not one pod's slice.
    let chips = a.get_usize("chips", 16) * pods;
    let validate_path = a.get_or("validate", "");
    if !validate_path.is_empty() {
        // Structural validation (ordering, zero steps, empty windows)
        // happens in load(); contextual validation then rejects traces
        // that contradict the run they are meant for: events past the
        // horizon, chips outside the slice, events on already-dead chips.
        let trace = match FaultTrace::load(&validate_path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("loading fault trace {validate_path}: {e}");
                return 2;
            }
        };
        let steps = a.get_usize("steps", 1000) as u64;
        if let Err(e) = trace.validate_in_context(steps, chips) {
            eprintln!("invalid fault trace {validate_path}: {e}");
            return 1;
        }
        println!(
            "trace {:?} valid: {} event(s) within {} steps on {} chips",
            trace.name,
            trace.events.len(),
            steps,
            chips
        );
        return 0;
    }
    let trace = FaultTrace::generate(
        &a.get_or("name", "trace"),
        a.get_usize("seed", 0) as u64,
        a.get_usize("steps", 1000) as u64,
        chips,
        a.get_usize("ckpt-every", 100) as u64,
        a.get_f64("restore-seconds", 30.0),
        a.get_f64("slowdown-rate", 0.001),
        a.get_f64("death-rate", 0.0002),
        a.get_f64("preempt-rate", 0.0001),
    );
    println!("{}", trace.dump());
    let out = a.get_or("out", "");
    if !out.is_empty() {
        if let Err(e) = trace.write(&out) {
            eprintln!("writing {out}: {e}");
            return 1;
        }
        eprintln!("trace written to {out} ({} event(s))", trace.events.len());
    }
    0
}

fn cmd_trace(tokens: &[String]) -> i32 {
    let cli = Cli::new("trace summarize FILE", "summarize a structured trace written by --trace")
        .opt(
            "tolerance",
            "",
            "relative tolerance for the accounting cross-check (default 1e-9)",
        );
    let a = match cli.parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    // `summarize` is the only verb today; keeping it explicit leaves room
    // for `trace diff` / `trace convert` without breaking invocations.
    let (verb, file) = match (a.positional.first(), a.positional.get(1)) {
        (Some(v), Some(f)) if a.positional.len() == 2 => (v.as_str(), f.clone()),
        _ => {
            eprintln!("usage: tpu-pod-train trace summarize FILE [--tolerance T]");
            return 2;
        }
    };
    if verb != "summarize" {
        eprintln!("unknown trace verb {verb:?} (expected \"summarize\")");
        return 2;
    }
    let trace = match Trace::load(std::path::Path::new(&file)) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("loading trace {file}: {e}");
            return 2;
        }
    };
    let tol = a.get_f64("tolerance", DEFAULT_TOLERANCE);
    let s = summarize(&trace, tol);
    s.print();
    if !s.ok() {
        eprintln!("trace accounting cross-check FAILED (see checks above)");
        return 1;
    }
    0
}

fn cmd_submit(_tokens: &[String]) -> i32 {
    let mut t = Table::new(
        "Simulated MLPerf-0.6 submission (TPU-v3, all §2 optimizations on)",
        &["model", "cores", "global batch", "mp", "epochs", "benchmark seconds"],
    );
    for m in all_models() {
        let cores = m.max_useful_cores().min(2048);
        let r = simulate(&m, cores, &SimOptions::default());
        t.row(&[
            m.name.to_string(),
            r.cores.to_string(),
            r.layout.global_batch.to_string(),
            r.layout.mp.to_string(),
            format!("{:.1}", r.epochs),
            format!("{:.1}", r.benchmark_seconds),
        ]);
    }
    t.print();
    0
}

fn cmd_info() -> i32 {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => {
            println!("artifacts ({}):", m.dir.display());
            for (name, a) in &m.artifacts {
                println!(
                    "  {:<28} {:>2} inputs {:>3} outputs  kind={}",
                    name,
                    a.inputs.len(),
                    a.outputs.len(),
                    a.meta.get("kind").map(String::as_str).unwrap_or("?")
                );
            }
            println!("\ntrainable models:");
            for (model, specs) in &m.params {
                let total: usize = specs.iter().map(|p| p.numel()).sum();
                println!("  {model:<24} {total:>10} params in {} tensors", specs.len());
            }
        }
        Err(e) => println!("no artifacts: {e:#}"),
    }
    println!("\nMLPerf-0.6 profiles:");
    for m in all_models() {
        println!(
            "  {:<12} {:>6.1}M params, opt {:?}, target {} {}, max batch {}",
            m.name,
            m.params / 1e6,
            m.optimizer,
            m.quality_target,
            m.quality_metric,
            m.max_batch
        );
    }
    0
}
