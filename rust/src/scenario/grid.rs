//! §2 ablation grids: the scenario × `SimOptions` cross-product driver.
//!
//! The paper's scaling story is an ablation story — spatial partitioning,
//! weight-update sharding, the optimizer and the gradient-summation
//! schedule each toggled across a chip ladder. [`AblationGrid`] makes
//! that cross-product declarative: each axis is a list of settings, and
//! [`AblationGrid::scenarios`] emits one labeled [`ScalingScenario`] per
//! combination, feeding the existing `SweepReport` v2 schema (every
//! record already carries the per-axis attribution fields).
//!
//! Grid naming convention (stable — `sweep --compare` matches on it):
//! `grid-{model}-sp:{on|off}-wus:{on|off}-gs:{gradsum}-opt:{optimizer}`
//! with the gradsum label from [`GradSumChoice::label`] and the optimizer
//! label from [`OptimizerAxis::label`]. Non-default multi-pod
//! combinations append `-pods:{P}-ipr:{R}-xp:{strategy}` (pod count,
//! inter-pod bandwidth ratio, [`CrossPodStrategy::label`]); the default
//! single-pod combination keeps the bare name, so every pre-pod baseline
//! still matches. Axis order in the emitted list is model (outer) →
//! spatial → wus → gradsum → optimizer → pods → ratio → strategy
//! (inner), each in its declared order, then the chip ladder within each
//! scenario.

use crate::models::registry::{all_models, Optimizer};
use crate::netsim::{CrossPodStrategy, PodSpec};

use super::presets::paper_chip_slices;
use super::{GradSumChoice, OptimizerChoice, ScalingScenario};

/// Optimizer axis of an ablation grid (Table 1's LARS-vs-SGD study as an
/// on/off toggle rather than a per-variant epochs pin).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerAxis {
    /// The model profile's own optimizer (the submission setting).
    Default,
    /// Force LARS (large-batch update traffic, 20 B/param).
    Lars,
    /// Force SGD + momentum (the pre-LARS baseline, 16 B/param).
    Sgd,
}

impl OptimizerAxis {
    pub fn label(self) -> &'static str {
        match self {
            OptimizerAxis::Default => "default",
            OptimizerAxis::Lars => "lars",
            OptimizerAxis::Sgd => "sgd",
        }
    }

    /// The scenario optimizer choice this axis value selects. Overrides
    /// keep the model's own epochs-to-converge curve (`epochs: None`):
    /// the grid ablates update *traffic*; the per-variant convergence
    /// study is Table 1 (`presets::table1_scenarios`).
    pub fn choice(self) -> OptimizerChoice {
        match self {
            OptimizerAxis::Default => OptimizerChoice::ModelDefault,
            OptimizerAxis::Lars => {
                OptimizerChoice::Override { optimizer: Optimizer::Lars, epochs: None }
            }
            OptimizerAxis::Sgd => {
                OptimizerChoice::Override { optimizer: Optimizer::Sgd, epochs: None }
            }
        }
    }
}

/// A scenario × `SimOptions` cross-product: models × chip ladder × the §2
/// on/off axes. Distributed eval stays on (it is not a §2 grid axis; the
/// side-card ablation lives in `simulator::SimOptions` and the benches).
#[derive(Clone, Debug)]
pub struct AblationGrid {
    /// Registry keys swept (outermost axis).
    pub models: Vec<String>,
    /// TPU-v3 chip ladder every emitted scenario sweeps.
    pub chips: Vec<usize>,
    /// Spatial-partitioning axis (§2 "spatial partitioning").
    pub spatial: Vec<bool>,
    /// Weight-update-sharding axis (§2 Fig. 4).
    pub weight_update_sharding: Vec<bool>,
    /// Gradient-summation schedule axis (§2 "optimize gradient summation").
    pub gradsum: Vec<GradSumChoice>,
    /// Optimizer axis (LARS vs SGD update traffic).
    pub optimizers: Vec<OptimizerAxis>,
    /// Multi-pod axis: pods per group (1 = the paper's single pod).
    pub pods: Vec<usize>,
    /// Inter-pod link bandwidth ratios, in `(0, 1]`.
    pub inter_pod_ratios: Vec<f64>,
    /// Cross-pod gradient-summation strategy axis.
    pub cross_pod: Vec<CrossPodStrategy>,
}

impl AblationGrid {
    /// The full §2 cross-product the paper implies: all five MLPerf-0.6
    /// models across the paper chip ladder, with spatial partitioning and
    /// weight-update sharding each on/off, the 2-D gradient summation
    /// pipelined vs serial, and LARS vs SGD — 80 scenarios, 480 points.
    pub fn full_paper() -> AblationGrid {
        AblationGrid {
            models: all_models().iter().map(|m| m.name.to_string()).collect(),
            chips: paper_chip_slices(),
            spatial: vec![true, false],
            weight_update_sharding: vec![true, false],
            gradsum: vec![GradSumChoice::Pipelined2D, GradSumChoice::Serial2D],
            optimizers: vec![OptimizerAxis::Lars, OptimizerAxis::Sgd],
            pods: vec![1],
            inter_pod_ratios: vec![1.0],
            cross_pod: vec![CrossPodStrategy::Hierarchical],
        }
    }

    /// Scenario count (points = `scenario_count() * chips.len()`).
    pub fn scenario_count(&self) -> usize {
        self.models.len()
            * self.spatial.len()
            * self.weight_update_sharding.len()
            * self.gradsum.len()
            * self.optimizers.len()
            * self.pods.len()
            * self.inter_pod_ratios.len()
            * self.cross_pod.len()
    }

    /// Grid points (scenarios × chip ladder).
    pub fn point_count(&self) -> usize {
        self.scenario_count() * self.chips.len()
    }

    /// The naming convention above, for one axis combination. The default
    /// single-pod spec keeps the historical (suffix-free) name so pre-pod
    /// baselines still match under `sweep --compare`.
    pub fn scenario_name(
        model: &str,
        spatial: bool,
        wus: bool,
        gradsum: GradSumChoice,
        optimizer: OptimizerAxis,
        pods: PodSpec,
    ) -> String {
        let onoff = |b: bool| if b { "on" } else { "off" };
        let mut name = format!(
            "grid-{model}-sp:{}-wus:{}-gs:{}-opt:{}",
            onoff(spatial),
            onoff(wus),
            gradsum.label(),
            optimizer.label()
        );
        if pods != PodSpec::default() {
            name.push_str(&format!(
                "-pods:{}-ipr:{}-xp:{}",
                pods.pods,
                pods.inter_pod_ratio,
                pods.strategy.label()
            ));
        }
        name
    }

    /// Emit every axis combination as a labeled submission-based scenario
    /// (deterministic order; names unique by construction).
    pub fn scenarios(&self) -> Vec<ScalingScenario> {
        let mut out = Vec::with_capacity(self.scenario_count());
        for model in &self.models {
            for &spatial in &self.spatial {
                for &wus in &self.weight_update_sharding {
                    for &gradsum in &self.gradsum {
                        for &opt in &self.optimizers {
                            for &pods in &self.pods {
                                for &ratio in &self.inter_pod_ratios {
                                    for &xp in &self.cross_pod {
                                        let spec = PodSpec::new(pods, ratio).with_strategy(xp);
                                        let mut s =
                                            ScalingScenario::submission(model, self.chips.clone())
                                                .named(Self::scenario_name(
                                                    model, spatial, wus, gradsum, opt, spec,
                                                ))
                                                .with_pods(pods, ratio)
                                                .with_cross_pod(xp);
                                        s.spatial_partitioning = spatial;
                                        s.weight_update_sharding = wus;
                                        s.gradsum = gradsum;
                                        s.optimizer = opt.choice();
                                        out.push(s);
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::SweepRunner;
    use std::collections::BTreeSet;

    #[test]
    fn full_paper_grid_shape() {
        let g = AblationGrid::full_paper();
        assert_eq!(g.scenario_count(), 5 * 2 * 2 * 2 * 2);
        assert_eq!(g.point_count(), 80 * 6);
        let scenarios = g.scenarios();
        assert_eq!(scenarios.len(), 80);
        // Names are unique (compare keys) and follow the convention.
        let names: BTreeSet<String> = scenarios.iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), scenarios.len());
        assert!(names.contains("grid-resnet50-sp:on-wus:on-gs:2d-pipelined-opt:lars"));
        assert!(names.contains("grid-gnmt-sp:off-wus:off-gs:2d-serial-opt:sgd"));
        // Every scenario validates (the runner's up-front contract).
        for s in &scenarios {
            s.validate().unwrap();
        }
    }

    #[test]
    fn axis_values_reach_the_scenarios() {
        let mut g = AblationGrid::full_paper();
        g.models = vec!["resnet50".into()];
        g.chips = vec![64];
        let scenarios = g.scenarios();
        assert_eq!(scenarios.len(), 16);
        assert_eq!(scenarios.iter().filter(|s| s.spatial_partitioning).count(), 8);
        assert_eq!(scenarios.iter().filter(|s| s.weight_update_sharding).count(), 8);
        assert_eq!(
            scenarios.iter().filter(|s| s.gradsum == GradSumChoice::Serial2D).count(),
            8
        );
        for s in &scenarios {
            assert!(s.distributed_eval, "distributed eval is not a grid axis");
        }
    }

    #[test]
    fn optimizer_axis_changes_update_traffic_only() {
        let mk = |opt: OptimizerAxis| {
            let mut g = AblationGrid::full_paper();
            g.models = vec!["transformer".into()];
            g.chips = vec![1024];
            g.spatial = vec![true];
            g.weight_update_sharding = vec![true];
            g.gradsum = vec![GradSumChoice::Pipelined2D];
            g.optimizers = vec![opt];
            SweepRunner::new(g.scenarios()).run().unwrap().records.remove(0)
        };
        let lars = mk(OptimizerAxis::Lars);
        let sgd = mk(OptimizerAxis::Sgd);
        // Same convergence curve, different optimizer bytes/param.
        assert_eq!(lars.epochs, sgd.epochs);
        assert!(lars.update_seconds > sgd.update_seconds, "LARS carries more state");
        assert_eq!(lars.compute_seconds, sgd.compute_seconds);
    }

    #[test]
    fn pod_axes_expand_the_grid_and_tag_names() {
        let mut g = AblationGrid::full_paper();
        g.models = vec!["resnet50".into()];
        g.chips = vec![64];
        g.spatial = vec![true];
        g.weight_update_sharding = vec![true];
        g.gradsum = vec![GradSumChoice::Pipelined2D];
        g.optimizers = vec![OptimizerAxis::Lars];
        g.pods = vec![1, 2];
        g.inter_pod_ratios = vec![1.0, 0.25];
        g.cross_pod = vec![CrossPodStrategy::Hierarchical, CrossPodStrategy::FlatRing];
        assert_eq!(g.scenario_count(), 8);
        let scenarios = g.scenarios();
        let names: BTreeSet<String> = scenarios.iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 8, "pod-axis names must stay unique");
        // The default combination keeps the historical suffix-free name.
        assert!(names.contains("grid-resnet50-sp:on-wus:on-gs:2d-pipelined-opt:lars"));
        assert!(names.contains(
            "grid-resnet50-sp:on-wus:on-gs:2d-pipelined-opt:lars-pods:2-ipr:0.25-xp:flat-ring"
        ));
        for s in &scenarios {
            s.validate().unwrap();
        }
        // The spec reaches the emitted scenario.
        let multi = scenarios
            .iter()
            .find(|s| s.name.ends_with("-pods:2-ipr:0.25-xp:hierarchical"))
            .unwrap();
        assert_eq!(multi.pods, PodSpec::new(2, 0.25));
    }

    #[test]
    fn small_grid_runs_end_to_end() {
        let mut g = AblationGrid::full_paper();
        g.models = vec!["ssd".into()];
        g.chips = vec![16, 64];
        let report = SweepRunner::new(g.scenarios()).run().unwrap();
        assert_eq!(report.records.len(), 16 * 2);
    }
}
