//! Per-axis marginal analysis of an ablation-grid sweep (`sweep --grid
//! --marginals`).
//!
//! An [`super::AblationGrid`] sweep prices every §2 toggle combination,
//! but the cross-product hides the question the paper answers per
//! optimization: *what did this one toggle buy at this scale?* This
//! module recovers that: for each axis — spatial partitioning,
//! weight-update sharding, gradient-summation pipelining (serial →
//! pipelined at the same torus dimensionality), and the optimizer (SGD →
//! LARS) — it pairs every grid record with the record that differs in
//! exactly that axis, and reports the benchmark-seconds ratio
//! baseline/optimized per chip count (median over the co-varying axes,
//! with the min/max spread). A ratio of 1.6 at 1024 chips reads "turning
//! this on makes the benchmark 1.6x faster at 1024 chips, marginalized
//! over every other toggle".
//!
//! Pairing is by the stable grid naming convention
//! (`grid-{model}-sp:..-wus:..-gs:..-opt:..`, optionally suffixed
//! `-pods:..-ipr:..-xp:..` for non-default multi-pod combinations, see
//! [`super::grid`]); non-grid records are ignored, and pairs with a
//! non-finite benchmark time (DNF points) are counted as skipped rather
//! than polluting the ratios. The multi-pod fields are held fixed by
//! every pairing (they are co-varying context, not a toggled axis), so a
//! 2-pod record only ever pairs with another 2-pod record.

use std::collections::HashMap;

use crate::benchkit::{fmt_ratio, Table};
use crate::util::json::{obj, Json};

use super::runner::SweepReport;

/// The parsed axis settings of one grid scenario name. The multi-pod
/// fields keep their textual grid-label form (pods "1", ratio "1",
/// strategy "hierarchical" for suffix-free names) — pairing only needs
/// equality, never arithmetic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GridKey {
    pub model: String,
    pub spatial: bool,
    pub wus: bool,
    pub gradsum: String,
    pub optimizer: String,
    pub pods: String,
    pub inter_pod_ratio: String,
    pub cross_pod: String,
}

/// Parse a grid scenario name
/// (`grid-{model}-sp:{on|off}-wus:{on|off}-gs:{label}-opt:{label}`, with
/// an optional `-pods:{P}-ipr:{R}-xp:{strategy}` multi-pod suffix).
/// Returns `None` for anything that does not follow the convention.
pub fn parse_grid_name(name: &str) -> Option<GridKey> {
    let rest = name.strip_prefix("grid-")?;
    let sp_at = rest.find("-sp:")?;
    let wus_at = rest.find("-wus:")?;
    let gs_at = rest.find("-gs:")?;
    let opt_at = rest.find("-opt:")?;
    if !(sp_at < wus_at && wus_at < gs_at && gs_at < opt_at) {
        return None;
    }
    let onoff = |s: &str| match s {
        "on" => Some(true),
        "off" => Some(false),
        _ => None,
    };
    let tail = &rest[opt_at + 5..];
    let (optimizer, pods, inter_pod_ratio, cross_pod) = match tail.find("-pods:") {
        None => {
            (tail.to_string(), "1".to_string(), "1".to_string(), "hierarchical".to_string())
        }
        Some(p_at) => {
            let podtail = &tail[p_at + 6..];
            let ipr_at = podtail.find("-ipr:")?;
            let xp_at = podtail.find("-xp:")?;
            if ipr_at >= xp_at {
                return None;
            }
            (
                tail[..p_at].to_string(),
                podtail[..ipr_at].to_string(),
                podtail[ipr_at + 5..xp_at].to_string(),
                podtail[xp_at + 4..].to_string(),
            )
        }
    };
    Some(GridKey {
        model: rest[..sp_at].to_string(),
        spatial: onoff(&rest[sp_at + 4..wus_at])?,
        wus: onoff(&rest[wus_at + 5..gs_at])?,
        gradsum: rest[gs_at + 4..opt_at].to_string(),
        optimizer,
        pods,
        inter_pod_ratio,
        cross_pod,
    })
}

impl GridKey {
    /// Canonical lookup string (all axes + model, order fixed).
    fn lookup(&self) -> String {
        format!(
            "{}|sp:{}|wus:{}|gs:{}|opt:{}|pods:{}|ipr:{}|xp:{}",
            self.model,
            self.spatial,
            self.wus,
            self.gradsum,
            self.optimizer,
            self.pods,
            self.inter_pod_ratio,
            self.cross_pod
        )
    }

    /// The key that differs from `self` in exactly the given axis, flipped
    /// to the optimized setting — or `None` when `self` already is the
    /// optimized side (so each pair is visited once, from the baseline).
    fn optimized_along(&self, axis: &str) -> Option<GridKey> {
        let mut k = self.clone();
        match axis {
            "spatial" if !self.spatial => k.spatial = true,
            "wus" if !self.wus => k.wus = true,
            "gradsum" if self.gradsum.contains("serial") => {
                k.gradsum = self.gradsum.replace("serial", "pipelined");
            }
            "optimizer" if self.optimizer == "sgd" => k.optimizer = "lars".to_string(),
            _ => return None,
        }
        Some(k)
    }
}

/// Marginal effect of one axis at one chip count, over every pair of grid
/// records that differ in exactly that axis.
#[derive(Clone, Debug)]
pub struct AxisMarginal {
    /// `spatial` | `wus` | `gradsum` | `optimizer`.
    pub axis: &'static str,
    pub chips: usize,
    /// Finite pairs that produced a ratio.
    pub pairs: usize,
    /// Pairs dropped because either side was DNF (non-finite seconds).
    pub skipped: usize,
    /// benchmark_seconds(baseline) / benchmark_seconds(optimized):
    /// >1 means the toggle bought speed at this scale.
    pub median_ratio: f64,
    pub min_ratio: f64,
    pub max_ratio: f64,
}

/// The full per-axis marginal report.
#[derive(Clone, Debug, Default)]
pub struct MarginalReport {
    pub rows: Vec<AxisMarginal>,
}

/// The axes in report order, with the baseline→optimized reading.
const AXES: [(&str, &str); 4] = [
    ("spatial", "off -> on"),
    ("wus", "off -> on"),
    ("gradsum", "serial -> pipelined"),
    ("optimizer", "sgd -> lars"),
];

/// Compute per-axis marginals from a grid sweep report. Errors when the
/// report holds no parseable grid records at all (e.g. a plain preset
/// sweep was passed).
pub fn grid_marginals(report: &SweepReport) -> Result<MarginalReport, String> {
    // (lookup, chips) -> benchmark seconds, for every grid-named record.
    let mut by_key: HashMap<(String, usize), f64> = HashMap::new();
    let mut parsed: Vec<(GridKey, usize, f64)> = Vec::new();
    for r in &report.records {
        if let Some(k) = parse_grid_name(&r.scenario) {
            by_key.insert((k.lookup(), r.chips), r.benchmark_seconds);
            parsed.push((k, r.chips, r.benchmark_seconds));
        }
    }
    if parsed.is_empty() {
        return Err(
            "no grid-named records in this report (marginals need a --grid sweep)".to_string()
        );
    }

    let mut rows = Vec::new();
    for (axis, _) in AXES {
        // chips -> (ratios, skipped) over every baseline record.
        let mut per_chips: HashMap<usize, (Vec<f64>, usize)> = HashMap::new();
        for (k, chips, base_s) in &parsed {
            let Some(opt_key) = k.optimized_along(axis) else { continue };
            let Some(&opt_s) = by_key.get(&(opt_key.lookup(), *chips)) else { continue };
            let entry = per_chips.entry(*chips).or_default();
            if base_s.is_finite() && opt_s.is_finite() && opt_s > 0.0 {
                entry.0.push(*base_s / opt_s);
            } else {
                entry.1 += 1;
            }
        }
        let mut chip_counts: Vec<usize> = per_chips.keys().copied().collect();
        chip_counts.sort_unstable();
        for chips in chip_counts {
            let (mut ratios, skipped) = per_chips.remove(&chips).expect("key just listed");
            if ratios.is_empty() {
                rows.push(AxisMarginal {
                    axis,
                    chips,
                    pairs: 0,
                    skipped,
                    median_ratio: f64::NAN,
                    min_ratio: f64::NAN,
                    max_ratio: f64::NAN,
                });
                continue;
            }
            ratios.sort_by(|a, b| a.partial_cmp(b).expect("finite ratios"));
            rows.push(AxisMarginal {
                axis,
                chips,
                pairs: ratios.len(),
                skipped,
                median_ratio: ratios[ratios.len() / 2],
                min_ratio: ratios[0],
                max_ratio: ratios[ratios.len() - 1],
            });
        }
    }
    Ok(MarginalReport { rows })
}

impl MarginalReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("report", Json::from("grid_marginals")),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            fn num(x: f64) -> Json {
                                if x.is_finite() {
                                    Json::Num(x)
                                } else {
                                    Json::Null
                                }
                            }
                            obj(vec![
                                ("axis", Json::from(r.axis)),
                                ("chips", Json::from(r.chips)),
                                ("pairs", Json::from(r.pairs)),
                                ("skipped", Json::from(r.skipped)),
                                ("median_ratio", num(r.median_ratio)),
                                ("min_ratio", num(r.min_ratio)),
                                ("max_ratio", num(r.max_ratio)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Render the per-axis table (one row per axis × chip count).
    pub fn print(&self) {
        let mut table = Table::new(
            "Per-axis marginal speedup (benchmark-seconds ratio, baseline/optimized)",
            &["axis", "toggle", "chips", "pairs", "median", "min", "max"],
        );
        for r in &self.rows {
            let toggle = AXES
                .iter()
                .find(|(a, _)| *a == r.axis)
                .map(|(_, t)| *t)
                .unwrap_or("?");
            let fmt = |x: f64| if x.is_finite() { fmt_ratio(x) } else { "DNF".to_string() };
            table.row(&[
                r.axis.to_string(),
                toggle.to_string(),
                r.chips.to_string(),
                format!("{}{}", r.pairs, if r.skipped > 0 { "*" } else { "" }),
                fmt(r.median_ratio),
                fmt(r.min_ratio),
                fmt(r.max_ratio),
            ]);
        }
        table.print();
        if self.rows.iter().any(|r| r.skipped > 0) {
            println!("  (* = DNF pairs excluded from the ratios)");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{AblationGrid, SweepRunner};

    #[test]
    fn grid_names_parse_and_reject() {
        let k = parse_grid_name("grid-resnet50-sp:on-wus:off-gs:2d-pipelined-opt:lars").unwrap();
        assert_eq!(
            k,
            GridKey {
                model: "resnet50".to_string(),
                spatial: true,
                wus: false,
                gradsum: "2d-pipelined".to_string(),
                optimizer: "lars".to_string(),
                pods: "1".to_string(),
                inter_pod_ratio: "1".to_string(),
                cross_pod: "hierarchical".to_string(),
            }
        );
        assert!(parse_grid_name("resnet50-submission").is_none());
        assert!(parse_grid_name("grid-x-sp:maybe-wus:on-gs:2d-serial-opt:sgd").is_none());
    }

    #[test]
    fn pod_suffixed_names_parse_and_default() {
        let name =
            "grid-resnet50-sp:on-wus:on-gs:2d-pipelined-opt:lars-pods:2-ipr:0.25-xp:flat-ring";
        let k = parse_grid_name(name).unwrap();
        assert_eq!(k.optimizer, "lars");
        assert_eq!(k.pods, "2");
        assert_eq!(k.inter_pod_ratio, "0.25");
        assert_eq!(k.cross_pod, "flat-ring");
        let bare = parse_grid_name("grid-resnet50-sp:on-wus:on-gs:2d-pipelined-opt:lars").unwrap();
        assert_eq!((bare.pods.as_str(), bare.inter_pod_ratio.as_str()), ("1", "1"));
        assert_eq!(bare.cross_pod, "hierarchical");
        // Different pod context never pairs with the bare grid.
        assert_ne!(k.lookup(), bare.lookup());
        // A mangled suffix ordering is rejected outright.
        let mangled = "grid-x-sp:on-wus:on-gs:2d-serial-opt:sgd-pods:2-xp:flat-ring-ipr:0.25";
        assert!(parse_grid_name(mangled).is_none());
    }

    #[test]
    fn optimized_counterparts() {
        let base = parse_grid_name("grid-ssd-sp:off-wus:off-gs:2d-serial-opt:sgd").unwrap();
        assert!(base.optimized_along("spatial").unwrap().spatial);
        assert!(base.optimized_along("wus").unwrap().wus);
        assert_eq!(base.optimized_along("gradsum").unwrap().gradsum, "2d-pipelined");
        assert_eq!(base.optimized_along("optimizer").unwrap().optimizer, "lars");
        // The optimized side itself produces no pair (each pair counted once).
        let best = parse_grid_name("grid-ssd-sp:on-wus:on-gs:2d-pipelined-opt:lars").unwrap();
        for (axis, _) in AXES {
            assert!(best.optimized_along(axis).is_none(), "{axis}");
        }
    }

    #[test]
    fn marginals_over_a_small_grid() {
        let mut g = AblationGrid::full_paper();
        g.models = vec!["resnet50".into()];
        g.chips = vec![16, 64];
        let report = SweepRunner::new(g.scenarios()).run().unwrap();
        let m = grid_marginals(&report).unwrap();
        // 4 axes x 2 chip counts, each axis pairing 8 of the 16 combos.
        assert_eq!(m.rows.len(), 8);
        for r in &m.rows {
            assert_eq!(r.pairs, 8, "{} @ {}", r.axis, r.chips);
            assert_eq!(r.skipped, 0);
            assert!(r.median_ratio.is_finite() && r.median_ratio > 0.0);
            assert!(r.min_ratio <= r.median_ratio && r.median_ratio <= r.max_ratio);
        }
        // The §2 performance toggles must not hurt ResNet-50 at 64 chips
        // (median). The optimizer axis is the exception by design: the
        // grid holds epochs fixed, so sgd -> lars only adds update state
        // traffic (20 vs 16 B/param) and its marginal sits at or just
        // under 1.0.
        for r in m.rows.iter().filter(|r| r.chips == 64) {
            if r.axis == "optimizer" {
                assert!(
                    r.median_ratio > 0.9 && r.median_ratio <= 1.0 + 1e-9,
                    "optimizer marginal {} out of range",
                    r.median_ratio
                );
            } else {
                assert!(
                    r.median_ratio >= 0.99,
                    "{}: median marginal {} < 1 at 64 chips",
                    r.axis,
                    r.median_ratio
                );
            }
        }
        // JSON round-trip.
        let j = Json::parse(&m.to_json().dump()).unwrap();
        assert_eq!(j.get("report").and_then(Json::as_str), Some("grid_marginals"));
        assert_eq!(j.get("rows").and_then(Json::as_arr).map(|a| a.len()), Some(8));
    }

    #[test]
    fn non_grid_report_is_an_error() {
        let s = crate::scenario::ScalingScenario::submission("resnet50", vec![16]);
        let report = SweepRunner::single(s).run().unwrap();
        assert!(grid_marginals(&report).is_err());
    }
}
