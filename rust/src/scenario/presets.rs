//! Canned scenarios reproducing the paper's figures — the benches
//! (benches/fig7..fig10, table1) and the `sweep` subcommand build on
//! these instead of hand-rolling simulator calls.

use crate::models::registry::{all_models, model, Optimizer};
use crate::simulator::spatial_speedup;

use super::{BatchSchedule, OptimizerChoice, ScalingScenario};

/// The pod-slice ladder the paper's scaling figures sweep (chips; 2 cores
/// per chip, so 32 → 1024 chips is 64 → 2048 cores).
pub fn paper_chip_slices() -> Vec<usize> {
    vec![32, 64, 128, 256, 512, 1024]
}

/// Fig. 7 "Batch sizes used in scaling MLPerf models": submission layout
/// per model across the submission slice range (128 → 2048 cores).
pub fn fig7_scenarios() -> Vec<ScalingScenario> {
    all_models()
        .iter()
        .map(|m| {
            ScalingScenario::submission(m.name, vec![64, 128, 256, 512, 1024])
                .named(format!("fig7-{}", m.name))
        })
        .collect()
}

/// Fig. 8 "Training epochs to converge when scaling to a larger batch
/// size": one fixed-batch scenario per (model, batch) point. The chip
/// count only sets the layout; the epochs prediction depends on the batch
/// alone.
pub fn fig8_scenarios(batches: &[usize]) -> Vec<ScalingScenario> {
    let mut out = Vec::new();
    for m in all_models() {
        for &b in batches {
            out.push(
                ScalingScenario::submission(m.name, vec![64])
                    .with_batch(BatchSchedule::Fixed(b))
                    .named(format!("fig8-{}-b{b}", m.name)),
            );
        }
    }
    out
}

/// Fig. 9 "MLPerf-0.6 benchmark seconds": submission configuration per
/// model across 64 → 2048 cores.
pub fn fig9_scenarios() -> Vec<ScalingScenario> {
    all_models()
        .iter()
        .map(|m| {
            ScalingScenario::submission(m.name, paper_chip_slices())
                .named(format!("fig9-{}", m.name))
        })
        .collect()
}

/// Fig. 10 "Speedup with model parallelism": the spatial-partition
/// planner's speedup for a model at partition degree `mp` (None for an
/// unknown model).
pub fn model_parallel_speedup(model_name: &str, mp: usize) -> Option<f64> {
    model(model_name).map(|m| spatial_speedup(&m, mp))
}

/// Table 1 "ResNet-50 on 2048 TPU cores, batch 32K": the three LARS
/// configurations differ (for the simulator) only in epochs-to-converge.
pub fn table1_scenarios() -> Vec<ScalingScenario> {
    [("scaled-momentum", 72.8), ("unscaled-momentum", 70.6), ("unscaled-momentum-tuned", 64.0)]
        .into_iter()
        .map(|(label, epochs)| {
            let mut s = ScalingScenario::submission("resnet50", vec![1024]);
            s.name = format!("table1-{label}");
            s.optimizer =
                OptimizerChoice::Override { optimizer: Optimizer::Lars, epochs: Some(epochs) };
            s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::SweepRunner;

    #[test]
    fn fig9_covers_all_models_and_slices() {
        let scenarios = fig9_scenarios();
        assert_eq!(scenarios.len(), 5);
        let report = SweepRunner::new(scenarios).run().unwrap();
        assert_eq!(report.records.len(), 5 * paper_chip_slices().len());
    }

    #[test]
    fn fig8_epochs_depend_only_on_batch() {
        // SSD anchors from the paper: 50 → 61 → 77.5 epochs.
        let scenarios = fig8_scenarios(&[256, 1024, 2048]);
        let report = SweepRunner::new(scenarios).run().unwrap();
        let ssd: Vec<f64> = report
            .records
            .iter()
            .filter(|r| r.model == "ssd")
            .map(|r| r.epochs)
            .collect();
        assert_eq!(ssd.len(), 3);
        assert!((ssd[0] - 50.0).abs() < 1e-9);
        assert!((ssd[1] - 61.0).abs() < 1e-9);
        assert!((ssd[2] - 77.5).abs() < 1e-9);
    }

    #[test]
    fn fig10_speedups_match_paper_shape() {
        let s4 = model_parallel_speedup("ssd", 4).unwrap();
        assert!((1.4..1.9).contains(&s4), "SSD 4-way speedup {s4}");
        let m4 = model_parallel_speedup("maskrcnn", 4).unwrap();
        assert!(m4 > s4, "Mask-RCNN partitions better: {m4} vs {s4}");
        assert!(model_parallel_speedup("nope", 4).is_none());
    }

    #[test]
    fn table1_rows_order_by_epochs() {
        let report = SweepRunner::new(table1_scenarios()).run().unwrap();
        assert_eq!(report.records.len(), 3);
        // Fewer epochs → fewer benchmark seconds, same step time.
        assert!(report.records[0].benchmark_seconds > report.records[1].benchmark_seconds);
        assert!(report.records[1].benchmark_seconds > report.records[2].benchmark_seconds);
        assert!(
            (report.records[0].step_seconds - report.records[2].step_seconds).abs() < 1e-12
        );
    }
}
