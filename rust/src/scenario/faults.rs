//! Failure/straggler traces as a first-class scenario axis.
//!
//! Production pods are not healthy: chips die, get preempted, or straggle.
//! A [`FaultTrace`] is a seeded, serializable schedule of such events; the
//! same trace drives both consumers:
//!
//! * the **simulator** ([`price_fault_trace`]): replays the events against
//!   a completed [`SimResult`], repricing the remaining steps over the
//!   degraded layout after each death (the dead chip's two cores leave and
//!   the run continues on exactly the survivors, the live trainer's
//!   elastic policy) and charging rolled-back steps plus
//!   checkpoint-restore time;
//! * the **live trainer** (`coordinator::trainer`): slowdown events mark
//!   straggled steps, death/preemption events kill the incarnation, and
//!   the coordinator restores from the last checkpoint on fewer cores.
//!
//! The headline metric is **goodput** — useful train time over wall-clock
//! train time (ML Productivity Goodput, arxiv 2502.06982) — surfaced per
//! [`SweepRecord`](super::SweepRecord) by `sweep --faults TRACE`. An empty
//! trace is priced as exactly 1.0 and leaves every record byte-identical
//! (the axis is strictly additive; pinned by `tests/fault_tolerance.rs`).
//!
//! `chip` indexes a failure domain: a chip in the simulator, a worker
//! rank in the live trainer.

use crate::models::registry::{Layout, ModelProfile};
use crate::simulator::{simulate, SimResult};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::runner::SweepRecord;
use super::ScalingScenario;

const FORMAT: &str = "tpu-pod-train-faults-v1";

/// What happens to a chip at a given step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The chip runs `factor`x slower for `steps` consecutive steps; the
    /// synchronous SPMD step is gated on the slowest participant, so the
    /// whole pod pays the factor.
    Slowdown { factor: f64, steps: u64 },
    /// The chip dies permanently; the run restores from the last
    /// checkpoint on exactly the surviving chips.
    Death,
    /// The slice is preempted for `down_seconds`, then resumes from the
    /// last checkpoint on the same cores.
    Preemption { down_seconds: f64 },
}

/// One scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// 1-based global training step at which the fault hits.
    pub step: u64,
    pub chip: usize,
    pub kind: FaultKind,
}

impl FaultEvent {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("step", Json::from(self.step as usize)),
            ("chip", Json::from(self.chip)),
        ];
        match self.kind {
            FaultKind::Slowdown { factor, steps } => {
                pairs.push(("kind", Json::from("slowdown")));
                pairs.push(("factor", Json::Num(factor)));
                pairs.push(("steps", Json::from(steps as usize)));
            }
            FaultKind::Death => pairs.push(("kind", Json::from("death"))),
            FaultKind::Preemption { down_seconds } => {
                pairs.push(("kind", Json::from("preemption")));
                pairs.push(("down_seconds", Json::Num(down_seconds)));
            }
        }
        obj(pairs)
    }

    fn from_json(j: &Json) -> Result<FaultEvent, String> {
        let step = j
            .get("step")
            .and_then(Json::as_usize)
            .ok_or_else(|| "fault event missing step".to_string())? as u64;
        let chip = j
            .get("chip")
            .and_then(Json::as_usize)
            .ok_or_else(|| "fault event missing chip".to_string())?;
        let kind = match j.get("kind").and_then(Json::as_str) {
            Some("slowdown") => FaultKind::Slowdown {
                factor: j.get("factor").and_then(Json::as_f64).unwrap_or(1.0),
                steps: j.get("steps").and_then(Json::as_usize).unwrap_or(1) as u64,
            },
            Some("death") => FaultKind::Death,
            Some("preemption") => FaultKind::Preemption {
                down_seconds: j.get("down_seconds").and_then(Json::as_f64).unwrap_or(0.0),
            },
            other => return Err(format!("unknown fault kind {other:?}")),
        };
        Ok(FaultEvent { step, chip, kind })
    }
}

/// A seeded, serializable schedule of per-step chip faults, plus the
/// recovery parameters the consumers need to price them.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultTrace {
    pub name: String,
    /// Simulator-side checkpoint cadence (steps between durable
    /// checkpoints; 0 = only the initial state is durable). The live
    /// trainer uses its own `--checkpoint-every` instead.
    pub ckpt_every_steps: u64,
    /// Wall-clock cost of one checkpoint restore.
    pub restore_seconds: f64,
    /// Must be sorted by `step` (nondecreasing).
    pub events: Vec<FaultEvent>,
}

impl FaultTrace {
    pub fn empty(name: impl Into<String>) -> FaultTrace {
        FaultTrace {
            name: name.into(),
            ckpt_every_steps: 0,
            restore_seconds: 0.0,
            events: Vec::new(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Generate a seeded random trace: independent per-step Bernoulli
    /// draws for each fault class. Deterministic given (seed, steps,
    /// chips, rates).
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        name: &str,
        seed: u64,
        steps: u64,
        chips: usize,
        ckpt_every_steps: u64,
        restore_seconds: f64,
        slowdown_per_step: f64,
        death_per_step: f64,
        preempt_per_step: f64,
    ) -> FaultTrace {
        let chips = chips.max(1) as u64;
        let mut rng = Rng::new(seed).fold_in(0xFA17);
        let mut events = Vec::new();
        for step in 1..=steps {
            if rng.uniform() < slowdown_per_step {
                events.push(FaultEvent {
                    step,
                    chip: rng.below(chips) as usize,
                    kind: FaultKind::Slowdown {
                        factor: 1.5 + 2.5 * rng.uniform(),
                        steps: 1 + rng.below(20),
                    },
                });
            }
            if rng.uniform() < death_per_step {
                events.push(FaultEvent {
                    step,
                    chip: rng.below(chips) as usize,
                    kind: FaultKind::Death,
                });
            }
            if rng.uniform() < preempt_per_step {
                events.push(FaultEvent {
                    step,
                    chip: rng.below(chips) as usize,
                    kind: FaultKind::Preemption { down_seconds: 10.0 + 50.0 * rng.uniform() },
                });
            }
        }
        FaultTrace {
            name: name.to_string(),
            ckpt_every_steps,
            restore_seconds,
            events,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.restore_seconds.is_finite() || self.restore_seconds < 0.0 {
            return Err(format!(
                "trace {:?}: restore_seconds {} must be finite and >= 0",
                self.name, self.restore_seconds
            ));
        }
        let mut prev = 0u64;
        for ev in &self.events {
            if ev.step == 0 {
                return Err(format!("trace {:?}: fault steps are 1-based", self.name));
            }
            if ev.step < prev {
                return Err(format!("trace {:?}: events not sorted by step", self.name));
            }
            prev = ev.step;
            match ev.kind {
                FaultKind::Slowdown { factor, steps } => {
                    if !factor.is_finite() || factor < 1.0 {
                        return Err(format!(
                            "trace {:?}: slowdown factor {factor} must be >= 1",
                            self.name
                        ));
                    }
                    if steps == 0 {
                        return Err(format!(
                            "trace {:?}: slowdown duration must be >= 1 step",
                            self.name
                        ));
                    }
                }
                FaultKind::Preemption { down_seconds } => {
                    if !down_seconds.is_finite() || down_seconds < 0.0 {
                        return Err(format!(
                            "trace {:?}: down_seconds {down_seconds} must be finite and >= 0",
                            self.name
                        ));
                    }
                }
                FaultKind::Death => {}
            }
        }
        Ok(())
    }

    /// Strict validation against the run the trace is meant for: on top of
    /// [`validate`](Self::validate), reject events the pricing/replay
    /// machinery would otherwise silently skip or that contradict each
    /// other — an event past `total_steps`, a chip outside the slice, any
    /// event aimed at a chip that an earlier event already killed (a dead
    /// chip cannot die again, straggle, or be preempted).
    pub fn validate_in_context(&self, total_steps: u64, chips: usize) -> Result<(), String> {
        self.validate()?;
        let mut dead: Vec<usize> = Vec::new();
        for ev in &self.events {
            if total_steps > 0 && ev.step > total_steps {
                return Err(format!(
                    "trace {:?}: event at step {} is past the run's {total_steps} steps",
                    self.name, ev.step
                ));
            }
            if chips > 0 && ev.chip >= chips {
                return Err(format!(
                    "trace {:?}: chip {} is outside the {chips}-chip slice",
                    self.name, ev.chip
                ));
            }
            if dead.contains(&ev.chip) {
                return Err(format!(
                    "trace {:?}: step {} targets chip {}, which is already dead",
                    self.name, ev.step, ev.chip
                ));
            }
            if ev.kind == FaultKind::Death {
                dead.push(ev.chip);
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("format", Json::Str(FORMAT.into())),
            ("name", Json::Str(self.name.clone())),
            ("ckpt_every_steps", Json::from(self.ckpt_every_steps as usize)),
            ("restore_seconds", Json::Num(self.restore_seconds)),
            ("events", Json::Arr(self.events.iter().map(FaultEvent::to_json).collect())),
        ])
    }

    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    pub fn parse(text: &str) -> Result<FaultTrace, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        if j.get("format").and_then(Json::as_str) != Some(FORMAT) {
            return Err("not a fault trace (bad format tag)".to_string());
        }
        let events: Result<Vec<FaultEvent>, String> = j
            .get("events")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(FaultEvent::from_json)
            .collect();
        let trace = FaultTrace {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            ckpt_every_steps: j.get("ckpt_every_steps").and_then(Json::as_usize).unwrap_or(0)
                as u64,
            restore_seconds: j.get("restore_seconds").and_then(Json::as_f64).unwrap_or(0.0),
            events: events?,
        };
        trace.validate()?;
        Ok(trace)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<FaultTrace, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        FaultTrace::parse(&text)
    }

    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.dump())
    }
}

/// Fault pricing of one sweep point.
#[derive(Clone, Copy, Debug)]
pub struct FaultOutcome {
    /// Useful train time / wall-clock train time; exactly 1.0 when no
    /// event applied.
    pub goodput: f64,
    /// Events that actually applied to this point (in-range step, live
    /// chip).
    pub fault_events: usize,
    /// Steps of work rolled back to the last durable checkpoint.
    pub lost_steps: f64,
    /// Total checkpoint-restore wall clock paid.
    pub restore_seconds: f64,
    /// Participating cores of the final (possibly degraded) layout.
    pub final_cores: usize,
    /// Wall-clock seconds of the faulted train loop (replaces
    /// `steps * step_seconds` in benchmark seconds).
    pub train_seconds: f64,
}

/// Replay a fault trace against a completed simulation.
///
/// Walks the events over the step timeline, keeping a resume frontier at
/// the last durable checkpoint (`ckpt_every_steps` cadence; 0 = only the
/// initial state). Slowdowns stretch the overlapped steps (synchronous
/// SPMD: the pod runs at the straggler's pace). Death rolls back to the
/// frontier, pays a restore, and reprices the remaining steps over
/// exactly the survivors (the dead chip's two cores leave the slice) —
/// mp capped to the surviving cores, replicas refilled up to the global
/// batch, the same elastic re-layout the live trainer performs.
/// Preemption rolls back, pays the downtime plus a restore, and
/// continues on the same cores.
pub fn price_fault_trace(
    s: &ScalingScenario,
    m: &ModelProfile,
    base: &SimResult,
    trace: &FaultTrace,
) -> FaultOutcome {
    let identity = FaultOutcome {
        goodput: 1.0,
        fault_events: 0,
        lost_steps: 0.0,
        restore_seconds: 0.0,
        final_cores: base.participating_cores,
        train_seconds: base.steps * base.step_seconds,
    };
    let total_u = base.steps.ceil() as u64;
    if !base.converged || trace.is_empty() || total_u == 0 {
        return identity;
    }

    let every = trace.ckpt_every_steps;
    let mut pos: u64 = 0; // resume frontier: last durable step
    let mut wall = 0.0f64;
    let mut wall_extra = 0.0f64; // straggler stretch, added at the end
    let mut lost = 0.0f64;
    let mut restore_total = 0.0f64;
    let mut cur_step_seconds = base.step_seconds;
    let mut cur_cores = base.cores;
    let mut cur_participating = base.participating_cores;
    let mut applied = 0usize;

    for ev in &trace.events {
        if ev.step < 1 || ev.step > total_u || ev.chip * 2 >= cur_cores {
            continue;
        }
        match ev.kind {
            FaultKind::Slowdown { factor, steps } => {
                let lo = ev.step.max(pos + 1);
                let hi = ev.step.saturating_add(steps - 1).min(total_u);
                if hi >= lo {
                    wall_extra += (factor - 1.0) * (hi - lo + 1) as f64 * cur_step_seconds;
                    applied += 1;
                }
            }
            FaultKind::Death | FaultKind::Preemption { .. } => {
                if ev.step <= pos {
                    continue; // already behind the frontier after a rollback
                }
                applied += 1;
                let reached = ev.step - 1;
                wall += (reached - pos) as f64 * cur_step_seconds;
                let ckpt = if every == 0 { 0 } else { (reached / every) * every };
                lost += (reached - ckpt) as f64;
                wall += trace.restore_seconds;
                restore_total += trace.restore_seconds;
                if let FaultKind::Preemption { down_seconds } = ev.kind {
                    wall += down_seconds;
                } else if cur_cores > 2 {
                    // Elastic re-layout on exactly the survivors: the dead
                    // chip takes its two cores with it.
                    cur_cores -= 2;
                    let mp = base.layout.mp.min(cur_cores).max(1);
                    let replicas = (cur_cores / mp).min(base.layout.global_batch).max(1);
                    let mut opts = s.sim_options(cur_cores);
                    opts.layout_override = Some(Layout {
                        cores: cur_cores,
                        mp,
                        replicas,
                        global_batch: base.layout.global_batch,
                    });
                    let degraded = simulate(m, cur_cores, &opts);
                    cur_step_seconds = degraded.step_seconds;
                    cur_participating = degraded.participating_cores;
                }
                pos = ckpt;
            }
        }
    }
    if applied == 0 {
        return identity;
    }
    wall += (total_u - pos) as f64 * cur_step_seconds + wall_extra;
    FaultOutcome {
        goodput: (base.steps * base.step_seconds) / wall,
        fault_events: applied,
        lost_steps: lost,
        restore_seconds: restore_total,
        final_cores: cur_participating,
        train_seconds: wall,
    }
}

/// Patch a sweep record with the fault pricing of its scenario's trace.
///
/// Strictly additive: when the scenario carries no trace, the trace is
/// empty, or no event applies to this point, the record is left
/// untouched — bit for bit — so fault-free sweeps stay byte-identical to
/// pre-fault-axis reports.
pub(super) fn apply_fault_trace(
    s: &ScalingScenario,
    m: &ModelProfile,
    r: &SimResult,
    rec: &mut SweepRecord,
) {
    let Some(trace) = &s.faults else { return };
    if trace.is_empty() {
        return;
    }
    let out = price_fault_trace(s, m, r, trace);
    if out.fault_events == 0 {
        return;
    }
    rec.goodput = out.goodput;
    rec.fault_events = out.fault_events;
    rec.lost_steps = out.lost_steps;
    rec.restore_seconds = out.restore_seconds;
    rec.final_cores = out.final_cores;
    if r.converged {
        rec.benchmark_seconds = out.train_seconds + r.eval_seconds + r.infra_seconds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn death_at(step: u64, chip: usize) -> FaultEvent {
        FaultEvent { step, chip, kind: FaultKind::Death }
    }

    #[test]
    fn json_round_trip() {
        let trace = FaultTrace {
            name: "mixed".into(),
            ckpt_every_steps: 100,
            restore_seconds: 30.0,
            events: vec![
                FaultEvent {
                    step: 5,
                    chip: 3,
                    kind: FaultKind::Slowdown { factor: 2.5, steps: 4 },
                },
                death_at(40, 1),
                FaultEvent {
                    step: 90,
                    chip: 0,
                    kind: FaultKind::Preemption { down_seconds: 12.5 },
                },
            ],
        };
        let back = FaultTrace::parse(&trace.dump()).unwrap();
        assert_eq!(back, trace);
    }

    #[test]
    fn generate_is_deterministic_and_valid() {
        let a = FaultTrace::generate("t", 7, 2000, 64, 100, 30.0, 0.01, 0.002, 0.001);
        let b = FaultTrace::generate("t", 7, 2000, 64, 100, 30.0, 0.01, 0.002, 0.001);
        assert_eq!(a, b);
        a.validate().unwrap();
        assert!(!a.is_empty(), "rates above should yield events over 2000 steps");
        let c = FaultTrace::generate("t", 8, 2000, 64, 100, 30.0, 0.01, 0.002, 0.001);
        assert_ne!(a, c, "different seed, different trace");
    }

    #[test]
    fn validate_rejects_bad_traces() {
        let mut t = FaultTrace::empty("bad");
        t.events = vec![death_at(0, 0)];
        assert!(t.validate().is_err(), "0-based step");
        t.events = vec![death_at(9, 0), death_at(3, 0)];
        assert!(t.validate().is_err(), "unsorted");
        t.events = vec![FaultEvent {
            step: 1,
            chip: 0,
            kind: FaultKind::Slowdown { factor: 0.5, steps: 1 },
        }];
        assert!(t.validate().is_err(), "speedup factor");
        t.events = Vec::new();
        t.restore_seconds = -1.0;
        assert!(t.validate().is_err(), "negative restore");
    }

    #[test]
    fn contextual_validation_rejects_contradictory_traces() {
        // Baseline: a sane trace passes with context.
        let mut t = FaultTrace::empty("ctx");
        t.events = vec![death_at(5, 1), death_at(9, 2)];
        t.validate_in_context(100, 16).unwrap();

        // Event past the run's total steps.
        t.events = vec![death_at(101, 1)];
        let err = t.validate_in_context(100, 16).unwrap_err();
        assert!(err.contains("past the run"), "{err}");

        // Chip outside the slice.
        t.events = vec![death_at(5, 16)];
        let err = t.validate_in_context(100, 16).unwrap_err();
        assert!(err.contains("outside the 16-chip slice"), "{err}");

        // Death of an already-dead chip.
        t.events = vec![death_at(5, 3), death_at(9, 3)];
        let err = t.validate_in_context(100, 16).unwrap_err();
        assert!(err.contains("already dead"), "{err}");

        // Any later event aimed at a dead chip is contradictory too.
        t.events = vec![
            death_at(5, 3),
            FaultEvent {
                step: 9,
                chip: 3,
                kind: FaultKind::Slowdown { factor: 2.0, steps: 2 },
            },
        ];
        let err = t.validate_in_context(100, 16).unwrap_err();
        assert!(err.contains("already dead"), "{err}");

        // Zero context fields disable the respective checks.
        t.events = vec![death_at(101, 31)];
        t.validate_in_context(0, 0).unwrap();
    }

    #[test]
    fn empty_trace_prices_identity() {
        let s = ScalingScenario::submission("resnet50", vec![1024]);
        let m = s.profile().unwrap();
        let r = simulate(&m, 2048, &s.sim_options(2048));
        let out = price_fault_trace(&s, &m, &r, &FaultTrace::empty("none"));
        assert_eq!(out.goodput, 1.0);
        assert_eq!(out.fault_events, 0);
        assert_eq!(out.lost_steps, 0.0);
        assert_eq!(out.final_cores, r.participating_cores);
    }

    #[test]
    fn death_rolls_back_and_degrades_layout() {
        let s = ScalingScenario::submission("resnet50", vec![1024]);
        let m = s.profile().unwrap();
        let r = simulate(&m, 2048, &s.sim_options(2048));
        assert!(r.converged);
        let trace = FaultTrace {
            name: "one-death".into(),
            ckpt_every_steps: 100,
            restore_seconds: 30.0,
            events: vec![death_at(250, 5)],
        };
        let out = price_fault_trace(&s, &m, &r, &trace);
        assert_eq!(out.fault_events, 1);
        // Died entering step 250: 249 done, last checkpoint at 200.
        assert_eq!(out.lost_steps, 49.0);
        assert_eq!(out.restore_seconds, 30.0);
        assert!(out.goodput < 1.0, "goodput {}", out.goodput);
        assert!(
            out.final_cores < r.participating_cores,
            "death must shrink the layout: {} vs {}",
            out.final_cores,
            r.participating_cores
        );
        assert!(out.train_seconds > r.steps * r.step_seconds);
    }

    #[test]
    fn slowdown_stretches_but_keeps_layout() {
        let s = ScalingScenario::submission("transformer", vec![512]);
        let m = s.profile().unwrap();
        let r = simulate(&m, 1024, &s.sim_options(1024));
        assert!(r.converged);
        let trace = FaultTrace {
            name: "straggler".into(),
            ckpt_every_steps: 0,
            restore_seconds: 0.0,
            events: vec![FaultEvent {
                step: 10,
                chip: 2,
                kind: FaultKind::Slowdown { factor: 3.0, steps: 5 },
            }],
        };
        let out = price_fault_trace(&s, &m, &r, &trace);
        assert_eq!(out.fault_events, 1);
        assert_eq!(out.lost_steps, 0.0);
        assert_eq!(out.final_cores, r.participating_cores);
        let expect = r.steps * r.step_seconds
            + (3.0 - 1.0) * 5.0 * r.step_seconds
            + (r.steps.ceil() - r.steps) * r.step_seconds;
        assert!((out.train_seconds - expect).abs() < 1e-9 * expect.max(1.0));
        assert!(out.goodput < 1.0);
    }

    #[test]
    fn out_of_range_events_do_not_apply() {
        let s = ScalingScenario::submission("resnet50", vec![16]);
        let m = s.profile().unwrap();
        let r = simulate(&m, 32, &s.sim_options(32));
        let trace = FaultTrace {
            name: "inapplicable".into(),
            ckpt_every_steps: 10,
            restore_seconds: 5.0,
            // Chip 9999 is outside a 16-chip slice; step beyond the run.
            events: vec![death_at(1, 9999), death_at(u64::MAX / 2, 0)],
        };
        let out = price_fault_trace(&s, &m, &r, &trace);
        assert_eq!(out.fault_events, 0);
        assert_eq!(out.goodput, 1.0);
    }
}
