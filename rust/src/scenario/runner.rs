//! Sweep execution: run a [`ScalingScenario`] grid point-by-point, record
//! the full step-time decomposition per point, and serialize JSON reports
//! (the `sweep` subcommand's output and the golden-trace test fixtures).

use crate::benchkit::Table;
use crate::models::registry::ModelProfile;
use crate::netsim::{Dir, Message, NetParams, NetSim, Torus};
use crate::simulator::simulate;
use crate::util::json::{obj, Json};
use crate::wus::ShardPlan;

use super::ScalingScenario;

/// One sweep point's full result record.
#[derive(Clone, Debug)]
pub struct SweepRecord {
    pub scenario: String,
    pub model: String,
    /// TPU-v3 chips at this point (2 cores per chip).
    pub chips: usize,
    pub cores: usize,
    /// Model-parallel degree the layout chose.
    pub mp: usize,
    pub replicas: usize,
    pub global_batch: usize,
    pub per_replica_batch: f64,
    /// Predicted epochs-to-quality (infinite = does not converge).
    pub epochs: f64,
    pub steps: f64,
    pub step_seconds: f64,
    pub compute_seconds: f64,
    pub gradsum_seconds: f64,
    pub update_seconds: f64,
    pub eval_seconds: f64,
    pub infra_seconds: f64,
    pub benchmark_seconds: f64,
    pub converged: bool,
    /// Weight-update shard imbalance (max/min shard elements) at this
    /// core count, from the model's gradient tensor census.
    pub shard_imbalance: f64,
    /// Spatial-partition speedup of the chosen mp degree (1.0 = pure DP).
    pub spatial_speedup: f64,
    /// Contention-validated gradient all-reduce time from the
    /// event-driven link simulator (see [`gradsum_contention_makespan`]).
    pub collective_makespan_seconds: f64,
}

impl SweepRecord {
    /// Serialize for reports and golden fixtures. Non-finite values (DNF
    /// points) become JSON null.
    pub fn to_json(&self) -> Json {
        fn num(x: f64) -> Json {
            if x.is_finite() {
                Json::Num(x)
            } else {
                Json::Null
            }
        }
        obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("model", Json::Str(self.model.clone())),
            ("chips", Json::from(self.chips)),
            ("cores", Json::from(self.cores)),
            ("mp", Json::from(self.mp)),
            ("replicas", Json::from(self.replicas)),
            ("global_batch", Json::from(self.global_batch)),
            ("per_replica_batch", num(self.per_replica_batch)),
            ("epochs", num(self.epochs)),
            ("steps", num(self.steps)),
            ("step_seconds", num(self.step_seconds)),
            ("compute_seconds", num(self.compute_seconds)),
            ("gradsum_seconds", num(self.gradsum_seconds)),
            ("update_seconds", num(self.update_seconds)),
            ("eval_seconds", num(self.eval_seconds)),
            ("infra_seconds", num(self.infra_seconds)),
            ("benchmark_seconds", num(self.benchmark_seconds)),
            ("converged", Json::Bool(self.converged)),
            ("shard_imbalance", num(self.shard_imbalance)),
            ("spatial_speedup", num(self.spatial_speedup)),
            ("collective_makespan_seconds", num(self.collective_makespan_seconds)),
        ])
    }
}

/// A completed sweep: every record of every scenario, in grid order.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    pub records: Vec<SweepRecord>,
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("version", Json::from(1usize)),
            ("records", Json::Arr(self.records.iter().map(SweepRecord::to_json).collect())),
        ])
    }

    /// Compact JSON text of the whole report.
    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.dump())
    }

    /// Human-readable summary table (one row per point).
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["scenario", "chips", "cores", "batch", "mp", "epochs", "step ms", "bench s"],
        );
        for r in &self.records {
            t.row(&[
                r.scenario.clone(),
                r.chips.to_string(),
                r.cores.to_string(),
                r.global_batch.to_string(),
                r.mp.to_string(),
                if r.epochs.is_finite() { format!("{:.1}", r.epochs) } else { "DNF".into() },
                format!("{:.3}", r.step_seconds * 1e3),
                if r.benchmark_seconds.is_finite() {
                    format!("{:.1}", r.benchmark_seconds)
                } else {
                    "DNF".into()
                },
            ]);
        }
        t
    }
}

/// Execute a set of scenarios in order.
#[derive(Clone, Debug, Default)]
pub struct SweepRunner {
    pub scenarios: Vec<ScalingScenario>,
}

impl SweepRunner {
    pub fn new(scenarios: Vec<ScalingScenario>) -> SweepRunner {
        SweepRunner { scenarios }
    }

    pub fn single(scenario: ScalingScenario) -> SweepRunner {
        SweepRunner { scenarios: vec![scenario] }
    }

    /// Validate every scenario up front, then run the full grid — a sweep
    /// either runs completely or fails before any simulation work.
    pub fn run(&self) -> Result<SweepReport, String> {
        for s in &self.scenarios {
            s.validate()?;
        }
        let mut records = Vec::new();
        for s in &self.scenarios {
            records.extend(run_scenario(s)?);
        }
        Ok(SweepReport { records })
    }
}

/// Run one scenario across its chip counts.
pub fn run_scenario(s: &ScalingScenario) -> Result<Vec<SweepRecord>, String> {
    let m = s.profile()?;
    Ok(s.chips.iter().map(|&chips| sweep_point(s, &m, chips)).collect())
}

/// Evaluate one (scenario, chips) grid point.
pub fn sweep_point(s: &ScalingScenario, m: &ModelProfile, chips: usize) -> SweepRecord {
    let cores = chips * 2;
    let opts = s.sim_options(cores);
    let r = simulate(m, cores, &opts);
    SweepRecord {
        scenario: s.name.clone(),
        model: m.name.to_string(),
        chips,
        cores,
        mp: r.layout.mp,
        replicas: r.layout.replicas,
        global_batch: r.layout.global_batch,
        per_replica_batch: r.layout.per_replica_batch(),
        epochs: r.epochs,
        steps: r.steps,
        step_seconds: r.step_seconds,
        compute_seconds: r.compute_seconds,
        gradsum_seconds: r.gradsum_seconds,
        update_seconds: r.update_seconds,
        eval_seconds: r.eval_seconds,
        infra_seconds: r.infra_seconds,
        benchmark_seconds: r.benchmark_seconds,
        converged: r.converged,
        shard_imbalance: shard_imbalance(m, cores),
        spatial_speedup: r.spatial_speedup,
        collective_makespan_seconds: gradsum_contention_makespan(
            m.params * 4.0,
            chips,
            s.gradsum.is_2d(),
        ),
    }
}

/// Weight-update shard imbalance at `cores` shards over the model's
/// gradient tensor census (paper §2 Fig. 4: contiguous element-balanced
/// shards of the flat parameter space).
fn shard_imbalance(m: &ModelProfile, cores: usize) -> f64 {
    let sizes: Vec<usize> =
        m.gradient_bytes().iter().map(|&b| ((b / 4.0) as usize).max(1)).collect();
    ShardPlan::balanced(&sizes, cores.max(1)).imbalance()
}

/// Contention check from the event-driven link simulator, matching the
/// scenario's gradient-summation schedule.
///
/// * 2-D (`two_d = true`): one ring step of phase 1 is every chip
///   shipping a 1/nx payload chunk to its +x neighbor simultaneously; the
///   analytic model assumes those transfers overlap perfectly, and
///   [`NetSim`] verifies it (the makespan of the batch equals one
///   transfer). The full all-reduce is `2(nx-1) + 2(ny-1)` such steps.
/// * 1-D (`two_d = false`): the single ring over all chips in row-major
///   order, `2(n-1)` steps of 1/n chunks; the wrap hop at each row end
///   crosses two links (the embedding cost the 2-D schedule avoids),
///   which the simulator prices via store-and-forward.
pub fn gradsum_contention_makespan(payload_bytes: f64, chips: usize, two_d: bool) -> f64 {
    let torus = Torus::for_chips(chips.max(1).next_power_of_two());
    let n = torus.chips();
    if n <= 1 {
        return 0.0;
    }
    let p = NetParams::default();
    let mut sim = NetSim::new(torus, p.link_bw, p.link_latency);
    if two_d {
        let bytes = payload_bytes / torus.nx as f64;
        let msgs: Vec<Message> = torus
            .coords()
            .map(|c| Message { src: c, dst: torus.step(c, Dir::XPlus), bytes, ready_at: 0.0 })
            .collect();
        let one_step = sim.makespan(&msgs);
        let ring_steps = 2 * (torus.nx - 1) + 2 * torus.ny.saturating_sub(1);
        one_step * ring_steps as f64
    } else {
        let bytes = payload_bytes / n as f64;
        let msgs: Vec<Message> = (0..n)
            .map(|i| Message {
                src: torus.coord(i),
                dst: torus.coord((i + 1) % n),
                bytes,
                ready_at: 0.0,
            })
            .collect();
        let one_step = sim.makespan(&msgs);
        one_step * (2 * (n - 1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{BatchSchedule, ScalingScenario};

    #[test]
    fn resnet_sweep_produces_one_record_per_chip_count() {
        let s = ScalingScenario::submission("resnet50", vec![16, 64, 256, 1024]);
        let recs = run_scenario(&s).unwrap();
        assert_eq!(recs.len(), 4);
        for (r, chips) in recs.iter().zip([16usize, 64, 256, 1024]) {
            assert_eq!(r.chips, chips);
            assert_eq!(r.cores, chips * 2);
            assert!(r.converged, "resnet50 @ {chips} chips should converge");
            assert!(r.step_seconds > 0.0);
            assert!(
                (r.step_seconds
                    - (r.compute_seconds + r.gradsum_seconds + r.update_seconds))
                    .abs()
                    < 1e-12
            );
        }
    }

    #[test]
    fn benchmark_seconds_shrink_with_scale_in_submission_config() {
        let s = ScalingScenario::submission("resnet50", vec![16, 64, 256, 1024]);
        let recs = run_scenario(&s).unwrap();
        for w in recs.windows(2) {
            assert!(
                w[1].benchmark_seconds < w[0].benchmark_seconds * 1.05,
                "{} chips: {:.1}s vs {} chips: {:.1}s",
                w[1].chips,
                w[1].benchmark_seconds,
                w[0].chips,
                w[0].benchmark_seconds
            );
        }
    }

    #[test]
    fn fixed_batch_overrides_layout() {
        let s = ScalingScenario::submission("resnet50", vec![64])
            .with_batch(BatchSchedule::Fixed(4096));
        let recs = run_scenario(&s).unwrap();
        assert_eq!(recs[0].global_batch, 4096);
        assert_eq!(recs[0].mp, 1);
        assert_eq!(recs[0].replicas, 128);
    }

    #[test]
    fn maskrcnn_reports_dnf_above_batch_wall() {
        // Fixed batch 256 > the 128 wall: the record must carry DNF, not
        // a bogus number.
        let s = ScalingScenario::submission("maskrcnn", vec![64])
            .with_batch(BatchSchedule::Fixed(256));
        let recs = run_scenario(&s).unwrap();
        assert!(!recs[0].converged);
        assert!(!recs[0].benchmark_seconds.is_finite());
        assert_eq!(recs[0].to_json().get("benchmark_seconds"), Some(&Json::Null));
    }

    #[test]
    fn ssd_engages_model_parallelism_at_pod_scale() {
        let s = ScalingScenario::submission("ssd", vec![1024]);
        let recs = run_scenario(&s).unwrap();
        assert!(recs[0].mp > 1);
        assert!(recs[0].spatial_speedup > 1.0);
    }

    #[test]
    fn shard_imbalance_is_small_and_bounded() {
        let s = ScalingScenario::submission("resnet50", vec![16, 1024]);
        for r in run_scenario(&s).unwrap() {
            assert!(r.shard_imbalance >= 1.0);
            assert!(r.shard_imbalance < 1.01, "{}", r.shard_imbalance);
        }
    }

    #[test]
    fn contention_makespan_positive_and_single_chip_zero() {
        assert_eq!(gradsum_contention_makespan(100e6, 1, true), 0.0);
        assert_eq!(gradsum_contention_makespan(100e6, 1, false), 0.0);
        let t16 = gradsum_contention_makespan(100e6, 16, true);
        let t1024 = gradsum_contention_makespan(100e6, 1024, true);
        assert!(t16 > 0.0 && t1024 > 0.0);
    }

    #[test]
    fn contention_confirms_1d_ring_slower_at_pod_scale() {
        // §2 / [19]: the 1-D ring's 2(n-1) latency-bound steps dwarf the
        // 2-D schedule's 2(nx-1)+2(ny-1) — visible under contention too.
        let t2d = gradsum_contention_makespan(100e6, 1024, true);
        let t1d = gradsum_contention_makespan(100e6, 1024, false);
        assert!(t1d > t2d, "1-D {t1d} should exceed 2-D {t2d} at pod scale");
    }

    #[test]
    fn report_round_trips_through_json() {
        let s = ScalingScenario::submission("transformer", vec![256, 1024]);
        let report = SweepRunner::single(s).run().unwrap();
        let parsed = Json::parse(&report.dump()).unwrap();
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].get("cores").unwrap().as_usize(), Some(2048));
        assert_eq!(recs[1].get("global_batch").unwrap().as_usize(), Some(2048));
    }

    #[test]
    fn runner_surfaces_validation_errors() {
        let bad = ScalingScenario::submission("nope", vec![16]);
        assert!(SweepRunner::single(bad).run().is_err());
    }
}
