//! Sweep execution: run a [`ScalingScenario`] grid, record the full
//! per-phase step-time attribution per point, and serialize JSON reports
//! (the `sweep` subcommand's output and the golden-trace test fixtures).
//! Also the `sweep --compare` diff engine: load a prior [`SweepReport`]
//! and report per-point benchmark and per-phase deltas.
//!
//! Point execution is grid-parallel ([`SweepRunner::run_jobs`]): points
//! are pulled off a shared queue by a `std::thread::scope` worker pool
//! and written back into grid order, so the report is byte-identical to
//! a serial run. The hot kernels are memoized in a [`SweepCache`] shared
//! by all workers — contention makespans by (participating torus,
//! payload, schedule) key, shard imbalance by (model, shards) — and the
//! per-model gradient census is hoisted into a per-scenario
//! [`ScenarioCtx`], computed once instead of once per chip point. Every
//! cache hit returns exactly the bits a fresh computation would, which
//! is what makes the parallel/serial byte-identity hold (pinned by
//! `tests/sweep_parallel.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::benchkit::Table;
use crate::metrics::{AttrVal, TraceSink, TRACK_COORD, TRACK_SWEEP_BASE};
use crate::costs::{gradient_census, shard_imbalance_from_census, Phase, PodLayout};
use crate::models::registry::ModelProfile;
use crate::netsim::{
    concurrent_gradsum_halo_makespan, cross_pod_ring_seconds, payload_uniform,
    pod_group_gradsum_makespan, pod_group_gradsum_makespan_guarded, schedule_fingerprint,
    CrossPodStrategy, Dir, GuardedMakespan, Message, NetParams, NetSim, PodSpec, Torus,
};
use crate::simulator::{simulate, SimResult};
use crate::util::json::{obj, Json};

use super::ScalingScenario;

/// One sweep point's full result record.
#[derive(Clone, Debug)]
pub struct SweepRecord {
    pub scenario: String,
    pub model: String,
    /// TPU-v3 chips at this point (2 cores per chip).
    pub chips: usize,
    pub cores: usize,
    /// Model-parallel degree the layout chose.
    pub mp: usize,
    pub replicas: usize,
    pub global_batch: usize,
    pub per_replica_batch: f64,
    /// Cores that hold a replica shard and do per-step work; every phase
    /// below is priced over its participating group, never raw `cores`.
    pub participating_cores: usize,
    pub surplus_cores: usize,
    /// Predicted epochs-to-quality (infinite = does not converge).
    pub epochs: f64,
    pub steps: f64,
    pub step_seconds: f64,
    pub compute_seconds: f64,
    /// Spatial-partition halo + distributed-BN communication per step.
    pub halo_seconds: f64,
    pub gradsum_seconds: f64,
    pub update_seconds: f64,
    pub eval_seconds: f64,
    pub infra_seconds: f64,
    pub benchmark_seconds: f64,
    pub converged: bool,
    /// Group sizes each phase was priced over (per-phase attribution).
    pub gradsum_cores: usize,
    pub update_shards: usize,
    pub eval_cores: usize,
    /// Weight-update shard imbalance (max/min shard elements) over the
    /// participating shards, from the model's gradient tensor census.
    pub shard_imbalance: f64,
    /// Spatial-partition speedup of the chosen mp degree (1.0 = pure DP).
    pub spatial_speedup: f64,
    /// Contention-validated gradient all-reduce time from the
    /// event-driven link simulator (see [`gradsum_contention_makespan`]),
    /// over the participating torus.
    pub collective_makespan_seconds: f64,
    /// Useful train time / wall-clock train time under the scenario's
    /// fault trace (exactly 1.0 when no fault applied; see
    /// [`super::price_fault_trace`]).
    pub goodput: f64,
    /// Fault events that applied to this point.
    pub fault_events: usize,
    /// Steps of work rolled back to the last durable checkpoint.
    pub lost_steps: f64,
    /// Total checkpoint-restore wall clock paid.
    pub restore_seconds: f64,
    /// Participating cores of the final (possibly fault-degraded) layout.
    pub final_cores: usize,
    /// Pods in the scenario's hierarchical group (1 = single flat pod).
    pub pods: usize,
    /// Inter-pod link bandwidth as a fraction of the torus link bandwidth.
    pub inter_pod_ratio: f64,
    /// Cross-pod gradient-summation strategy label
    /// ([`CrossPodStrategy::label`]); single-pod records carry the
    /// default "hierarchical".
    pub cross_pod_strategy: String,
    /// Gradsum makespan when the spatial-partition halo traffic shares
    /// the links concurrently (see [`concurrent_contention_makespan`]).
    /// Equals `collective_makespan_seconds` exactly when the point has no
    /// halo traffic.
    pub concurrent_makespan_seconds: f64,
}

impl SweepRecord {
    /// Serialize for reports and golden fixtures. Non-finite values (DNF
    /// points) become JSON null.
    pub fn to_json(&self) -> Json {
        fn num(x: f64) -> Json {
            if x.is_finite() {
                Json::Num(x)
            } else {
                Json::Null
            }
        }
        obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("model", Json::Str(self.model.clone())),
            ("chips", Json::from(self.chips)),
            ("cores", Json::from(self.cores)),
            ("mp", Json::from(self.mp)),
            ("replicas", Json::from(self.replicas)),
            ("global_batch", Json::from(self.global_batch)),
            ("per_replica_batch", num(self.per_replica_batch)),
            ("participating_cores", Json::from(self.participating_cores)),
            ("surplus_cores", Json::from(self.surplus_cores)),
            ("epochs", num(self.epochs)),
            ("steps", num(self.steps)),
            ("step_seconds", num(self.step_seconds)),
            ("compute_seconds", num(self.compute_seconds)),
            ("halo_seconds", num(self.halo_seconds)),
            ("gradsum_seconds", num(self.gradsum_seconds)),
            ("update_seconds", num(self.update_seconds)),
            ("eval_seconds", num(self.eval_seconds)),
            ("infra_seconds", num(self.infra_seconds)),
            ("benchmark_seconds", num(self.benchmark_seconds)),
            ("converged", Json::Bool(self.converged)),
            ("gradsum_cores", Json::from(self.gradsum_cores)),
            ("update_shards", Json::from(self.update_shards)),
            ("eval_cores", Json::from(self.eval_cores)),
            ("shard_imbalance", num(self.shard_imbalance)),
            ("spatial_speedup", num(self.spatial_speedup)),
            ("collective_makespan_seconds", num(self.collective_makespan_seconds)),
            ("goodput", num(self.goodput)),
            ("fault_events", Json::from(self.fault_events)),
            ("lost_steps", num(self.lost_steps)),
            ("restore_seconds", num(self.restore_seconds)),
            ("final_cores", Json::from(self.final_cores)),
            ("pods", Json::from(self.pods)),
            ("inter_pod_ratio", num(self.inter_pod_ratio)),
            ("cross_pod_strategy", Json::Str(self.cross_pod_strategy.clone())),
            ("concurrent_makespan_seconds", num(self.concurrent_makespan_seconds)),
        ])
    }

    /// Parse a record back from report JSON. Null numerics (DNF points)
    /// become infinity; keys absent from older-schema baselines become
    /// NaN ("unknown"), which the compare engine skips.
    pub fn from_json(j: &Json) -> Result<SweepRecord, String> {
        let text = |k: &str| -> Result<String, String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(String::from)
                .ok_or_else(|| format!("record missing string field {k:?}"))
        };
        let num = |k: &str| -> f64 {
            match j.get(k) {
                Some(Json::Num(x)) => *x,
                Some(Json::Null) => f64::INFINITY,
                _ => f64::NAN,
            }
        };
        let int = |k: &str| j.get(k).and_then(Json::as_usize).unwrap_or(0);
        Ok(SweepRecord {
            scenario: text("scenario")?,
            model: text("model")?,
            chips: int("chips"),
            cores: int("cores"),
            mp: int("mp"),
            replicas: int("replicas"),
            global_batch: int("global_batch"),
            per_replica_batch: num("per_replica_batch"),
            participating_cores: int("participating_cores"),
            surplus_cores: int("surplus_cores"),
            epochs: num("epochs"),
            steps: num("steps"),
            step_seconds: num("step_seconds"),
            compute_seconds: num("compute_seconds"),
            halo_seconds: num("halo_seconds"),
            gradsum_seconds: num("gradsum_seconds"),
            update_seconds: num("update_seconds"),
            eval_seconds: num("eval_seconds"),
            infra_seconds: num("infra_seconds"),
            benchmark_seconds: num("benchmark_seconds"),
            converged: j.get("converged").and_then(Json::as_bool).unwrap_or(false),
            gradsum_cores: int("gradsum_cores"),
            update_shards: int("update_shards"),
            eval_cores: int("eval_cores"),
            shard_imbalance: num("shard_imbalance"),
            spatial_speedup: num("spatial_speedup"),
            collective_makespan_seconds: num("collective_makespan_seconds"),
            // Older baselines predate the fault axis: read as fault-free.
            goodput: match j.get("goodput") {
                Some(Json::Num(x)) => *x,
                Some(Json::Null) => f64::INFINITY,
                _ => 1.0,
            },
            fault_events: int("fault_events"),
            lost_steps: match j.get("lost_steps") {
                Some(Json::Num(x)) => *x,
                Some(Json::Null) => f64::INFINITY,
                _ => 0.0,
            },
            restore_seconds: match j.get("restore_seconds") {
                Some(Json::Num(x)) => *x,
                Some(Json::Null) => f64::INFINITY,
                _ => 0.0,
            },
            final_cores: int("final_cores"),
            // Baselines that predate the multi-pod axis are single-pod.
            pods: j.get("pods").and_then(Json::as_usize).unwrap_or(1),
            inter_pod_ratio: match j.get("inter_pod_ratio") {
                Some(Json::Num(x)) => *x,
                Some(Json::Null) => f64::INFINITY,
                _ => 1.0,
            },
            cross_pod_strategy: j
                .get("cross_pod_strategy")
                .and_then(Json::as_str)
                .unwrap_or("hierarchical")
                .to_string(),
            concurrent_makespan_seconds: num("concurrent_makespan_seconds"),
        })
    }
}

/// A completed sweep: every record of every scenario, in grid order.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    pub records: Vec<SweepRecord>,
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("version", Json::from(2usize)),
            ("records", Json::Arr(self.records.iter().map(SweepRecord::to_json).collect())),
        ])
    }

    /// Compact JSON text of the whole report.
    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.dump())
    }

    /// Parse a report produced by [`SweepReport::dump`] (any schema
    /// version — missing per-phase fields read as unknown).
    pub fn parse(text: &str) -> Result<SweepReport, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let records = j
            .get("records")
            .and_then(Json::as_arr)
            .ok_or_else(|| "report has no records array".to_string())?;
        let records: Result<Vec<SweepRecord>, String> =
            records.iter().map(SweepRecord::from_json).collect();
        Ok(SweepReport { records: records? })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<SweepReport, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        SweepReport::parse(&text)
    }

    /// Human-readable summary table (one row per point).
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["scenario", "chips", "active/cores", "batch", "mp", "epochs", "step ms", "bench s"],
        );
        for r in &self.records {
            t.row(&[
                r.scenario.clone(),
                r.chips.to_string(),
                format!("{}/{}", r.participating_cores, r.cores),
                r.global_batch.to_string(),
                r.mp.to_string(),
                if r.epochs.is_finite() {
                    format!("{:.1}", r.epochs)
                } else {
                    "DNF".into()
                },
                format!("{:.3}", r.step_seconds * 1e3),
                if r.benchmark_seconds.is_finite() {
                    format!("{:.1}", r.benchmark_seconds)
                } else {
                    "DNF".into()
                },
            ]);
        }
        t
    }
}

/// Per-scenario data hoisted out of the per-chip-point loop: the resolved
/// model profile (post optimizer override), the gradient payload the
/// contention kernel prices, and the gradient-tensor element census
/// feeding the shard-imbalance metric. All three depend only on the
/// scenario, never on the chip count, so they are computed once per
/// [`ScalingScenario`] instead of once per point.
struct ScenarioCtx {
    profile: ModelProfile,
    /// Total gradient payload bytes (f32 params) for the contention kernel.
    payload_bytes: f64,
    /// Gradient tensor element census for `shard_imbalance`.
    census: Vec<usize>,
}

impl ScenarioCtx {
    fn new(s: &ScalingScenario) -> Result<ScenarioCtx, String> {
        Ok(ScenarioCtx::for_profile(s.profile()?))
    }

    fn for_profile(profile: ModelProfile) -> ScenarioCtx {
        let payload_bytes = profile.params * 4.0;
        let census = gradient_census(&profile);
        ScenarioCtx { profile, payload_bytes, census }
    }
}

/// Full key of one memoized makespan: every input of the kernel —
/// participating chips, payload (or the fingerprint of a non-uniform
/// per-chip schedule), gradsum shape, multi-pod spec, and any concurrent
/// halo phase. Two sweep points share an entry only when every one of
/// these coincides, which is what keeps cache hits value-exact.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct MakespanKey {
    chips: usize,
    /// `payload_bytes.to_bits()`; 0 for fingerprinted schedules.
    payload_bits: u64,
    two_d: bool,
    pods: usize,
    ratio_bits: u64,
    strategy: CrossPodStrategy,
    /// [`schedule_fingerprint`] of a non-uniform per-chip payload
    /// schedule; 0 for the uniform (payload-keyed) case.
    schedule: u64,
    /// Concurrent halo phase (0 / 0 when the point has no halo traffic).
    halo_group: usize,
    halo_bits: u64,
}

impl MakespanKey {
    fn point(payload_bytes: f64, chips: usize, two_d: bool, pods: PodSpec) -> MakespanKey {
        MakespanKey {
            chips,
            payload_bits: payload_bytes.to_bits(),
            two_d,
            pods: pods.pods,
            ratio_bits: pods.inter_pod_ratio.to_bits(),
            strategy: pods.strategy,
            schedule: 0,
            halo_group: 0,
            halo_bits: 0,
        }
    }
}

/// Memoized hot kernels shared by every point (and worker thread) of a
/// sweep. Keys capture every input of the memoized function, so a cache
/// hit returns exactly the bits a fresh computation would — memoization
/// can never change a report, only the time it takes to produce one.
/// Lookups are check-then-insert: two workers missing the same key both
/// compute it and insert identical values — duplicated work, never a
/// divergent result.
#[derive(Default)]
pub struct SweepCache {
    /// [`MakespanKey`] → event-driven / fast-path contention makespan.
    makespans: Mutex<HashMap<MakespanKey, f64>>,
    /// (model, participating shards) → weight-update shard imbalance.
    imbalance: Mutex<HashMap<(&'static str, usize), f64>>,
    /// Hit/miss tallies (relaxed; purely observational — they feed the
    /// `sweep.cache.*` trace counters and never affect results).
    makespan_hits: AtomicU64,
    makespan_misses: AtomicU64,
    imbalance_hits: AtomicU64,
    imbalance_misses: AtomicU64,
}

impl SweepCache {
    fn memo_makespan(&self, key: MakespanKey, compute: impl FnOnce() -> f64) -> f64 {
        if let Some(&v) = self.makespans.lock().unwrap().get(&key) {
            self.makespan_hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        self.makespan_misses.fetch_add(1, Ordering::Relaxed);
        let v = compute();
        self.makespans.lock().unwrap().insert(key, v);
        v
    }

    /// Contention makespan of the scenario's gradient-summation schedule
    /// over the participating group (see
    /// [`gradsum_contention_makespan_pods`] for the pricing rules).
    fn contention_makespan(
        &self,
        payload_bytes: f64,
        chips: usize,
        two_d: bool,
        pods: PodSpec,
    ) -> f64 {
        self.memo_makespan(MakespanKey::point(payload_bytes, chips, two_d, pods), || {
            gradsum_contention_makespan_pods(payload_bytes, chips, two_d, pods)
        })
    }

    /// Gradsum makespan with the spatial-partition halo phase sharing the
    /// links concurrently (see [`concurrent_contention_makespan`]).
    fn concurrent_makespan(
        &self,
        payload_bytes: f64,
        chips: usize,
        two_d: bool,
        pods: PodSpec,
        halo_group: usize,
        halo_seconds: f64,
    ) -> f64 {
        let key = MakespanKey {
            halo_group,
            halo_bits: halo_seconds.to_bits(),
            ..MakespanKey::point(payload_bytes, chips, two_d, pods)
        };
        self.memo_makespan(key, || {
            concurrent_contention_makespan(
                payload_bytes,
                chips,
                two_d,
                pods,
                halo_group,
                halo_seconds,
            )
        })
    }

    /// Makespan of a *non-uniform* per-chip payload schedule, memoized by
    /// its [`schedule_fingerprint`] (the uniform case hits the same entry
    /// as any permutation-identical schedule; distinct schedules can
    /// never collide on a payload-keyed entry because their key carries
    /// `payload_bits = 0`). The `fastpath` flag reports whether the
    /// symmetry shortcut priced the schedule — `false` for every
    /// non-uniform schedule, which is what routes them through the
    /// event-driven simulation.
    pub fn scheduled_makespan(
        &self,
        payloads: &[f64],
        chips: usize,
        pods: PodSpec,
    ) -> GuardedMakespan {
        let key = MakespanKey {
            payload_bits: 0,
            schedule: schedule_fingerprint(payloads),
            ..MakespanKey::point(0.0, chips, true, pods)
        };
        let seconds = self.memo_makespan(key, || {
            pod_group_gradsum_makespan_guarded(
                chips,
                pods,
                PodLayout::TORUS_MAX_ASPECT,
                payloads,
                &NetParams::default(),
            )
            .seconds
        });
        GuardedMakespan { seconds, fastpath: payload_uniform(payloads) }
    }

    fn shard_imbalance(&self, ctx: &ScenarioCtx, shards: usize) -> f64 {
        let key = (ctx.profile.name, shards);
        if let Some(&v) = self.imbalance.lock().unwrap().get(&key) {
            self.imbalance_hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        self.imbalance_misses.fetch_add(1, Ordering::Relaxed);
        let v = shard_imbalance_from_census(&ctx.census, shards);
        self.imbalance.lock().unwrap().insert(key, v);
        v
    }
}

/// Execute a set of scenarios in order.
#[derive(Clone, Debug, Default)]
pub struct SweepRunner {
    pub scenarios: Vec<ScalingScenario>,
}

impl SweepRunner {
    pub fn new(scenarios: Vec<ScalingScenario>) -> SweepRunner {
        SweepRunner { scenarios }
    }

    pub fn single(scenario: ScalingScenario) -> SweepRunner {
        SweepRunner { scenarios: vec![scenario] }
    }

    /// Validate every scenario up front, then run the full grid — a sweep
    /// either runs completely or fails before any simulation work.
    pub fn run(&self) -> Result<SweepReport, String> {
        self.run_jobs(1)
    }

    /// [`SweepRunner::run`] over `jobs` worker threads (0 = one per
    /// available core). Points are scheduled dynamically but written back
    /// into grid order, and the memoized kernels are value-exact, so the
    /// report is byte-identical to `jobs = 1` regardless of thread count
    /// or scheduling order.
    pub fn run_jobs(&self, jobs: usize) -> Result<SweepReport, String> {
        self.run_jobs_traced(jobs, &TraceSink::disabled())
    }

    /// [`SweepRunner::run_jobs`] with per-point `sweep.point` spans on one
    /// trace track per worker (queue-wait attribution in the span attrs)
    /// and `sweep.cache.*` hit/miss counters on the coordinator track.
    /// The report itself is identical to the untraced run; the *trace*
    /// event sequence is only deterministic at `jobs = 1`, where points
    /// retire in grid order on a single track.
    pub fn run_jobs_traced(&self, jobs: usize, sink: &TraceSink) -> Result<SweepReport, String> {
        let mut ctxs = Vec::with_capacity(self.scenarios.len());
        for s in &self.scenarios {
            ctxs.push(ScenarioCtx::new(s)?);
        }
        let points: Vec<(usize, usize)> = self
            .scenarios
            .iter()
            .enumerate()
            .flat_map(|(si, s)| s.chips.iter().map(move |&chips| (si, chips)))
            .collect();
        let jobs = pool_workers(jobs, points.len());
        let cache = SweepCache::default();
        let mut co = sink.local(TRACK_COORD, 0);
        let pool0 = co.start();
        co.instant("sweep.pool.start", || {
            vec![("points", AttrVal::from(points.len())), ("workers", AttrVal::from(jobs))]
        });
        let mut records: Vec<Option<SweepRecord>> = Vec::new();
        records.resize_with(points.len(), || None);
        if jobs == 1 {
            let mut tl = sink.local(TRACK_SWEEP_BASE, 0);
            for (i, (slot, &(si, chips))) in records.iter_mut().zip(&points).enumerate() {
                let t0 = tl.start();
                *slot = Some(sweep_point_ctx(&self.scenarios[si], &ctxs[si], chips, &cache));
                let name = self.scenarios[si].name.clone();
                tl.span("sweep.point", t0, || {
                    vec![
                        ("scenario", AttrVal::Str(name)),
                        ("chips", AttrVal::from(chips)),
                        ("point", AttrVal::from(i)),
                        ("queue_wait_s", AttrVal::Num(t0 - pool0)),
                    ]
                });
            }
        } else {
            let next = AtomicUsize::new(0);
            let mut buckets: Vec<Vec<(usize, SweepRecord)>> = Vec::new();
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for w in 0..jobs {
                    let next = &next;
                    let points = &points;
                    let scenarios = &self.scenarios;
                    let ctxs = &ctxs;
                    let cache = &cache;
                    let mut tl = sink.local(TRACK_SWEEP_BASE + w as u32, 0);
                    handles.push(scope.spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= points.len() {
                                break;
                            }
                            let (si, chips) = points[i];
                            let t0 = tl.start();
                            let rec = sweep_point_ctx(&scenarios[si], &ctxs[si], chips, cache);
                            let name = scenarios[si].name.clone();
                            tl.span("sweep.point", t0, || {
                                vec![
                                    ("scenario", AttrVal::Str(name)),
                                    ("chips", AttrVal::from(chips)),
                                    ("point", AttrVal::from(i)),
                                    ("queue_wait_s", AttrVal::Num(t0 - pool0)),
                                ]
                            });
                            out.push((i, rec));
                        }
                        out
                    }));
                }
                for h in handles {
                    buckets.push(h.join().expect("sweep worker panicked"));
                }
            });
            for (i, rec) in buckets.into_iter().flatten() {
                records[i] = Some(rec);
            }
        }
        co.counter("sweep.cache.makespan_hits", cache.makespan_hits.load(Ordering::Relaxed) as f64);
        co.counter(
            "sweep.cache.makespan_misses",
            cache.makespan_misses.load(Ordering::Relaxed) as f64,
        );
        co.counter(
            "sweep.cache.imbalance_hits",
            cache.imbalance_hits.load(Ordering::Relaxed) as f64,
        );
        co.counter(
            "sweep.cache.imbalance_misses",
            cache.imbalance_misses.load(Ordering::Relaxed) as f64,
        );
        Ok(SweepReport {
            records: records.into_iter().map(|r| r.expect("sweep point not computed")).collect(),
        })
    }
}

/// Resolve a `--jobs` value: 0 means one worker per available core.
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        jobs
    }
}

/// Worker count [`SweepRunner::run_jobs`] actually uses for a grid of
/// `points` points: [`effective_jobs`] capped at the point count — the
/// single sizing rule, shared by the CLI banner and the bench record.
pub fn pool_workers(jobs: usize, points: usize) -> usize {
    effective_jobs(jobs).min(points).max(1)
}

/// Run one scenario across its chip counts (the census and profile are
/// hoisted out of the chip loop; a scenario-local kernel cache covers the
/// repeated payload/torus keys of the chip ladder).
pub fn run_scenario(s: &ScalingScenario) -> Result<Vec<SweepRecord>, String> {
    let ctx = ScenarioCtx::new(s)?;
    let cache = SweepCache::default();
    Ok(s.chips.iter().map(|&chips| sweep_point_ctx(s, &ctx, chips, &cache)).collect())
}

/// Evaluate one (scenario, chips) grid point against a hoisted scenario
/// context and the shared kernel cache.
fn sweep_point_ctx(
    s: &ScalingScenario,
    ctx: &ScenarioCtx,
    chips: usize,
    cache: &SweepCache,
) -> SweepRecord {
    let m = &ctx.profile;
    let cores = chips * 2;
    let opts = s.sim_options(cores);
    let r = simulate(m, cores, &opts);
    let imbalance = cache.shard_imbalance(ctx, r.participating_cores);
    let part_chips = (r.participating_cores / 2).max(1);
    let makespan =
        cache.contention_makespan(ctx.payload_bytes, part_chips, s.gradsum.is_2d(), s.pods);
    // Points without halo traffic have nothing to contend with: the
    // concurrent price *is* the clean price, reused bit-for-bit.
    let concurrent = if r.halo_seconds > 0.0 {
        cache.concurrent_makespan(
            ctx.payload_bytes,
            part_chips,
            s.gradsum.is_2d(),
            s.pods,
            r.layout.mp,
            r.halo_seconds,
        )
    } else {
        makespan
    };
    let mut rec = assemble_record(s, m, chips, &r, imbalance, makespan, concurrent);
    super::faults::apply_fault_trace(s, m, &r, &mut rec);
    rec
}

/// The single construction site for the record schema: assemble one
/// point's record from a completed simulation plus the two kernel prices
/// (memoized by the engine; computed raw by the bench reference).
pub(super) fn assemble_record(
    s: &ScalingScenario,
    m: &ModelProfile,
    chips: usize,
    r: &SimResult,
    shard_imbalance: f64,
    collective_makespan_seconds: f64,
    concurrent_makespan_seconds: f64,
) -> SweepRecord {
    SweepRecord {
        scenario: s.name.clone(),
        model: m.name.to_string(),
        chips,
        cores: chips * 2,
        mp: r.layout.mp,
        replicas: r.layout.replicas,
        global_batch: r.layout.global_batch,
        per_replica_batch: r.layout.per_replica_batch(),
        participating_cores: r.participating_cores,
        surplus_cores: r.surplus_cores,
        epochs: r.epochs,
        steps: r.steps,
        step_seconds: r.step_seconds,
        compute_seconds: r.compute_seconds,
        halo_seconds: r.halo_seconds,
        gradsum_seconds: r.gradsum_seconds,
        update_seconds: r.update_seconds,
        eval_seconds: r.eval_seconds,
        infra_seconds: r.infra_seconds,
        benchmark_seconds: r.benchmark_seconds,
        converged: r.converged,
        gradsum_cores: r.phase_cores(Phase::GradSum),
        update_shards: r.phase_cores(Phase::WeightUpdate),
        eval_cores: r.phase_cores(Phase::Eval),
        shard_imbalance,
        spatial_speedup: r.spatial_speedup,
        collective_makespan_seconds,
        goodput: 1.0,
        fault_events: 0,
        lost_steps: 0.0,
        restore_seconds: 0.0,
        final_cores: r.participating_cores,
        pods: s.pods.pods,
        inter_pod_ratio: s.pods.inter_pod_ratio,
        cross_pod_strategy: s.pods.strategy.label().to_string(),
        concurrent_makespan_seconds,
    }
}

/// Evaluate one (scenario, chips) grid point. Single-point convenience
/// form: builds a throwaway context and cache, so the record is identical
/// to what [`SweepRunner::run_jobs`] produces for the same point.
pub fn sweep_point(s: &ScalingScenario, m: &ModelProfile, chips: usize) -> SweepRecord {
    let ctx = ScenarioCtx::for_profile(m.clone());
    sweep_point_ctx(s, &ctx, chips, &SweepCache::default())
}

/// One ring step under contention: every chip ships half a `chunk_bytes`
/// payload to each neighbor along `dir_plus`/`dir_minus` simultaneously
/// (the bidirectional ring the analytic model assumes). Returns the
/// event-driven makespan of the batch.
fn bidirectional_ring_step(
    torus: &Torus,
    ring_len: usize,
    dir_plus: Dir,
    dir_minus: Dir,
    chunk_bytes: f64,
    p: &NetParams,
) -> f64 {
    if ring_len <= 1 {
        return 0.0;
    }
    let mut sim = NetSim::new(*torus, p.link_bw, p.link_latency);
    let msgs: Vec<Message> = torus
        .coords()
        .flat_map(|c| {
            [
                Message {
                    src: c,
                    dst: torus.step(c, dir_plus),
                    bytes: chunk_bytes / 2.0,
                    ready_at: 0.0,
                },
                Message {
                    src: c,
                    dst: torus.step(c, dir_minus),
                    bytes: chunk_bytes / 2.0,
                    ready_at: 0.0,
                },
            ]
        })
        .collect();
    sim.makespan(&msgs)
}

/// Contention check from the event-driven link simulator, matching the
/// scenario's gradient-summation schedule.
///
/// * 2-D (`two_d = true`): the full 4-phase schedule of
///   `CostModel::all_reduce(ArAlgo::Torus2D, ..)` — reduce-scatter along
///   the X rings (`nx - 1` bidirectional steps of `1/nx` chunks), reduce-
///   scatter of the shard along the Y rings (`ny - 1` steps of
///   `1/(nx*ny)` chunks), then the two matching all-gather phases in
///   reverse. Every step is simulated as a batch of simultaneous
///   neighbor transfers; the analytic model assumes they overlap
///   perfectly and [`NetSim`] verifies it (the makespan of each batch
///   equals one transfer), so with both torus dimensions >= 4 the total
///   equals the analytic time minus its per-phase software overheads.
///   On a 2-wide dimension the +/- half-chunks fold onto one link under
///   shortest-path routing and honestly serialize.
/// * 1-D (`two_d = false`): the single ring over all chips in row-major
///   order, `2(n-1)` steps of 1/n chunks; the wrap hop at each row end
///   crosses two links (the embedding cost the 2-D schedule avoids),
///   which the simulator prices via store-and-forward.
pub fn gradsum_contention_makespan(payload_bytes: f64, chips: usize, two_d: bool) -> f64 {
    let torus = Torus::for_chips_idle(chips.max(1), PodLayout::TORUS_MAX_ASPECT).0;
    let n = torus.chips();
    if n <= 1 {
        return 0.0;
    }
    let p = NetParams::default();
    if two_d {
        let x_step = bidirectional_ring_step(
            &torus,
            torus.nx,
            Dir::XPlus,
            Dir::XMinus,
            payload_bytes / torus.nx as f64,
            &p,
        );
        let y_step = bidirectional_ring_step(
            &torus,
            torus.ny,
            Dir::YPlus,
            Dir::YMinus,
            payload_bytes / (torus.nx * torus.ny) as f64,
            &p,
        );
        // Phases 1+4 ride the X rings, phases 2+3 the Y rings.
        2.0 * ((torus.nx - 1) as f64 * x_step + (torus.ny - 1) as f64 * y_step)
    } else {
        let bytes = payload_bytes / n as f64;
        let mut sim = NetSim::new(torus, p.link_bw, p.link_latency);
        let msgs: Vec<Message> = (0..n)
            .map(|i| Message {
                src: torus.coord(i),
                dst: torus.coord((i + 1) % n),
                bytes,
                ready_at: 0.0,
            })
            .collect();
        let one_step = sim.makespan(&msgs);
        one_step * (2 * (n - 1)) as f64
    }
}

/// Multi-pod generalization of [`gradsum_contention_makespan`]: the
/// collapsed single-pod spec reproduces the flat price bit-for-bit; a
/// real hierarchy prices the intra-pod schedule over the per-pod torus
/// plus the cross-pod term of the scenario's [`CrossPodStrategy`].
///
/// * 2-D schedules go through [`pod_group_gradsum_makespan`], whose
///   collapsed branch is the exact symmetry fast-path the single-pod
///   cache used.
/// * 1-D hierarchical keeps the event-driven ring embedding per pod and
///   adds the analytic cross-pod shard ring
///   ([`cross_pod_ring_seconds`]).
/// * The flat-ring strategy is one ring over every chip of every pod
///   with slow boundary links; it is inherently 1-D, so both schedule
///   shapes price it through [`pod_group_gradsum_makespan`].
pub fn gradsum_contention_makespan_pods(
    payload_bytes: f64,
    chips: usize,
    two_d: bool,
    pods: PodSpec,
) -> f64 {
    let p = NetParams::default();
    if two_d {
        pod_group_gradsum_makespan(
            chips.max(1),
            pods,
            PodLayout::TORUS_MAX_ASPECT,
            payload_bytes,
            &p,
        )
    } else if pods.collapses() {
        gradsum_contention_makespan(payload_bytes, chips, false)
    } else {
        match pods.strategy {
            CrossPodStrategy::FlatRing => pod_group_gradsum_makespan(
                chips.max(1),
                pods,
                PodLayout::TORUS_MAX_ASPECT,
                payload_bytes,
                &p,
            ),
            CrossPodStrategy::Hierarchical => {
                let per_pod = (chips / pods.pods).max(1);
                let torus = Torus::for_chips_idle(per_pod, PodLayout::TORUS_MAX_ASPECT).0;
                gradsum_contention_makespan(payload_bytes, per_pod, false)
                    + cross_pod_ring_seconds(pods, payload_bytes / torus.chips() as f64, &p)
            }
        }
    }
}

/// Gradsum makespan when the spatial-partition halo phase shares the
/// links *concurrently* instead of being priced in isolation: the halo
/// payload (converted back to link-equivalent bytes at the default link
/// bandwidth) is injected into the same event simulation as the first
/// gradsum ring step, so overlapping messages queue on shared links (see
/// [`concurrent_gradsum_halo_makespan`]). The cross-pod addendum of a
/// real hierarchy rides on top, exactly as in
/// [`gradsum_contention_makespan_pods`]. With no halo traffic the result
/// is the clean (phase-isolated) price.
pub fn concurrent_contention_makespan(
    payload_bytes: f64,
    chips: usize,
    two_d: bool,
    pods: PodSpec,
    halo_group: usize,
    halo_seconds: f64,
) -> f64 {
    let p = NetParams::default();
    let halo_bytes = halo_seconds * p.link_bw;
    let local_chips =
        if pods.collapses() { chips.max(1) } else { (chips.max(1) / pods.pods).max(1) };
    let torus = Torus::for_chips_idle(local_chips, PodLayout::TORUS_MAX_ASPECT).0;
    let payloads = vec![payload_bytes; torus.chips()];
    let joint =
        concurrent_gradsum_halo_makespan(torus, &payloads, halo_group, halo_bytes, two_d, &p)
            .seconds;
    // The cross-pod shard ring (zero for a collapsed spec) does not
    // overlap the intra-pod halo traffic; it rides after the joint phase.
    let cross = gradsum_contention_makespan_pods(payload_bytes, chips, two_d, pods)
        - gradsum_contention_makespan_pods(payload_bytes, local_chips, two_d, PodSpec::default());
    joint + cross
}

/// One point's diff between a baseline and a new report.
#[derive(Clone, Debug)]
pub struct PointDiff {
    pub scenario: String,
    pub chips: usize,
    pub base_benchmark: f64,
    pub new_benchmark: f64,
    /// (phase label, base seconds, new seconds) for the per-phase fields.
    pub phase_deltas: Vec<(&'static str, f64, f64)>,
    pub regression: bool,
}

impl PointDiff {
    /// Relative benchmark-seconds change (positive = slower).
    pub fn benchmark_delta(&self) -> f64 {
        rel_delta(self.base_benchmark, self.new_benchmark)
    }
}

fn rel_delta(base: f64, new: f64) -> f64 {
    if base.is_finite() && new.is_finite() && base != 0.0 {
        (new - base) / base
    } else {
        f64::NAN
    }
}

fn fmt_delta(base: f64, new: f64) -> String {
    let d = rel_delta(base, new);
    if d.is_nan() {
        "—".to_string()
    } else {
        format!("{:+.2}%", 100.0 * d)
    }
}

/// A full baseline-vs-new comparison (the `sweep --compare` engine).
#[derive(Clone, Debug)]
pub struct SweepComparison {
    pub diffs: Vec<PointDiff>,
    /// Baseline points with no match in the new report, and vice versa.
    pub only_in_base: usize,
    pub only_in_new: usize,
    pub tolerance: f64,
}

impl SweepComparison {
    pub fn regressions(&self) -> usize {
        self.diffs.iter().filter(|d| d.regression).count()
    }

    /// Per-point table: benchmark seconds and per-phase deltas.
    pub fn table(&self) -> Table {
        let headers = [
            "scenario", "chips", "base s", "new s", "Δbench", "compute", "halo", "gradsum",
            "update", "eval", "verdict",
        ];
        let mut t = Table::new(
            &format!("Sweep diff vs baseline (tolerance {:.1}%)", 100.0 * self.tolerance),
            &headers,
        );
        for d in &self.diffs {
            let phase = |label: &str| {
                d.phase_deltas
                    .iter()
                    .find(|(l, _, _)| *l == label)
                    .map(|&(_, b, n)| fmt_delta(b, n))
                    .unwrap_or_else(|| "—".to_string())
            };
            let fmt_s = |x: f64| {
                if x.is_finite() {
                    format!("{x:.1}")
                } else {
                    "DNF".to_string()
                }
            };
            t.row(&[
                d.scenario.clone(),
                d.chips.to_string(),
                fmt_s(d.base_benchmark),
                fmt_s(d.new_benchmark),
                fmt_delta(d.base_benchmark, d.new_benchmark),
                phase("compute"),
                phase("halo"),
                phase("gradsum"),
                phase("update"),
                phase("eval"),
                if d.regression { "REGRESSION".into() } else { "ok".to_string() },
            ]);
        }
        t
    }
}

/// Diff a new report against a baseline: points are matched by
/// (scenario, chips); a point regresses when its benchmark seconds grow
/// beyond `tolerance` (relative), or when a converged baseline point
/// stops converging.
pub fn compare_reports(
    base: &SweepReport,
    new: &SweepReport,
    tolerance: f64,
) -> SweepComparison {
    use std::collections::BTreeMap;
    let mut new_by_key: BTreeMap<(String, usize), &SweepRecord> = BTreeMap::new();
    for r in &new.records {
        new_by_key.entry((r.scenario.clone(), r.chips)).or_insert(r);
    }
    let mut diffs = Vec::new();
    let mut only_in_base = 0;
    for b in &base.records {
        let Some(n) = new_by_key.remove(&(b.scenario.clone(), b.chips)) else {
            only_in_base += 1;
            continue;
        };
        let regression = (b.benchmark_seconds.is_finite()
            && n.benchmark_seconds.is_finite()
            && n.benchmark_seconds > b.benchmark_seconds * (1.0 + tolerance))
            || (b.benchmark_seconds.is_finite() && !n.benchmark_seconds.is_finite());
        diffs.push(PointDiff {
            scenario: b.scenario.clone(),
            chips: b.chips,
            base_benchmark: b.benchmark_seconds,
            new_benchmark: n.benchmark_seconds,
            phase_deltas: vec![
                ("compute", b.compute_seconds, n.compute_seconds),
                ("halo", b.halo_seconds, n.halo_seconds),
                ("gradsum", b.gradsum_seconds, n.gradsum_seconds),
                ("update", b.update_seconds, n.update_seconds),
                ("eval", b.eval_seconds, n.eval_seconds),
            ],
            regression,
        });
    }
    SweepComparison { diffs, only_in_base, only_in_new: new_by_key.len(), tolerance }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{BatchSchedule, ScalingScenario};

    #[test]
    fn resnet_sweep_produces_one_record_per_chip_count() {
        let s = ScalingScenario::submission("resnet50", vec![16, 64, 256, 1024]);
        let recs = run_scenario(&s).unwrap();
        assert_eq!(recs.len(), 4);
        for (r, chips) in recs.iter().zip([16usize, 64, 256, 1024]) {
            assert_eq!(r.chips, chips);
            assert_eq!(r.cores, chips * 2);
            assert!(r.converged, "resnet50 @ {chips} chips should converge");
            assert!(r.step_seconds > 0.0);
            assert!(
                (r.step_seconds
                    - (r.compute_seconds
                        + r.halo_seconds
                        + r.gradsum_seconds
                        + r.update_seconds))
                    .abs()
                    < 1e-12
            );
        }
    }

    #[test]
    fn benchmark_seconds_shrink_with_scale_in_submission_config() {
        let s = ScalingScenario::submission("resnet50", vec![16, 64, 256, 1024]);
        let recs = run_scenario(&s).unwrap();
        for w in recs.windows(2) {
            assert!(
                w[1].benchmark_seconds < w[0].benchmark_seconds * 1.05,
                "{} chips: {:.1}s vs {} chips: {:.1}s",
                w[1].chips,
                w[1].benchmark_seconds,
                w[0].chips,
                w[0].benchmark_seconds
            );
        }
    }

    #[test]
    fn fixed_batch_overrides_layout() {
        let s = ScalingScenario::submission("resnet50", vec![64])
            .with_batch(BatchSchedule::Fixed(4096));
        let recs = run_scenario(&s).unwrap();
        assert_eq!(recs[0].global_batch, 4096);
        assert_eq!(recs[0].mp, 1);
        assert_eq!(recs[0].replicas, 128);
        assert_eq!(recs[0].participating_cores, 128);
        assert_eq!(recs[0].surplus_cores, 0);
    }

    #[test]
    fn surplus_cores_reported_and_phases_priced_over_participants() {
        // Fixed batch 128 on 512 cores: 384 cores idle; every phase group
        // must be the participating 128, not the machine 512.
        let s = ScalingScenario::submission("resnet50", vec![256])
            .with_batch(BatchSchedule::Fixed(128));
        let r = run_scenario(&s).unwrap().remove(0);
        assert_eq!(r.participating_cores, 128);
        assert_eq!(r.surplus_cores, 384);
        assert_eq!(r.gradsum_cores, 128);
        assert_eq!(r.update_shards, 128);
        assert_eq!(r.eval_cores, 128);
    }

    #[test]
    fn maskrcnn_reports_dnf_above_batch_wall() {
        // Fixed batch 256 > the 128 wall: the record must carry DNF, not
        // a bogus number.
        let s = ScalingScenario::submission("maskrcnn", vec![64])
            .with_batch(BatchSchedule::Fixed(256));
        let recs = run_scenario(&s).unwrap();
        assert!(!recs[0].converged);
        assert!(!recs[0].benchmark_seconds.is_finite());
        assert_eq!(recs[0].to_json().get("benchmark_seconds"), Some(&Json::Null));
    }

    #[test]
    fn ssd_engages_model_parallelism_at_pod_scale() {
        let s = ScalingScenario::submission("ssd", vec![1024]);
        let recs = run_scenario(&s).unwrap();
        assert!(recs[0].mp > 1);
        assert!(recs[0].spatial_speedup > 1.0);
        assert!(recs[0].halo_seconds > 0.0);
    }

    #[test]
    fn shard_imbalance_is_small_and_bounded() {
        let s = ScalingScenario::submission("resnet50", vec![16, 1024]);
        for r in run_scenario(&s).unwrap() {
            assert!(r.shard_imbalance >= 1.0);
            assert!(r.shard_imbalance < 1.01, "{}", r.shard_imbalance);
        }
    }

    #[test]
    fn contention_makespan_positive_and_single_chip_zero() {
        assert_eq!(gradsum_contention_makespan(100e6, 1, true), 0.0);
        assert_eq!(gradsum_contention_makespan(100e6, 1, false), 0.0);
        let t16 = gradsum_contention_makespan(100e6, 16, true);
        let t1024 = gradsum_contention_makespan(100e6, 1024, true);
        assert!(t16 > 0.0 && t1024 > 0.0);
    }

    #[test]
    fn contention_confirms_1d_ring_slower_at_pod_scale() {
        // §2 / [19]: the 1-D ring's 2(n-1) latency-bound steps dwarf the
        // 2-D schedule's 2(nx-1)+2(ny-1) — visible under contention too.
        let t2d = gradsum_contention_makespan(100e6, 1024, true);
        let t1d = gradsum_contention_makespan(100e6, 1024, false);
        assert!(t1d > t2d, "1-D {t1d} should exceed 2-D {t2d} at pod scale");
    }

    #[test]
    fn report_round_trips_through_json() {
        let s = ScalingScenario::submission("transformer", vec![256, 1024]);
        let report = SweepRunner::single(s).run().unwrap();
        let parsed = SweepReport::parse(&report.dump()).unwrap();
        assert_eq!(parsed.records.len(), 2);
        for (a, b) in report.records.iter().zip(&parsed.records) {
            assert_eq!(a.to_json(), b.to_json());
        }
        assert_eq!(parsed.records[1].cores, 2048);
        assert_eq!(parsed.records[1].global_batch, 2048);
    }

    #[test]
    fn compare_reports_flags_only_real_regressions() {
        let s = ScalingScenario::submission("resnet50", vec![64, 256]);
        let base = SweepRunner::single(s).run().unwrap();
        // Identical reports: no regressions.
        let same = compare_reports(&base, &base, 0.01);
        assert_eq!(same.regressions(), 0);
        assert_eq!(same.diffs.len(), 2);
        assert_eq!((same.only_in_base, same.only_in_new), (0, 0));
        // Slow one point down beyond tolerance.
        let mut slower = base.clone();
        slower.records[1].benchmark_seconds *= 1.10;
        slower.records[1].gradsum_seconds *= 2.0;
        let cmp = compare_reports(&base, &slower, 0.05);
        assert_eq!(cmp.regressions(), 1);
        let d = cmp.diffs.iter().find(|d| d.regression).unwrap();
        assert_eq!(d.chips, 256);
        assert!((d.benchmark_delta() - 0.10).abs() < 1e-9);
        // Speedups are not regressions.
        let mut faster = base.clone();
        faster.records[0].benchmark_seconds *= 0.5;
        assert_eq!(compare_reports(&base, &faster, 0.05).regressions(), 0);
    }

    #[test]
    fn compare_reports_treats_dnf_transition_as_regression() {
        let s = ScalingScenario::submission("resnet50", vec![64]);
        let base = SweepRunner::single(s).run().unwrap();
        let mut broken = base.clone();
        broken.records[0].benchmark_seconds = f64::INFINITY;
        broken.records[0].converged = false;
        assert_eq!(compare_reports(&base, &broken, 0.05).regressions(), 1);
    }

    #[test]
    fn compare_reports_counts_unmatched_points() {
        let s = ScalingScenario::submission("resnet50", vec![64, 256]);
        let base = SweepRunner::single(s).run().unwrap();
        let mut partial = base.clone();
        partial.records.truncate(1);
        let cmp = compare_reports(&base, &partial, 0.05);
        assert_eq!(cmp.only_in_base, 1);
        assert_eq!(cmp.only_in_new, 0);
        let cmp = compare_reports(&partial, &base, 0.05);
        assert_eq!(cmp.only_in_base, 0);
        assert_eq!(cmp.only_in_new, 1);
    }

    #[test]
    fn old_schema_baselines_parse_with_unknown_phases() {
        // A version-1 report (pre per-phase attribution) still loads; the
        // absent halo field reads as NaN and its delta renders as "—".
        let old = r#"{"version":1,"records":[{"scenario":"s","model":"resnet50",
            "chips":64,"cores":128,"mp":1,"replicas":128,"global_batch":2048,
            "per_replica_batch":16.0,"epochs":42.0,"steps":100.0,
            "step_seconds":0.01,"compute_seconds":0.008,
            "gradsum_seconds":0.001,"update_seconds":0.001,
            "eval_seconds":1.0,"infra_seconds":3.0,"benchmark_seconds":10.0,
            "converged":true,"shard_imbalance":1.0,"spatial_speedup":1.0,
            "collective_makespan_seconds":0.001}]}"#;
        let report = SweepReport::parse(old).unwrap();
        assert_eq!(report.records.len(), 1);
        assert!(report.records[0].halo_seconds.is_nan());
        assert_eq!(report.records[0].participating_cores, 0);
        let cmp = compare_reports(&report, &report, 0.05);
        assert_eq!(cmp.regressions(), 0);
    }

    #[test]
    fn pre_pod_baselines_read_as_single_pod() {
        // A record written before the multi-pod axis existed carries no
        // pod fields: it must parse as a flat single-pod point, with the
        // concurrent makespan unknown (NaN, skipped by the comparer).
        let old = r#"{"version":2,"records":[{"scenario":"s","model":"resnet50",
            "chips":64,"cores":128,"benchmark_seconds":10.0,"converged":true,
            "collective_makespan_seconds":0.001}]}"#;
        let report = SweepReport::parse(old).unwrap();
        let r = &report.records[0];
        assert_eq!(r.pods, 1);
        assert_eq!(r.inter_pod_ratio, 1.0);
        assert_eq!(r.cross_pod_strategy, "hierarchical");
        assert!(r.concurrent_makespan_seconds.is_nan());
        assert_eq!(compare_reports(&report, &report, 0.05).regressions(), 0);
    }

    #[test]
    fn multi_pod_contention_collapses_and_orders() {
        let payload = 1.0e8;
        // Collapsing specs reproduce the flat single-pod prices bit-for-bit.
        let flat_1d = gradsum_contention_makespan(payload, 256, false);
        let flat_2d = crate::netsim::torus2d_gradsum_makespan(
            Torus::for_chips_idle(256, PodLayout::TORUS_MAX_ASPECT).0,
            payload,
            &NetParams::default(),
        );
        for pods in [PodSpec::default(), PodSpec::new(1, 0.25), PodSpec::new(4, 1.0)] {
            let p1 = gradsum_contention_makespan_pods(payload, 256, false, pods);
            assert_eq!(p1.to_bits(), flat_1d.to_bits());
            let p2 = gradsum_contention_makespan_pods(payload, 256, true, pods);
            assert_eq!(p2.to_bits(), flat_2d.to_bits());
        }
        // A real hierarchy costs more than its per-pod torus alone, and a
        // slower inter-pod link strictly more than a faster one.
        let hier25 = gradsum_contention_makespan_pods(payload, 1024, true, PodSpec::new(2, 0.25));
        let hier05 = gradsum_contention_makespan_pods(payload, 1024, true, PodSpec::new(2, 0.05));
        let per_pod = crate::netsim::torus2d_gradsum_makespan(
            Torus::for_chips_idle(512, PodLayout::TORUS_MAX_ASPECT).0,
            payload,
            &NetParams::default(),
        );
        assert!(hier25 > per_pod, "cross-pod term must be visible: {hier25} vs {per_pod}");
        assert!(hier05 > hier25, "slower inter-pod links must cost more");
        // The flat ring drags every chunk across the slow boundary links.
        let flat_ring = gradsum_contention_makespan_pods(
            payload,
            1024,
            true,
            PodSpec::new(2, 0.25).with_strategy(CrossPodStrategy::FlatRing),
        );
        assert!(flat_ring > hier25, "flat ring {flat_ring} should exceed hierarchical {hier25}");
        // 1-D hierarchy: per-pod ring plus the cross-pod shard ring.
        let hier_1d = gradsum_contention_makespan_pods(payload, 1024, false, PodSpec::new(2, 0.25));
        assert!(hier_1d > gradsum_contention_makespan(payload, 512, false));
    }

    #[test]
    fn concurrent_price_reuses_clean_price_without_halo() {
        let payload = 1.0e8;
        for two_d in [true, false] {
            let clean = gradsum_contention_makespan_pods(payload, 64, two_d, PodSpec::default());
            let no_halo =
                concurrent_contention_makespan(payload, 64, two_d, PodSpec::default(), 4, 0.0);
            assert_eq!(no_halo.to_bits(), clean.to_bits());
            // Real halo traffic queues on the shared links: the joint
            // makespan strictly exceeds the phase-isolated price.
            let with_halo =
                concurrent_contention_makespan(payload, 64, two_d, PodSpec::default(), 4, 1e-3);
            assert!(
                with_halo > clean,
                "two_d={two_d}: concurrent {with_halo} should exceed clean {clean}"
            );
        }
    }

    #[test]
    fn cache_distinguishes_payload_schedules() {
        let cache = SweepCache::default();
        let uniform = vec![1.0e6; 16];
        let u = cache.scheduled_makespan(&uniform, 16, PodSpec::default());
        assert!(u.fastpath, "uniform schedules take the symmetry fast-path");
        let mut skew = uniform.clone();
        skew[3] *= 4.0;
        let s1 = cache.scheduled_makespan(&skew, 16, PodSpec::default());
        assert!(!s1.fastpath, "non-uniform schedules must bypass the fast-path");
        assert!(s1.seconds > u.seconds);
        // Same schedule again: a cache hit returning exactly the same bits.
        let hits = cache.makespan_hits.load(Ordering::Relaxed);
        let s2 = cache.scheduled_makespan(&skew, 16, PodSpec::default());
        assert_eq!(s1.seconds.to_bits(), s2.seconds.to_bits());
        assert_eq!(cache.makespan_hits.load(Ordering::Relaxed), hits + 1);
        // A multi-pod spec keys separately and still flags non-uniform.
        let s3 = cache.scheduled_makespan(&skew, 16, PodSpec::new(2, 0.25));
        assert!(!s3.fastpath);
        assert_ne!(s3.seconds.to_bits(), s1.seconds.to_bits());
    }
}
