//! Pod-scale scenario sweep engine — the experiment driver behind the
//! paper's Figs. 7-10 and Table 1.
//!
//! The repo models every §2 ingredient separately (the pod simulator, the
//! torus cost model, weight-update sharding plans, the spatial-partition
//! planner); this module composes them into *declarative* experiments:
//!
//! * [`ScalingScenario`] — one model × a set of pod slices (chip counts)
//!   × a batch schedule × the §2 optimization toggles. Validated up
//!   front, so a sweep either runs completely or fails with a message.
//! * [`SweepRunner`] / [`run_scenario`] — execute the scenario grid; each
//!   point yields a [`SweepRecord`] (layout, participating vs surplus
//!   cores, per-phase step-time attribution with each phase's group size,
//!   shard imbalance, contention-checked collective time, predicted
//!   epochs-to-quality, benchmark seconds). [`SweepRunner::run_jobs`]
//!   executes points on a worker pool with memoized hot kernels; its
//!   output is byte-identical to a serial run.
//! * [`AblationGrid`] — the scenario × `SimOptions` cross-product driver:
//!   every §2 axis (spatial on/off, WUS on/off, gradsum serial/pipelined,
//!   LARS vs SGD) as labeled scenarios (`tpu-pod-train sweep --grid`),
//!   plus the multi-pod axes (pod count × inter-pod bandwidth ratio ×
//!   cross-pod gradsum strategy) layered on via `--pods`,
//!   `--inter-pod-ratio` and `--cross-pod`.
//! * [`SweepReport`] — the record set with JSON serialization
//!   (`tpu-pod-train sweep` writes these; golden-trace tests pin them),
//!   plus [`compare_reports`] — the `sweep --compare baseline.json` diff
//!   engine every perf PR uses to prove its win.
//! * [`run_sweep_bench`] — the tier-1 perf harness behind
//!   `BENCH_sweep.json` (ablation grid, reference vs memoized engines).
//! * [`FaultTrace`] — the failure/straggler axis (`sweep --faults TRACE`):
//!   seeded per-step chip slowdown/death/preemption events, priced into
//!   per-record **goodput** (useful train time / wall clock, counting
//!   rolled-back work and checkpoint restores) by [`price_fault_trace`];
//!   the same trace drives the live trainer's elastic restarts.
//!
//! How sweeps map to the paper:
//!
//! * Fig. 7 (batch vs cores): [`presets::fig7_scenarios`] — submission
//!   batch schedule, read `global_batch`/`mp` per point.
//! * Fig. 8 (epochs vs batch): [`presets::fig8_scenarios`] — fixed-batch
//!   schedule, read `epochs` (the convergence-curve prediction).
//! * Fig. 9 (benchmark seconds): [`presets::fig9_scenarios`] — read
//!   `benchmark_seconds` across slices.
//! * Fig. 10 (model parallelism): [`presets::model_parallel_speedup`].
//! * Table 1 (LARS variants): [`presets::table1_scenarios`] — optimizer
//!   override with per-variant epochs-to-converge.

pub mod bench;
pub mod faults;
pub mod grid;
pub mod marginals;
pub mod presets;
pub mod runner;

pub use bench::{
    reference_point, run_backend_bench, run_sweep_bench, run_trace_bench, BackendBench,
    BackendCase, SweepBench, TraceBench,
};
pub use faults::{price_fault_trace, FaultEvent, FaultKind, FaultOutcome, FaultTrace};
pub use grid::{AblationGrid, OptimizerAxis};
pub use marginals::{grid_marginals, parse_grid_name, AxisMarginal, GridKey, MarginalReport};
pub use presets::{
    fig7_scenarios, fig8_scenarios, fig9_scenarios, model_parallel_speedup, paper_chip_slices,
    table1_scenarios,
};
pub use runner::{
    compare_reports, concurrent_contention_makespan, effective_jobs, gradsum_contention_makespan,
    gradsum_contention_makespan_pods, pool_workers, run_scenario, sweep_point, PointDiff,
    SweepCache, SweepComparison, SweepRecord, SweepReport, SweepRunner,
};

use crate::models::registry::{model, Layout, ModelProfile, Optimizer};
use crate::netsim::{CrossPodStrategy, PodSpec};
use crate::simulator::SimOptions;

/// How the global batch is chosen at each sweep point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchSchedule {
    /// The Google-submission layout policy (`ModelProfile::layout`,
    /// Fig. 7 shape: only ResNet-50 scales its batch aggressively).
    Submission,
    /// The same global batch at every chip count (strong-scaling and
    /// Fig. 8 epochs-vs-batch studies).
    Fixed(usize),
}

/// Gradient-summation schedule under sweep (§2 ablation axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradSumChoice {
    /// The submission configuration: 2-D torus schedule, pipelined
    /// non-contiguous gathers/scatters.
    Pipelined2D,
    /// 2-D schedule, fully exposed gathers (the paper's baseline).
    Serial2D,
    /// Single 1-D ring, pipelined.
    Pipelined1D,
    /// Single 1-D ring, exposed (the pre-[19] worst case).
    Serial1D,
}

impl GradSumChoice {
    pub fn is_2d(self) -> bool {
        matches!(self, GradSumChoice::Pipelined2D | GradSumChoice::Serial2D)
    }

    pub fn is_pipelined(self) -> bool {
        matches!(self, GradSumChoice::Pipelined2D | GradSumChoice::Pipelined1D)
    }

    pub fn label(self) -> &'static str {
        match self {
            GradSumChoice::Pipelined2D => "2d-pipelined",
            GradSumChoice::Serial2D => "2d-serial",
            GradSumChoice::Pipelined1D => "1d-pipelined",
            GradSumChoice::Serial1D => "1d-serial",
        }
    }
}

/// Optimizer selection for a sweep (Table 1 optimizer studies replace the
/// model's default optimizer and its epochs-to-converge).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptimizerChoice {
    /// The model profile's own optimizer and convergence curve.
    ModelDefault,
    /// Force an optimizer (update-traffic model) and optionally pin the
    /// epochs-to-converge (Table 1 rows differ only in epochs).
    Override { optimizer: Optimizer, epochs: Option<f64> },
}

/// One declarative sweep: a model swept across TPU-v3 pod slices with a
/// batch schedule and the §2 technique toggles.
#[derive(Clone, Debug)]
pub struct ScalingScenario {
    /// Report label (e.g. "fig9-resnet50").
    pub name: String,
    /// Registry key: resnet50 | ssd | maskrcnn | transformer | gnmt.
    pub model: String,
    /// TPU-v3 chip counts (2 cores per chip); powers of two, e.g.
    /// `[16, 64, 256, 1024]` spans one rack to the full pod.
    pub chips: Vec<usize>,
    pub batch: BatchSchedule,
    pub optimizer: OptimizerChoice,
    pub gradsum: GradSumChoice,
    pub weight_update_sharding: bool,
    pub distributed_eval: bool,
    pub spatial_partitioning: bool,
    /// Optional failure/straggler schedule. `None` and an empty trace are
    /// both priced as goodput 1.0 and leave records byte-identical.
    pub faults: Option<FaultTrace>,
    /// Live-calibrated compute coefficient (`sweep --costs-from`): price
    /// compute at this achieved forward-GFLOP/s instead of the TPU-v3
    /// datasheet roofline. `None` = stock TPU-v3.
    pub compute_gflops: Option<f64>,
    /// Multi-pod topology: pod count, inter-pod bandwidth ratio and
    /// cross-pod gradsum strategy. The default single-pod spec prices
    /// bit-identically to the pre-hierarchy sweep.
    pub pods: PodSpec,
}

impl ScalingScenario {
    /// The submission configuration (every §2 optimization on) for a model
    /// across the given chip counts.
    pub fn submission(model_name: &str, chips: Vec<usize>) -> ScalingScenario {
        ScalingScenario {
            name: format!("{model_name}-submission"),
            model: model_name.to_string(),
            chips,
            batch: BatchSchedule::Submission,
            optimizer: OptimizerChoice::ModelDefault,
            gradsum: GradSumChoice::Pipelined2D,
            weight_update_sharding: true,
            distributed_eval: true,
            spatial_partitioning: true,
            faults: None,
            compute_gflops: None,
            pods: PodSpec::default(),
        }
    }

    pub fn named(mut self, name: impl Into<String>) -> ScalingScenario {
        self.name = name.into();
        self
    }

    pub fn with_batch(mut self, batch: BatchSchedule) -> ScalingScenario {
        self.batch = batch;
        self
    }

    pub fn with_faults(mut self, faults: FaultTrace) -> ScalingScenario {
        self.faults = Some(faults);
        self
    }

    /// Price compute with a live-calibrated coefficient (the
    /// `fitted_gflops` of a `sweep --live` calibration report).
    pub fn with_compute_gflops(mut self, gflops: f64) -> ScalingScenario {
        self.compute_gflops = Some(gflops);
        self
    }

    /// Span `pods` pods joined by inter-pod links at `inter_pod_ratio`
    /// of the torus link bandwidth (keeps the current strategy).
    pub fn with_pods(mut self, pods: usize, inter_pod_ratio: f64) -> ScalingScenario {
        self.pods = PodSpec { pods, inter_pod_ratio, ..self.pods };
        self
    }

    /// Pick the cross-pod gradient-summation strategy.
    pub fn with_cross_pod(mut self, strategy: CrossPodStrategy) -> ScalingScenario {
        self.pods.strategy = strategy;
        self
    }

    /// Check the spec and resolve the model profile.
    pub fn validate(&self) -> Result<ModelProfile, String> {
        let m = model(&self.model)
            .ok_or_else(|| format!("scenario {:?}: unknown model {:?}", self.name, self.model))?;
        if self.chips.is_empty() {
            return Err(format!("scenario {:?}: empty chip list", self.name));
        }
        for (i, &c) in self.chips.iter().enumerate() {
            if c == 0 {
                return Err(format!("scenario {:?}: chip count must be nonzero", self.name));
            }
            // Duplicate points would collide in reports and in the
            // `sweep --compare` (scenario, chips) match keys.
            if self.chips[..i].contains(&c) {
                return Err(format!("scenario {:?}: duplicate chip count {c}", self.name));
            }
        }
        if let BatchSchedule::Fixed(b) = self.batch {
            if b == 0 {
                return Err(format!("scenario {:?}: fixed global batch must be > 0", self.name));
            }
        }
        if let Some(trace) = &self.faults {
            trace.validate()?;
        }
        self.pods.validate().map_err(|e| format!("scenario {:?}: {e}", self.name))?;
        Ok(m)
    }

    /// The effective model profile after any optimizer override.
    pub fn profile(&self) -> Result<ModelProfile, String> {
        let mut m = self.validate()?;
        if let OptimizerChoice::Override { optimizer, .. } = self.optimizer {
            m.optimizer = optimizer;
        }
        Ok(m)
    }

    /// Simulator options for one sweep point at `cores` TPU-v3 cores.
    pub fn sim_options(&self, cores: usize) -> SimOptions {
        let layout_override = match self.batch {
            BatchSchedule::Submission => None,
            BatchSchedule::Fixed(global_batch) => Some(fixed_batch_layout(cores, global_batch)),
        };
        let epochs_override = match self.optimizer {
            OptimizerChoice::Override { epochs, .. } => epochs,
            OptimizerChoice::ModelDefault => None,
        };
        SimOptions {
            gradsum_2d: self.gradsum.is_2d(),
            gradsum_pipelined: self.gradsum.is_pipelined(),
            weight_update_sharding: self.weight_update_sharding,
            distributed_eval: self.distributed_eval,
            spatial_partitioning: self.spatial_partitioning,
            epochs_override,
            layout_override,
            compute_gflops: self.compute_gflops,
            pods: self.pods,
        }
    }
}

/// Pure data-parallel layout for a fixed global batch (strong scaling):
/// replicas are capped by the batch (surplus cores idle), no model
/// parallelism.
///
/// When `cores > global_batch`, the surplus cores hold no replica; the
/// `costs::PodLayout` participation accounting prices every phase over
/// the `replicas * mp` participating cores, so idle cores buy no
/// gradsum/update/eval time (the record reports them as
/// `surplus_cores`).
pub fn fixed_batch_layout(cores: usize, global_batch: usize) -> Layout {
    let replicas = cores.min(global_batch).max(1);
    Layout { cores, mp: 1, replicas, global_batch }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submission_scenario_validates() {
        let s = ScalingScenario::submission("resnet50", vec![16, 64, 256, 1024]);
        let m = s.validate().unwrap();
        assert_eq!(m.name, "resnet50");
        assert_eq!(s.gradsum, GradSumChoice::Pipelined2D);
    }

    #[test]
    fn unknown_model_rejected() {
        let s = ScalingScenario::submission("alexnet", vec![16]);
        assert!(s.validate().is_err());
    }

    #[test]
    fn bad_chip_counts_rejected() {
        assert!(ScalingScenario::submission("ssd", vec![]).validate().is_err());
        // Arbitrary (non-power-of-two) counts are valid since the
        // elastic-survivor work; only zero and duplicates are rejected.
        assert!(ScalingScenario::submission("ssd", vec![48]).validate().is_ok());
        assert!(ScalingScenario::submission("ssd", vec![0]).validate().is_err());
        assert!(ScalingScenario::submission("ssd", vec![64, 64]).validate().is_err());
    }

    #[test]
    fn pod_spec_flows_into_sim_options_and_validates() {
        let s = ScalingScenario::submission("resnet50", vec![64])
            .with_pods(2, 0.25)
            .with_cross_pod(CrossPodStrategy::FlatRing);
        assert!(s.validate().is_ok());
        let opts = s.sim_options(128);
        assert_eq!(opts.pods.pods, 2);
        assert_eq!(opts.pods.inter_pod_ratio, 0.25);
        assert_eq!(opts.pods.strategy, CrossPodStrategy::FlatRing);
        assert!(ScalingScenario::submission("resnet50", vec![64])
            .with_pods(0, 0.25)
            .validate()
            .is_err());
        assert!(ScalingScenario::submission("resnet50", vec![64])
            .with_pods(2, 1.5)
            .validate()
            .is_err());
    }

    #[test]
    fn zero_fixed_batch_rejected() {
        let s = ScalingScenario::submission("ssd", vec![16]).with_batch(BatchSchedule::Fixed(0));
        assert!(s.validate().is_err());
    }

    #[test]
    fn optimizer_override_changes_profile() {
        let mut s = ScalingScenario::submission("resnet50", vec![16]);
        s.optimizer =
            OptimizerChoice::Override { optimizer: Optimizer::Adam, epochs: Some(50.0) };
        let m = s.profile().unwrap();
        assert_eq!(m.optimizer, Optimizer::Adam);
        let opts = s.sim_options(32);
        assert_eq!(opts.epochs_override, Some(50.0));
    }

    #[test]
    fn fixed_batch_layout_caps_replicas() {
        let l = fixed_batch_layout(2048, 128);
        assert_eq!(l.replicas, 128);
        assert_eq!(l.mp, 1);
        assert_eq!(l.per_replica_batch(), 1.0);
        let l = fixed_batch_layout(32, 32768);
        assert_eq!(l.replicas, 32);
        assert_eq!(l.per_replica_batch(), 1024.0);
    }

    #[test]
    fn gradsum_choice_axes() {
        assert!(GradSumChoice::Pipelined2D.is_2d() && GradSumChoice::Pipelined2D.is_pipelined());
        assert!(!GradSumChoice::Serial1D.is_2d() && !GradSumChoice::Serial1D.is_pipelined());
        assert_eq!(GradSumChoice::Serial2D.label(), "2d-serial");
    }
}
