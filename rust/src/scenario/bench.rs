//! Tier-1-runnable sweep perf harness (`BENCH_sweep.json`).
//!
//! Times the §2 ablation grid three ways — the pre-memoization serial
//! reference (fresh gradient census and full event-driven contention
//! simulation per point), the memoized engine on one worker, and the
//! memoized engine on the full worker pool — and cross-checks that all
//! three produce byte-identical reports before reporting wall-clock and
//! points/sec. `tests/bench_sweep.rs` runs it under plain `cargo test`
//! (no artifacts needed) and writes `BENCH_sweep.json` at the workspace
//! root so the perf trajectory is tracked per commit; the `sweep_grid`
//! bench binary prints the same numbers as a table.

use crate::costs::shard_imbalance;
use crate::models::registry::ModelProfile;
use crate::simulator::simulate;
use crate::util::json::{obj, Json};
use crate::util::timer::Timer;

use super::grid::AblationGrid;
use super::runner::{
    assemble_record, gradsum_contention_makespan, pool_workers, SweepRecord, SweepReport,
    SweepRunner,
};
use super::ScalingScenario;

/// One timed run of the ablation grid through the three engines.
#[derive(Clone, Debug)]
pub struct SweepBench {
    pub scenarios: usize,
    pub points: usize,
    /// Worker threads the parallel pass used.
    pub jobs: usize,
    /// Serial pre-memoization reference (per-point census + full
    /// event-driven contention kernel — the engine before this layer).
    pub baseline_s: f64,
    /// Memoized engine, one worker.
    pub serial_s: f64,
    /// Memoized engine, `jobs` workers.
    pub parallel_s: f64,
}

impl SweepBench {
    pub fn speedup_vs_baseline(&self) -> f64 {
        self.baseline_s / self.parallel_s
    }

    pub fn points_per_sec(&self, wall_s: f64) -> f64 {
        self.points as f64 / wall_s.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("bench", Json::from("sweep_grid")),
            ("scenarios", Json::from(self.scenarios)),
            ("points", Json::from(self.points)),
            ("jobs", Json::from(self.jobs)),
            ("baseline_serial_seconds", Json::from(self.baseline_s)),
            ("memoized_serial_seconds", Json::from(self.serial_s)),
            ("memoized_parallel_seconds", Json::from(self.parallel_s)),
            ("baseline_points_per_sec", Json::from(self.points_per_sec(self.baseline_s))),
            ("parallel_points_per_sec", Json::from(self.points_per_sec(self.parallel_s))),
            ("speedup_vs_baseline", Json::from(self.speedup_vs_baseline())),
            ("speedup_serial_only", Json::from(self.baseline_s / self.serial_s.max(1e-12))),
        ])
    }

    /// Write the record (`BENCH_sweep.json`).
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())
    }
}

/// The pre-memoization per-point evaluator, kept as the timing and
/// correctness reference: a fresh gradient census per point (via
/// [`shard_imbalance`]) and the full event-driven contention simulation
/// (no symmetry fast-path, no cache). The record itself comes from the
/// engine's single construction site, so only the two kernel prices can
/// ever differ from the memoized path.
pub fn reference_point(s: &ScalingScenario, m: &ModelProfile, chips: usize) -> SweepRecord {
    let cores = chips * 2;
    let opts = s.sim_options(cores);
    let r = simulate(m, cores, &opts);
    let imbalance = shard_imbalance(m, r.participating_cores);
    let makespan = gradsum_contention_makespan(
        m.params * 4.0,
        (r.participating_cores / 2).max(1),
        s.gradsum.is_2d(),
    );
    let mut rec = assemble_record(s, m, chips, &r, imbalance, makespan);
    super::faults::apply_fault_trace(s, m, &r, &mut rec);
    rec
}

/// Time the grid through the reference and the memoized serial/parallel
/// engines; error out if any pair of reports differs by a single byte.
pub fn run_sweep_bench(grid: &AblationGrid, jobs: usize) -> Result<SweepBench, String> {
    let scenarios = grid.scenarios();
    let runner = SweepRunner::new(scenarios.clone());
    let jobs = pool_workers(jobs, grid.point_count());

    let t = Timer::start();
    let mut reference = Vec::with_capacity(grid.point_count());
    for s in &scenarios {
        let m = s.profile()?;
        for &chips in &s.chips {
            reference.push(reference_point(s, &m, chips));
        }
    }
    let baseline_s = t.secs();
    let reference = SweepReport { records: reference };

    let t = Timer::start();
    let serial = runner.run_jobs(1)?;
    let serial_s = t.secs();

    let t = Timer::start();
    let parallel = runner.run_jobs(jobs)?;
    let parallel_s = t.secs();

    let serial_dump = serial.dump();
    if parallel.dump() != serial_dump {
        return Err(format!("parallel sweep ({jobs} jobs) is not byte-identical to serial"));
    }
    if reference.dump() != serial_dump {
        return Err("memoized engine diverged from the pre-memoization reference".into());
    }
    Ok(SweepBench {
        scenarios: scenarios.len(),
        points: reference.records.len(),
        jobs,
        baseline_s,
        serial_s,
        parallel_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_engines_agree_on_a_small_grid() {
        let mut g = AblationGrid::full_paper();
        g.models = vec!["resnet50".into(), "gnmt".into()];
        g.chips = vec![16, 256];
        let b = run_sweep_bench(&g, 2).unwrap();
        assert_eq!(b.scenarios, 32);
        assert_eq!(b.points, 64);
        assert_eq!(b.jobs, 2);
        assert!(b.baseline_s > 0.0 && b.serial_s > 0.0 && b.parallel_s > 0.0);
        let j = b.to_json();
        assert_eq!(j.get("points").and_then(Json::as_usize), Some(64));
        assert!(j.get("speedup_vs_baseline").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
