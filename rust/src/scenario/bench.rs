//! Tier-1-runnable perf harnesses (`BENCH_sweep.json`, `BENCH_backend.json`).
//!
//! [`run_sweep_bench`] times the §2 ablation grid three ways — the
//! pre-memoization serial reference (fresh gradient census and full
//! event-driven contention simulation per point), the memoized engine on
//! one worker, and the memoized engine on the full worker pool — and
//! cross-checks that all three produce byte-identical reports before
//! reporting wall-clock and points/sec. `tests/bench_sweep.rs` runs it
//! under plain `cargo test` (no artifacts needed) and writes
//! `BENCH_sweep.json` at the workspace root so the perf trajectory is
//! tracked per commit; the `sweep_grid` bench binary prints the same
//! numbers as a table.
//!
//! [`run_backend_bench`] is the same pattern for the reference executor:
//! it times `train_step` per proxy family through the naive scalar
//! kernels, the tiled serial kernels and the tiled kernels at N executor
//! threads — cross-checking that all three produce bit-identical losses
//! and gradients first — and records steps/sec plus speedup-vs-naive in
//! `BENCH_backend.json` (`tests/bench_backend.rs`; the `runtime_micro`
//! bench binary prints the matrix as a table).

use crate::coordinator::{train, TrainConfig};
use crate::costs::shard_imbalance;
use crate::data::synthetic::{ImageTask, LmTask};
use crate::metrics::TraceSink;
use crate::models::proxy::{proxy_dims, TaskKind};
use crate::models::registry::ModelProfile;
use crate::runtime::{
    param_specs_for, Backend, KernelMode, Precision, ReferenceBackend, StepBatch,
};
use crate::simulator::simulate;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

use super::grid::AblationGrid;
use super::runner::{
    assemble_record, concurrent_contention_makespan, gradsum_contention_makespan_pods,
    pool_workers, SweepRecord, SweepReport, SweepRunner,
};
use super::ScalingScenario;

/// One timed run of the ablation grid through the three engines.
#[derive(Clone, Debug)]
pub struct SweepBench {
    pub scenarios: usize,
    pub points: usize,
    /// Worker threads the parallel pass used.
    pub jobs: usize,
    /// Serial pre-memoization reference (per-point census + full
    /// event-driven contention kernel — the engine before this layer).
    pub baseline_s: f64,
    /// Memoized engine, one worker.
    pub serial_s: f64,
    /// Memoized engine, `jobs` workers.
    pub parallel_s: f64,
}

impl SweepBench {
    pub fn speedup_vs_baseline(&self) -> f64 {
        self.baseline_s / self.parallel_s
    }

    pub fn points_per_sec(&self, wall_s: f64) -> f64 {
        self.points as f64 / wall_s.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("bench", Json::from("sweep_grid")),
            ("scenarios", Json::from(self.scenarios)),
            ("points", Json::from(self.points)),
            ("jobs", Json::from(self.jobs)),
            ("baseline_serial_seconds", Json::from(self.baseline_s)),
            ("memoized_serial_seconds", Json::from(self.serial_s)),
            ("memoized_parallel_seconds", Json::from(self.parallel_s)),
            ("baseline_points_per_sec", Json::from(self.points_per_sec(self.baseline_s))),
            ("parallel_points_per_sec", Json::from(self.points_per_sec(self.parallel_s))),
            ("speedup_vs_baseline", Json::from(self.speedup_vs_baseline())),
            ("speedup_serial_only", Json::from(self.baseline_s / self.serial_s.max(1e-12))),
        ])
    }

    /// Write the record (`BENCH_sweep.json`).
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())
    }
}

/// The pre-memoization per-point evaluator, kept as the timing and
/// correctness reference: a fresh gradient census per point (via
/// [`shard_imbalance`]) and the full event-driven contention simulation
/// (no symmetry fast-path, no cache). The record itself comes from the
/// engine's single construction site, so only the two kernel prices can
/// ever differ from the memoized path.
pub fn reference_point(s: &ScalingScenario, m: &ModelProfile, chips: usize) -> SweepRecord {
    let cores = chips * 2;
    let opts = s.sim_options(cores);
    let r = simulate(m, cores, &opts);
    let imbalance = shard_imbalance(m, r.participating_cores);
    let part_chips = (r.participating_cores / 2).max(1);
    let makespan =
        gradsum_contention_makespan_pods(m.params * 4.0, part_chips, s.gradsum.is_2d(), s.pods);
    let concurrent = if r.halo_seconds > 0.0 {
        concurrent_contention_makespan(
            m.params * 4.0,
            part_chips,
            s.gradsum.is_2d(),
            s.pods,
            r.layout.mp,
            r.halo_seconds,
        )
    } else {
        makespan
    };
    let mut rec = assemble_record(s, m, chips, &r, imbalance, makespan, concurrent);
    super::faults::apply_fault_trace(s, m, &r, &mut rec);
    rec
}

/// Time the grid through the reference and the memoized serial/parallel
/// engines; error out if any pair of reports differs by a single byte.
pub fn run_sweep_bench(grid: &AblationGrid, jobs: usize) -> Result<SweepBench, String> {
    let scenarios = grid.scenarios();
    let runner = SweepRunner::new(scenarios.clone());
    let jobs = pool_workers(jobs, grid.point_count());

    let t = Timer::start();
    let mut reference = Vec::with_capacity(grid.point_count());
    for s in &scenarios {
        let m = s.profile()?;
        for &chips in &s.chips {
            reference.push(reference_point(s, &m, chips));
        }
    }
    let baseline_s = t.secs();
    let reference = SweepReport { records: reference };

    let t = Timer::start();
    let serial = runner.run_jobs(1)?;
    let serial_s = t.secs();

    let t = Timer::start();
    let parallel = runner.run_jobs(jobs)?;
    let parallel_s = t.secs();

    let serial_dump = serial.dump();
    if parallel.dump() != serial_dump {
        return Err(format!("parallel sweep ({jobs} jobs) is not byte-identical to serial"));
    }
    if reference.dump() != serial_dump {
        return Err("memoized engine diverged from the pre-memoization reference".into());
    }
    Ok(SweepBench {
        scenarios: scenarios.len(),
        points: reference.records.len(),
        jobs,
        baseline_s,
        serial_s,
        parallel_s,
    })
}

/// One proxy family's `train_step` timings through the three executor
/// configurations (same params, same batch, bit-identical outputs).
#[derive(Clone, Debug)]
pub struct BackendCase {
    pub family: String,
    /// Per-core batch the step was timed at (the family default).
    pub batch: usize,
    /// Executor threads of the threaded configuration.
    pub threads: usize,
    pub naive_step_s: f64,
    pub tiled_step_s: f64,
    pub threaded_step_s: f64,
}

impl BackendCase {
    pub fn speedup_tiled(&self) -> f64 {
        self.naive_step_s / self.tiled_step_s.max(1e-12)
    }

    pub fn speedup_threaded(&self) -> f64 {
        self.naive_step_s / self.threaded_step_s.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("family", Json::from(self.family.as_str())),
            ("batch_per_core", Json::from(self.batch)),
            ("threads", Json::from(self.threads)),
            ("naive_step_seconds", Json::from(self.naive_step_s)),
            ("tiled_step_seconds", Json::from(self.tiled_step_s)),
            ("threaded_step_seconds", Json::from(self.threaded_step_s)),
            ("naive_steps_per_sec", Json::from(1.0 / self.naive_step_s.max(1e-12))),
            ("tiled_steps_per_sec", Json::from(1.0 / self.tiled_step_s.max(1e-12))),
            ("threaded_steps_per_sec", Json::from(1.0 / self.threaded_step_s.max(1e-12))),
            ("speedup_tiled_vs_naive", Json::from(self.speedup_tiled())),
            ("speedup_threaded_vs_naive", Json::from(self.speedup_threaded())),
        ])
    }
}

/// The full naive / tiled / threaded matrix (`BENCH_backend.json`).
#[derive(Clone, Debug)]
pub struct BackendBench {
    /// Resolved executor thread count of the threaded column.
    pub threads: usize,
    /// Timed steps per configuration (after one warmup step).
    pub steps: usize,
    pub cases: Vec<BackendCase>,
}

impl BackendBench {
    /// Geometric-mean threaded speedup across families (the headline).
    pub fn geomean_speedup_threaded(&self) -> f64 {
        if self.cases.is_empty() {
            return 1.0;
        }
        let log_sum: f64 = self.cases.iter().map(|c| c.speedup_threaded().ln()).sum();
        (log_sum / self.cases.len() as f64).exp()
    }

    pub fn max_speedup_threaded(&self) -> f64 {
        self.cases.iter().map(BackendCase::speedup_threaded).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("bench", Json::from("backend_matrix")),
            ("threads", Json::from(self.threads)),
            ("steps_timed", Json::from(self.steps)),
            ("cases", Json::Arr(self.cases.iter().map(BackendCase::to_json).collect())),
            ("geomean_speedup_threaded", Json::from(self.geomean_speedup_threaded())),
            ("max_speedup_threaded", Json::from(self.max_speedup_threaded())),
        ])
    }

    /// Write the record (`BENCH_backend.json`).
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())
    }
}

/// Tracing overhead harness (`BENCH_trace.json`): the same seeded
/// reference-trainer run with the sink disabled and enabled, cross-checked
/// bit-for-bit (losses, evals, final params) before any timing is trusted.
/// The disabled column is the no-tracing baseline the step loop must not
/// regress against; `overhead_pct` is the enabled sink's full price —
/// clock reads, attr closures, per-thread buffers and the final drain.
#[derive(Clone, Debug)]
pub struct TraceBench {
    pub model: String,
    pub cores: usize,
    pub steps: usize,
    /// Wall seconds of the timed run with the disabled (no-op) sink.
    pub disabled_s: f64,
    /// Wall seconds of the same run with an enabled sink recording.
    pub enabled_s: f64,
    /// Events the enabled run recorded (spans + instants + counters).
    pub events: usize,
}

impl TraceBench {
    /// Enabled-over-disabled wall-clock overhead in percent (can be
    /// slightly negative on noisy machines; the acceptance bound reads
    /// the artifact, it is not asserted here).
    pub fn overhead_pct(&self) -> f64 {
        (self.enabled_s / self.disabled_s.max(1e-12) - 1.0) * 100.0
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("bench", Json::from("trace_overhead")),
            ("model", Json::from(self.model.as_str())),
            ("cores", Json::from(self.cores)),
            ("steps", Json::from(self.steps)),
            ("disabled_seconds", Json::from(self.disabled_s)),
            ("enabled_seconds", Json::from(self.enabled_s)),
            ("events", Json::from(self.events)),
            ("overhead_pct", Json::from(self.overhead_pct())),
        ])
    }

    /// Write the record (`BENCH_trace.json`).
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())
    }
}

/// The shared run configuration: evals on (so eval spans are exercised)
/// and everything else at `quick` defaults. Only the sink differs.
fn trace_bench_cfg(model: &str, cores: usize, steps: usize, sink: TraceSink) -> TrainConfig {
    let mut cfg = TrainConfig::quick(model, cores, steps);
    cfg.eval_every = (steps / 4).max(1);
    cfg.eval_examples = 64;
    cfg.trace = sink;
    cfg
}

fn bits_identical(a: &[Vec<f32>], b: &[Vec<f32>]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// Time one seeded trainer run with the sink disabled and enabled (after
/// one untimed warmup), erroring out unless the two runs are bit-identical
/// in step losses and final parameters — the "tracing never perturbs the
/// numerics" contract `BENCH_trace.json` rides on.
pub fn run_trace_bench(model: &str, cores: usize, steps: usize) -> Result<TraceBench, String> {
    // Warmup: pays thread spawn + allocator churn so neither timed run does.
    train(&trace_bench_cfg(model, cores, steps, TraceSink::disabled()))
        .map_err(|e| e.to_string())?;

    let t = Timer::start();
    let off = train(&trace_bench_cfg(model, cores, steps, TraceSink::disabled()))
        .map_err(|e| e.to_string())?;
    let disabled_s = t.secs();

    let sink = TraceSink::enabled();
    let t = Timer::start();
    let on = train(&trace_bench_cfg(model, cores, steps, sink.clone()))
        .map_err(|e| e.to_string())?;
    let enabled_s = t.secs();
    let events = sink.drain().len();

    let losses_identical = off.step_losses.len() == on.step_losses.len()
        && off.step_losses.iter().zip(&on.step_losses).all(|(a, b)| a.to_bits() == b.to_bits());
    if !losses_identical {
        return Err(format!("{model}: traced run's step losses differ from the untraced run"));
    }
    if !bits_identical(&off.final_params, &on.final_params) {
        return Err(format!("{model}: traced run's final params differ from the untraced run"));
    }
    if events == 0 {
        return Err(format!("{model}: enabled sink recorded no events"));
    }
    Ok(TraceBench { model: model.to_string(), cores, steps, disabled_s, enabled_s, events })
}

/// Seeded params + one batch for a proxy family (shared by all three
/// executor configurations so outputs are comparable bit-for-bit).
fn bench_inputs(family: &str) -> Result<(Vec<Vec<f32>>, StepBatch, usize), String> {
    let dims = proxy_dims(family).ok_or_else(|| format!("unknown proxy family {family:?}"))?;
    let mut rng = Rng::new(0xB0B).fold_in(family.len() as u64);
    let params: Vec<Vec<f32>> =
        param_specs_for(&dims).iter().map(|s| rng.normal_vec(s.numel(), 0.05)).collect();
    let batch = match dims.kind {
        TaskKind::Lm => {
            let task = LmTask::new(dims.vocab, 0.05);
            let b = task.batch(&mut rng, dims.batch_per_core, dims.seq);
            StepBatch::Lm { tokens: b.tokens, targets: b.targets }
        }
        TaskKind::Image => {
            let task = ImageTask::new(dims.image, dims.classes, 2.0, 0xEEE);
            let b = task.batch(&mut rng, dims.batch_per_core);
            StepBatch::Image { images: b.images, labels: b.labels }
        }
    };
    Ok((params, batch, dims.batch_per_core))
}

/// Mean `train_step` seconds over `steps` timed iterations (one warmup).
fn time_steps(
    backend: &ReferenceBackend,
    params: &[Vec<f32>],
    batch: &StepBatch,
    steps: usize,
) -> Result<f64, String> {
    backend.train_step(params, batch).map_err(|e| e.to_string())?;
    let t = Timer::start();
    for _ in 0..steps.max(1) {
        std::hint::black_box(backend.train_step(params, batch).map_err(|e| e.to_string())?);
    }
    Ok(t.secs() / steps.max(1) as f64)
}

/// Time the naive / tiled / threaded matrix over `families`, erroring out
/// unless all three configurations produce bit-identical losses and
/// gradients (the determinism contract `BENCH_backend.json` rides on).
/// `threads == 0` means one per available hardware thread.
pub fn run_backend_bench(
    families: &[&str],
    steps: usize,
    threads: usize,
) -> Result<BackendBench, String> {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    let mut cases = Vec::with_capacity(families.len());
    for family in families {
        let (params, batch, per_core) = bench_inputs(family)?;
        let dims = proxy_dims(family).expect("checked by bench_inputs");
        let naive =
            ReferenceBackend::with_options(dims, Precision::F32, KernelMode::Naive, 1);
        let tiled =
            ReferenceBackend::with_options(dims, Precision::F32, KernelMode::Tiled, 1);
        let threaded =
            ReferenceBackend::with_options(dims, Precision::F32, KernelMode::Tiled, threads);

        let (l0, g0) = naive.train_step(&params, &batch).map_err(|e| e.to_string())?;
        for (label, b) in [("tiled", &tiled), ("threaded", &threaded)] {
            let (l, g) = b.train_step(&params, &batch).map_err(|e| e.to_string())?;
            if l.to_bits() != l0.to_bits() || g != g0 {
                return Err(format!(
                    "{family}: {label} executor is not bit-identical to naive"
                ));
            }
        }

        cases.push(BackendCase {
            family: family.to_string(),
            batch: per_core,
            threads,
            naive_step_s: time_steps(&naive, &params, &batch, steps)?,
            tiled_step_s: time_steps(&tiled, &params, &batch, steps)?,
            threaded_step_s: time_steps(&threaded, &params, &batch, steps)?,
        });
    }
    Ok(BackendBench { threads, steps, cases })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_engines_agree_on_a_small_grid() {
        let mut g = AblationGrid::full_paper();
        g.models = vec!["resnet50".into(), "gnmt".into()];
        g.chips = vec![16, 256];
        let b = run_sweep_bench(&g, 2).unwrap();
        assert_eq!(b.scenarios, 32);
        assert_eq!(b.points, 64);
        assert_eq!(b.jobs, 2);
        assert!(b.baseline_s > 0.0 && b.serial_s > 0.0 && b.parallel_s > 0.0);
        let j = b.to_json();
        assert_eq!(j.get("points").and_then(Json::as_usize), Some(64));
        assert!(j.get("speedup_vs_baseline").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn backend_matrix_is_bit_identical_and_records_speedups() {
        // Two families, few steps: the cross-check (naive == tiled ==
        // threaded, bit-for-bit) is the assertion that matters; timing
        // numbers are recorded, not asserted (CI machines are noisy).
        let b = run_backend_bench(&["gnmt", "resnet50"], 2, 2).unwrap();
        assert_eq!(b.cases.len(), 2);
        assert_eq!(b.threads, 2);
        for c in &b.cases {
            assert!(c.naive_step_s > 0.0 && c.tiled_step_s > 0.0 && c.threaded_step_s > 0.0);
        }
        let j = b.to_json();
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("backend_matrix"));
        assert!(j.get("geomean_speedup_threaded").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(j.get("cases").and_then(Json::as_arr).map(|a| a.len()), Some(2));
    }
}
