//! Participation-aware pod layout: which cores actually take part in each
//! phase of a training step.
//!
//! A machine allocation (`cores`) and the layout the batch policy chose
//! (`replicas` x `mp`) need not coincide: with a fixed global batch and
//! more cores than examples (strong-scaling sweeps, the no-spatial
//! ablation), the surplus cores hold no replica and do **no** work. The
//! seed simulator nevertheless priced gradient summation, weight-update
//! sharding and distributed evaluation over ALL cores, so surplus cores
//! kept shrinking those phases — the ROADMAP "Idle-core accounting" bug.
//! [`PodLayout`] is the fix: every phase cost is priced over the
//! *participating* core set this type derives.

use crate::models::registry::Layout;
use crate::netsim::{Placement, PodSpec, TopologySpec, Torus};

/// Core-participation view of a [`Layout`] on a TPU-v3 pod slice
/// (2 cores per chip).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PodLayout {
    /// Machine cores allocated to the job (the pod slice).
    pub cores: usize,
    /// Spatial/graph model-parallel degree within one replica.
    pub mp: usize,
    /// Data-parallel replica count.
    pub replicas: usize,
    pub global_batch: usize,
    /// Multi-pod shape of the allocation. The default single-pod spec
    /// collapses every price to the flat-torus model bit-identically.
    pub pods: PodSpec,
}

impl PodLayout {
    pub fn from_layout(l: &Layout) -> PodLayout {
        PodLayout {
            cores: l.cores,
            mp: l.mp,
            replicas: l.replicas,
            global_batch: l.global_batch,
            pods: PodSpec::default(),
        }
    }

    /// The same layout spanning a multi-pod group.
    pub fn with_pods(mut self, pods: PodSpec) -> PodLayout {
        self.pods = pods;
        self
    }

    /// Cores that hold a replica shard and do per-step work.
    pub fn participating_cores(&self) -> usize {
        (self.replicas * self.mp).min(self.cores).max(1)
    }

    /// Cores idling because the batch cannot occupy them.
    pub fn surplus_cores(&self) -> usize {
        self.cores - self.participating_cores().min(self.cores)
    }

    pub fn per_replica_batch(&self) -> f64 {
        self.global_batch as f64 / self.replicas as f64
    }

    /// Gradient summation runs over every core holding gradients: the
    /// data-parallel replicas times their spatial workers (spatial
    /// partitioning replicates the weights, so each spatial worker holds a
    /// full gradient set).
    pub fn gradsum_cores(&self) -> usize {
        self.participating_cores()
    }

    /// Weight-update sharding distributes the optimizer over the cores
    /// that hold weights — the participating set, one shard per core.
    pub fn update_shards(&self) -> usize {
        self.participating_cores()
    }

    /// Distributed in-loop evaluation shares the eval set over the cores
    /// running the train loop.
    pub fn eval_cores(&self) -> usize {
        self.participating_cores()
    }

    /// Halo exchange happens inside one spatial-partition group.
    pub fn halo_group(&self) -> usize {
        self.mp
    }

    /// Aspect-ratio cap for [`participating_torus`](Self::participating_torus):
    /// ragged chip counts whose exact factorization would degenerate into a
    /// long 1-D ring leave a few chips idle instead.
    pub const TORUS_MAX_ASPECT: usize = 4;

    /// Torus spanned by the participating cores (surplus chips carry no
    /// collective traffic). Any chip count is allowed: the layout is the
    /// near-square rectangle over at most that many chips, with the
    /// remainder explicitly idle ([`idle_torus_chips`](Self::idle_torus_chips)).
    /// Power-of-two participations keep their exact historical slices.
    pub fn participating_torus(&self) -> Torus {
        TopologySpec::Capped { max_aspect: Self::TORUS_MAX_ASPECT }
            .place((self.participating_cores() / 2).max(1))
            .pod_torus
    }

    /// Chips left out of the participating torus because the survivor count
    /// does not factor into an acceptable rectangle (0 for well-factoring
    /// counts, including every power of two).
    pub fn idle_torus_chips(&self) -> usize {
        TopologySpec::Capped { max_aspect: Self::TORUS_MAX_ASPECT }
            .place((self.participating_cores() / 2).max(1))
            .idle
    }

    /// Multi-pod placement of the participating chips: the collapsed
    /// single-pod spec reproduces [`participating_torus`](Self::participating_torus)
    /// exactly; a real hierarchy splits the chips evenly across pods.
    pub fn pod_group(&self) -> Placement {
        let chips = (self.participating_cores() / 2).max(1);
        if self.pods.collapses() {
            TopologySpec::Capped { max_aspect: Self::TORUS_MAX_ASPECT }.place(chips)
        } else {
            TopologySpec::Pods {
                pods: self.pods.pods,
                max_aspect: Self::TORUS_MAX_ASPECT,
                inter_pod_ratio: self.pods.inter_pod_ratio,
            }
            .place(chips)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout(cores: usize, mp: usize, replicas: usize, batch: usize) -> PodLayout {
        PodLayout::from_layout(&Layout { cores, mp, replicas, global_batch: batch })
    }

    #[test]
    fn fully_occupied_pod_has_no_surplus() {
        let p = layout(2048, 1, 2048, 32768);
        assert_eq!(p.participating_cores(), 2048);
        assert_eq!(p.surplus_cores(), 0);
        assert_eq!(p.participating_torus().chips(), 1024);
    }

    #[test]
    fn batch_limited_layout_exposes_surplus() {
        // GNMT at the full pod: 1024 replicas on 2048 cores.
        let p = layout(2048, 1, 1024, 1024);
        assert_eq!(p.participating_cores(), 1024);
        assert_eq!(p.surplus_cores(), 1024);
        assert_eq!(p.participating_torus().chips(), 512);
    }

    #[test]
    fn model_parallel_groups_count_toward_participation() {
        // Mask-RCNN at 2048 cores: 128 replicas x mp 4 = 512 active.
        let p = layout(2048, 4, 128, 128);
        assert_eq!(p.participating_cores(), 512);
        assert_eq!(p.surplus_cores(), 1536);
        assert_eq!(p.halo_group(), 4);
        assert_eq!(p.gradsum_cores(), 512);
        assert_eq!(p.update_shards(), 512);
        assert_eq!(p.participating_torus().chips(), 256);
    }

    #[test]
    fn degenerate_single_core() {
        let p = layout(1, 1, 1, 4);
        assert_eq!(p.participating_cores(), 1);
        assert_eq!(p.surplus_cores(), 0);
        assert_eq!(p.participating_torus().chips(), 1);
    }

    #[test]
    fn non_power_of_two_participation_gets_exact_torus() {
        // 6 cores -> 3 chips, exact 3x1 ring, nothing idle.
        let p = layout(6, 1, 6, 24);
        assert_eq!(p.participating_cores(), 6);
        assert_eq!(p.participating_torus().chips(), 3);
        assert_eq!(p.idle_torus_chips(), 0);
        // 194 cores -> 97 chips (prime): 12x8 rectangle with 1 chip idle.
        let p = layout(194, 1, 194, 1024);
        assert_eq!(p.participating_torus().chips(), 96);
        assert_eq!(p.idle_torus_chips(), 1);
    }

    #[test]
    fn pod_group_collapses_to_the_participating_torus() {
        let p = layout(2048, 1, 2048, 32768);
        let g = p.pod_group();
        assert_eq!((g.pods, g.pod_torus.chips()), (1, 1024));
        assert_eq!(g.pod_torus.chips(), p.participating_torus().chips());
        // A real hierarchy splits the same chips across pods.
        let multi = p.with_pods(PodSpec::new(2, 0.25)).pod_group();
        assert_eq!((multi.pods, multi.pod_torus.chips()), (2, 512));
        assert_eq!(multi.used_chips(), 1024);
    }

    #[test]
    fn participation_never_exceeds_allocation() {
        // A hand-built override can claim more replicas than cores; the
        // participating set is clamped to the machine.
        let p = layout(64, 1, 128, 128);
        assert_eq!(p.participating_cores(), 64);
        assert_eq!(p.surplus_cores(), 0);
    }
}
