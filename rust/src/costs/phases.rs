//! Per-phase step cost models ([`StepCostModel`]) and their composition
//! ([`CostStack`]): the single pricing path behind `simulate()`, the
//! paper-figure benches and the scenario sweep runner.
//!
//! Each §2 technique is priced over the core set that actually
//! participates in it (see [`PodLayout`]):
//!
//! | phase | backed by | participating set |
//! |---|---|---|
//! | Compute | `devicesim` roofline + `spatial` planner | replicas x mp |
//! | Halo | `spatial` planner comm split | the mp group |
//! | GradSum | `netsim::GradSumModel` on the participating torus | replicas x mp |
//! | WeightUpdate | `devicesim::weight_update_cost` + `wus::ShardPlan` | one shard per participating core |
//! | Eval | `evaluation::EvalSharding` padding arithmetic | participating cores (or the 16-core side-card) |
//! | Infra | fixed run overhead | the whole allocation |

use crate::devicesim::{weight_update_cost, Device, TPU_V3};
use crate::evaluation::EvalSharding;
use crate::models::registry::ModelProfile;
use crate::netsim::{
    cross_pod_ring_seconds, ArAlgo, CostModel, CrossPodStrategy, GradSumModel, NetParams,
    TopologySpec, Torus,
};
use crate::spatial::plan::{maskrcnn_stage1_layers, plan, ssd_layers};
use crate::wus::ShardPlan;

use super::PodLayout;

/// Fixed infrastructure overhead per eval in the in-loop scheme (loop
/// switch) and per eval in the side-card scheme (checkpoint transfer) —
/// the "infrastructure overheads [that] dominate" (paper §3 Transformer).
pub const INLOOP_EVAL_OVERHEAD_S: f64 = 0.35;
pub const SIDECARD_EVAL_OVERHEAD_S: f64 = 6.0;
/// Cores of the fixed side-card eval slice in the baseline scheme.
pub const SIDECARD_CORES: usize = 16;
/// Fixed per-run infrastructure inside the measured window.
pub const INFRA_SECONDS: f64 = 3.0;

/// Step/run phases of the §2 cost decomposition.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Phase {
    Compute,
    Halo,
    GradSum,
    WeightUpdate,
    Eval,
    Infra,
}

impl Phase {
    pub const ALL: [Phase; 6] = [
        Phase::Compute,
        Phase::Halo,
        Phase::GradSum,
        Phase::WeightUpdate,
        Phase::Eval,
        Phase::Infra,
    ];

    /// Per-training-step phases (the rest are per-run / per-eval).
    pub fn per_step(self) -> bool {
        matches!(self, Phase::Compute | Phase::Halo | Phase::GradSum | Phase::WeightUpdate)
    }

    pub fn label(self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Halo => "halo",
            Phase::GradSum => "gradsum",
            Phase::WeightUpdate => "update",
            Phase::Eval => "eval",
            Phase::Infra => "infra",
        }
    }
}

/// One phase's price: seconds per occurrence (per training step for step
/// phases, per eval pass for Eval, per run for Infra) and the core group
/// it was priced over.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PhaseCost {
    pub phase: Phase,
    pub seconds: f64,
    /// Size of the participating group this phase was priced over.
    pub cores: usize,
}

/// A phase cost model: prices one §2 technique over its participating
/// core set.
pub trait StepCostModel {
    fn phase(&self) -> Phase;
    fn cost(&self, m: &ModelProfile, pod: &PodLayout) -> PhaseCost;
}

/// Configuration for the standard §2 stack (every ablation axis of the
/// paper plus the device/network constants).
#[derive(Clone, Copy, Debug)]
pub struct CostConfig {
    pub dev: Device,
    pub net: NetParams,
    pub gradsum_algo: ArAlgo,
    pub gradsum_pipelined: bool,
    pub weight_update_sharding: bool,
    pub distributed_eval: bool,
    pub spatial_partitioning: bool,
}

impl Default for CostConfig {
    /// The Google-submission configuration: every §2 optimization on.
    fn default() -> CostConfig {
        CostConfig {
            dev: TPU_V3,
            net: NetParams::default(),
            gradsum_algo: ArAlgo::Torus2D,
            gradsum_pipelined: true,
            weight_update_sharding: true,
            distributed_eval: true,
            spatial_partitioning: true,
        }
    }
}

/// Spatial-partitioning factors for a model at partition degree `mp`:
/// overall speedup of the partitioned step and the fraction of the
/// partitioned step spent on halo + distributed-BN communication.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpatialFactors {
    pub speedup: f64,
    pub comm_fraction: f64,
}

impl SpatialFactors {
    pub const IDENTITY: SpatialFactors = SpatialFactors { speedup: 1.0, comm_fraction: 0.0 };
}

/// Plan a model's spatial partition at degree `mp` and return its factors
/// (identity for mp <= 1 or models without a partitionable stack).
pub fn spatial_factors(m: &ModelProfile, mp: usize, dev: &Device) -> SpatialFactors {
    if mp <= 1 {
        return SpatialFactors::IDENTITY;
    }
    // Halo cost uses a small local neighborhood model.
    let net = CostModel::new(Torus::new(2, 2), NetParams::default());
    let layers = match m.name {
        "ssd" => ssd_layers(),
        "maskrcnn" => maskrcnn_stage1_layers(),
        _ => return SpatialFactors::IDENTITY,
    };
    let p = plan(&layers, mp, dev, &net);
    SpatialFactors { speedup: p.speedup(), comm_fraction: p.comm_fraction() }
}

/// The gradient-tensor element census [`shard_imbalance`] shards. The
/// census depends only on the model, so sweep drivers hoist it out of
/// their per-point loops (one census per scenario, not per chip count).
pub fn gradient_census(m: &ModelProfile) -> Vec<usize> {
    m.gradient_bytes().iter().map(|&b| ((b / 4.0) as usize).max(1)).collect()
}

/// [`shard_imbalance`] over a precomputed [`gradient_census`].
pub fn shard_imbalance_from_census(census: &[usize], shards: usize) -> f64 {
    ShardPlan::balanced(census, shards.max(1)).imbalance()
}

/// Weight-update shard imbalance (max/min shard elements) over the
/// model's gradient tensor census at `shards` shards — the contiguous
/// element-balanced plan of `wus::ShardPlan` (paper §2 Fig. 4).
pub fn shard_imbalance(m: &ModelProfile, shards: usize) -> f64 {
    shard_imbalance_from_census(&gradient_census(m), shards)
}

/// Per-replica forward+backward compute time on the device roofline
/// before any spatial partitioning (fwd + bwd ~ 3x fwd FLOPs; MXU
/// utilization degrades at small per-core batch).
fn replica_compute_seconds(dev: &Device, m: &ModelProfile, pod: &PodLayout) -> f64 {
    let epr = pod.per_replica_batch();
    dev.compute_time_batched(
        3.0 * m.fwd_flops_per_example * epr,
        m.hbm_bytes_per_example * epr,
        epr * m.util_units_per_example,
    )
}

/// Compute phase: the roofline step time, accelerated by the spatial
/// partition (communication share excluded — that is [`HaloPhase`]).
pub struct ComputePhase {
    pub dev: Device,
    pub spatial_partitioning: bool,
}

impl StepCostModel for ComputePhase {
    fn phase(&self) -> Phase {
        Phase::Compute
    }

    fn cost(&self, m: &ModelProfile, pod: &PodLayout) -> PhaseCost {
        let raw = replica_compute_seconds(&self.dev, m, pod);
        let f = if self.spatial_partitioning {
            spatial_factors(m, pod.mp, &self.dev)
        } else {
            SpatialFactors::IDENTITY
        };
        PhaseCost {
            phase: Phase::Compute,
            seconds: raw / f.speedup * (1.0 - f.comm_fraction),
            cores: pod.participating_cores(),
        }
    }
}

/// Halo phase: the spatial partition's halo-exchange + distributed-BN
/// communication share, priced over the mp group.
pub struct HaloPhase {
    pub dev: Device,
    pub spatial_partitioning: bool,
}

impl StepCostModel for HaloPhase {
    fn phase(&self) -> Phase {
        Phase::Halo
    }

    fn cost(&self, m: &ModelProfile, pod: &PodLayout) -> PhaseCost {
        let f = if self.spatial_partitioning {
            spatial_factors(m, pod.mp, &self.dev)
        } else {
            SpatialFactors::IDENTITY
        };
        let seconds = if f.comm_fraction > 0.0 {
            replica_compute_seconds(&self.dev, m, pod) / f.speedup * f.comm_fraction
        } else {
            0.0
        };
        PhaseCost { phase: Phase::Halo, seconds, cores: pod.halo_group() }
    }
}

/// Gradient-summation phase: the §2 schedule over the participating
/// torus (surplus chips carry no all-reduce traffic). Multi-pod layouts
/// ([`PodLayout::pods`]) add a cross-pod term: hierarchical
/// reduce-then-broadcast prices the intra-pod schedule plus a shard
/// all-reduce over the slow inter-pod links; the flat-ring strategy
/// prices one global 1-D ring whose every step runs at the inter-pod
/// rate. Single-pod layouts are priced by the pre-hierarchy code path
/// verbatim (bit-identical — pinned by the golden fixtures).
pub struct GradSumPhase {
    pub net: NetParams,
    pub algo: ArAlgo,
    pub pipelined: bool,
}

impl GradSumPhase {
    fn schedule_seconds(&self, gs: &GradSumModel, tensors: &[f64]) -> f64 {
        if self.pipelined {
            gs.pipelined(tensors)
        } else {
            gs.serial(tensors)
        }
    }
}

impl StepCostModel for GradSumPhase {
    fn phase(&self) -> Phase {
        Phase::GradSum
    }

    fn cost(&self, m: &ModelProfile, pod: &PodLayout) -> PhaseCost {
        let tensors = m.gradient_bytes();
        let seconds = if pod.pods.collapses() {
            let net = CostModel::new(pod.participating_torus(), self.net);
            let gs = GradSumModel { cost: &net, algo: self.algo };
            self.schedule_seconds(&gs, &tensors)
        } else {
            let group = pod.pod_group();
            match pod.pods.strategy {
                CrossPodStrategy::Hierarchical => {
                    let net = CostModel::new(group.pod_torus, self.net);
                    let gs = GradSumModel { cost: &net, algo: self.algo };
                    let intra = self.schedule_seconds(&gs, &tensors);
                    let total: f64 = tensors.iter().sum();
                    let shard = total / group.pod_torus.chips().max(1) as f64;
                    intra + cross_pod_ring_seconds(pod.pods, shard, &self.net)
                }
                CrossPodStrategy::FlatRing => {
                    // One global ring; the boundary links gate every step,
                    // so the whole ring runs at the inter-pod rate.
                    let slow = NetParams {
                        link_bw: pod.pods.inter_pod_ratio * self.net.link_bw,
                        ..self.net
                    };
                    let flat = TopologySpec::Capped { max_aspect: PodLayout::TORUS_MAX_ASPECT }
                        .place(group.used_chips().max(1))
                        .pod_torus;
                    let net = CostModel::new(flat, slow);
                    let gs = GradSumModel { cost: &net, algo: ArAlgo::Ring1D };
                    self.schedule_seconds(&gs, &tensors)
                }
            }
        };
        PhaseCost { phase: Phase::GradSum, seconds, cores: pod.gradsum_cores() }
    }
}

/// Weight-update phase: replicated vs sharded (one `wus::ShardPlan` shard
/// per participating core; the all-gather rides the participating torus).
pub struct WeightUpdatePhase {
    pub dev: Device,
    pub net: NetParams,
    pub sharding: bool,
}

impl StepCostModel for WeightUpdatePhase {
    fn phase(&self) -> Phase {
        Phase::WeightUpdate
    }

    fn cost(&self, m: &ModelProfile, pod: &PodLayout) -> PhaseCost {
        let shards = pod.update_shards();
        let net = CostModel::new(pod.participating_torus(), self.net);
        let uc =
            weight_update_cost(&self.dev, &net, m.params, m.optimizer.bytes_per_param(), shards);
        let seconds = if self.sharding {
            uc.sharded.min(uc.replicated)
        } else {
            uc.replicated
        };
        PhaseCost { phase: Phase::WeightUpdate, seconds, cores: shards }
    }
}

/// Evaluation phase: one eval pass, sharded over the participating cores
/// (in-loop) or the fixed side-card slice, with `EvalSharding`'s padding
/// arithmetic (padding overhead <= one stride — paper §2).
pub struct EvalPhase {
    pub dev: Device,
    pub distributed: bool,
}

impl StepCostModel for EvalPhase {
    fn phase(&self) -> Phase {
        Phase::Eval
    }

    fn cost(&self, m: &ModelProfile, pod: &PodLayout) -> PhaseCost {
        let (cores, overhead) = if self.distributed {
            (pod.eval_cores(), INLOOP_EVAL_OVERHEAD_S)
        } else {
            (SIDECARD_CORES, SIDECARD_EVAL_OVERHEAD_S)
        };
        let sharding = EvalSharding::new(m.eval_examples, cores, 1);
        let per_core_examples = sharding.padded_per_core() as f64;
        let seconds = per_core_examples * m.fwd_flops_per_example
            / (self.dev.peak_flops * self.dev.mxu_efficiency)
            + overhead;
        PhaseCost { phase: Phase::Eval, seconds, cores }
    }
}

/// Fixed per-run infrastructure inside the measured window.
pub struct InfraPhase;

impl StepCostModel for InfraPhase {
    fn phase(&self) -> Phase {
        Phase::Infra
    }

    fn cost(&self, _m: &ModelProfile, pod: &PodLayout) -> PhaseCost {
        PhaseCost { phase: Phase::Infra, seconds: INFRA_SECONDS, cores: pod.cores }
    }
}

/// A composed set of phase models — evaluate them all against one
/// (model, layout) point to get the full [`StepBreakdown`].
pub struct CostStack {
    pub phases: Vec<Box<dyn StepCostModel>>,
}

impl CostStack {
    /// The standard §2 stack for a configuration.
    pub fn standard(cfg: &CostConfig) -> CostStack {
        CostStack {
            phases: vec![
                Box::new(ComputePhase {
                    dev: cfg.dev,
                    spatial_partitioning: cfg.spatial_partitioning,
                }),
                Box::new(HaloPhase {
                    dev: cfg.dev,
                    spatial_partitioning: cfg.spatial_partitioning,
                }),
                Box::new(GradSumPhase {
                    net: cfg.net,
                    algo: cfg.gradsum_algo,
                    pipelined: cfg.gradsum_pipelined,
                }),
                Box::new(WeightUpdatePhase {
                    dev: cfg.dev,
                    net: cfg.net,
                    sharding: cfg.weight_update_sharding,
                }),
                Box::new(EvalPhase { dev: cfg.dev, distributed: cfg.distributed_eval }),
                Box::new(InfraPhase),
            ],
        }
    }

    /// Price every phase for one (model, layout) point.
    pub fn breakdown(&self, m: &ModelProfile, pod: &PodLayout) -> StepBreakdown {
        StepBreakdown { phases: self.phases.iter().map(|p| p.cost(m, pod)).collect() }
    }
}

/// The per-phase price list for one (model, layout) point.
#[derive(Clone, Debug, Default)]
pub struct StepBreakdown {
    pub phases: Vec<PhaseCost>,
}

impl StepBreakdown {
    pub fn get(&self, phase: Phase) -> Option<&PhaseCost> {
        self.phases.iter().find(|c| c.phase == phase)
    }

    /// Seconds of a phase (0 when the stack lacks it).
    pub fn seconds(&self, phase: Phase) -> f64 {
        self.get(phase).map(|c| c.seconds).unwrap_or(0.0)
    }

    /// Participating cores of a phase (0 when the stack lacks it).
    pub fn cores(&self, phase: Phase) -> usize {
        self.get(phase).map(|c| c.cores).unwrap_or(0)
    }

    /// One synchronous training step: the sum of the per-step phases.
    pub fn step_seconds(&self) -> f64 {
        self.phases.iter().filter(|c| c.phase.per_step()).map(|c| c.seconds).sum()
    }

    /// End-to-end seconds for a run of `steps` training steps and `evals`
    /// evaluation passes (plus the fixed infra overhead).
    pub fn benchmark_seconds(&self, steps: f64, evals: f64) -> f64 {
        steps * self.step_seconds()
            + evals * self.seconds(Phase::Eval)
            + self.seconds(Phase::Infra)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::registry::{model, Layout};

    fn pod(cores: usize, mp: usize, replicas: usize, batch: usize) -> PodLayout {
        PodLayout::from_layout(&Layout { cores, mp, replicas, global_batch: batch })
    }

    #[test]
    fn standard_stack_covers_every_phase() {
        let stack = CostStack::standard(&CostConfig::default());
        let m = model("resnet50").unwrap();
        let bd = stack.breakdown(&m, &pod(2048, 1, 2048, 32768));
        for phase in Phase::ALL {
            assert!(bd.get(phase).is_some(), "{phase:?} missing");
        }
        assert!(bd.step_seconds() > 0.0);
        assert_eq!(bd.seconds(Phase::Infra), INFRA_SECONDS);
    }

    #[test]
    fn surplus_cores_do_not_change_step_phase_pricing() {
        // The tentpole bug fix: pricing depends on the participating set
        // only, so the same layout on a bigger machine costs the same.
        let stack = CostStack::standard(&CostConfig::default());
        let m = model("resnet50").unwrap();
        let occupied = stack.breakdown(&m, &pod(512, 1, 512, 8192));
        let surplus = stack.breakdown(&m, &pod(2048, 1, 512, 8192));
        let step_phases =
            [Phase::Compute, Phase::Halo, Phase::GradSum, Phase::WeightUpdate, Phase::Eval];
        for phase in step_phases {
            assert_eq!(
                occupied.seconds(phase),
                surplus.seconds(phase),
                "{phase:?} priced over surplus cores"
            );
            assert_eq!(occupied.cores(phase), surplus.cores(phase));
        }
        assert_eq!(occupied.step_seconds(), surplus.step_seconds());
    }

    #[test]
    fn phases_are_priced_over_their_groups() {
        let stack = CostStack::standard(&CostConfig::default());
        let m = model("maskrcnn").unwrap();
        let p = pod(2048, 4, 128, 128);
        let bd = stack.breakdown(&m, &p);
        assert_eq!(bd.cores(Phase::Compute), 512);
        assert_eq!(bd.cores(Phase::GradSum), 512);
        assert_eq!(bd.cores(Phase::WeightUpdate), 512);
        assert_eq!(bd.cores(Phase::Eval), 512);
        assert_eq!(bd.cores(Phase::Halo), 4);
        assert_eq!(bd.cores(Phase::Infra), 2048);
        assert!(bd.seconds(Phase::Halo) > 0.0, "mp 4 must pay halo");
    }

    #[test]
    fn compute_plus_halo_equals_spatially_accelerated_step() {
        // The halo split is attribution-only: compute + halo must equal
        // the raw roofline time divided by the plan speedup.
        let m = model("ssd").unwrap();
        let p = pod(2048, 4, 512, 2048);
        let stack = CostStack::standard(&CostConfig::default());
        let bd = stack.breakdown(&m, &p);
        let raw = replica_compute_seconds(&TPU_V3, &m, &p);
        let f = spatial_factors(&m, 4, &TPU_V3);
        assert!(f.speedup > 1.0 && f.comm_fraction > 0.0);
        let expect = raw / f.speedup;
        let got = bd.seconds(Phase::Compute) + bd.seconds(Phase::Halo);
        assert!((got - expect).abs() < 1e-12 * expect, "{got} vs {expect}");
    }

    #[test]
    fn sidecard_eval_is_priced_over_the_sidecard() {
        let m = model("transformer").unwrap();
        let p = pod(2048, 1, 2048, 2048);
        let dist = EvalPhase { dev: TPU_V3, distributed: true }.cost(&m, &p);
        let side = EvalPhase { dev: TPU_V3, distributed: false }.cost(&m, &p);
        assert_eq!(dist.cores, 2048);
        assert_eq!(side.cores, SIDECARD_CORES);
        assert!(side.seconds > dist.seconds);
    }

    #[test]
    fn eval_padding_rounds_up_to_a_stride() {
        // 50000 examples over 2048 cores: 25 per core, not 24.41.
        let m = model("resnet50").unwrap();
        let p = pod(2048, 1, 2048, 32768);
        let c = EvalPhase { dev: TPU_V3, distributed: true }.cost(&m, &p);
        let per_core = 25.0;
        let expect = per_core * m.fwd_flops_per_example
            / (TPU_V3.peak_flops * TPU_V3.mxu_efficiency)
            + INLOOP_EVAL_OVERHEAD_S;
        assert!((c.seconds - expect).abs() < 1e-15);
    }

    #[test]
    fn spatial_factors_identity_for_pure_dp_models() {
        let m = model("resnet50").unwrap();
        assert_eq!(spatial_factors(&m, 1, &TPU_V3), SpatialFactors::IDENTITY);
        assert_eq!(spatial_factors(&m, 4, &TPU_V3), SpatialFactors::IDENTITY);
        let ssd = model("ssd").unwrap();
        let f = spatial_factors(&ssd, 4, &TPU_V3);
        assert!((1.4..1.9).contains(&f.speedup), "SSD 4-way speedup {}", f.speedup);
        assert!(f.comm_fraction > 0.0 && f.comm_fraction < 1.0);
    }

    #[test]
    fn multi_pod_gradsum_adds_a_cross_pod_term() {
        use crate::netsim::PodSpec;
        let m = model("resnet50").unwrap();
        let stack = CostStack::standard(&CostConfig::default());
        let single = stack.breakdown(&m, &pod(2048, 1, 2048, 32768));
        let collapsed =
            stack.breakdown(&m, &pod(2048, 1, 2048, 32768).with_pods(PodSpec::new(2, 1.0)));
        // Ratio 1.0 collapses: bit-identical to the single-pod price.
        assert_eq!(
            single.seconds(Phase::GradSum).to_bits(),
            collapsed.seconds(Phase::GradSum).to_bits()
        );
        let hier =
            stack.breakdown(&m, &pod(2048, 1, 2048, 32768).with_pods(PodSpec::new(2, 0.25)));
        let slower =
            stack.breakdown(&m, &pod(2048, 1, 2048, 32768).with_pods(PodSpec::new(2, 0.05)));
        assert!(
            slower.seconds(Phase::GradSum) > hier.seconds(Phase::GradSum),
            "slower inter-pod links must cost more: {} vs {}",
            slower.seconds(Phase::GradSum),
            hier.seconds(Phase::GradSum)
        );
        let flat = stack.breakdown(
            &m,
            &pod(2048, 1, 2048, 32768).with_pods(PodSpec {
                strategy: CrossPodStrategy::FlatRing,
                ..PodSpec::new(2, 0.25)
            }),
        );
        assert!(
            flat.seconds(Phase::GradSum) > hier.seconds(Phase::GradSum),
            "the global slow ring must lose to hierarchical reduce-then-broadcast"
        );
        // Only gradient summation crosses pod boundaries.
        for phase in [Phase::Compute, Phase::Halo, Phase::WeightUpdate, Phase::Eval] {
            assert_eq!(single.seconds(phase).to_bits(), hier.seconds(phase).to_bits());
        }
    }

    #[test]
    fn shard_imbalance_uses_participating_shards() {
        let m = model("resnet50").unwrap();
        let i = shard_imbalance(&m, 2048);
        assert!(i >= 1.0 && i < 1.01, "{i}");
        // More shards over the same census cannot reduce imbalance.
        assert!(shard_imbalance(&m, 4096) >= i - 1e-12);
    }
}
