//! Participation-aware cost-model layer (the pricing substrate behind the
//! pod simulator, the paper-figure benches and the scenario sweep runner).
//!
//! The paper's headline numbers (Figs. 7-10, Table 1) depend on pricing
//! each §2 technique over the cores that actually participate in it:
//! gradient summation over the replicas' torus, weight-update sharding
//! over the shard group, halo exchange over the spatial-partition group,
//! distributed eval over the cores running the train loop. This module
//! makes that attribution a first-class layer:
//!
//! * [`PodLayout`] — a layout's participation view: participating vs
//!   surplus cores, per-phase group sizes, the participating torus.
//! * [`Phase`] / [`PhaseCost`] — the §2 phase taxonomy (compute, halo,
//!   gradsum, weight update, eval, infra) with per-group pricing.
//! * [`StepCostModel`] — the per-phase pricing trait; implementations are
//!   backed by `devicesim`, `netsim::{CostModel, GradSumModel}`,
//!   `wus::ShardPlan`, `evaluation::EvalSharding` and the `spatial`
//!   planner.
//! * [`CostStack`] / [`StepBreakdown`] — composition + the resulting
//!   price list, consumed by `simulator::simulate()` and serialized per
//!   sweep point by `scenario::SweepRecord`.

pub mod layout;
pub mod phases;

pub use layout::PodLayout;
pub use phases::{
    gradient_census, shard_imbalance, shard_imbalance_from_census, spatial_factors,
    ComputePhase, CostConfig, CostStack, EvalPhase, GradSumPhase, HaloPhase, InfraPhase, Phase,
    PhaseCost, SpatialFactors, StepBreakdown, StepCostModel, WeightUpdatePhase, INFRA_SECONDS,
    INLOOP_EVAL_OVERHEAD_S, SIDECARD_CORES, SIDECARD_EVAL_OVERHEAD_S,
};
