//! The data-parallel trainer (see module docs in `coordinator`).
//!
//! The step loop is backend-agnostic: a [`Backend`] (selected by
//! [`TrainConfig::backend`]) turns params + batch into loss + exact
//! gradients, and everything around it — input pipeline, 2-D gradient
//! summation, replicated/sharded weight update, distributed eval — is the
//! same coordinator code whether the executor is the in-Rust reference
//! fwd/bwd or PJRT over AOT artifacts.
//!
//! The trainer is fault-tolerant: `checkpoint_every`/`checkpoint_dir`
//! write self-contained v2 checkpoints (params + optimizer accumulators +
//! per-rank data-RNG states), `resume` restarts from one bit-identically
//! on the reference backend, and a [`FaultTrace`] injects per-step chip
//! slowdowns, deaths, and preemptions: a fatal event tears the pod down,
//! rolls back to the newest durable checkpoint (on the next
//! power-of-two-smaller slice for deaths), and replays — the lost work is
//! reported as goodput = useful steps / executed steps.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::checkpoint::{self, OptSnapshot, TrainState};
use crate::collectives::{
    all_gather_concat, broadcast, gradsum_pipelined_ws, gradsum_serial, GradSumWorkspace,
    Placement,
};
use crate::data::synthetic::{ImageTask, LmTask};
use crate::evaluation::{distributed_eval, EvalChunk, EvalSharding};
use crate::fabric::{run_spmd, Endpoint};
use crate::metrics::{AttrVal, StepBreakdown, TraceLocal, TraceSink, TRACK_COORD, TRACK_STEP};
use crate::models::proxy::{proxy_dims, TaskKind};
use crate::optim::{
    adam_step, lars_step, sgd_momentum_step, AdamConfig, AdamState, LarsConfig, LarsState,
};
use crate::runtime::{
    param_specs_for, Backend, BackendChoice, KernelMode, Manifest, ParamSpec, PjRtBackend,
    Precision, ReferenceBackend, StepBatch,
};
use crate::scenario::{FaultEvent, FaultKind, FaultTrace};
use crate::util::rng::{Rng, RngState};
use crate::util::timer::Timer;
use crate::wus::{ShardPlan, ShardedAdam, ShardedLars, ShardedSgd};

/// Optimizer selection.
#[derive(Clone, Copy, Debug)]
pub enum OptChoice {
    Adam { cfg: AdamConfig, lr: f32 },
    Lars { cfg: LarsConfig, lr: f32 },
    Sgd { lr: f32, momentum: f32 },
}

/// Gradient-summation schedule (§2).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum GradSumMode {
    /// Per-tensor 2-D all-reduces with exposed gathers (baseline).
    Serial,
    /// The paper's pipelined non-contiguous scheme; the quantum is the
    /// pack granularity overlapped with network waits.
    Pipelined { quantum: usize },
}

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model key: a proxy family (`transformer`, `resnet50`, …) for the
    /// reference backend, a manifest key (`transformer_tiny`) for PJRT.
    pub model: String,
    /// Data-parallel worker threads ("cores"); any positive count —
    /// collectives run on the near-square factorization of the world.
    pub cores: usize,
    pub steps: usize,
    /// Evaluate every N steps (0 = never).
    pub eval_every: usize,
    pub eval_examples: usize,
    pub opt: OptChoice,
    /// Weight-update sharding on/off (§2 Fig. 4).
    pub use_wus: bool,
    pub gradsum: GradSumMode,
    /// Which fwd/bwd executor drives the step loop.
    pub backend: BackendChoice,
    /// Per-core batch override (reference backend only; PJRT shapes are
    /// fixed at AOT time). `None` = the model's default.
    pub batch_override: Option<usize>,
    pub seed: u64,
    /// LM label-noise floor (Lm) — drives the accuracy ceiling.
    pub task_difficulty: f64,
    /// Image-task signal strength alpha (Image kind; higher = easier).
    pub image_alpha: f32,
    /// Stop early once eval accuracy reaches this (None = run all steps).
    pub quality_target: Option<f64>,
    /// Linear warmup (steps) then polynomial decay to `steps` — the MLPerf
    /// ResNet schedule shape (paper Table 1 columns). 0 = constant lr.
    pub warmup_steps: usize,
    /// Write a durable checkpoint every N steps (0 = never); requires
    /// `checkpoint_dir`.
    pub checkpoint_every: usize,
    /// Directory for `ckpt-step{N:06}.ckpt` files.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from this checkpoint file instead of initializing fresh.
    pub resume: Option<PathBuf>,
    /// Injected fault/straggler trace; `chip` indexes a worker rank.
    pub faults: Option<FaultTrace>,
    /// Rank 0 aborts the whole process (exit code 3) right after
    /// completing this step — the CI crash-resume smoke. 0 = never.
    pub kill_at: usize,
    /// Intra-core executor threads for the reference backend's tiled
    /// kernels (1 = serial; 0 = host parallelism). Output is bit-identical
    /// for every value — the split is over disjoint output rows, never a
    /// cross-thread reduction. PJRT ignores this.
    pub exec_threads: usize,
    /// Structured trace recorder (`--trace FILE`). The disabled sink is
    /// free: no allocation, no clock reads, and the step loop's numerics
    /// never depend on it, so a traced run is bit-identical to an untraced
    /// one. Rank 0 records per-step phase spans; the coordinator records
    /// incarnation/fault/rollback events and the final report counters.
    pub trace: TraceSink,
}

impl TrainConfig {
    /// Effective lr multiplier at a (1-based) step under the schedule.
    pub fn lr_factor(&self, step: usize) -> f32 {
        if self.warmup_steps == 0 {
            return 1.0;
        }
        let w = self.warmup_steps as f32;
        let s = step as f32;
        if s < w {
            return s / w;
        }
        let span = (self.steps as f32 - w).max(1.0);
        let frac = ((s - w) / span).clamp(0.0, 1.0);
        (1.0 - frac) * (1.0 - frac)
    }
}

impl TrainConfig {
    pub fn quick(model: &str, cores: usize, steps: usize) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            cores,
            steps,
            eval_every: 0,
            eval_examples: 256,
            opt: OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 },
            use_wus: false,
            gradsum: GradSumMode::Pipelined { quantum: 4096 },
            backend: BackendChoice::Reference,
            batch_override: None,
            seed: 0,
            task_difficulty: 0.05,
            image_alpha: 2.0,
            quality_target: None,
            warmup_steps: 0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: None,
            faults: None,
            kill_at: 0,
            exec_threads: 1,
            trace: TraceSink::disabled(),
        }
    }
}

/// One evaluation record.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
}

/// Trainer output (rank-0 view; workers are synchronous so identical).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub step_losses: Vec<f32>,
    pub evals: Vec<EvalPoint>,
    pub breakdown: StepBreakdown,
    pub wallclock_s: f64,
    pub init_s: f64,
    /// First step whose eval met the quality target.
    pub converged_at: Option<usize>,
    pub params_total: usize,
    /// Cumulative backend execute seconds (PJRT or reference fwd/bwd).
    pub exec_s: f64,
    /// Forward share of `exec_s` (reference backend times fwd and bwd
    /// separately inside the pass; PJRT reports everything as forward).
    pub fwd_s: f64,
    /// Backward share of `exec_s`.
    pub bwd_s: f64,
    /// Final parameter tensors (for resume bit-identity checks).
    pub final_params: Vec<Vec<f32>>,
    /// Step the run resumed from (0 = fresh start).
    pub resumed_from: u64,
    /// Steps at which checkpoints were durably written.
    pub checkpoints: Vec<u64>,
    /// Useful steps / executed steps (1.0 = no work lost to faults).
    pub goodput: f64,
    /// Steps of work discarded by fault rollbacks.
    pub lost_steps: u64,
    /// Checkpoint restores triggered by fatal fault events.
    pub restores: usize,
    /// Worker count at the end (elastic restarts halve it per death).
    pub final_cores: usize,
    /// Executed steps that overlapped an injected straggler window.
    pub straggled_steps: usize,
}

/// One incarnation's marching orders: where to restart from and the first
/// fault-killed step (the incarnation stops *before* executing it).
struct IncarnationPlan {
    resume: Option<PathBuf>,
    /// Global steps already completed before this incarnation.
    start: usize,
    stop_before: Option<usize>,
    /// Incarnation index — the trace epoch, so a restarted rank-0 step
    /// loop gets its own ordering namespace on the same track.
    epoch: u32,
}

/// Static per-run context shared (read-only) by all workers.
struct RunCtx {
    cfg: TrainConfig,
    kind: TaskKind,
    specs: Vec<ParamSpec>,
    batch: usize,
    seq: usize,
    vocab: usize,
    image: usize,
    classes: usize,
    exec: BackendCtx,
    plan: IncarnationPlan,
}

/// Resolved executor context (model lookup happens once, in `train()`).
enum BackendCtx {
    Reference { dims: crate::models::proxy::ProxyDims },
    PjRt(PjRtCtx),
}

struct PjRtCtx {
    manifest_dir: std::path::PathBuf,
    train_art: String,
    eval_art: String,
}

fn kind_of(model: &str) -> Result<TaskKind> {
    proxy_dims(model)
        .map(|d| d.kind)
        .ok_or_else(|| anyhow!("unknown model family: {model}"))
}

fn init_params(specs: &[ParamSpec], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    specs
        .iter()
        .map(|s| {
            let n = s.numel();
            if s.name.ends_with(".scale") {
                vec![1.0; n]
            } else if s.name.ends_with(".bias")
                || s.name.ends_with(".b1")
                || s.name.ends_with(".b2")
                || s.name.ends_with(".b")
            {
                vec![0.0; n]
            } else {
                let fan_in = s.shape[..s.shape.len() - 1].iter().product::<usize>().max(1);
                let std = (1.0 / fan_in as f32).sqrt();
                rng.normal_vec(n, std)
            }
        })
        .collect()
}

/// Build this worker's backend. PJRT runtimes are `Rc`-based (not `Send`),
/// so construction happens inside the worker thread.
fn make_backend(ctx: &RunCtx) -> Result<Box<dyn Backend>> {
    match &ctx.exec {
        BackendCtx::Reference { dims } => {
            let precision = match ctx.cfg.backend {
                BackendChoice::ReferenceBf16 => Precision::Bf16,
                _ => Precision::F32,
            };
            Ok(Box::new(ReferenceBackend::with_options(
                *dims,
                precision,
                KernelMode::Tiled,
                ctx.cfg.exec_threads,
            )))
        }
        BackendCtx::PjRt(p) => {
            Ok(Box::new(PjRtBackend::new(&p.manifest_dir, &p.train_art, &p.eval_art)?))
        }
    }
}

/// Replicated optimizer state (per tensor).
enum OptState {
    Adam(Vec<AdamState>),
    Lars(Vec<LarsState>),
    Sgd(Vec<Vec<f32>>),
}

/// Sharded optimizer (weight-update sharding, §2 Fig. 4).
enum ShardedOpt {
    Lars(ShardedLars),
    Adam(ShardedAdam),
    Sgd(ShardedSgd),
}

/// Checkpoint file name under `dir` for a (1-based) global step.
pub fn checkpoint_path(dir: &Path, step: u64) -> PathBuf {
    dir.join(format!("ckpt-step{step:06}.ckpt"))
}

/// Newest durable checkpoint at or before `completed`, existence-checked:
/// a fault can strike before the first write, and files can be pruned.
fn latest_checkpoint(cfg: &TrainConfig, completed: usize) -> (usize, Option<PathBuf>) {
    let every = cfg.checkpoint_every;
    let dir = match (&cfg.checkpoint_dir, every) {
        (Some(d), e) if e > 0 => d,
        _ => return (0, None),
    };
    let mut step = (completed / every) * every;
    while step > 0 {
        let p = checkpoint_path(dir, step as u64);
        if p.exists() {
            return (step, Some(p));
        }
        step -= every;
    }
    (0, None)
}

fn opt_kind_name(opt: &OptChoice) -> &'static str {
    match opt {
        OptChoice::Adam { .. } => "adam",
        OptChoice::Lars { .. } => "lars",
        OptChoice::Sgd { .. } => "sgd",
    }
}

fn find_slot<'a>(slots: &'a [(String, Vec<f32>)], name: &str) -> Result<&'a [f32]> {
    slots
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| v.as_slice())
        .ok_or_else(|| anyhow!("checkpoint is missing optimizer slot {name:?}"))
}

/// Split a full-length optimizer slot back into per-tensor state.
fn split_slot(full: &[f32], sizes: &[usize]) -> Result<Vec<Vec<f32>>> {
    let total: usize = sizes.iter().sum();
    if full.len() != total {
        bail!("optimizer slot has {} elems, params have {total}", full.len());
    }
    let mut out = Vec::with_capacity(sizes.len());
    let mut off = 0;
    for &n in sizes {
        out.push(full[off..off + n].to_vec());
        off += n;
    }
    Ok(out)
}

/// Concatenate per-tensor state into one full-length slot, writing
/// explicit zeros for lazily-unallocated tensors (the optimizers size
/// their accumulators on first touch).
fn flatten_state<'a>(parts: impl Iterator<Item = &'a [f32]>, sizes: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(sizes.iter().sum());
    for (p, &n) in parts.zip(sizes) {
        if p.is_empty() {
            let cur = out.len();
            out.resize(cur + n, 0.0);
        } else {
            debug_assert_eq!(p.len(), n);
            out.extend_from_slice(p);
        }
    }
    out
}

/// Restore optimizer accumulators from a checkpoint snapshot. Slots are
/// stored full-length, so an elastic restart re-slices them under the new
/// world's shard plan for free.
fn restore_opt_state(
    cfg: &TrainConfig,
    st: &TrainState,
    sizes: &[usize],
    replicated: Option<&mut OptState>,
    sharded: Option<&mut ShardedOpt>,
) -> Result<()> {
    let want = opt_kind_name(&cfg.opt);
    if st.opt.kind != want {
        bail!("checkpoint optimizer is {:?} but the run uses {want:?}", st.opt.kind);
    }
    if let Some(sh) = sharded {
        match sh {
            ShardedOpt::Lars(sl) => sl.restore_full_state(&st.opt.slots).map_err(|e| anyhow!(e))?,
            ShardedOpt::Sgd(ss) => ss.restore_full_state(&st.opt.slots).map_err(|e| anyhow!(e))?,
            ShardedOpt::Adam(sa) => {
                sa.restore_full_state(&st.opt.slots).map_err(|e| anyhow!(e))?;
                sa.set_step_count(st.opt.adam_step);
            }
        }
        return Ok(());
    }
    match replicated.expect("replicated optimizer") {
        OptState::Adam(states) => {
            let m = split_slot(find_slot(&st.opt.slots, "m")?, sizes)?;
            let v = split_slot(find_slot(&st.opt.slots, "v")?, sizes)?;
            for ((s, mi), vi) in states.iter_mut().zip(m).zip(v) {
                s.m = mi;
                s.v = vi;
            }
        }
        OptState::Lars(states) => {
            let vel = split_slot(find_slot(&st.opt.slots, "velocity")?, sizes)?;
            for (s, vi) in states.iter_mut().zip(vel) {
                s.v = vi;
            }
        }
        OptState::Sgd(vels) => {
            let vel = split_slot(find_slot(&st.opt.slots, "velocity")?, sizes)?;
            for (slot, vi) in vels.iter_mut().zip(vel) {
                *slot = vi;
            }
        }
    }
    Ok(())
}

/// Snapshot the optimizer for a checkpoint. Sharded state all-gathers its
/// full slots (a collective — every rank must call this); replicated state
/// is identical on every rank, so it flattens rank-locally.
fn snapshot_opt(
    ep: &mut Endpoint,
    group: &[usize],
    cfg: &TrainConfig,
    sizes: &[usize],
    replicated: Option<&OptState>,
    sharded: Option<&ShardedOpt>,
    step: u64,
) -> OptSnapshot {
    let kind = opt_kind_name(&cfg.opt).to_string();
    if let Some(sh) = sharded {
        let (slots, adam_step) = match sh {
            ShardedOpt::Lars(sl) => (sl.gather_full_state(ep, group), 0),
            ShardedOpt::Sgd(ss) => (ss.gather_full_state(ep, group), 0),
            ShardedOpt::Adam(sa) => (sa.gather_full_state(ep, group), sa.step_count()),
        };
        return OptSnapshot { kind, adam_step, slots };
    }
    let (adam_step, slots) = match replicated.expect("replicated optimizer") {
        OptState::Adam(states) => (
            step,
            vec![
                ("m".to_string(), flatten_state(states.iter().map(|s| s.m.as_slice()), sizes)),
                ("v".to_string(), flatten_state(states.iter().map(|s| s.v.as_slice()), sizes)),
            ],
        ),
        OptState::Lars(states) => (
            0,
            vec![(
                "velocity".to_string(),
                flatten_state(states.iter().map(|s| s.v.as_slice()), sizes),
            )],
        ),
        OptState::Sgd(vels) => (
            0,
            vec![(
                "velocity".to_string(),
                flatten_state(vels.iter().map(|v| v.as_slice()), sizes),
            )],
        ),
    };
    OptSnapshot { kind, adam_step, slots }
}

/// f32-encoded RNG state: 4 state words + a spare flag + the spare word,
/// each u64 as four u16 limbs (every limb is exact in f32, so the state
/// rides the f32 collective fabric losslessly).
const RNG_ENC_LEN: usize = 21;

fn encode_u64(out: &mut Vec<f32>, w: u64) {
    for i in 0..4 {
        out.push(((w >> (16 * i)) & 0xFFFF) as f32);
    }
}

fn decode_u64(limbs: &[f32]) -> u64 {
    limbs.iter().enumerate().fold(0u64, |acc, (i, &x)| acc | ((x as u64) << (16 * i)))
}

fn encode_rng_state(st: &RngState) -> Vec<f32> {
    let mut out = Vec::with_capacity(RNG_ENC_LEN);
    for &w in &st.s {
        encode_u64(&mut out, w);
    }
    out.push(if st.spare.is_some() { 1.0 } else { 0.0 });
    encode_u64(&mut out, st.spare.unwrap_or(0));
    out
}

fn decode_rng_state(limbs: &[f32]) -> RngState {
    let mut s = [0u64; 4];
    for (i, w) in s.iter_mut().enumerate() {
        *w = decode_u64(&limbs[4 * i..4 * i + 4]);
    }
    let spare = if limbs[16] != 0.0 { Some(decode_u64(&limbs[17..21])) } else { None };
    RngState { s, spare }
}

/// Resolve the model once and bind one incarnation's plan.
fn build_ctx(cfg: &TrainConfig, plan: IncarnationPlan) -> Result<RunCtx> {
    match cfg.backend {
        BackendChoice::Reference | BackendChoice::ReferenceBf16 => {
            let dims = proxy_dims(&cfg.model).ok_or_else(|| {
                anyhow!(
                    "no reference proxy for model {:?} (known families: {})",
                    cfg.model,
                    crate::models::proxy::known_families()
                )
            })?;
            Ok(RunCtx {
                cfg: cfg.clone(),
                kind: dims.kind,
                specs: param_specs_for(&dims),
                batch: cfg.batch_override.unwrap_or(dims.batch_per_core),
                seq: dims.seq,
                vocab: dims.vocab,
                image: dims.image,
                classes: dims.classes,
                exec: BackendCtx::Reference { dims },
                plan,
            })
        }
        BackendChoice::PjRt => {
            if cfg.batch_override.is_some() {
                bail!("per-core batch override requires the reference backend \
                       (PJRT artifact shapes are fixed at AOT time)");
            }
            let manifest = Manifest::load(Manifest::default_dir())?;
            let specs: Vec<ParamSpec> = manifest.model_params(&cfg.model)?.to_vec();
            let kind = kind_of(&cfg.model)?;
            let family = cfg.model.split('_').next().unwrap().to_string();
            let preset =
                cfg.model.split_once('_').map(|(_, p)| p).unwrap_or("tiny").to_string();
            let get = |key: &str| manifest.config_usize(&cfg.model, key);
            let pjrt = PjRtCtx {
                manifest_dir: manifest.dir.clone(),
                train_art: format!("{family}_train_{preset}"),
                eval_art: format!("{family}_eval_{preset}"),
            };
            // Fail fast before spawning workers: missing artifacts, and a
            // missing PJRT client (e.g. the offline `xla` stub), must be
            // clean errors rather than worker panics.
            manifest.artifact(&pjrt.train_art)?;
            manifest.artifact(&pjrt.eval_art)?;
            drop(crate::runtime::Runtime::with_manifest(std::rc::Rc::new(manifest.clone()))?);
            Ok(RunCtx {
                cfg: cfg.clone(),
                kind,
                specs,
                batch: get("batch_per_core")?,
                seq: if kind == TaskKind::Lm { get("seq")? } else { 0 },
                vocab: if kind == TaskKind::Lm { get("vocab")? } else { 0 },
                image: if kind == TaskKind::Image { get("image")? } else { 0 },
                classes: if kind == TaskKind::Image { get("classes")? } else { 0 },
                exec: BackendCtx::PjRt(pjrt),
                plan,
            })
        }
    }
}

/// Fold one incarnation's report into the run-level accumulator.
fn merge_incarnation(report: &mut TrainReport, inc: TrainReport) {
    report.step_losses.extend(inc.step_losses);
    report.evals.extend(inc.evals);
    report.checkpoints.extend(inc.checkpoints);
    report.straggled_steps += inc.straggled_steps;
    report.breakdown.compute_s += inc.breakdown.compute_s;
    report.breakdown.gradsum_s += inc.breakdown.gradsum_s;
    report.breakdown.update_s += inc.breakdown.update_s;
    report.breakdown.input_s += inc.breakdown.input_s;
    report.breakdown.steps += inc.breakdown.steps;
    report.wallclock_s += inc.wallclock_s;
    report.init_s += inc.init_s;
    report.exec_s += inc.exec_s;
    report.fwd_s += inc.fwd_s;
    report.bwd_s += inc.bwd_s;
    report.params_total = inc.params_total;
    if report.converged_at.is_none() {
        report.converged_at = inc.converged_at;
    }
    report.final_params = inc.final_params;
}

/// Run the trainer; returns the rank-0 report.
///
/// With a fault trace this is an *incarnation loop*: each incarnation
/// trains until the run finishes or the next fatal (death/preemption)
/// event strikes; a fatal event rolls the run back to the newest durable
/// checkpoint — losing the steps since it — and, for a death, restarts
/// elastically on **exactly the survivors** (world − 1; any world size is
/// a valid world size, powers of two included but not required). Goodput
/// = useful steps / executed steps (exactly 1.0 when no fault applies).
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    if cfg.cores == 0 {
        bail!("--cores must be at least 1");
    }
    if cfg.checkpoint_every > 0 {
        let dir = cfg
            .checkpoint_dir
            .as_ref()
            .ok_or_else(|| anyhow!("checkpoint-every requires a checkpoint dir"))?;
        std::fs::create_dir_all(dir)?;
    }
    if let Some(trace) = &cfg.faults {
        trace.validate().map_err(|e| anyhow!("invalid fault trace: {e}"))?;
    }
    let resumed_from = match &cfg.resume {
        Some(path) => checkpoint::peek_step(path)?,
        None => 0,
    };

    // Fatal events only; stragglers are handled inside the step loop.
    let fatal: Vec<FaultEvent> = cfg
        .faults
        .iter()
        .flat_map(|t| t.events.iter().copied())
        .filter(|ev| !matches!(ev.kind, FaultKind::Slowdown { .. }))
        .collect();

    let mut world = cfg.cores;
    let mut start = resumed_from as usize;
    let mut resume = cfg.resume.clone();
    let mut fi = 0usize;
    let mut report = TrainReport { resumed_from, goodput: 1.0, ..Default::default() };
    let mut executed = 0usize;
    let mut completed;
    // Coordinator-track trace timeline: incarnation boundaries, fault and
    // rollback instants, and (at the end) the report's accounting counters
    // that `trace summarize` cross-checks span sums against.
    let mut co = cfg.trace.local(TRACK_COORD, 0);
    let mut incarnation: u32 = 0;

    loop {
        co.instant("incarnation.start", || {
            vec![
                ("incarnation", AttrVal::from(incarnation as usize)),
                ("start_step", AttrVal::from(start)),
                ("world", AttrVal::from(world)),
                ("resumed", AttrVal::Int(resume.is_some() as i64)),
            ]
        });
        // Next fault event that can kill this incarnation (an event aimed
        // at an already-dead rank, or at already-replayed steps, skips).
        let mut stop: Option<(usize, usize)> = None;
        while fi < fatal.len() {
            let ev = &fatal[fi];
            let step = ev.step as usize;
            if step <= start || ev.chip >= world {
                fi += 1;
                continue;
            }
            if step > cfg.steps {
                fi = fatal.len();
                break;
            }
            stop = Some((fi, step));
            break;
        }

        let plan = IncarnationPlan {
            resume: resume.clone(),
            start,
            stop_before: stop.map(|(_, s)| s),
            epoch: incarnation,
        };
        let ctx = build_ctx(cfg, plan)?;
        let results = Mutex::new(Vec::<(usize, TrainReport)>::new());
        run_spmd(world, |ep| {
            let r = worker(ep, &ctx)
                .unwrap_or_else(|e| panic!("worker {} failed: {e:#}", ep.rank));
            results.lock().unwrap().push((ep.rank, r));
        });
        let mut all = results.into_inner().unwrap();
        all.sort_by_key(|(r, _)| *r);
        let inc = all
            .into_iter()
            .next()
            .map(|(_, rep)| rep)
            .ok_or_else(|| anyhow!("no worker results"))?;

        executed += inc.step_losses.len();
        completed = start + inc.step_losses.len();
        merge_incarnation(&mut report, inc);

        let hit_fault = match stop {
            Some((_, fstep)) => completed + 1 == fstep && report.converged_at.is_none(),
            None => false,
        };
        if !hit_fault {
            break;
        }
        let (idx, fstep) = stop.expect("fatal event");

        // Roll back to the newest durable checkpoint; everything past it
        // is lost work.
        report.restores += 1;
        let (ckpt_step, ckpt_path) = latest_checkpoint(cfg, completed);
        report.lost_steps += (completed - ckpt_step) as u64;
        let fault_name = match fatal[idx].kind {
            FaultKind::Death => "fault.death",
            _ => "fault.preemption",
        };
        co.instant(fault_name, || {
            vec![("step", AttrVal::from(fstep)), ("chip", AttrVal::from(fatal[idx].chip))]
        });
        co.instant("rollback", || {
            vec![
                ("to_step", AttrVal::from(ckpt_step)),
                ("lost_steps", AttrVal::from(completed - ckpt_step)),
            ]
        });
        if fatal[idx].kind == FaultKind::Death {
            if world == 1 {
                bail!("fault trace killed the last worker at step {fstep}");
            }
            world -= 1; // elastic restart on exactly the survivors
        }
        resume = ckpt_path;
        start = ckpt_step;
        fi = idx + 1;
        incarnation += 1;
    }

    let useful = completed.saturating_sub(resumed_from as usize);
    report.goodput = if executed == 0 { 1.0 } else { useful as f64 / executed as f64 };
    report.final_cores = world;
    // Embed the final accounting in the trace itself: `trace summarize`
    // re-derives these from the span durations and fails on disagreement.
    co.counter("report.steps", report.breakdown.steps as f64);
    co.counter("report.input_s", report.breakdown.input_s);
    co.counter("report.compute_s", report.breakdown.compute_s);
    co.counter("report.gradsum_s", report.breakdown.gradsum_s);
    co.counter("report.update_s", report.breakdown.update_s);
    co.counter("report.exec_s", report.exec_s);
    co.counter("report.fwd_s", report.fwd_s);
    co.counter("report.bwd_s", report.bwd_s);
    co.counter("report.goodput", report.goodput);
    co.counter("report.lost_steps", report.lost_steps as f64);
    co.counter("report.restores", report.restores as f64);
    co.counter("report.checkpoints", report.checkpoints.len() as f64);
    co.counter("report.final_cores", world as f64);
    Ok(report)
}

fn worker(ep: &mut Endpoint, ctx: &RunCtx) -> Result<TrainReport> {
    let cfg = &ctx.cfg;
    let init_timer = Timer::start();
    let world = ep.world;
    let group: Vec<usize> = (0..world).collect();
    let place = Placement::new(world);

    // ---- init phase (excluded from the MLPerf clock) ---------------------
    let backend = make_backend(ctx)?;

    // Fresh start: rank 0 initializes and the weights ride the broadcast
    // collective. Resume: every rank reads the same self-contained v2
    // file, so params are identical with no collective at all.
    let restored: Option<TrainState> = match &ctx.plan.resume {
        Some(path) => {
            let st = checkpoint::load(path, &ctx.specs)
                .map_err(|e| anyhow!("restore from {}: {e}", path.display()))?;
            if st.step as usize != ctx.plan.start {
                bail!(
                    "checkpoint {} is at step {} but the plan resumes at {}",
                    path.display(),
                    st.step,
                    ctx.plan.start
                );
            }
            Some(st)
        }
        None => None,
    };
    let mut params: Vec<Vec<f32>> = match &restored {
        Some(st) => st.params.clone(),
        None => {
            let mut p = if ep.rank == 0 {
                init_params(&ctx.specs, cfg.seed)
            } else {
                ctx.specs.iter().map(|s| vec![0.0; s.numel()]).collect()
            };
            for t in p.iter_mut() {
                broadcast(ep, &group, 0, t);
            }
            p
        }
    };

    // Training data decorrelated per worker; eval set shared via seeds.
    // The data RNG *is* the input-pipeline cursor: restoring it resumes
    // the stream at the exact batch the checkpointed run would draw next
    // (v1 checkpoints carry no RNG — those fall back to a fresh stream).
    let lm_task = LmTask::new(ctx.vocab.max(2), cfg.task_difficulty);
    let img_task =
        ImageTask::new(ctx.image.max(1), ctx.classes.max(2), cfg.image_alpha, cfg.seed ^ 0xEEE);
    let mut data_rng = match restored.as_ref().and_then(|st| st.rng.get(ep.rank)) {
        Some(state) => Rng::restore(state),
        None => Rng::new(cfg.seed).fold_in(1000 + ep.rank as u64),
    };

    // Optimizer state (replicated or sharded per §2 Fig. 4).
    let is_1d: Vec<bool> = ctx.specs.iter().map(|s| s.shape.len() <= 1).collect();
    let sizes: Vec<usize> = ctx.specs.iter().map(|s| s.numel()).collect();
    let mut replicated: Option<OptState> = None;
    let mut sharded: Option<ShardedOpt> = None;
    if cfg.use_wus {
        let plan = ShardPlan::balanced(&sizes, world);
        sharded = Some(match cfg.opt {
            OptChoice::Lars { cfg: lc, .. } => {
                ShardedOpt::Lars(ShardedLars::new(lc, plan, ep.rank, is_1d.clone()))
            }
            OptChoice::Adam { cfg: ac, .. } => {
                ShardedOpt::Adam(ShardedAdam::new(ac, plan, ep.rank))
            }
            OptChoice::Sgd { momentum, .. } => {
                ShardedOpt::Sgd(ShardedSgd::new(momentum, plan, ep.rank))
            }
        });
    } else {
        replicated = Some(match cfg.opt {
            OptChoice::Adam { .. } => {
                OptState::Adam(ctx.specs.iter().map(|_| AdamState::default()).collect())
            }
            OptChoice::Lars { .. } => {
                OptState::Lars(ctx.specs.iter().map(|_| LarsState::default()).collect())
            }
            OptChoice::Sgd { .. } => OptState::Sgd(ctx.specs.iter().map(|_| vec![]).collect()),
        });
    }
    if let Some(st) = &restored {
        if st.opt.kind != "none" {
            restore_opt_state(cfg, st, &sizes, replicated.as_mut(), sharded.as_mut())?;
        }
    }

    let mut report =
        TrainReport { params_total: sizes.iter().sum(), ..Default::default() };
    report.init_s = init_timer.secs();
    // Staging buffer for the pipelined gradient summation, reused across
    // steps (on TPU this is the fixed on-device staging area; reallocating
    // it every step pays page-fault zeroing on the whole gradient set).
    let mut gradsum_ws = GradSumWorkspace::default();
    // Rank 0 records the per-step phase spans (the report is the rank-0
    // view, so its accounting and these spans must agree); other ranks
    // carry a disabled local, which records nothing.
    let mut tr = if ep.rank == 0 {
        cfg.trace.local(TRACK_STEP, ctx.plan.epoch)
    } else {
        TraceLocal::disabled()
    };
    // Rank 0's background checkpoint writer: saves stream to `<file>.tmp`
    // on a writer thread and publish via atomic rename while the step loop
    // keeps training; at most one save is in flight (see checkpoint docs).
    let mut ckpt_writer = checkpoint::AsyncWriter::with_trace(
        if ep.rank == 0 { cfg.trace.clone() } else { TraceSink::disabled() },
        ctx.plan.epoch,
    );
    let wall = Timer::start();

    // ---- nested train-and-eval tight loop (§2) ---------------------------
    for step in (ctx.plan.start + 1)..=cfg.steps {
        if let Some(fatal) = ctx.plan.stop_before {
            if step >= fatal {
                break; // the fault strikes mid-step: this step's work is lost
            }
        }
        // Injected stragglers stretch the step but never kill it — the
        // synchronous SPMD step is gated on the slowest live participant.
        let mut straggled = false;
        if let Some(trace) = &cfg.faults {
            let s = step as u64;
            straggled = trace.events.iter().any(|ev| {
                matches!(ev.kind, FaultKind::Slowdown { steps, .. }
                    if ev.chip < world && s >= ev.step && s < ev.step.saturating_add(steps))
            });
            if straggled {
                report.straggled_steps += 1;
            }
        }
        let t_step = tr.start();

        // -- input pipeline --
        let t_in = Timer::start();
        let batch = match ctx.kind {
            TaskKind::Lm => {
                let b = lm_task.batch(&mut data_rng, ctx.batch, ctx.seq);
                StepBatch::Lm { tokens: b.tokens, targets: b.targets }
            }
            TaskKind::Image => {
                let b = img_task.batch(&mut data_rng, ctx.batch);
                StepBatch::Image { images: b.images, labels: b.labels }
            }
        };
        let d_in = t_in.secs();
        report.breakdown.input_s += d_in;
        tr.span_at("trainer.input", t_step, d_in, || vec![("step", AttrVal::from(step))]);

        // -- fwd/bwd on the backend executor --
        // The span reuses the exact Timer duration the breakdown adds, so
        // span sums reproduce report accounting; fwd/bwd sub-spans come
        // from the executor's cumulative phase clock deltas (which also
        // advance during eval — the eval span accounts for those).
        let (pf0, pb0) =
            if tr.is_enabled() { backend.phase_seconds() } else { (0.0, 0.0) };
        let t_c0 = tr.start();
        let t_c = Timer::start();
        let (loss, mut grads) = backend.train_step(&params, &batch)?;
        let d_c = t_c.secs();
        report.breakdown.compute_s += d_c;
        if tr.is_enabled() {
            let (pf1, pb1) = backend.phase_seconds();
            tr.span_at("trainer.compute", t_c0, d_c, || vec![("step", AttrVal::from(step))]);
            tr.span_at("trainer.fwd", t_c0, pf1 - pf0, Vec::new);
            tr.span_at("trainer.bwd", t_c0 + (pf1 - pf0), pb1 - pb0, Vec::new);
        }

        // -- gradient summation (§2) --
        let t_g0 = tr.start();
        let t_g = Timer::start();
        match cfg.gradsum {
            GradSumMode::Serial => gradsum_serial(ep, &place, &mut grads),
            GradSumMode::Pipelined { quantum } => {
                gradsum_pipelined_ws(ep, &place, &mut grads, quantum, &mut gradsum_ws)
            }
        }
        let scale = 1.0 / world as f32;
        for g in grads.iter_mut() {
            for x in g.iter_mut() {
                *x *= scale;
            }
        }
        let d_g = t_g.secs();
        report.breakdown.gradsum_s += d_g;
        tr.span_at("trainer.gradsum", t_g0, d_g, || vec![("step", AttrVal::from(step))]);

        // -- weight update (replicated or WUS, §2 Fig. 4) --
        let t_u0 = tr.start();
        let t_u = Timer::start();
        let lrf = cfg.lr_factor(step);
        match &mut replicated {
            Some(OptState::Adam(states)) => {
                let (ac, lr) = match cfg.opt {
                    OptChoice::Adam { cfg, lr } => (cfg, lr),
                    _ => unreachable!(),
                };
                for ti in 0..params.len() {
                    adam_step(&ac, lr * lrf, step as u64, &mut params[ti], &grads[ti],
                              &mut states[ti]);
                }
            }
            Some(OptState::Lars(states)) => {
                let (lc, lr) = match cfg.opt {
                    OptChoice::Lars { cfg, lr } => (cfg, lr),
                    _ => unreachable!(),
                };
                for ti in 0..params.len() {
                    lars_step(&lc, lr * lrf, &mut params[ti], &grads[ti], &mut states[ti],
                              is_1d[ti]);
                }
            }
            Some(OptState::Sgd(vels)) => {
                let (lr, mom) = match cfg.opt {
                    OptChoice::Sgd { lr, momentum } => (lr, momentum),
                    _ => unreachable!(),
                };
                for ti in 0..params.len() {
                    sgd_momentum_step(lr * lrf, mom, &mut params[ti], &grads[ti],
                                      &mut vels[ti]);
                }
            }
            None => {
                let lr = match cfg.opt {
                    OptChoice::Adam { lr, .. }
                    | OptChoice::Lars { lr, .. }
                    | OptChoice::Sgd { lr, .. } => lr,
                };
                match sharded.as_mut().expect("wus optimizer") {
                    ShardedOpt::Lars(sl) => sl.step(ep, &group, lr * lrf, &mut params, &grads),
                    ShardedOpt::Adam(sa) => sa.step(ep, &group, lr * lrf, &mut params, &grads),
                    ShardedOpt::Sgd(ss) => ss.step(ep, &group, lr * lrf, &mut params, &grads),
                }
            }
        }
        let d_u = t_u.secs();
        report.breakdown.update_s += d_u;
        tr.span_at("trainer.update", t_u0, d_u, || vec![("step", AttrVal::from(step))]);
        report.breakdown.steps += 1;
        report.step_losses.push(loss);

        // -- distributed evaluation (§2) --
        if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
            // Eval runs the same executor, advancing its cumulative fwd/bwd
            // clocks; the deltas ride the eval span so `trace summarize`
            // can still reconcile span sums with `report.fwd_s`/`bwd_s`.
            let (ef0, eb0) =
                if tr.is_enabled() { backend.phase_seconds() } else { (0.0, 0.0) };
            let t_e0 = tr.start();
            let sharding = EvalSharding::new(cfg.eval_examples, world, ctx.batch);
            let res = distributed_eval(ep, &group, &sharding, |chunk| {
                let eb = eval_batch_for(ctx, chunk, &lm_task, &img_task);
                backend
                    .eval_step(&params, &eb, &chunk.mask)
                    .expect("eval execution failed")
            });
            if tr.is_enabled() {
                let (ef1, eb1) = backend.phase_seconds();
                tr.span("trainer.eval", t_e0, || {
                    vec![
                        ("step", AttrVal::from(step)),
                        ("accuracy", AttrVal::Num(res.accuracy)),
                        ("exec_fwd_s", AttrVal::Num(ef1 - ef0)),
                        ("exec_bwd_s", AttrVal::Num(eb1 - eb0)),
                    ]
                });
            }
            report.evals.push(EvalPoint { step, loss: res.loss, accuracy: res.accuracy });
            if let Some(target) = cfg.quality_target {
                if res.accuracy >= target && report.converged_at.is_none() {
                    report.converged_at = Some(step);
                    tr.span("trainer.step", t_step, || {
                        vec![
                            ("step", AttrVal::from(step)),
                            ("straggled", AttrVal::Int(straggled as i64)),
                        ]
                    });
                    break; // synchronous: all workers see the same metric
                }
            }
        }

        // -- durable checkpoint (fault-tolerance layer) --
        if cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0 {
            // Every rank contributes its data-RNG state (u16 limbs ride
            // the f32 fabric exactly) and, under WUS, its optimizer shard;
            // rank 0 then writes one self-contained v2 file.
            let t_s0 = tr.start();
            let mine = encode_rng_state(&data_rng.state());
            let gathered = all_gather_concat(ep, &group, &mine);
            let rng_states: Vec<RngState> = (0..world)
                .map(|r| decode_rng_state(&gathered[r * RNG_ENC_LEN..(r + 1) * RNG_ENC_LEN]))
                .collect();
            let opt = snapshot_opt(ep, &group, cfg, &sizes, replicated.as_ref(),
                                   sharded.as_ref(), step as u64);
            if ep.rank == 0 {
                let dir = cfg.checkpoint_dir.as_ref().expect("checkpoint dir");
                let path = checkpoint_path(dir, step as u64);
                let state = TrainState {
                    step: step as u64,
                    params: params.clone(),
                    opt,
                    rng: rng_states,
                    world,
                };
                tr.span("trainer.ckpt.snapshot", t_s0, || {
                    vec![("step", AttrVal::from(step))]
                });
                // The owned snapshot goes to the writer thread; training
                // continues while the save streams to `<path>.tmp` and is
                // published by atomic rename. An enqueue that waits here is
                // back-pressure from the previous save — the span makes
                // that stall visible.
                let t_q0 = tr.start();
                ckpt_writer
                    .enqueue(path.clone(), ctx.specs.clone(), state)
                    .map_err(|e| anyhow!("checkpoint {}: {e}", path.display()))?;
                tr.span("trainer.ckpt.enqueue", t_q0, || {
                    vec![("step", AttrVal::from(step))]
                });
                report.checkpoints.push(step as u64);
            }
        }

        tr.span("trainer.step", t_step, || {
            vec![("step", AttrVal::from(step)), ("straggled", AttrVal::Int(straggled as i64))]
        });

        // -- crash injection (CI crash-resume smoke) --
        if cfg.kill_at == step && ep.rank == 0 {
            // The in-flight save (if any) must be published before the
            // abort: a kill at or after a checkpoint step never loses that
            // checkpoint, only a kill *during* the write does — and then
            // the torn bytes sit in a `.tmp` the loaders never read.
            if let Err(e) = ckpt_writer.drain() {
                eprintln!("kill-at: draining checkpoint writer failed: {e:#}");
            }
            eprintln!("kill-at: aborting the process after step {step}");
            std::process::exit(3);
        }
    }
    // Surface any in-flight save before reporting success: a checkpoint
    // the caller saw in `report.checkpoints` must be durable by the time
    // `train()` returns.
    ckpt_writer.drain().map_err(|e| anyhow!("checkpoint writer: {e}"))?;
    report.wallclock_s = wall.secs();
    report.exec_s = backend.execute_seconds();
    let (fwd, bwd) = backend.phase_seconds();
    report.fwd_s = fwd;
    report.bwd_s = bwd;
    report.final_params = params;
    Ok(report)
}

/// Build the (deterministic, index-seeded) eval batch for one chunk —
/// every core regenerates the same global example for the same index, so
/// the distributed metrics are independent of the core count.
fn eval_batch_for(
    ctx: &RunCtx,
    chunk: &EvalChunk,
    lm_task: &LmTask,
    img_task: &ImageTask,
) -> StepBatch {
    let eval_seed = ctx.cfg.seed ^ 0x5EED_0000;
    match ctx.kind {
        TaskKind::Lm => {
            let mut tokens = Vec::with_capacity(chunk.indices.len() * ctx.seq);
            let mut targets = Vec::with_capacity(chunk.indices.len() * ctx.seq);
            for &g in &chunk.indices {
                let mut rng = Rng::new(eval_seed).fold_in(g as u64);
                let b = lm_task.batch(&mut rng, 1, ctx.seq);
                tokens.extend(b.tokens);
                targets.extend(b.targets);
            }
            StepBatch::Lm { tokens, targets }
        }
        TaskKind::Image => {
            let dim = ctx.image * ctx.image * 3;
            let mut images = Vec::with_capacity(chunk.indices.len() * dim);
            let mut labels = Vec::with_capacity(chunk.indices.len());
            for &g in &chunk.indices {
                let mut rng = Rng::new(eval_seed).fold_in(g as u64);
                let b = img_task.batch(&mut rng, 1);
                images.extend(b.images);
                labels.extend(b.labels);
            }
            StepBatch::Image { images, labels }
        }
    }
}
