//! The data-parallel trainer (see module docs in `coordinator`).
//!
//! The step loop is backend-agnostic: a [`Backend`] (selected by
//! [`TrainConfig::backend`]) turns params + batch into loss + exact
//! gradients, and everything around it — input pipeline, 2-D gradient
//! summation, replicated/sharded weight update, distributed eval — is the
//! same coordinator code whether the executor is the in-Rust reference
//! fwd/bwd or PJRT over AOT artifacts.

use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::collectives::{
    broadcast, gradsum_pipelined_ws, gradsum_serial, GradSumWorkspace, Placement,
};
use crate::data::synthetic::{ImageTask, LmTask};
use crate::evaluation::{distributed_eval, EvalChunk, EvalSharding};
use crate::fabric::{run_spmd, Endpoint};
use crate::metrics::StepBreakdown;
use crate::models::proxy::{proxy_dims, TaskKind};
use crate::optim::{
    adam_step, lars_step, sgd_momentum_step, AdamConfig, AdamState, LarsConfig, LarsState,
};
use crate::runtime::{
    param_specs_for, Backend, BackendChoice, Manifest, ParamSpec, PjRtBackend, Precision,
    ReferenceBackend, StepBatch,
};
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use crate::wus::{ShardPlan, ShardedAdam, ShardedLars, ShardedSgd};

/// Optimizer selection.
#[derive(Clone, Copy, Debug)]
pub enum OptChoice {
    Adam { cfg: AdamConfig, lr: f32 },
    Lars { cfg: LarsConfig, lr: f32 },
    Sgd { lr: f32, momentum: f32 },
}

/// Gradient-summation schedule (§2).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum GradSumMode {
    /// Per-tensor 2-D all-reduces with exposed gathers (baseline).
    Serial,
    /// The paper's pipelined non-contiguous scheme; the quantum is the
    /// pack granularity overlapped with network waits.
    Pipelined { quantum: usize },
}

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model key: a proxy family (`transformer`, `resnet50`, …) for the
    /// reference backend, a manifest key (`transformer_tiny`) for PJRT.
    pub model: String,
    /// Data-parallel worker threads ("cores"); power of two.
    pub cores: usize,
    pub steps: usize,
    /// Evaluate every N steps (0 = never).
    pub eval_every: usize,
    pub eval_examples: usize,
    pub opt: OptChoice,
    /// Weight-update sharding on/off (§2 Fig. 4).
    pub use_wus: bool,
    pub gradsum: GradSumMode,
    /// Which fwd/bwd executor drives the step loop.
    pub backend: BackendChoice,
    /// Per-core batch override (reference backend only; PJRT shapes are
    /// fixed at AOT time). `None` = the model's default.
    pub batch_override: Option<usize>,
    pub seed: u64,
    /// LM label-noise floor (Lm) — drives the accuracy ceiling.
    pub task_difficulty: f64,
    /// Image-task signal strength alpha (Image kind; higher = easier).
    pub image_alpha: f32,
    /// Stop early once eval accuracy reaches this (None = run all steps).
    pub quality_target: Option<f64>,
    /// Linear warmup (steps) then polynomial decay to `steps` — the MLPerf
    /// ResNet schedule shape (paper Table 1 columns). 0 = constant lr.
    pub warmup_steps: usize,
}

impl TrainConfig {
    /// Effective lr multiplier at a (1-based) step under the schedule.
    pub fn lr_factor(&self, step: usize) -> f32 {
        if self.warmup_steps == 0 {
            return 1.0;
        }
        let w = self.warmup_steps as f32;
        let s = step as f32;
        if s < w {
            return s / w;
        }
        let span = (self.steps as f32 - w).max(1.0);
        let frac = ((s - w) / span).clamp(0.0, 1.0);
        (1.0 - frac) * (1.0 - frac)
    }
}

impl TrainConfig {
    pub fn quick(model: &str, cores: usize, steps: usize) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            cores,
            steps,
            eval_every: 0,
            eval_examples: 256,
            opt: OptChoice::Adam { cfg: AdamConfig::default(), lr: 1e-3 },
            use_wus: false,
            gradsum: GradSumMode::Pipelined { quantum: 4096 },
            backend: BackendChoice::Reference,
            batch_override: None,
            seed: 0,
            task_difficulty: 0.05,
            image_alpha: 2.0,
            quality_target: None,
            warmup_steps: 0,
        }
    }
}

/// One evaluation record.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
}

/// Trainer output (rank-0 view; workers are synchronous so identical).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub step_losses: Vec<f32>,
    pub evals: Vec<EvalPoint>,
    pub breakdown: StepBreakdown,
    pub wallclock_s: f64,
    pub init_s: f64,
    /// First step whose eval met the quality target.
    pub converged_at: Option<usize>,
    pub params_total: usize,
    /// Cumulative backend execute seconds (PJRT or reference fwd/bwd).
    pub exec_s: f64,
}

/// Static per-run context shared (read-only) by all workers.
struct RunCtx {
    cfg: TrainConfig,
    kind: TaskKind,
    specs: Vec<ParamSpec>,
    batch: usize,
    seq: usize,
    vocab: usize,
    image: usize,
    classes: usize,
    exec: BackendCtx,
}

/// Resolved executor context (model lookup happens once, in `train()`).
enum BackendCtx {
    Reference { dims: crate::models::proxy::ProxyDims },
    PjRt(PjRtCtx),
}

struct PjRtCtx {
    manifest_dir: std::path::PathBuf,
    train_art: String,
    eval_art: String,
}

fn kind_of(model: &str) -> Result<TaskKind> {
    proxy_dims(model)
        .map(|d| d.kind)
        .ok_or_else(|| anyhow!("unknown model family: {model}"))
}

fn init_params(specs: &[ParamSpec], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    specs
        .iter()
        .map(|s| {
            let n = s.numel();
            if s.name.ends_with(".scale") {
                vec![1.0; n]
            } else if s.name.ends_with(".bias")
                || s.name.ends_with(".b1")
                || s.name.ends_with(".b2")
                || s.name.ends_with(".b")
            {
                vec![0.0; n]
            } else {
                let fan_in = s.shape[..s.shape.len() - 1].iter().product::<usize>().max(1);
                let std = (1.0 / fan_in as f32).sqrt();
                rng.normal_vec(n, std)
            }
        })
        .collect()
}

/// Build this worker's backend. PJRT runtimes are `Rc`-based (not `Send`),
/// so construction happens inside the worker thread.
fn make_backend(ctx: &RunCtx) -> Result<Box<dyn Backend>> {
    match &ctx.exec {
        BackendCtx::Reference { dims } => {
            let precision = match ctx.cfg.backend {
                BackendChoice::ReferenceBf16 => Precision::Bf16,
                _ => Precision::F32,
            };
            Ok(Box::new(ReferenceBackend::with_dims(*dims, precision)))
        }
        BackendCtx::PjRt(p) => {
            Ok(Box::new(PjRtBackend::new(&p.manifest_dir, &p.train_art, &p.eval_art)?))
        }
    }
}

/// Replicated optimizer state (per tensor).
enum OptState {
    Adam(Vec<AdamState>),
    Lars(Vec<LarsState>),
    Sgd(Vec<Vec<f32>>),
}

/// Sharded optimizer (weight-update sharding, §2 Fig. 4).
enum ShardedOpt {
    Lars(ShardedLars),
    Adam(ShardedAdam),
    Sgd(ShardedSgd),
}

/// Run the trainer; returns the rank-0 report.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    assert!(cfg.cores.is_power_of_two(), "cores must be a power of two");
    let ctx = match cfg.backend {
        BackendChoice::Reference | BackendChoice::ReferenceBf16 => {
            let dims = proxy_dims(&cfg.model).ok_or_else(|| {
                anyhow!(
                    "no reference proxy for model {:?} (known families: {})",
                    cfg.model,
                    crate::models::proxy::known_families()
                )
            })?;
            RunCtx {
                cfg: cfg.clone(),
                kind: dims.kind,
                specs: param_specs_for(&dims),
                batch: cfg.batch_override.unwrap_or(dims.batch_per_core),
                seq: dims.seq,
                vocab: dims.vocab,
                image: dims.image,
                classes: dims.classes,
                exec: BackendCtx::Reference { dims },
            }
        }
        BackendChoice::PjRt => {
            if cfg.batch_override.is_some() {
                bail!("per-core batch override requires the reference backend \
                       (PJRT artifact shapes are fixed at AOT time)");
            }
            let manifest = Manifest::load(Manifest::default_dir())?;
            let specs: Vec<ParamSpec> = manifest.model_params(&cfg.model)?.to_vec();
            let kind = kind_of(&cfg.model)?;
            let family = cfg.model.split('_').next().unwrap().to_string();
            let preset =
                cfg.model.split_once('_').map(|(_, p)| p).unwrap_or("tiny").to_string();
            let get = |key: &str| manifest.config_usize(&cfg.model, key);
            let pjrt = PjRtCtx {
                manifest_dir: manifest.dir.clone(),
                train_art: format!("{family}_train_{preset}"),
                eval_art: format!("{family}_eval_{preset}"),
            };
            // Fail fast before spawning workers: missing artifacts, and a
            // missing PJRT client (e.g. the offline `xla` stub), must be
            // clean errors rather than worker panics.
            manifest.artifact(&pjrt.train_art)?;
            manifest.artifact(&pjrt.eval_art)?;
            drop(crate::runtime::Runtime::with_manifest(std::rc::Rc::new(manifest.clone()))?);
            RunCtx {
                cfg: cfg.clone(),
                kind,
                specs,
                batch: get("batch_per_core")?,
                seq: if kind == TaskKind::Lm { get("seq")? } else { 0 },
                vocab: if kind == TaskKind::Lm { get("vocab")? } else { 0 },
                image: if kind == TaskKind::Image { get("image")? } else { 0 },
                classes: if kind == TaskKind::Image { get("classes")? } else { 0 },
                exec: BackendCtx::PjRt(pjrt),
            }
        }
    };

    let results = Mutex::new(Vec::<(usize, TrainReport)>::new());
    run_spmd(cfg.cores, |ep| {
        let r = worker(ep, &ctx)
            .unwrap_or_else(|e| panic!("worker {} failed: {e:#}", ep.rank));
        results.lock().unwrap().push((ep.rank, r));
    });

    let mut all = results.into_inner().unwrap();
    all.sort_by_key(|(r, _)| *r);
    all.into_iter().next().map(|(_, rep)| rep).ok_or_else(|| anyhow!("no worker results"))
}

fn worker(ep: &mut Endpoint, ctx: &RunCtx) -> Result<TrainReport> {
    let cfg = &ctx.cfg;
    let init_timer = Timer::start();
    let world = ep.world;
    let group: Vec<usize> = (0..world).collect();
    let place = Placement::new(world);

    // ---- init phase (excluded from the MLPerf clock) ---------------------
    let backend = make_backend(ctx)?;

    // Rank 0 initializes; weights ride the broadcast collective.
    let mut params: Vec<Vec<f32>> = if ep.rank == 0 {
        init_params(&ctx.specs, cfg.seed)
    } else {
        ctx.specs.iter().map(|s| vec![0.0; s.numel()]).collect()
    };
    for t in params.iter_mut() {
        broadcast(ep, &group, 0, t);
    }

    // Training data decorrelated per worker; eval set shared via seeds.
    let lm_task = LmTask::new(ctx.vocab.max(2), cfg.task_difficulty);
    let img_task =
        ImageTask::new(ctx.image.max(1), ctx.classes.max(2), cfg.image_alpha, cfg.seed ^ 0xEEE);
    let mut data_rng = Rng::new(cfg.seed).fold_in(1000 + ep.rank as u64);

    // Optimizer state (replicated or sharded per §2 Fig. 4).
    let is_1d: Vec<bool> = ctx.specs.iter().map(|s| s.shape.len() <= 1).collect();
    let sizes: Vec<usize> = ctx.specs.iter().map(|s| s.numel()).collect();
    let mut replicated: Option<OptState> = None;
    let mut sharded: Option<ShardedOpt> = None;
    if cfg.use_wus {
        let plan = ShardPlan::balanced(&sizes, world);
        sharded = Some(match cfg.opt {
            OptChoice::Lars { cfg: lc, .. } => {
                ShardedOpt::Lars(ShardedLars::new(lc, plan, ep.rank, is_1d.clone()))
            }
            OptChoice::Adam { cfg: ac, .. } => {
                ShardedOpt::Adam(ShardedAdam::new(ac, plan, ep.rank))
            }
            OptChoice::Sgd { momentum, .. } => {
                ShardedOpt::Sgd(ShardedSgd::new(momentum, plan, ep.rank))
            }
        });
    } else {
        replicated = Some(match cfg.opt {
            OptChoice::Adam { .. } => {
                OptState::Adam(ctx.specs.iter().map(|_| AdamState::default()).collect())
            }
            OptChoice::Lars { .. } => {
                OptState::Lars(ctx.specs.iter().map(|_| LarsState::default()).collect())
            }
            OptChoice::Sgd { .. } => OptState::Sgd(ctx.specs.iter().map(|_| vec![]).collect()),
        });
    }

    let mut report =
        TrainReport { params_total: sizes.iter().sum(), ..Default::default() };
    report.init_s = init_timer.secs();
    // Staging buffer for the pipelined gradient summation, reused across
    // steps (on TPU this is the fixed on-device staging area; reallocating
    // it every step pays page-fault zeroing on the whole gradient set).
    let mut gradsum_ws = GradSumWorkspace::default();
    let wall = Timer::start();

    // ---- nested train-and-eval tight loop (§2) ---------------------------
    for step in 1..=cfg.steps {
        // -- input pipeline --
        let t_in = Timer::start();
        let batch = match ctx.kind {
            TaskKind::Lm => {
                let b = lm_task.batch(&mut data_rng, ctx.batch, ctx.seq);
                StepBatch::Lm { tokens: b.tokens, targets: b.targets }
            }
            TaskKind::Image => {
                let b = img_task.batch(&mut data_rng, ctx.batch);
                StepBatch::Image { images: b.images, labels: b.labels }
            }
        };
        report.breakdown.input_s += t_in.secs();

        // -- fwd/bwd on the backend executor --
        let t_c = Timer::start();
        let (loss, mut grads) = backend.train_step(&params, &batch)?;
        report.breakdown.compute_s += t_c.secs();

        // -- gradient summation (§2) --
        let t_g = Timer::start();
        match cfg.gradsum {
            GradSumMode::Serial => gradsum_serial(ep, &place, &mut grads),
            GradSumMode::Pipelined { quantum } => {
                gradsum_pipelined_ws(ep, &place, &mut grads, quantum, &mut gradsum_ws)
            }
        }
        let scale = 1.0 / world as f32;
        for g in grads.iter_mut() {
            for x in g.iter_mut() {
                *x *= scale;
            }
        }
        report.breakdown.gradsum_s += t_g.secs();

        // -- weight update (replicated or WUS, §2 Fig. 4) --
        let t_u = Timer::start();
        let lrf = cfg.lr_factor(step);
        match &mut replicated {
            Some(OptState::Adam(states)) => {
                let (ac, lr) = match cfg.opt {
                    OptChoice::Adam { cfg, lr } => (cfg, lr),
                    _ => unreachable!(),
                };
                for ti in 0..params.len() {
                    adam_step(&ac, lr * lrf, step as u64, &mut params[ti], &grads[ti],
                              &mut states[ti]);
                }
            }
            Some(OptState::Lars(states)) => {
                let (lc, lr) = match cfg.opt {
                    OptChoice::Lars { cfg, lr } => (cfg, lr),
                    _ => unreachable!(),
                };
                for ti in 0..params.len() {
                    lars_step(&lc, lr * lrf, &mut params[ti], &grads[ti], &mut states[ti],
                              is_1d[ti]);
                }
            }
            Some(OptState::Sgd(vels)) => {
                let (lr, mom) = match cfg.opt {
                    OptChoice::Sgd { lr, momentum } => (lr, momentum),
                    _ => unreachable!(),
                };
                for ti in 0..params.len() {
                    sgd_momentum_step(lr * lrf, mom, &mut params[ti], &grads[ti],
                                      &mut vels[ti]);
                }
            }
            None => {
                let lr = match cfg.opt {
                    OptChoice::Adam { lr, .. }
                    | OptChoice::Lars { lr, .. }
                    | OptChoice::Sgd { lr, .. } => lr,
                };
                match sharded.as_mut().expect("wus optimizer") {
                    ShardedOpt::Lars(sl) => sl.step(ep, &group, lr * lrf, &mut params, &grads),
                    ShardedOpt::Adam(sa) => sa.step(ep, &group, lr * lrf, &mut params, &grads),
                    ShardedOpt::Sgd(ss) => ss.step(ep, &group, lr * lrf, &mut params, &grads),
                }
            }
        }
        report.breakdown.update_s += t_u.secs();
        report.breakdown.steps += 1;
        report.step_losses.push(loss);

        // -- distributed evaluation (§2) --
        if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
            let sharding = EvalSharding::new(cfg.eval_examples, world, ctx.batch);
            let res = distributed_eval(ep, &group, &sharding, |chunk| {
                let eb = eval_batch_for(ctx, chunk, &lm_task, &img_task);
                backend
                    .eval_step(&params, &eb, &chunk.mask)
                    .expect("eval execution failed")
            });
            report.evals.push(EvalPoint { step, loss: res.loss, accuracy: res.accuracy });
            if let Some(target) = cfg.quality_target {
                if res.accuracy >= target && report.converged_at.is_none() {
                    report.converged_at = Some(step);
                    break; // synchronous: all workers see the same metric
                }
            }
        }
    }
    report.wallclock_s = wall.secs();
    report.exec_s = backend.execute_seconds();
    Ok(report)
}

/// Build the (deterministic, index-seeded) eval batch for one chunk —
/// every core regenerates the same global example for the same index, so
/// the distributed metrics are independent of the core count.
fn eval_batch_for(
    ctx: &RunCtx,
    chunk: &EvalChunk,
    lm_task: &LmTask,
    img_task: &ImageTask,
) -> StepBatch {
    let eval_seed = ctx.cfg.seed ^ 0x5EED_0000;
    match ctx.kind {
        TaskKind::Lm => {
            let mut tokens = Vec::with_capacity(chunk.indices.len() * ctx.seq);
            let mut targets = Vec::with_capacity(chunk.indices.len() * ctx.seq);
            for &g in &chunk.indices {
                let mut rng = Rng::new(eval_seed).fold_in(g as u64);
                let b = lm_task.batch(&mut rng, 1, ctx.seq);
                tokens.extend(b.tokens);
                targets.extend(b.targets);
            }
            StepBatch::Lm { tokens, targets }
        }
        TaskKind::Image => {
            let dim = ctx.image * ctx.image * 3;
            let mut images = Vec::with_capacity(chunk.indices.len() * dim);
            let mut labels = Vec::with_capacity(chunk.indices.len());
            for &g in &chunk.indices {
                let mut rng = Rng::new(eval_seed).fold_in(g as u64);
                let b = img_task.batch(&mut rng, 1);
                images.extend(b.images);
                labels.extend(b.labels);
            }
            StepBatch::Image { images, labels }
        }
    }
}
