//! The L3 coordinator: a real data-parallel trainer over the in-process
//! pod. Each worker thread owns a PJRT runtime executing the AOT-compiled
//! train/eval steps; the coordinator composes the paper's techniques:
//!
//! * per-core fwd/bwd via the L2/L1 HLO (Python never on this path),
//! * pipelined 2-D gradient summation on real gradient tensors (§2),
//! * replicated or sharded (WUS, §2 Fig. 4) optimizer updates,
//! * the nested train-and-eval tight loop with distributed, padded,
//!   masked evaluation (§2),
//! * MLPerf timing rules (init excluded) via `metrics::RunLog`.

pub mod trainer;

pub use trainer::{train, GradSumMode, OptChoice, TrainConfig, TrainReport};
