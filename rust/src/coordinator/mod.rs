//! The L3 coordinator: a real data-parallel trainer over the in-process
//! pod. Each worker thread owns a fwd/bwd executor — the in-Rust
//! [`crate::runtime::ReferenceBackend`] by default, or a PJRT runtime
//! executing the AOT-compiled train/eval steps — behind the
//! [`crate::runtime::Backend`] boundary; the coordinator composes the
//! paper's techniques:
//!
//! * per-core fwd/bwd with exact analytic gradients (reference executor
//!   in tier-1; the L2/L1 HLO via PJRT when artifacts are available),
//! * pipelined 2-D gradient summation on real gradient tensors (§2),
//! * replicated or sharded (WUS, §2 Fig. 4) optimizer updates —
//!   LARS, Adam and momentum SGD,
//! * the nested train-and-eval tight loop with distributed, padded,
//!   masked evaluation (§2),
//! * MLPerf timing rules (init excluded) via `metrics::RunLog`,
//! * fault-tolerant elastic training: durable v2 checkpoints
//!   (params + optimizer accumulators + per-rank data-RNG states),
//!   bit-identical resume on the reference backend, and injected
//!   [`crate::scenario::FaultTrace`] failures — a chip death rolls back
//!   to the newest checkpoint and restarts on half the cores; the lost
//!   work is reported as goodput (useful steps / executed steps).

pub mod trainer;

pub use trainer::{
    checkpoint_path, train, EvalPoint, GradSumMode, OptChoice, TrainConfig, TrainReport,
};
