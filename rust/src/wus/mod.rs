//! Weight-update sharding (paper §2, Fig. 4):
//!
//! > "When the number of examples per TPU-v3 accelerator core is small, we
//! > observe the optimizer weight update computation results in significant
//! > overheads. For example, with ResNet-50 on 2048 TPU-v3 cores, the LARS
//! > optimizer weight update overhead is about 6% of the total device step
//! > time. In the MLPerf Transformer model, the ADAM optimizer weight update
//! > time is about 45% of the step time. So, we distribute the weight update
//! > computation across TPU-v3 cores, and then use an optimized all-gather
//! > to broadcast the new weights to all the TPU-v3 cores."
//!
//! Each core owns a contiguous, element-balanced shard of the flattened
//! parameter space, keeps optimizer state ONLY for that shard (the memory
//! saving), applies the update there, and an all-gather broadcasts the new
//! weights. LARS needs per-tensor norms, which no single shard can see —
//! they are computed from per-shard partial sums with one small scalar
//! all-reduce, exactly how the XLA implementation distributes them.
//!
//! The simulator side prices this through `costs::WeightUpdatePhase`
//! (one [`ShardPlan`] shard per *participating* core) and reports the
//! plan's `imbalance()` per sweep point via `costs::shard_imbalance`.

use std::ops::Range;

use crate::collectives::{all_reduce_scalars, owned_chunk, ring_all_gather, FlatView};
use crate::fabric::Endpoint;
use crate::optim::{AdamConfig, LarsConfig, LarsVariant};

/// Contiguous, element-balanced shard assignment over the flat parameter
/// space.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub total: usize,
    pub ranges: Vec<Range<usize>>,
    /// Flat offset of each tensor (last entry = total).
    pub offsets: Vec<usize>,
}

impl ShardPlan {
    pub fn balanced(tensor_sizes: &[usize], shards: usize) -> ShardPlan {
        let total: usize = tensor_sizes.iter().sum();
        let mut offsets = Vec::with_capacity(tensor_sizes.len() + 1);
        let mut acc = 0;
        for &s in tensor_sizes {
            offsets.push(acc);
            acc += s;
        }
        offsets.push(acc);
        let ranges = (0..shards)
            .map(|s| crate::collectives::chunk_range(total, shards, s))
            .collect();
        ShardPlan { total, ranges, offsets }
    }

    /// Max shard imbalance: max/min shard elements (≤ total/shards + 1).
    pub fn imbalance(&self) -> f64 {
        let sizes: Vec<usize> = self.ranges.iter().map(|r| r.len()).collect();
        let max = *sizes.iter().max().unwrap_or(&0);
        let min = *sizes.iter().min().unwrap_or(&1).max(&1);
        max as f64 / min as f64
    }

    /// Optimizer-state elements a core must hold, sharded vs replicated.
    pub fn state_elems_sharded(&self, shard: usize) -> usize {
        self.ranges[shard].len()
    }

    /// For tensor `ti`, the overlap of shard range `r` expressed as
    /// (within-tensor range).
    pub fn tensor_overlap(&self, ti: usize, r: &Range<usize>) -> Option<Range<usize>> {
        let t0 = self.offsets[ti];
        let t1 = self.offsets[ti + 1];
        let lo = r.start.max(t0);
        let hi = r.end.min(t1);
        (lo < hi).then(|| lo - t0..hi - t0)
    }
}

/// All-gather one sharded optimizer slot into a full-length vector
/// (identical on every core). The shard plan's ranges coincide with the
/// ring all-gather chunk layout, so this is one in-place ring pass.
/// Used to serialize WUS optimizer state into checkpoint format v2.
pub fn gather_slot(
    ep: &mut Endpoint,
    group: &[usize],
    plan: &ShardPlan,
    shard: usize,
    mine: &[f32],
) -> Vec<f32> {
    debug_assert_eq!(mine.len(), plan.ranges[shard].len());
    let mut staging = vec![0.0f32; plan.total];
    staging[plan.ranges[shard].clone()].copy_from_slice(mine);
    ring_all_gather(ep, group, &mut staging);
    staging
}

/// Slice this core's shard out of a named full-length checkpoint slot.
fn restore_slot(
    plan: &ShardPlan,
    shard: usize,
    slots: &[(String, Vec<f32>)],
    name: &str,
) -> Result<Vec<f32>, String> {
    let full = slots
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, d)| d)
        .ok_or_else(|| format!("checkpoint optimizer state missing slot {name:?}"))?;
    if full.len() != plan.total {
        return Err(format!("slot {name:?}: {} elems, plan needs {}", full.len(), plan.total));
    }
    Ok(full[plan.ranges[shard].clone()].to_vec())
}

/// Sharded LARS: per-core momentum state for its shard only.
pub struct ShardedLars {
    pub cfg: LarsConfig,
    pub plan: ShardPlan,
    pub shard: usize,
    /// Momentum buffer, length = my shard length.
    v: Vec<f32>,
    /// Which tensors are 1-D (exempt from adaptation).
    is_1d: Vec<bool>,
    /// Reused all-gather staging (avoids per-step mmap + page faults).
    staging: Vec<f32>,
}

impl ShardedLars {
    /// `rank` is this core's position in the (rank-ordered) group; the
    /// shard it owns is `owned_chunk(rank)` so the weight broadcast can run
    /// as an in-place ring all-gather (no staging reshuffle).
    pub fn new(cfg: LarsConfig, plan: ShardPlan, rank: usize, is_1d: Vec<bool>) -> ShardedLars {
        let shard = owned_chunk(rank, plan.ranges.len());
        let len = plan.ranges[shard].len();
        let staging = vec![0.0; plan.total];
        ShardedLars { cfg, plan, shard, v: vec![0.0; len], is_1d, staging }
    }

    /// One synchronous sharded step: updates `params` in place on every
    /// core (shard update + all-gather). `grads` must already be summed
    /// across cores (gradient summation happens before WUS).
    pub fn step(
        &mut self,
        ep: &mut Endpoint,
        group: &[usize],
        lr: f32,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
    ) {
        let ntensors = params.len();
        let my_range = self.plan.ranges[self.shard].clone();

        // --- distributed per-tensor norms (f32 partial sums + all-reduce) --
        let mut partials = vec![0.0f32; 2 * ntensors];
        for ti in 0..ntensors {
            if let Some(tr) = self.plan.tensor_overlap(ti, &my_range) {
                let w = &params[ti][tr.clone()];
                let g = &grads[ti][tr];
                partials[2 * ti] = w.iter().map(|x| x * x).sum();
                partials[2 * ti + 1] = g.iter().map(|x| x * x).sum();
            }
        }
        all_reduce_scalars(ep, group, &mut partials);

        // --- update my shard -------------------------------------------
        let beta = self.cfg.weight_decay;
        let m = self.cfg.momentum;
        let mut vi = 0;
        for ti in 0..ntensors {
            if let Some(tr) = self.plan.tensor_overlap(ti, &my_range) {
                let lam = if self.cfg.skip_adaptation_for_1d && self.is_1d[ti] {
                    1.0
                } else {
                    let w_norm = partials[2 * ti].sqrt();
                    let g_norm = partials[2 * ti + 1].sqrt();
                    self.cfg.eta * w_norm / (g_norm + beta * w_norm + 1e-9)
                };
                let w = &mut params[ti][tr.clone()];
                let g = &grads[ti][tr];
                match self.cfg.variant {
                    LarsVariant::Scaled => {
                        for i in 0..w.len() {
                            let upd = g[i] + beta * w[i];
                            self.v[vi] = m * self.v[vi] + upd;
                            w[i] -= lr * lam * self.v[vi];
                            vi += 1;
                        }
                    }
                    LarsVariant::Unscaled => {
                        for i in 0..w.len() {
                            let upd = g[i] + beta * w[i];
                            self.v[vi] = m * self.v[vi] + lr * lam * upd;
                            w[i] -= self.v[vi];
                            vi += 1;
                        }
                    }
                }
            }
        }
        debug_assert_eq!(vi, my_range.len());

        // --- all-gather the fresh weights --------------------------------
        gather_weights(ep, group, &self.plan, self.shard, params, &mut self.staging);
    }

    /// All-gather the full (unsharded) momentum for checkpoint format v2.
    pub fn gather_full_state(
        &self,
        ep: &mut Endpoint,
        group: &[usize],
    ) -> Vec<(String, Vec<f32>)> {
        vec![("velocity".into(), gather_slot(ep, group, &self.plan, self.shard, &self.v))]
    }

    /// Restore this core's shard from full-length checkpoint slots.
    pub fn restore_full_state(&mut self, slots: &[(String, Vec<f32>)]) -> Result<(), String> {
        self.v = restore_slot(&self.plan, self.shard, slots, "velocity")?;
        Ok(())
    }
}

/// Sharded Adam (Transformer's optimizer; the 45%-of-step-time case).
pub struct ShardedAdam {
    pub cfg: AdamConfig,
    pub plan: ShardPlan,
    pub shard: usize,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
    /// Reused all-gather staging (avoids per-step mmap + page faults).
    staging: Vec<f32>,
}

impl ShardedAdam {
    /// See [`ShardedLars::new`] for the `rank` → shard mapping.
    pub fn new(cfg: AdamConfig, plan: ShardPlan, rank: usize) -> ShardedAdam {
        let shard = owned_chunk(rank, plan.ranges.len());
        let len = plan.ranges[shard].len();
        let staging = vec![0.0; plan.total];
        ShardedAdam { cfg, plan, shard, m: vec![0.0; len], v: vec![0.0; len], step: 0, staging }
    }

    pub fn step(
        &mut self,
        ep: &mut Endpoint,
        group: &[usize],
        lr: f32,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
    ) {
        self.step += 1;
        let my_range = self.plan.ranges[self.shard].clone();
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.step as i32);
        let bc2 = 1.0 - b2.powi(self.step as i32);
        let mut si = 0;
        for ti in 0..params.len() {
            if let Some(tr) = self.plan.tensor_overlap(ti, &my_range) {
                let w = &mut params[ti][tr.clone()];
                let g = &grads[ti][tr];
                for i in 0..w.len() {
                    self.m[si] = b1 * self.m[si] + (1.0 - b1) * g[i];
                    self.v[si] = b2 * self.v[si] + (1.0 - b2) * g[i] * g[i];
                    let m_hat = self.m[si] / bc1;
                    let v_hat = self.v[si] / bc2;
                    w[i] -= lr * m_hat / (v_hat.sqrt() + self.cfg.eps);
                    si += 1;
                }
            }
        }
        gather_weights(ep, group, &self.plan, self.shard, params, &mut self.staging);
    }

    /// Adam's bias-correction step counter (for checkpointing).
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Restore the bias-correction counter alongside `restore_full_state`.
    pub fn set_step_count(&mut self, step: u64) {
        self.step = step;
    }

    /// All-gather the full (unsharded) moments for checkpoint format v2.
    pub fn gather_full_state(
        &self,
        ep: &mut Endpoint,
        group: &[usize],
    ) -> Vec<(String, Vec<f32>)> {
        vec![
            ("m".into(), gather_slot(ep, group, &self.plan, self.shard, &self.m)),
            ("v".into(), gather_slot(ep, group, &self.plan, self.shard, &self.v)),
        ]
    }

    /// Restore this core's shard from full-length checkpoint slots.
    pub fn restore_full_state(&mut self, slots: &[(String, Vec<f32>)]) -> Result<(), String> {
        self.m = restore_slot(&self.plan, self.shard, slots, "m")?;
        self.v = restore_slot(&self.plan, self.shard, slots, "v")?;
        Ok(())
    }
}

/// Sharded momentum SGD (the paper's LARS-vs-SGD ablation baseline):
/// per-core velocity state for its shard only, then the weight all-gather.
/// Matches `optim::sgd_momentum_step` exactly.
pub struct ShardedSgd {
    pub momentum: f32,
    pub plan: ShardPlan,
    pub shard: usize,
    v: Vec<f32>,
    /// Reused all-gather staging (avoids per-step mmap + page faults).
    staging: Vec<f32>,
}

impl ShardedSgd {
    /// See [`ShardedLars::new`] for the `rank` → shard mapping.
    pub fn new(momentum: f32, plan: ShardPlan, rank: usize) -> ShardedSgd {
        let shard = owned_chunk(rank, plan.ranges.len());
        let len = plan.ranges[shard].len();
        let staging = vec![0.0; plan.total];
        ShardedSgd { momentum, plan, shard, v: vec![0.0; len], staging }
    }

    pub fn step(
        &mut self,
        ep: &mut Endpoint,
        group: &[usize],
        lr: f32,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
    ) {
        let my_range = self.plan.ranges[self.shard].clone();
        let mut si = 0;
        for ti in 0..params.len() {
            if let Some(tr) = self.plan.tensor_overlap(ti, &my_range) {
                let w = &mut params[ti][tr.clone()];
                let g = &grads[ti][tr];
                for i in 0..w.len() {
                    self.v[si] = self.momentum * self.v[si] + g[i];
                    w[i] -= lr * self.v[si];
                    si += 1;
                }
            }
        }
        debug_assert_eq!(si, my_range.len());
        gather_weights(ep, group, &self.plan, self.shard, params, &mut self.staging);
    }

    /// All-gather the full (unsharded) velocity for checkpoint format v2.
    pub fn gather_full_state(
        &self,
        ep: &mut Endpoint,
        group: &[usize],
    ) -> Vec<(String, Vec<f32>)> {
        vec![("velocity".into(), gather_slot(ep, group, &self.plan, self.shard, &self.v))]
    }

    /// Restore this core's shard from full-length checkpoint slots.
    pub fn restore_full_state(&mut self, slots: &[(String, Vec<f32>)]) -> Result<(), String> {
        self.v = restore_slot(&self.plan, self.shard, slots, "velocity")?;
        Ok(())
    }
}

/// All-gather freshly-updated weight shards back to every core.
///
/// The shard plan's ranges coincide with the ring all-gather's chunk
/// layout (`chunk_range`) and each rank owns `owned_chunk(rank)`, so the
/// broadcast is a single in-place ring all-gather over a flat staging
/// buffer: pack my chunk → ring — incoming chunks land at their final
/// offsets — → unpack everything once.
fn gather_weights(
    ep: &mut Endpoint,
    group: &[usize],
    plan: &ShardPlan,
    shard: usize,
    params: &mut [Vec<f32>],
    staging: &mut [f32],
) {
    debug_assert_eq!(staging.len(), plan.total);
    let my_range = plan.ranges[shard].clone();
    {
        let view = FlatView::new(params.iter_mut().map(|t| t.as_mut_slice()).collect());
        view.pack(my_range.start, my_range.end, &mut staging[my_range.clone()]);
    }
    ring_all_gather(ep, group, staging);
    let mut view = FlatView::new(params.iter_mut().map(|t| t.as_mut_slice()).collect());
    view.unpack(0, plan.total, staging);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::run_spmd;
    use crate::optim::{adam_step, lars_step, AdamState, LarsState};
    use crate::util::rng::Rng;

    fn make_params(seed: u64, sizes: &[usize]) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        sizes.iter().map(|&s| rng.normal_vec(s, 1.0)).collect()
    }

    #[test]
    fn plan_is_balanced_and_covers() {
        let plan = ShardPlan::balanced(&[7, 13, 100, 1], 8);
        assert_eq!(plan.total, 121);
        assert!(plan.imbalance() <= 16.0 / 15.0 + 1e-9);
        let mut covered = 0;
        for r in &plan.ranges {
            assert_eq!(r.start, covered);
            covered = r.end;
        }
        assert_eq!(covered, 121);
    }

    #[test]
    fn tensor_overlap_math() {
        let plan = ShardPlan::balanced(&[4, 4], 2);
        // shard 0 = flat 0..4 = tensor0 entirely
        assert_eq!(plan.tensor_overlap(0, &plan.ranges[0]), Some(0..4));
        assert_eq!(plan.tensor_overlap(1, &plan.ranges[0]), None);
        assert_eq!(plan.tensor_overlap(1, &plan.ranges[1]), Some(0..4));
    }

    #[test]
    fn sharded_state_is_fraction_of_replicated() {
        let plan = ShardPlan::balanced(&[1000, 2000, 3000], 8);
        let per_core = plan.state_elems_sharded(0);
        assert!(per_core <= 6000 / 8 + 1);
    }

    /// The crux: a sharded LARS trajectory must match the single-core
    /// (replicated) implementation exactly, for both variants — sharding is
    /// an execution strategy, not a math change.
    #[test]
    fn sharded_lars_matches_replicated() {
        for variant in [LarsVariant::Scaled, LarsVariant::Unscaled] {
            let sizes = [33usize, 5, 64, 2];
            let world = 4;
            let is_1d = vec![false, true, false, true];
            let cfg = LarsConfig { variant, ..Default::default() };

            // Replicated reference on one core.
            let mut ref_params = make_params(1, &sizes);
            let grads1: Vec<Vec<f32>> = make_params(2, &sizes);
            let grads2: Vec<Vec<f32>> = make_params(3, &sizes);
            let mut states: Vec<LarsState> = sizes.iter().map(|_| LarsState::default()).collect();
            for g in [&grads1, &grads2] {
                for ti in 0..sizes.len() {
                    lars_step(&cfg, 0.05, &mut ref_params[ti], &g[ti], &mut states[ti], is_1d[ti]);
                }
            }

            // Sharded across 4 fabric cores.
            let out = run_spmd(world, |ep| {
                let plan = ShardPlan::balanced(&sizes, world);
                let mut opt = ShardedLars::new(cfg, plan, ep.rank, is_1d.clone());
                let group: Vec<usize> = (0..world).collect();
                let mut params = make_params(1, &sizes);
                let grads1 = make_params(2, &sizes);
                let grads2 = make_params(3, &sizes);
                opt.step(ep, &group, 0.05, &mut params, &grads1);
                opt.step(ep, &group, 0.05, &mut params, &grads2);
                params
            });
            for r in 0..world {
                for ti in 0..sizes.len() {
                    for (a, b) in out[r][ti].iter().zip(&ref_params[ti]) {
                        assert!(
                            (a - b).abs() < 1e-5,
                            "{variant:?} rank {r} tensor {ti}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_adam_matches_replicated() {
        let sizes = [17usize, 40, 3];
        let world = 4;
        let cfg = AdamConfig::default();

        let mut ref_params = make_params(5, &sizes);
        let grads: Vec<Vec<Vec<f32>>> = (0..3).map(|s| make_params(10 + s, &sizes)).collect();
        let mut states: Vec<AdamState> = sizes.iter().map(|_| AdamState::default()).collect();
        for (step, g) in grads.iter().enumerate() {
            for ti in 0..sizes.len() {
                adam_step(&cfg, 1e-2, (step + 1) as u64, &mut ref_params[ti], &g[ti],
                          &mut states[ti]);
            }
        }

        let out = run_spmd(world, |ep| {
            let plan = ShardPlan::balanced(&sizes, world);
            let mut opt = ShardedAdam::new(cfg, plan, ep.rank);
            let group: Vec<usize> = (0..world).collect();
            let mut params = make_params(5, &sizes);
            for s in 0..3 {
                let g = make_params(10 + s, &sizes);
                opt.step(ep, &group, 1e-2, &mut params, &g);
            }
            params
        });
        for r in 0..world {
            for ti in 0..sizes.len() {
                for (a, b) in out[r][ti].iter().zip(&ref_params[ti]) {
                    assert!((a - b).abs() < 1e-5, "rank {r} tensor {ti}");
                }
            }
        }
    }

    #[test]
    fn sharded_sgd_matches_replicated() {
        use crate::optim::sgd_momentum_step;
        let sizes = [19usize, 7, 50];
        let world = 4;

        let mut ref_params = make_params(30, &sizes);
        let mut vels: Vec<Vec<f32>> = sizes.iter().map(|_| vec![]).collect();
        for s in 0..3 {
            let g = make_params(40 + s, &sizes);
            for ti in 0..sizes.len() {
                sgd_momentum_step(0.05, 0.9, &mut ref_params[ti], &g[ti], &mut vels[ti]);
            }
        }

        let out = run_spmd(world, |ep| {
            let plan = ShardPlan::balanced(&sizes, world);
            let mut opt = ShardedSgd::new(0.9, plan, ep.rank);
            let group: Vec<usize> = (0..world).collect();
            let mut params = make_params(30, &sizes);
            for s in 0..3 {
                let g = make_params(40 + s, &sizes);
                opt.step(ep, &group, 0.05, &mut params, &g);
            }
            params
        });
        for r in 0..world {
            for ti in 0..sizes.len() {
                for (a, b) in out[r][ti].iter().zip(&ref_params[ti]) {
                    assert!((a - b).abs() < 1e-5, "rank {r} tensor {ti}: {a} vs {b}");
                }
            }
        }
    }

    /// Checkpoint round trip for sharded state: step, gather the full
    /// moments, rebuild a fresh optimizer from the gathered slots, and the
    /// restored optimizer must continue the trajectory bit-exactly.
    #[test]
    fn adam_state_gather_restore_round_trips() {
        let sizes = [17usize, 40, 3];
        let world = 4;
        let cfg = AdamConfig::default();
        let out = run_spmd(world, |ep| {
            let plan = ShardPlan::balanced(&sizes, world);
            let group: Vec<usize> = (0..world).collect();
            let mut opt = ShardedAdam::new(cfg, plan.clone(), ep.rank);
            let mut params = make_params(50, &sizes);
            let g1 = make_params(51, &sizes);
            opt.step(ep, &group, 1e-2, &mut params, &g1);

            // Snapshot (as the trainer would) and rebuild from it.
            let slots = opt.gather_full_state(ep, &group);
            let mut restored = ShardedAdam::new(cfg, plan, ep.rank);
            restored.restore_full_state(&slots).unwrap();
            restored.set_step_count(opt.step_count());
            assert_eq!(restored.step_count(), 1);

            // Both continue one more step on cloned params: must agree
            // bitwise.
            let g2 = make_params(52, &sizes);
            let mut params2 = params.clone();
            opt.step(ep, &group, 1e-2, &mut params, &g2);
            restored.step(ep, &group, 1e-2, &mut params2, &g2);
            assert_eq!(params, params2, "rank {} restored opt diverged", ep.rank);
            params
        });
        for r in 1..world {
            assert_eq!(out[r], out[0], "rank {r} diverged");
        }
    }

    #[test]
    fn all_cores_agree_after_gather() {
        let sizes = [11usize, 29];
        let world = 8;
        let out = run_spmd(world, |ep| {
            let plan = ShardPlan::balanced(&sizes, world);
            let mut opt = ShardedAdam::new(AdamConfig::default(), plan, ep.rank);
            let group: Vec<usize> = (0..world).collect();
            let mut params = make_params(20, &sizes);
            let g = make_params(21, &sizes);
            opt.step(ep, &group, 1e-2, &mut params, &g);
            params
        });
        for r in 1..world {
            assert_eq!(out[r], out[0], "rank {r} diverged");
        }
    }
}
