//! Artifact manifest: the contract between the AOT pipeline
//! (`python/compile/aot.py`) and the Rust runtime. Parsed from
//! `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Dtypes the AOT pipeline emits.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// One input or output of an artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: BTreeMap<String, String>,
}

/// One named parameter tensor of a model.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    /// model name → ordered parameter specs.
    pub params: BTreeMap<String, Vec<ParamSpec>>,
    /// model name → config key/values (as strings).
    pub configs: BTreeMap<String, BTreeMap<String, String>>,
}

fn io_from_json(j: &Json) -> Result<IoSpec> {
    let name = j.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("io missing name"))?;
    let dtype = Dtype::parse(
        j.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("io missing dtype"))?,
    )?;
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("io missing shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(IoSpec { name: name.to_string(), dtype, shape })
}

fn json_scalar_to_string(j: &Json) -> String {
    match j {
        Json::Str(s) => s.clone(),
        other => other.dump(),
    }
}

impl Manifest {
    /// Load from a directory containing manifest.json.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {path:?} — build the AOT artifacts with \
                 `python python/compile/aot.py` (writes artifacts/, or set ARTIFACTS_DIR)"
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(io_from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(io_from_json)
                .collect::<Result<Vec<_>>>()?;
            let mut meta = BTreeMap::new();
            if let Some(Json::Obj(m)) = a.get("meta") {
                for (k, v) in m {
                    meta.insert(k.clone(), json_scalar_to_string(v));
                }
            }
            artifacts.insert(name.clone(), ArtifactMeta { name, file, inputs, outputs, meta });
        }

        let mut params = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("params") {
            for (model, list) in m {
                let specs = list
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|p| {
                        let name = p
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string();
                        let shape = p
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("param missing shape"))?
                            .iter()
                            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<Vec<_>>>()?;
                        Ok(ParamSpec { name, shape })
                    })
                    .collect::<Result<Vec<_>>>()?;
                params.insert(model.clone(), specs);
            }
        }

        let mut configs = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("configs") {
            for (model, cfg) in m {
                let mut entries = BTreeMap::new();
                if let Json::Obj(c) = cfg {
                    for (k, v) in c {
                        entries.insert(k.clone(), json_scalar_to_string(v));
                    }
                }
                configs.insert(model.clone(), entries);
            }
        }

        Ok(Manifest { dir, artifacts, params, configs })
    }

    /// Default artifacts directory: `$ARTIFACTS_DIR` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("ARTIFACTS_DIR").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }

    pub fn model_params(&self, model: &str) -> Result<&[ParamSpec]> {
        self.params
            .get(model)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Total parameter count of a model.
    pub fn total_params(&self, model: &str) -> Result<usize> {
        Ok(self.model_params(model)?.iter().map(ParamSpec::numel).sum())
    }

    pub fn config_usize(&self, model: &str, key: &str) -> Result<usize> {
        self.configs
            .get(model)
            .and_then(|c| c.get(key))
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| anyhow!("config {model}.{key} missing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
                {"name": "m_train", "file": "m.hlo.txt",
                 "inputs": [{"name": "w", "dtype": "f32", "shape": [4, 2]},
                            {"name": "t", "dtype": "i32", "shape": [8]}],
                 "outputs": [{"name": "loss", "dtype": "f32", "shape": []}],
                 "meta": {"kind": "train_step", "model": "m"}}],
               "params": {"m": [{"name": "w", "shape": [4, 2]}]},
               "configs": {"m": {"batch_per_core": 8, "name": "m"}}}"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("tpt_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let a = m.artifact("m_train").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.inputs[0].numel(), 8);
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(a.meta.get("kind").unwrap(), "train_step");
        assert_eq!(m.total_params("m").unwrap(), 8);
        assert_eq!(m.config_usize("m", "batch_per_core").unwrap(), 8);
        assert!(m.hlo_path("m_train").unwrap().ends_with("m.hlo.txt"));
    }

    #[test]
    fn missing_artifact_is_error() {
        let dir = std::env::temp_dir().join("tpt_manifest_test2");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.model_params("nope").is_err());
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("python/compile/aot.py"));
    }
}
