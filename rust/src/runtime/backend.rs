//! The executor boundary of the trainer: a [`Backend`] turns a parameter
//! set plus one data batch into a loss and exact gradients (train) or
//! masked eval sums (eval). Everything *around* that boundary — the data
//! pipeline, gradient summation, weight-update sharding, optimizers and
//! distributed evaluation — is backend-agnostic coordinator code.
//!
//! Two implementations:
//!
//! * [`crate::runtime::reference::ReferenceBackend`] — the pure-Rust
//!   fwd/bwd executor over the [`crate::models::proxy`] dense proxies; no
//!   artifacts, deterministic, runs in tier-1 CI (`--backend reference`).
//! * [`PjRtBackend`] — the AOT/PJRT path: each worker compiles the
//!   `*_train_*` / `*_eval_*` HLO artifacts once and executes them per
//!   step (`--backend pjrt`; requires `artifacts/` and the real `xla`
//!   binding, see `rust/src/runtime/xla.rs`).

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::{HostTensor, Manifest, Runtime};

/// Which executor the trainer drives.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BackendChoice {
    /// In-Rust reference executor, f32 activations (default).
    Reference,
    /// Reference executor with bf16-rounded activations (paper §2 mixed
    /// precision: bf16 storage, f32 math).
    ReferenceBf16,
    /// AOT artifacts via PJRT.
    PjRt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<BackendChoice> {
        match s {
            "reference" => Ok(BackendChoice::Reference),
            "reference-bf16" => Ok(BackendChoice::ReferenceBf16),
            "pjrt" => Ok(BackendChoice::PjRt),
            other => {
                bail!("unknown backend {other:?} (expected reference | reference-bf16 | pjrt)")
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendChoice::Reference => "reference",
            BackendChoice::ReferenceBf16 => "reference-bf16",
            BackendChoice::PjRt => "pjrt",
        }
    }
}

/// One core's data batch, in the shape the input pipeline produces.
#[derive(Clone, Debug)]
pub enum StepBatch {
    /// `tokens`/`targets` are `[batch * seq]` row-major.
    Lm { tokens: Vec<i32>, targets: Vec<i32> },
    /// `images` is `[batch * side * side * 3]` NHWC, `labels` `[batch]`.
    Image { images: Vec<f32>, labels: Vec<i32> },
}

/// The fwd/bwd executor a trainer worker drives. One instance per worker
/// thread (the PJRT client is `Rc`-based, mirroring per-core executables).
pub trait Backend {
    fn name(&self) -> &'static str;

    /// One forward/backward pass over the local batch. Returns the *mean*
    /// loss over the batch and the gradient of that mean loss per
    /// parameter tensor (manifest/spec order) — ready for cross-core
    /// gradient summation followed by a 1/world rescale.
    fn train_step(&self, params: &[Vec<f32>], batch: &StepBatch) -> Result<(f32, Vec<Vec<f32>>)>;

    /// Masked evaluation over one chunk: `mask[b]` is 1.0 for real
    /// examples and 0.0 for padding slots (paper §2). Returns
    /// `(loss_sum, correct_sum, example_count)` — per-example loss and
    /// accuracy weighted by the mask, so padded slots contribute nothing.
    fn eval_step(
        &self,
        params: &[Vec<f32>],
        batch: &StepBatch,
        mask: &[f32],
    ) -> Result<(f32, f32, f32)>;

    /// Cumulative executor seconds (perf accounting; PJRT execute time or
    /// reference fwd/bwd time).
    fn execute_seconds(&self) -> f64;

    /// Executor seconds split `(forward, backward)` for phase-by-phase
    /// comparison against the simulator's compute attribution. Backends
    /// that cannot attribute (PJRT runs fwd+bwd as one executable) report
    /// everything as forward.
    fn phase_seconds(&self) -> (f64, f64) {
        (self.execute_seconds(), 0.0)
    }
}

/// [`Backend`] over the AOT artifacts: marshals params + batch into the
/// `*_train_*` / `*_eval_*` executables exactly as the artifact manifest
/// specifies (f32 inputs in spec order, i32 inputs after).
pub struct PjRtBackend {
    rt: Runtime,
    train_art: String,
    eval_art: String,
}

impl PjRtBackend {
    /// Build a per-worker runtime, compile (warm) both artifacts. The
    /// [`StepBatch`] variant (not a stored kind) selects the marshalling
    /// order, so the same backend serves both task families.
    pub fn new(manifest_dir: &Path, train_art: &str, eval_art: &str) -> Result<PjRtBackend> {
        let rt = Runtime::with_manifest(Rc::new(Manifest::load(manifest_dir)?))?;
        rt.warmup(&[train_art, eval_art])?;
        Ok(PjRtBackend {
            rt,
            train_art: train_art.to_string(),
            eval_art: eval_art.to_string(),
        })
    }
}

impl Backend for PjRtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn train_step(&self, params: &[Vec<f32>], batch: &StepBatch) -> Result<(f32, Vec<Vec<f32>>)> {
        let mut f32_inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let outputs: Vec<HostTensor> = match batch {
            StepBatch::Lm { tokens, targets } => {
                self.rt.execute_raw(&self.train_art, &f32_inputs, &[tokens, targets])?
            }
            StepBatch::Image { images, labels } => {
                f32_inputs.push(images);
                self.rt.execute_raw(&self.train_art, &f32_inputs, &[labels])?
            }
        };
        let loss = outputs[0].data[0];
        let grads = outputs.into_iter().skip(1).map(|t| t.data).collect();
        Ok((loss, grads))
    }

    fn eval_step(
        &self,
        params: &[Vec<f32>],
        batch: &StepBatch,
        mask: &[f32],
    ) -> Result<(f32, f32, f32)> {
        let mut f32_inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let out = match batch {
            StepBatch::Lm { tokens, targets } => {
                f32_inputs.push(mask);
                self.rt.execute_raw(&self.eval_art, &f32_inputs, &[tokens, targets])?
            }
            StepBatch::Image { images, labels } => {
                f32_inputs.push(images);
                f32_inputs.push(mask);
                self.rt.execute_raw(&self.eval_art, &f32_inputs, &[labels])?
            }
        };
        Ok((out[0].data[0], out[1].data[0], out[2].data[0]))
    }

    fn execute_seconds(&self) -> f64 {
        *self.rt.execute_seconds.borrow()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_choice_round_trips() {
        for s in ["reference", "reference-bf16", "pjrt"] {
            assert_eq!(BackendChoice::parse(s).unwrap().label(), s);
        }
        assert!(BackendChoice::parse("tpu").is_err());
    }
}
