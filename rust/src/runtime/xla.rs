//! Offline stand-in for the `xla` PJRT binding crate.
//!
//! The real binding (xla_extension: `PjRtClient` → `HloModuleProto` →
//! `XlaComputation` → compile → execute) is not part of the offline build
//! closure. This module mirrors the API surface `runtime` uses so the
//! crate compiles and tests without it; [`PjRtClient::cpu`] fails with a
//! clear message, so every artifact-dependent path (the real trainer, the
//! integration tests) reports "PJRT backend not available" instead of a
//! link error, while artifact-independent subsystems (simulator, scenario
//! sweeps, collectives, netsim) never touch it.
//!
//! Re-enabling real execution is a two-line change: delete the
//! `mod xla;` declaration in `runtime/mod.rs` and add the real `xla`
//! crate to rust/Cargo.toml.

use std::path::Path;

/// Error type for every stub operation.
#[derive(Debug)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "PJRT backend not available in this offline build (the `xla` binding crate is \
         not vendored); artifact execution requires the real runtime — see \
         rust/src/runtime/xla.rs"
            .to_string(),
    )
}

/// Stub PJRT client: construction always fails, so nothing downstream of
/// it can be reached.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub HLO module proto (the real one parses HLO text).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Stub XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}
