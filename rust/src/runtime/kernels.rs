//! Blocked/tiled f32 kernels for the reference executor.
//!
//! Every kernel here obeys one contract: **each output element is a sum
//! over its reduction axis in ascending index order**, no matter how the
//! loop nest is tiled and no matter which thread computes it. Tiling
//! reorders the *traversal* (so a `TILE_K`-row block of the weight matrix
//! or a `TILE_N`-row block of the activations stays cache-hot across the
//! rows that reuse it) but never the per-element accumulation sequence —
//! f32 addition is not associative, so that fixed order is what makes the
//! executor bit-deterministic run-to-run, thread-count-invariant, and
//! bit-identical to the pre-tiling scalar loops.
//!
//! The kernels operate on *row spans*: the caller hands each worker a
//! contiguous block of output rows (units for activations, weight-matrix
//! rows for gradients, column ranges for bias sums). Because no two spans
//! overlap and every reduction runs over its full axis inside one kernel
//! call, splitting work across `--exec-threads` needs no cross-thread
//! reduction tree at all — the "tree" is degenerate by construction.
//!
//! Tile sizes are compile-time constants (they are part of the
//! determinism contract only in that they must not depend on the thread
//! count; the accumulation order is tile-size-invariant anyway). 64-row
//! blocks keep a `64 x 512` f32 panel at 128 KiB — inside L2 on anything
//! we run on, the same reasoning as the MXU-feeding 8x128 tiles on the
//! real hardware.

/// Reduction-axis block: rows of `w` (or units of `x`) revisited while a
/// panel is cache-hot.
pub const TILE_K: usize = 64;
/// Unit-axis block for weight-gradient accumulation.
pub const TILE_N: usize = 64;

/// Contiguous span `t` of `n` items split across `threads` workers:
/// the first `n % threads` spans get one extra item. Empty spans (when
/// `n < threads`) are fine — the kernels no-op on them.
pub fn span_of(t: usize, threads: usize, n: usize) -> (usize, usize) {
    let base = n / threads;
    let rem = n % threads;
    let lo = t * base + t.min(rem);
    let hi = lo + base + usize::from(t < rem);
    (lo, hi.min(n))
}

/// All `threads` spans of `n` items, in order.
pub fn spans(n: usize, threads: usize) -> Vec<(usize, usize)> {
    let threads = threads.max(1);
    (0..threads).map(|t| span_of(t, threads, n)).collect()
}

/// `out[r] = bias + x[r] · w` for `rows` rows: `out[r*jdim + j] =
/// bias[j] + Σ_k x[r*kdim + k] · w[k*jdim + j]`, k ascending. Zero inputs
/// skip their row of `w` (a relu-sparsity win; skipping an exact-zero
/// contribution does not change the sum).
pub fn matmul_bias_rows(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    rows: usize,
    kdim: usize,
    jdim: usize,
) {
    debug_assert!(x.len() >= rows * kdim);
    debug_assert_eq!(w.len(), kdim * jdim);
    debug_assert_eq!(bias.len(), jdim);
    debug_assert!(out.len() >= rows * jdim);
    for r in 0..rows {
        out[r * jdim..(r + 1) * jdim].copy_from_slice(bias);
    }
    let mut kb = 0;
    while kb < kdim {
        let kend = (kb + TILE_K).min(kdim);
        for r in 0..rows {
            let xrow = &x[r * kdim..(r + 1) * kdim];
            let orow = &mut out[r * jdim..(r + 1) * jdim];
            for k in kb..kend {
                let xv = xrow[k];
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[k * jdim..(k + 1) * jdim];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        kb = kend;
    }
}

/// `out[r] = dy[r] · wᵀ` for `rows` rows: `out[r*kdim + k] =
/// Σ_j dy[r*jdim + j] · w[k*jdim + j]`, j ascending. The j-axis is
/// blocked so the `dy` row segment and the `w` panel stay hot, but each
/// output element accumulates straight through ascending j.
pub fn matmul_wt_rows(
    dy: &[f32],
    w: &[f32],
    out: &mut [f32],
    rows: usize,
    jdim: usize,
    kdim: usize,
) {
    debug_assert!(dy.len() >= rows * jdim);
    debug_assert_eq!(w.len(), kdim * jdim);
    debug_assert!(out.len() >= rows * kdim);
    out[..rows * kdim].fill(0.0);
    let mut jb = 0;
    while jb < jdim {
        let jend = (jb + TILE_K).min(jdim);
        for r in 0..rows {
            let dyrow = &dy[r * jdim..(r + 1) * jdim];
            let orow = &mut out[r * kdim..(r + 1) * kdim];
            for (k, o) in orow.iter_mut().enumerate() {
                let wrow = &w[k * jdim..(k + 1) * jdim];
                let mut acc = *o;
                for j in jb..jend {
                    acc += dyrow[j] * wrow[j];
                }
                *o = acc;
            }
        }
        jb = jend;
    }
}

/// Weight-gradient rows `k_lo..k_hi` of `gw = xᵀ · dy`:
/// `gw[(k-k_lo)*jdim + j] += Σ_n x[n*kdim + k] · dy[n*jdim + j]`, n
/// ascending (blocked by [`TILE_N`] so the `dy` panel is reused across
/// the span's k rows). `gw` must cover exactly the span and start zeroed
/// (or hold a prior partial — the kernel accumulates).
#[allow(clippy::too_many_arguments)]
pub fn grad_weights_rows(
    x: &[f32],
    dy: &[f32],
    gw: &mut [f32],
    k_lo: usize,
    k_hi: usize,
    kdim: usize,
    jdim: usize,
    n_units: usize,
) {
    debug_assert!(x.len() >= n_units * kdim);
    debug_assert!(dy.len() >= n_units * jdim);
    debug_assert!(gw.len() >= (k_hi - k_lo) * jdim);
    let mut nb = 0;
    while nb < n_units {
        let nend = (nb + TILE_N).min(n_units);
        for k in k_lo..k_hi {
            let grow = &mut gw[(k - k_lo) * jdim..(k - k_lo + 1) * jdim];
            for n in nb..nend {
                let xv = x[n * kdim + k];
                if xv == 0.0 {
                    continue;
                }
                let dyrow = &dy[n * jdim..(n + 1) * jdim];
                for (g, &dv) in grow.iter_mut().zip(dyrow) {
                    *g += xv * dv;
                }
            }
        }
        nb = nend;
    }
}

/// Column-range weighted sum `out[j-j_lo] += Σ_n dy[n*jdim + j] ·
/// x[n*jdim + j]`, n ascending — the LayerNorm scale-gradient kernel
/// (`dscale = Σ dn0 ⊙ xhat`), split by output columns across workers.
pub fn colsum_mul_rows(
    dy: &[f32],
    x: &[f32],
    out: &mut [f32],
    j_lo: usize,
    j_hi: usize,
    jdim: usize,
    n_units: usize,
) {
    debug_assert!(dy.len() >= n_units * jdim);
    debug_assert!(x.len() >= n_units * jdim);
    debug_assert!(out.len() >= j_hi - j_lo);
    for n in 0..n_units {
        let drow = &dy[n * jdim..(n + 1) * jdim];
        let xrow = &x[n * jdim..(n + 1) * jdim];
        for j in j_lo..j_hi {
            out[j - j_lo] += drow[j] * xrow[j];
        }
    }
}

/// Column-range sum `out[j-j_lo] += Σ_n dy[n*jdim + j]`, n ascending —
/// the bias-gradient kernel, split by output columns across workers.
pub fn colsum_rows(
    dy: &[f32],
    out: &mut [f32],
    j_lo: usize,
    j_hi: usize,
    jdim: usize,
    n_units: usize,
) {
    debug_assert!(dy.len() >= n_units * jdim);
    debug_assert!(out.len() >= j_hi - j_lo);
    for n in 0..n_units {
        let row = &dy[n * jdim..(n + 1) * jdim];
        for j in j_lo..j_hi {
            out[j - j_lo] += row[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul_bias(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        n: usize,
        k: usize,
        j: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; n * j];
        for r in 0..n {
            out[r * j..(r + 1) * j].copy_from_slice(b);
            for ki in 0..k {
                let xv = x[r * k + ki];
                if xv == 0.0 {
                    continue;
                }
                for ji in 0..j {
                    out[r * j + ji] += xv * w[ki * j + ji];
                }
            }
        }
        out
    }

    #[test]
    fn spans_partition_exactly() {
        for n in [0, 1, 5, 17, 64, 1000] {
            for t in [1, 2, 3, 7, 16] {
                let sp = spans(n, t);
                assert_eq!(sp.len(), t);
                assert_eq!(sp[0].0, 0);
                assert_eq!(sp[t - 1].1, n);
                for w in sp.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "spans must tile {n} over {t}");
                }
            }
        }
    }

    /// The crux of the determinism contract: tiled accumulation order is
    /// per-element ascending, i.e. bit-identical to the plain scalar loop
    /// — not merely close.
    #[test]
    fn tiled_matmul_bitwise_matches_naive_order() {
        let (n, k, j) = (13, TILE_K + 9, 37); // force multiple k-blocks
        let mut rng = Rng::new(7);
        let mut x = rng.normal_vec(n * k, 1.0);
        for v in x.iter_mut().step_by(3) {
            *v = 0.0; // exercise the sparsity skip
        }
        let w = rng.normal_vec(k * j, 0.5);
        let b = rng.normal_vec(j, 0.1);
        let expected = naive_matmul_bias(&x, &w, &b, n, k, j);
        let mut out = vec![0.0f32; n * j];
        matmul_bias_rows(&x, &w, &b, &mut out, n, k, j);
        assert_eq!(out, expected, "tiled kernel must keep ascending-k accumulation");
    }

    #[test]
    fn wt_kernel_matches_naive_dot() {
        let (n, jdim, kdim) = (9, TILE_K + 5, 31);
        let mut rng = Rng::new(8);
        let dy = rng.normal_vec(n * jdim, 1.0);
        let w = rng.normal_vec(kdim * jdim, 0.5);
        let mut expected = vec![0.0f32; n * kdim];
        for r in 0..n {
            for k in 0..kdim {
                let mut acc = 0.0f32;
                for j in 0..jdim {
                    acc += dy[r * jdim + j] * w[k * jdim + j];
                }
                expected[r * kdim + k] = acc;
            }
        }
        let mut out = vec![1.0f32; n * kdim]; // kernel must overwrite, not accumulate into garbage
        matmul_wt_rows(&dy, &w, &mut out, n, jdim, kdim);
        assert_eq!(out, expected);
    }

    #[test]
    fn grad_kernel_span_split_is_exact() {
        let (n, kdim, jdim) = (TILE_N + 11, 23, 17);
        let mut rng = Rng::new(9);
        let mut x = rng.normal_vec(n * kdim, 1.0);
        for v in x.iter_mut().step_by(5) {
            *v = 0.0;
        }
        let dy = rng.normal_vec(n * jdim, 1.0);
        // Whole-matrix reference: units ascending per element.
        let mut full = vec![0.0f32; kdim * jdim];
        grad_weights_rows(&x, &dy, &mut full, 0, kdim, kdim, jdim, n);
        // Span-split (as --exec-threads does): must reassemble bitwise.
        for threads in [2, 3, 5] {
            let mut pieced = vec![0.0f32; kdim * jdim];
            for (lo, hi) in spans(kdim, threads) {
                let span = &mut pieced[lo * jdim..hi * jdim];
                grad_weights_rows(&x, &dy, span, lo, hi, kdim, jdim, n);
            }
            assert_eq!(pieced, full, "row-span split must be bit-exact at {threads} threads");
        }
    }

    #[test]
    fn colsum_span_split_is_exact() {
        let (n, jdim) = (40, 29);
        let mut rng = Rng::new(10);
        let dy = rng.normal_vec(n * jdim, 1.0);
        let mut full = vec![0.0f32; jdim];
        colsum_rows(&dy, &mut full, 0, jdim, jdim, n);
        for threads in [2, 4, 31] {
            let mut pieced = vec![0.0f32; jdim];
            for (lo, hi) in spans(jdim, threads) {
                colsum_rows(&dy, &mut pieced[lo..hi], lo, hi, jdim, n);
            }
            assert_eq!(pieced, full);
        }
    }
}
