//! `runtime::reference` — the in-Rust forward/backward executor.
//!
//! A miniature dense network per registry model (see
//! [`crate::models::proxy`]): an embedding/dense input layer, a ReLU, a
//! BN-ish learned normalization, a dense trunk layer, and a softmax
//! cross-entropy head — with *exact analytic gradients* computed in f32
//! (optionally with bf16-rounded activation storage, the paper's §2
//! mixed-precision rule: 16-bit storage, 32-bit math).
//!
//! The normalization is per-example over the feature axis (a LayerNorm).
//! Batch-statistics BN would couple examples, so padded/masked eval slots
//! and the chunking of the distributed evaluation would change the
//! metrics; per-example statistics keep eval results exactly independent
//! of core count and padding — the invariance `evaluation` promises.
//!
//! Everything is sequential, allocation-order deterministic f32: two runs
//! of the same [`crate::coordinator::TrainConfig`] produce bit-identical
//! loss curves (pinned by the integration suite). This is what lets the
//! live trainer run — and be CI-gated — with no AOT artifacts.
//!
//! Layer stack (`N` units = examples, or `batch * seq` positions for LM):
//!
//! ```text
//! x [N, in] ──fc0.w/b──► h0 [N, H] ──relu──► a0
//!   a0 ──layernorm·norm.scale+norm.bias──► n0
//!   n0 ──fc1.w/b──► h1 ──relu──► a1
//!   a1 ──out.w/b──► logits [N, C] ──softmax CE──► loss
//! ```
//!
//! For LM the input is the one-hot of the current token, so `fc0.w` is the
//! embedding table and the first matmul is a row lookup (same math, no
//! materialized one-hot).

use std::cell::Cell;

use anyhow::{anyhow, bail, Result};

use crate::models::proxy::{proxy_dims, ProxyDims, TaskKind};
use crate::runtime::backend::{Backend, StepBatch};
use crate::runtime::ParamSpec;
use crate::util::bf16::Bf16;
use crate::util::timer::Timer;

/// Activation storage precision (math is always f32).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Precision {
    F32,
    Bf16,
}

const LN_EPS: f32 = 1e-5;

// Parameter tensor order (must match `param_specs_for`).
const W0: usize = 0;
const B0: usize = 1;
const SCALE: usize = 2;
const BIAS: usize = 3;
const W1: usize = 4;
const B1: usize = 5;
const W2: usize = 6;
const B2: usize = 7;

/// The reference executor for one model proxy.
pub struct ReferenceBackend {
    dims: ProxyDims,
    specs: Vec<ParamSpec>,
    precision: Precision,
    execute_seconds: Cell<f64>,
}

/// Parameter specs of a proxy, in executor order. Names follow the
/// trainer's init conventions: `.scale` starts at one, `.bias`/`.b` at
/// zero, matrices at fan-in-scaled normal.
pub fn param_specs_for(dims: &ProxyDims) -> Vec<ParamSpec> {
    let (input, hidden, out) = (dims.input_dim(), dims.hidden, dims.output_dim());
    vec![
        ParamSpec { name: "fc0.w".into(), shape: vec![input, hidden] },
        ParamSpec { name: "fc0.b".into(), shape: vec![hidden] },
        ParamSpec { name: "norm.scale".into(), shape: vec![hidden] },
        ParamSpec { name: "norm.bias".into(), shape: vec![hidden] },
        ParamSpec { name: "fc1.w".into(), shape: vec![hidden, hidden] },
        ParamSpec { name: "fc1.b".into(), shape: vec![hidden] },
        ParamSpec { name: "out.w".into(), shape: vec![hidden, out] },
        ParamSpec { name: "out.b".into(), shape: vec![out] },
    ]
}

/// Result of one fwd(/bwd) pass, mask-weighted.
struct PassOut {
    loss_sum: f32,
    correct_sum: f32,
    /// Σ mask (examples) — the eval `count`; equals the unit-weight sum
    /// divided by `seq` only for LM, so it is tracked separately.
    examples: f32,
    grads: Option<Vec<Vec<f32>>>,
}

impl ReferenceBackend {
    /// Resolve a model key via the proxy registry.
    pub fn new(model: &str, precision: Precision) -> Result<ReferenceBackend> {
        let dims = proxy_dims(model).ok_or_else(|| {
            anyhow!(
                "no reference proxy for model {model:?} (known families: {})",
                crate::models::proxy::known_families()
            )
        })?;
        Ok(ReferenceBackend::with_dims(dims, precision))
    }

    /// Build directly from dims (tests use tiny custom shapes).
    pub fn with_dims(dims: ProxyDims, precision: Precision) -> ReferenceBackend {
        let specs = param_specs_for(&dims);
        ReferenceBackend { dims, specs, precision, execute_seconds: Cell::new(0.0) }
    }

    pub fn specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    pub fn dims(&self) -> &ProxyDims {
        &self.dims
    }

    fn round(&self, xs: &mut [f32]) {
        if self.precision == Precision::Bf16 {
            for x in xs.iter_mut() {
                *x = Bf16::from_f32(*x).to_f32();
            }
        }
    }

    fn check_params(&self, params: &[Vec<f32>]) -> Result<()> {
        if params.len() != self.specs.len() {
            bail!("expected {} parameter tensors, got {}", self.specs.len(), params.len());
        }
        for (p, s) in params.iter().zip(&self.specs) {
            if p.len() != s.numel() {
                bail!("param {} has {} elements, expected {:?}", s.name, p.len(), s.shape);
            }
        }
        Ok(())
    }

    /// The full forward(/backward) pass. `mask` is per-example (1.0 real /
    /// 0.0 padding); `None` means train mode (every unit weight 1). When
    /// `want_grads`, returns gradients of the *mean* loss over the
    /// weighted units.
    fn pass(
        &self,
        params: &[Vec<f32>],
        batch: &StepBatch,
        mask: Option<&[f32]>,
        want_grads: bool,
    ) -> Result<PassOut> {
        self.check_params(params)?;
        let t0 = Timer::start();
        let d = &self.dims;
        let (h, c) = (d.hidden, d.output_dim());

        // ---- resolve the batch into N units + per-unit weights ----------
        let (n_units, targets): (usize, &[i32]) = match (batch, d.kind) {
            (StepBatch::Lm { tokens, targets }, TaskKind::Lm) => {
                if tokens.len() != targets.len() {
                    bail!("LM batch: {} tokens vs {} targets", tokens.len(), targets.len());
                }
                if d.seq == 0 || tokens.len() % d.seq != 0 {
                    bail!("LM batch length {} not a multiple of seq {}", tokens.len(), d.seq);
                }
                for &t in tokens.iter().chain(targets.iter()) {
                    if t < 0 || t as usize >= d.vocab {
                        bail!("token {t} outside vocab 0..{}", d.vocab);
                    }
                }
                (tokens.len(), targets)
            }
            (StepBatch::Image { images, labels }, TaskKind::Image) => {
                let dim = d.input_dim();
                if images.len() != labels.len() * dim {
                    bail!(
                        "image batch: {} pixels vs {} labels x {dim}",
                        images.len(),
                        labels.len()
                    );
                }
                for &l in labels {
                    if l < 0 || l as usize >= d.classes {
                        bail!("label {l} outside classes 0..{}", d.classes);
                    }
                }
                (labels.len(), labels)
            }
            _ => bail!("batch kind does not match the {} proxy", d.family),
        };
        let batch_examples = match d.kind {
            TaskKind::Lm => n_units / d.seq,
            TaskKind::Image => n_units,
        };
        if let Some(m) = mask {
            if m.len() != batch_examples {
                bail!("mask has {} entries for {batch_examples} examples", m.len());
            }
        }
        // Per-unit weight: example mask, spread over seq positions for LM.
        let unit_weight = |unit: usize| -> f32 {
            let example = match d.kind {
                TaskKind::Lm => unit / d.seq,
                TaskKind::Image => unit,
            };
            let m = mask.map(|m| m[example]).unwrap_or(1.0);
            match d.kind {
                TaskKind::Lm => m / d.seq as f32,
                TaskKind::Image => m,
            }
        };
        let weight_total: f32 = (0..n_units).map(&unit_weight).sum();
        let examples: f32 = match mask {
            Some(m) => m.iter().sum(),
            None => batch_examples as f32,
        };

        // ---- forward ----------------------------------------------------
        // h0 = x . fc0.w + fc0.b (embedding row lookup for LM)
        let mut a0 = vec![0.0f32; n_units * h];
        match batch {
            StepBatch::Lm { tokens, .. } => {
                for (unit, &t) in tokens.iter().enumerate() {
                    let row = &params[W0][t as usize * h..(t as usize + 1) * h];
                    let out = &mut a0[unit * h..(unit + 1) * h];
                    for ((o, &w), &b) in out.iter_mut().zip(row).zip(&params[B0]) {
                        *o = w + b;
                    }
                }
            }
            StepBatch::Image { images, .. } => {
                let dim = d.input_dim();
                for unit in 0..n_units {
                    let x = &images[unit * dim..(unit + 1) * dim];
                    let out = &mut a0[unit * h..(unit + 1) * h];
                    out.copy_from_slice(&params[B0]);
                    for (k, &xv) in x.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &params[W0][k * h..(k + 1) * h];
                        for (o, &w) in out.iter_mut().zip(wrow) {
                            *o += xv * w;
                        }
                    }
                }
            }
        }
        // relu in place; a0 > 0 later doubles as the h0 > 0 mask.
        for x in a0.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        self.round(&mut a0);

        // Per-example LayerNorm: xhat = (a0 - mu) / sqrt(var + eps).
        let mut xhat = vec![0.0f32; n_units * h];
        let mut inv = vec![0.0f32; n_units];
        let mut n0 = vec![0.0f32; n_units * h];
        for unit in 0..n_units {
            let row = &a0[unit * h..(unit + 1) * h];
            let mu = row.iter().sum::<f32>() / h as f32;
            let var = row.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / h as f32;
            let iv = 1.0 / (var + LN_EPS).sqrt();
            inv[unit] = iv;
            let xh = &mut xhat[unit * h..(unit + 1) * h];
            let no = &mut n0[unit * h..(unit + 1) * h];
            for j in 0..h {
                xh[j] = (row[j] - mu) * iv;
                no[j] = xh[j] * params[SCALE][j] + params[BIAS][j];
            }
        }
        self.round(&mut n0);

        // h1 = n0 . fc1.w + fc1.b; a1 = relu(h1)
        let mut a1 = vec![0.0f32; n_units * h];
        for unit in 0..n_units {
            let x = &n0[unit * h..(unit + 1) * h];
            let out = &mut a1[unit * h..(unit + 1) * h];
            out.copy_from_slice(&params[B1]);
            for (k, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &params[W1][k * h..(k + 1) * h];
                for (o, &w) in out.iter_mut().zip(wrow) {
                    *o += xv * w;
                }
            }
        }
        for x in a1.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        self.round(&mut a1);

        // logits = a1 . out.w + out.b
        let mut logits = vec![0.0f32; n_units * c];
        for unit in 0..n_units {
            let x = &a1[unit * h..(unit + 1) * h];
            let out = &mut logits[unit * c..(unit + 1) * c];
            out.copy_from_slice(&params[B2]);
            for (k, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &params[W2][k * c..(k + 1) * c];
                for (o, &w) in out.iter_mut().zip(wrow) {
                    *o += xv * w;
                }
            }
        }
        self.round(&mut logits);

        // Softmax cross-entropy + top-1, mask-weighted.
        let mut probs = vec![0.0f32; n_units * c];
        let mut loss_sum = 0.0f32;
        let mut correct_sum = 0.0f32;
        for unit in 0..n_units {
            let row = &logits[unit * c..(unit + 1) * c];
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut argmax = 0;
            for (j, &x) in row.iter().enumerate() {
                if x > row[argmax] {
                    argmax = j;
                }
                probs[unit * c + j] = (x - max).exp();
            }
            let denom: f32 = probs[unit * c..(unit + 1) * c].iter().sum();
            for p in probs[unit * c..(unit + 1) * c].iter_mut() {
                *p /= denom;
            }
            let y = targets[unit] as usize;
            let w = unit_weight(unit);
            loss_sum += w * -(probs[unit * c + y] + 1e-12).ln();
            if argmax == y {
                correct_sum += w;
            }
        }

        if !want_grads {
            self.execute_seconds.set(self.execute_seconds.get() + t0.secs());
            return Ok(PassOut { loss_sum, correct_sum, examples, grads: None });
        }

        // ---- backward (gradient of loss_sum / weight_total) -------------
        let denom = weight_total.max(1e-12);
        let mut grads: Vec<Vec<f32>> =
            self.specs.iter().map(|s| vec![0.0f32; s.numel()]).collect();

        // dlogits = (softmax - onehot) * w / denom
        let mut dlogits = probs; // reuse
        for unit in 0..n_units {
            let w = unit_weight(unit) / denom;
            let y = targets[unit] as usize;
            let row = &mut dlogits[unit * c..(unit + 1) * c];
            row[y] -= 1.0;
            for x in row.iter_mut() {
                *x *= w;
            }
        }

        // out layer backward: dW2 = a1^T dlogits, db2 = sum dlogits,
        // da1 = dlogits . W2^T
        let mut dh1 = vec![0.0f32; n_units * h];
        {
            let (dw2, db2s) = {
                let (left, right) = grads.split_at_mut(B2);
                (&mut left[W2], &mut right[0])
            };
            for unit in 0..n_units {
                let dl = &dlogits[unit * c..(unit + 1) * c];
                let a = &a1[unit * h..(unit + 1) * h];
                for (db, &dv) in db2s.iter_mut().zip(dl) {
                    *db += dv;
                }
                let dh = &mut dh1[unit * h..(unit + 1) * h];
                for (k, &av) in a.iter().enumerate() {
                    let wrow = &params[W2][k * c..(k + 1) * c];
                    let gw = &mut dw2[k * c..(k + 1) * c];
                    let mut acc = 0.0f32;
                    for j in 0..c {
                        if av != 0.0 {
                            gw[j] += av * dl[j];
                        }
                        acc += dl[j] * wrow[j];
                    }
                    // relu mask: a1 == 0 means h1 <= 0.
                    dh[k] = if av > 0.0 { acc } else { 0.0 };
                }
            }
        }

        // trunk layer backward: dW1 = n0^T dh1, db1 = sum dh1,
        // dn0 = dh1 . W1^T
        let mut dn0 = vec![0.0f32; n_units * h];
        {
            let (dw1, db1s) = {
                let (left, right) = grads.split_at_mut(B1);
                (&mut left[W1], &mut right[0])
            };
            for unit in 0..n_units {
                let dh = &dh1[unit * h..(unit + 1) * h];
                let x = &n0[unit * h..(unit + 1) * h];
                for (db, &dv) in db1s.iter_mut().zip(dh) {
                    *db += dv;
                }
                let dn = &mut dn0[unit * h..(unit + 1) * h];
                for (k, &xv) in x.iter().enumerate() {
                    let wrow = &params[W1][k * h..(k + 1) * h];
                    let gw = &mut dw1[k * h..(k + 1) * h];
                    let mut acc = 0.0f32;
                    for j in 0..h {
                        if xv != 0.0 {
                            gw[j] += xv * dh[j];
                        }
                        acc += dh[j] * wrow[j];
                    }
                    dn[k] = acc;
                }
            }
        }

        // LayerNorm backward (per example row):
        // dscale = Σ dn0*xhat, dbias = Σ dn0, dxhat = dn0*scale,
        // da0 = inv/H (H dxhat − Σdxhat − xhat Σ(dxhat·xhat))
        let mut da0 = vec![0.0f32; n_units * h];
        {
            let (dscale, dbias) = {
                let (left, right) = grads.split_at_mut(BIAS);
                (&mut left[SCALE], &mut right[0])
            };
            let hf = h as f32;
            for unit in 0..n_units {
                let dn = &dn0[unit * h..(unit + 1) * h];
                let xh = &xhat[unit * h..(unit + 1) * h];
                let mut s1 = 0.0f32;
                let mut s2 = 0.0f32;
                for j in 0..h {
                    dscale[j] += dn[j] * xh[j];
                    dbias[j] += dn[j];
                    let dxh = dn[j] * params[SCALE][j];
                    s1 += dxh;
                    s2 += dxh * xh[j];
                }
                let da = &mut da0[unit * h..(unit + 1) * h];
                let iv = inv[unit] / hf;
                for j in 0..h {
                    let dxh = dn[j] * params[SCALE][j];
                    da[j] = iv * (hf * dxh - s1 - xh[j] * s2);
                }
            }
        }

        // relu mask for layer 0, then input layer backward.
        for (da, &av) in da0.iter_mut().zip(&a0) {
            if av <= 0.0 {
                *da = 0.0;
            }
        }
        {
            let (dw0, db0s) = {
                let (left, right) = grads.split_at_mut(B0);
                (&mut left[W0], &mut right[0])
            };
            match batch {
                StepBatch::Lm { tokens, .. } => {
                    for (unit, &t) in tokens.iter().enumerate() {
                        let da = &da0[unit * h..(unit + 1) * h];
                        let gw = &mut dw0[t as usize * h..(t as usize + 1) * h];
                        for j in 0..h {
                            gw[j] += da[j];
                            db0s[j] += da[j];
                        }
                    }
                }
                StepBatch::Image { images, .. } => {
                    let dim = d.input_dim();
                    for unit in 0..n_units {
                        let da = &da0[unit * h..(unit + 1) * h];
                        let x = &images[unit * dim..(unit + 1) * dim];
                        for (db, &dv) in db0s.iter_mut().zip(da) {
                            *db += dv;
                        }
                        for (k, &xv) in x.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            let gw = &mut dw0[k * h..(k + 1) * h];
                            for (g, &dv) in gw.iter_mut().zip(da) {
                                *g += xv * dv;
                            }
                        }
                    }
                }
            }
        }

        self.execute_seconds.set(self.execute_seconds.get() + t0.secs());
        Ok(PassOut { loss_sum, correct_sum, examples, grads: Some(grads) })
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        match self.precision {
            Precision::F32 => "reference",
            Precision::Bf16 => "reference-bf16",
        }
    }

    fn train_step(&self, params: &[Vec<f32>], batch: &StepBatch) -> Result<(f32, Vec<Vec<f32>>)> {
        let out = self.pass(params, batch, None, true)?;
        // Unit weights sum to the example count for both families (LM
        // positions carry weight 1/seq), so this is the batch-mean loss.
        let loss = out.loss_sum / out.examples.max(1e-12);
        Ok((loss, out.grads.expect("grads requested")))
    }

    fn eval_step(
        &self,
        params: &[Vec<f32>],
        batch: &StepBatch,
        mask: &[f32],
    ) -> Result<(f32, f32, f32)> {
        let out = self.pass(params, batch, Some(mask), false)?;
        Ok((out.loss_sum, out.correct_sum, out.examples))
    }

    fn execute_seconds(&self) -> f64 {
        self.execute_seconds.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_image_dims() -> ProxyDims {
        ProxyDims {
            family: "cnn",
            kind: TaskKind::Image,
            hidden: 6,
            batch_per_core: 4,
            vocab: 0,
            seq: 0,
            image: 2, // input_dim = 12
            classes: 5,
        }
    }

    fn tiny_lm_dims() -> ProxyDims {
        ProxyDims {
            family: "transformer",
            kind: TaskKind::Lm,
            hidden: 6,
            batch_per_core: 2,
            vocab: 7,
            seq: 3,
            image: 0,
            classes: 0,
        }
    }

    fn init(specs: &[ParamSpec], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        specs
            .iter()
            .map(|s| {
                if s.name.ends_with(".scale") {
                    vec![1.0; s.numel()]
                } else if s.name.ends_with(".bias") || s.name.ends_with(".b") {
                    vec![0.0; s.numel()]
                } else {
                    let fan_in = s.shape[..s.shape.len() - 1].iter().product::<usize>().max(1);
                    rng.normal_vec(s.numel(), (1.0 / fan_in as f32).sqrt())
                }
            })
            .collect()
    }

    fn image_batch(dims: &ProxyDims, n: usize, seed: u64) -> StepBatch {
        let mut rng = Rng::new(seed);
        let dim = dims.input_dim();
        let images = rng.normal_vec(n * dim, 1.0);
        let labels = (0..n).map(|_| rng.below(dims.classes as u64) as i32).collect();
        StepBatch::Image { images, labels }
    }

    fn lm_batch(dims: &ProxyDims, batch: usize, seed: u64) -> StepBatch {
        let mut rng = Rng::new(seed);
        let n = batch * dims.seq;
        let tokens: Vec<i32> = (0..n).map(|_| rng.below(dims.vocab as u64) as i32).collect();
        let targets: Vec<i32> =
            tokens.iter().map(|&t| ((5 * t as i64 + 3) % dims.vocab as i64) as i32).collect();
        StepBatch::Lm { tokens, targets }
    }

    #[test]
    fn specs_follow_trainer_init_conventions() {
        let dims = proxy_dims("transformer").unwrap();
        let specs = param_specs_for(&dims);
        assert_eq!(specs.len(), 8);
        assert_eq!(specs[W0].shape, vec![dims.vocab, dims.hidden]);
        assert_eq!(specs[SCALE].name, "norm.scale");
        assert!(specs[BIAS].name.ends_with(".bias"));
        assert!(specs[B0].name.ends_with(".b"));
        assert_eq!(specs[W2].shape, vec![dims.hidden, dims.vocab]);
        let total: usize = specs.iter().map(ParamSpec::numel).sum();
        assert!(total > 10_000, "transformer proxy should be MLP-scale, got {total}");
    }

    /// The crux: analytic gradients must match central finite differences
    /// of the f32 forward pass, for both task families.
    #[test]
    fn analytic_grads_match_finite_differences() {
        for (dims, batch) in [
            (tiny_image_dims(), image_batch(&tiny_image_dims(), 4, 11)),
            (tiny_lm_dims(), lm_batch(&tiny_lm_dims(), 2, 12)),
        ] {
            let be = ReferenceBackend::with_dims(dims, Precision::F32);
            let mut params = init(be.specs(), 3);
            let (_, grads) = be.train_step(&params, &batch).unwrap();
            let eps = 5e-3f32;
            let mut rng = Rng::new(99);
            for ti in 0..params.len() {
                let n = params[ti].len();
                for _ in 0..n.min(8) {
                    let i = rng.below(n as u64) as usize;
                    let orig = params[ti][i];
                    params[ti][i] = orig + eps;
                    let (lp, _) = be.train_step(&params, &batch).unwrap();
                    params[ti][i] = orig - eps;
                    let (lm, _) = be.train_step(&params, &batch).unwrap();
                    params[ti][i] = orig;
                    let num = (lp - lm) / (2.0 * eps);
                    let ana = grads[ti][i];
                    assert!(
                        (num - ana).abs() < 1e-3 + 0.05 * num.abs(),
                        "{} tensor {ti}[{i}]: numeric {num} vs analytic {ana}",
                        be.dims().family
                    );
                }
            }
        }
    }

    #[test]
    fn bf16_grads_stay_close_to_f32() {
        let dims = tiny_image_dims();
        let f32_be = ReferenceBackend::with_dims(dims, Precision::F32);
        let bf_be = ReferenceBackend::with_dims(dims, Precision::Bf16);
        let params = init(f32_be.specs(), 5);
        let batch = image_batch(&dims, 8, 21);
        let (l32, g32) = f32_be.train_step(&params, &batch).unwrap();
        let (l16, g16) = bf_be.train_step(&params, &batch).unwrap();
        assert!((l32 - l16).abs() < 0.05 * l32.abs() + 1e-3, "loss {l32} vs {l16}");
        for (a, b) in g32.iter().zip(&g16) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 2e-3 + 0.05 * x.abs(), "grad {x} vs {y}");
            }
        }
    }

    #[test]
    fn masked_eval_slots_contribute_nothing() {
        let dims = tiny_image_dims();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let params = init(be.specs(), 7);
        let full = image_batch(&dims, 3, 31);
        let (li, ci, ni) = be.eval_step(&params, &full, &[1.0, 1.0, 0.0]).unwrap();
        // The same first two examples, no padding.
        let (images, labels) = match &full {
            StepBatch::Image { images, labels } => {
                (images[..2 * dims.input_dim()].to_vec(), labels[..2].to_vec())
            }
            _ => unreachable!(),
        };
        let trimmed = StepBatch::Image { images, labels };
        let (lt, ct, nt) = be.eval_step(&params, &trimmed, &[1.0, 1.0]).unwrap();
        assert_eq!(ni, 2.0);
        assert_eq!(nt, 2.0);
        assert_eq!(li, lt, "masked loss must equal the unpadded loss bitwise");
        assert_eq!(ci, ct);
    }

    #[test]
    fn passes_are_bitwise_deterministic() {
        let dims = tiny_lm_dims();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let params = init(be.specs(), 9);
        let batch = lm_batch(&dims, 4, 41);
        let (l1, g1) = be.train_step(&params, &batch).unwrap();
        let (l2, g2) = be.train_step(&params, &batch).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
    }

    #[test]
    fn adam_on_the_proxy_learns_the_planted_image_task() {
        use crate::data::synthetic::ImageTask;
        use crate::optim::{adam_step, AdamConfig, AdamState};
        let dims = proxy_dims("ssd").unwrap();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let mut params = init(be.specs(), 1);
        let task = ImageTask::new(dims.image, dims.classes, 2.0, 0xEEE);
        let mut rng = Rng::new(0);
        let mut states: Vec<AdamState> = be.specs().iter().map(|_| AdamState::default()).collect();
        let cfg = AdamConfig::default();
        let mut losses = Vec::new();
        for step in 1..=30u64 {
            let b = task.batch(&mut rng, 16);
            let batch = StepBatch::Image { images: b.images, labels: b.labels };
            let (loss, grads) = be.train_step(&params, &batch).unwrap();
            losses.push(loss);
            for ti in 0..params.len() {
                adam_step(&cfg, 3e-3, step, &mut params[ti], &grads[ti], &mut states[ti]);
            }
        }
        let first = losses[..5].iter().sum::<f32>() / 5.0;
        let last = losses[25..].iter().sum::<f32>() / 5.0;
        assert!(last < first * 0.5, "loss should halve: first {first:.3} last {last:.3}");
    }

    #[test]
    fn bad_inputs_are_clean_errors() {
        let dims = tiny_lm_dims();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let params = init(be.specs(), 2);
        // Token outside the vocab.
        let batch = StepBatch::Lm { tokens: vec![99; 3], targets: vec![0; 3] };
        assert!(be.train_step(&params, &batch).is_err());
        // Wrong batch kind for the proxy family.
        let batch = StepBatch::Image { images: vec![0.0; 12], labels: vec![0] };
        assert!(be.train_step(&params, &batch).is_err());
        // Wrong parameter shape.
        let mut bad = params.clone();
        bad[0].pop();
        let batch = lm_batch(&dims, 1, 1);
        assert!(be.train_step(&bad, &batch).is_err());
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(ReferenceBackend::new("bert_large", Precision::F32).is_err());
    }
}
