//! `runtime::reference` — the in-Rust forward/backward executor.
//!
//! A miniature dense network per registry model (see
//! [`crate::models::proxy`]): an embedding/dense input layer, a ReLU, a
//! BN-ish learned normalization, a dense trunk layer, and a softmax
//! cross-entropy head — with *exact analytic gradients* computed in f32
//! (optionally with bf16-rounded activation storage, the paper's §2
//! mixed-precision rule: 16-bit storage, 32-bit math).
//!
//! The normalization is per-example over the feature axis (a LayerNorm).
//! Batch-statistics BN would couple examples, so padded/masked eval slots
//! and the chunking of the distributed evaluation would change the
//! metrics; per-example statistics keep eval results exactly independent
//! of core count and padding — the invariance `evaluation` promises.
//!
//! Two kernel paths share the pass (selected by [`KernelMode`]):
//!
//! * **Tiled** (default) — the blocked kernels of
//!   [`crate::runtime::kernels`] over workspaces reused across steps, with
//!   an optional intra-core thread split (`--exec-threads`). Every
//!   parallel stage splits *disjoint output rows* across workers and each
//!   element still accumulates over its full reduction axis in ascending
//!   order, so the output is bit-identical for any thread count —
//!   including 1 — and bit-identical to the naive path. See
//!   `runtime/README.md` § Performance for the determinism contract.
//! * **Naive** — the original fused scalar loops, kept verbatim as the
//!   measurable pre-tiling baseline (`BENCH_backend.json`) and as the
//!   bit-parity oracle for the tiled path.
//!
//! Either way the executor is allocation-order deterministic f32: two
//! runs of the same [`crate::coordinator::TrainConfig`] produce
//! bit-identical loss curves (pinned by the integration suite). This is
//! what lets the live trainer run — and be CI-gated — with no AOT
//! artifacts.
//!
//! Layer stack (`N` units = examples, or `batch * seq` positions for LM):
//!
//! ```text
//! x [N, in] ──fc0.w/b──► h0 [N, H] ──relu──► a0
//!   a0 ──layernorm·norm.scale+norm.bias──► n0
//!   n0 ──fc1.w/b──► h1 ──relu──► a1
//!   a1 ──out.w/b──► logits [N, C] ──softmax CE──► loss
//! ```
//!
//! For LM the input is the one-hot of the current token, so `fc0.w` is the
//! embedding table and the first matmul is a row lookup (same math, no
//! materialized one-hot).

use std::cell::{Cell, RefCell};

use anyhow::{anyhow, bail, Result};

use crate::models::proxy::{proxy_dims, ProxyDims, TaskKind};
use crate::runtime::backend::{Backend, StepBatch};
use crate::runtime::kernels::{
    colsum_mul_rows, colsum_rows, grad_weights_rows, matmul_bias_rows, matmul_wt_rows, spans,
};
use crate::runtime::ParamSpec;
use crate::util::bf16::Bf16;
use crate::util::timer::Timer;

/// Activation storage precision (math is always f32).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Precision {
    F32,
    Bf16,
}

/// Which executor implementation a [`ReferenceBackend`] runs. Both
/// produce bit-identical results (pinned in tests); they differ only in
/// wall-clock.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelMode {
    /// The pre-tiling fused scalar loops with per-step allocation — the
    /// perf baseline `BENCH_backend.json` speedups are measured against.
    Naive,
    /// Blocked kernels + workspace reuse + optional `--exec-threads`
    /// intra-core split (the default).
    Tiled,
}

const LN_EPS: f32 = 1e-5;

// Parameter tensor order (must match `param_specs_for`).
const W0: usize = 0;
const B0: usize = 1;
const SCALE: usize = 2;
const BIAS: usize = 3;
const W1: usize = 4;
const B1: usize = 5;
const W2: usize = 6;
const B2: usize = 7;

/// The reference executor for one model proxy.
pub struct ReferenceBackend {
    dims: ProxyDims,
    specs: Vec<ParamSpec>,
    precision: Precision,
    mode: KernelMode,
    threads: usize,
    ws: RefCell<Workspace>,
    fwd_seconds: Cell<f64>,
    bwd_seconds: Cell<f64>,
}

/// Parameter specs of a proxy, in executor order. Names follow the
/// trainer's init conventions: `.scale` starts at one, `.bias`/`.b` at
/// zero, matrices at fan-in-scaled normal.
pub fn param_specs_for(dims: &ProxyDims) -> Vec<ParamSpec> {
    let (input, hidden, out) = (dims.input_dim(), dims.hidden, dims.output_dim());
    vec![
        ParamSpec { name: "fc0.w".into(), shape: vec![input, hidden] },
        ParamSpec { name: "fc0.b".into(), shape: vec![hidden] },
        ParamSpec { name: "norm.scale".into(), shape: vec![hidden] },
        ParamSpec { name: "norm.bias".into(), shape: vec![hidden] },
        ParamSpec { name: "fc1.w".into(), shape: vec![hidden, hidden] },
        ParamSpec { name: "fc1.b".into(), shape: vec![hidden] },
        ParamSpec { name: "out.w".into(), shape: vec![hidden, out] },
        ParamSpec { name: "out.b".into(), shape: vec![out] },
    ]
}

/// Result of one fwd(/bwd) pass, mask-weighted.
struct PassOut {
    loss_sum: f32,
    correct_sum: f32,
    /// Σ mask (examples) — the eval `count`; equals the unit-weight sum
    /// divided by `seq` only for LM, so it is tracked separately.
    examples: f32,
    grads: Option<Vec<Vec<f32>>>,
}

/// Pass buffers reused across steps (tiled path). Every region in use is
/// fully overwritten each pass, so `resize` (which keeps capacity) is the
/// only per-step bookkeeping — no per-step allocation on the hot path.
#[derive(Default)]
struct Workspace {
    a0: Vec<f32>,
    xhat: Vec<f32>,
    inv: Vec<f32>,
    n0: Vec<f32>,
    a1: Vec<f32>,
    /// Logits, then softmax probabilities, then dlogits — in place.
    probs: Vec<f32>,
    losses: Vec<f32>,
    correct: Vec<f32>,
    dh1: Vec<f32>,
    dn0: Vec<f32>,
    da0: Vec<f32>,
}

/// Per-unit loss weight (example mask, spread over seq positions for LM).
/// `Copy + Sync` so stage closures can use it from worker threads.
#[derive(Clone, Copy)]
struct UnitWeight<'a> {
    kind: TaskKind,
    seq: usize,
    mask: Option<&'a [f32]>,
}

impl UnitWeight<'_> {
    fn w(&self, unit: usize) -> f32 {
        let example = match self.kind {
            TaskKind::Lm => unit / self.seq,
            TaskKind::Image => unit,
        };
        let m = self.mask.map(|m| m[example]).unwrap_or(1.0);
        match self.kind {
            TaskKind::Lm => m / self.seq as f32,
            TaskKind::Image => m,
        }
    }
}

fn round_slice(precision: Precision, xs: &mut [f32]) {
    if precision == Precision::Bf16 {
        for x in xs.iter_mut() {
            *x = Bf16::from_f32(*x).to_f32();
        }
    }
}

/// Split `buf` into the per-worker row spans (spans must partition
/// `0..rows` in order, as [`spans`] produces).
fn split_rows<'a>(
    buf: &'a mut [f32],
    spans: &[(usize, usize)],
    row: usize,
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(spans.len());
    let mut rest = buf;
    for &(lo, hi) in spans {
        let (head, tail) = rest.split_at_mut((hi - lo) * row);
        out.push(head);
        rest = tail;
    }
    out
}

/// Run one slab of work per worker. A single slab runs inline; otherwise
/// one scoped thread per slab (`std::thread::scope`, the `SweepRunner`
/// pattern). Slabs own disjoint `&mut` output rows, so no synchronization
/// and no cross-thread reduction exist — which is exactly why the result
/// cannot depend on the thread count.
fn run_slabs<S: Send>(slabs: Vec<S>, work: impl Fn(S) + Sync) {
    if slabs.len() <= 1 {
        for s in slabs {
            work(s);
        }
        return;
    }
    std::thread::scope(|scope| {
        let w = &work;
        for s in slabs {
            scope.spawn(move || w(s));
        }
    });
}

/// One worker's slice of the forward pass: a contiguous unit range and
/// the matching rows of every activation buffer.
struct FwdSlab<'a> {
    lo: usize,
    hi: usize,
    a0: &'a mut [f32],
    xhat: &'a mut [f32],
    inv: &'a mut [f32],
    n0: &'a mut [f32],
    a1: &'a mut [f32],
    probs: &'a mut [f32],
    losses: &'a mut [f32],
    correct: &'a mut [f32],
}

/// One worker's slice of the data-gradient stage (dlogits → da0).
struct BwdSlab<'a> {
    lo: usize,
    hi: usize,
    probs: &'a mut [f32],
    dh1: &'a mut [f32],
    dn0: &'a mut [f32],
    da0: &'a mut [f32],
    a0: &'a [f32],
    xhat: &'a [f32],
    inv: &'a [f32],
    a1: &'a [f32],
}

/// One worker's slice of every gradient tensor: weight-matrix *rows*
/// (contiguous in row-major) and bias/norm column ranges.
struct GradSlab<'a> {
    /// Input-dim row range of `dW0` (vocab rows for LM).
    k0: (usize, usize),
    /// Hidden range: rows of `dW1`/`dW2`, columns of `db0`/`db1`/`dscale`/`dbias`.
    kh: (usize, usize),
    /// Class/vocab-out column range of `db2`.
    kc: (usize, usize),
    dw0: &'a mut [f32],
    db0: &'a mut [f32],
    dscale: &'a mut [f32],
    dbias: &'a mut [f32],
    dw1: &'a mut [f32],
    db1: &'a mut [f32],
    dw2: &'a mut [f32],
    db2: &'a mut [f32],
}

impl ReferenceBackend {
    /// Resolve a model key via the proxy registry.
    pub fn new(model: &str, precision: Precision) -> Result<ReferenceBackend> {
        let dims = proxy_dims(model).ok_or_else(|| {
            anyhow!(
                "no reference proxy for model {model:?} (known families: {})",
                crate::models::proxy::known_families()
            )
        })?;
        Ok(ReferenceBackend::with_dims(dims, precision))
    }

    /// Build directly from dims (tests use tiny custom shapes). Tiled
    /// kernels, single-threaded.
    pub fn with_dims(dims: ProxyDims, precision: Precision) -> ReferenceBackend {
        ReferenceBackend::with_options(dims, precision, KernelMode::Tiled, 1)
    }

    /// Full constructor. `threads == 0` means auto (one per available
    /// hardware thread); the result does not depend on the choice — only
    /// wall-clock does.
    pub fn with_options(
        dims: ProxyDims,
        precision: Precision,
        mode: KernelMode,
        threads: usize,
    ) -> ReferenceBackend {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        let specs = param_specs_for(&dims);
        ReferenceBackend {
            dims,
            specs,
            precision,
            mode,
            threads,
            ws: RefCell::new(Workspace::default()),
            fwd_seconds: Cell::new(0.0),
            bwd_seconds: Cell::new(0.0),
        }
    }

    pub fn specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    pub fn dims(&self) -> &ProxyDims {
        &self.dims
    }

    pub fn exec_threads(&self) -> usize {
        self.threads
    }

    pub fn kernel_mode(&self) -> KernelMode {
        self.mode
    }

    fn check_params(&self, params: &[Vec<f32>]) -> Result<()> {
        if params.len() != self.specs.len() {
            bail!("expected {} parameter tensors, got {}", self.specs.len(), params.len());
        }
        for (p, s) in params.iter().zip(&self.specs) {
            if p.len() != s.numel() {
                bail!("param {} has {} elements, expected {:?}", s.name, p.len(), s.shape);
            }
        }
        Ok(())
    }

    /// The full forward(/backward) pass. `mask` is per-example (1.0 real /
    /// 0.0 padding); `None` means train mode (every unit weight 1). When
    /// `want_grads`, returns gradients of the *mean* loss over the
    /// weighted units.
    fn pass(
        &self,
        params: &[Vec<f32>],
        batch: &StepBatch,
        mask: Option<&[f32]>,
        want_grads: bool,
    ) -> Result<PassOut> {
        self.check_params(params)?;
        let d = &self.dims;

        // ---- resolve the batch into N units + per-unit weights ----------
        let (n_units, targets): (usize, &[i32]) = match (batch, d.kind) {
            (StepBatch::Lm { tokens, targets }, TaskKind::Lm) => {
                if tokens.len() != targets.len() {
                    bail!("LM batch: {} tokens vs {} targets", tokens.len(), targets.len());
                }
                if d.seq == 0 || tokens.len() % d.seq != 0 {
                    bail!("LM batch length {} not a multiple of seq {}", tokens.len(), d.seq);
                }
                for &t in tokens.iter().chain(targets.iter()) {
                    if t < 0 || t as usize >= d.vocab {
                        bail!("token {t} outside vocab 0..{}", d.vocab);
                    }
                }
                (tokens.len(), targets)
            }
            (StepBatch::Image { images, labels }, TaskKind::Image) => {
                let dim = d.input_dim();
                if images.len() != labels.len() * dim {
                    bail!(
                        "image batch: {} pixels vs {} labels x {dim}",
                        images.len(),
                        labels.len()
                    );
                }
                for &l in labels {
                    if l < 0 || l as usize >= d.classes {
                        bail!("label {l} outside classes 0..{}", d.classes);
                    }
                }
                (labels.len(), labels)
            }
            _ => bail!("batch kind does not match the {} proxy", d.family),
        };
        let batch_examples = match d.kind {
            TaskKind::Lm => n_units / d.seq,
            TaskKind::Image => n_units,
        };
        if let Some(m) = mask {
            if m.len() != batch_examples {
                bail!("mask has {} entries for {batch_examples} examples", m.len());
            }
        }
        let uw = UnitWeight { kind: d.kind, seq: d.seq, mask };
        let weight_total: f32 = (0..n_units).map(|u| uw.w(u)).sum();
        let examples: f32 = match mask {
            Some(m) => m.iter().sum(),
            None => batch_examples as f32,
        };

        match self.mode {
            KernelMode::Naive => self.pass_naive(
                params,
                batch,
                targets,
                n_units,
                uw,
                weight_total,
                examples,
                want_grads,
            ),
            KernelMode::Tiled => self.pass_tiled(
                params,
                batch,
                targets,
                n_units,
                uw,
                weight_total,
                examples,
                want_grads,
            ),
        }
    }

    /// Tiled kernels over reused workspaces, optionally split across
    /// `self.threads` workers. Three spawn points per train pass (forward,
    /// data gradients, weight gradients), one for eval; each splits
    /// disjoint output rows, so the bits never depend on the split.
    #[allow(clippy::too_many_arguments)]
    fn pass_tiled(
        &self,
        params: &[Vec<f32>],
        batch: &StepBatch,
        targets: &[i32],
        n_units: usize,
        uw: UnitWeight,
        weight_total: f32,
        examples: f32,
        want_grads: bool,
    ) -> Result<PassOut> {
        let d = self.dims;
        let (h, c) = (d.hidden, d.output_dim());
        let in_dim = d.input_dim();
        let threads = self.threads.max(1);
        let precision = self.precision;

        let t_fwd = Timer::start();
        let mut ws_guard = self.ws.borrow_mut();
        let ws = &mut *ws_guard;
        ws.a0.resize(n_units * h, 0.0);
        ws.xhat.resize(n_units * h, 0.0);
        ws.inv.resize(n_units, 0.0);
        ws.n0.resize(n_units * h, 0.0);
        ws.a1.resize(n_units * h, 0.0);
        ws.probs.resize(n_units * c, 0.0);
        ws.losses.resize(n_units, 0.0);
        ws.correct.resize(n_units, 0.0);
        if want_grads {
            ws.dh1.resize(n_units * h, 0.0);
            ws.dn0.resize(n_units * h, 0.0);
            ws.da0.resize(n_units * h, 0.0);
        }
        let Workspace { a0, xhat, inv, n0, a1, probs, losses, correct, dh1, dn0, da0 } = ws;
        let unit_spans = spans(n_units, threads);

        // ---- forward ----------------------------------------------------
        {
            let mut a0s = split_rows(&mut a0[..], &unit_spans, h).into_iter();
            let mut xhs = split_rows(&mut xhat[..], &unit_spans, h).into_iter();
            let mut ivs = split_rows(&mut inv[..], &unit_spans, 1).into_iter();
            let mut n0s = split_rows(&mut n0[..], &unit_spans, h).into_iter();
            let mut a1s = split_rows(&mut a1[..], &unit_spans, h).into_iter();
            let mut prs = split_rows(&mut probs[..], &unit_spans, c).into_iter();
            let mut lss = split_rows(&mut losses[..], &unit_spans, 1).into_iter();
            let mut crs = split_rows(&mut correct[..], &unit_spans, 1).into_iter();
            let mut slabs = Vec::with_capacity(unit_spans.len());
            for &(lo, hi) in &unit_spans {
                slabs.push(FwdSlab {
                    lo,
                    hi,
                    a0: a0s.next().unwrap(),
                    xhat: xhs.next().unwrap(),
                    inv: ivs.next().unwrap(),
                    n0: n0s.next().unwrap(),
                    a1: a1s.next().unwrap(),
                    probs: prs.next().unwrap(),
                    losses: lss.next().unwrap(),
                    correct: crs.next().unwrap(),
                });
            }
            run_slabs(slabs, |slab: FwdSlab| {
                let rows = slab.hi - slab.lo;
                if rows == 0 {
                    return;
                }
                // h0 = x . fc0.w + fc0.b (embedding row lookup for LM)
                match batch {
                    StepBatch::Lm { tokens, .. } => {
                        for (r, &t) in tokens[slab.lo..slab.hi].iter().enumerate() {
                            let row = &params[W0][t as usize * h..(t as usize + 1) * h];
                            let out = &mut slab.a0[r * h..(r + 1) * h];
                            for ((o, &w), &b) in out.iter_mut().zip(row).zip(&params[B0]) {
                                *o = w + b;
                            }
                        }
                    }
                    StepBatch::Image { images, .. } => {
                        matmul_bias_rows(
                            &images[slab.lo * in_dim..slab.hi * in_dim],
                            &params[W0],
                            &params[B0],
                            slab.a0,
                            rows,
                            in_dim,
                            h,
                        );
                    }
                }
                // relu in place; a0 > 0 later doubles as the h0 > 0 mask.
                for x in slab.a0.iter_mut() {
                    if *x < 0.0 {
                        *x = 0.0;
                    }
                }
                round_slice(precision, slab.a0);

                // Per-example LayerNorm: xhat = (a0 - mu) / sqrt(var + eps).
                for r in 0..rows {
                    let row = &slab.a0[r * h..(r + 1) * h];
                    let mu = row.iter().sum::<f32>() / h as f32;
                    let var = row.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / h as f32;
                    let iv = 1.0 / (var + LN_EPS).sqrt();
                    slab.inv[r] = iv;
                    let xh = &mut slab.xhat[r * h..(r + 1) * h];
                    let no = &mut slab.n0[r * h..(r + 1) * h];
                    for j in 0..h {
                        xh[j] = (row[j] - mu) * iv;
                        no[j] = xh[j] * params[SCALE][j] + params[BIAS][j];
                    }
                }
                round_slice(precision, slab.n0);

                // h1 = n0 . fc1.w + fc1.b; a1 = relu(h1)
                matmul_bias_rows(slab.n0, &params[W1], &params[B1], slab.a1, rows, h, h);
                for x in slab.a1.iter_mut() {
                    if *x < 0.0 {
                        *x = 0.0;
                    }
                }
                round_slice(precision, slab.a1);

                // logits = a1 . out.w + out.b (into the probs buffer)
                matmul_bias_rows(slab.a1, &params[W2], &params[B2], slab.probs, rows, h, c);
                round_slice(precision, slab.probs);

                // Softmax in place + per-unit CE loss and top-1 marker
                // (weights applied in the serial reduction below).
                for r in 0..rows {
                    let row = &mut slab.probs[r * c..(r + 1) * c];
                    let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                    let mut argmax = 0;
                    for (j, &x) in row.iter().enumerate() {
                        if x > row[argmax] {
                            argmax = j;
                        }
                    }
                    for x in row.iter_mut() {
                        *x = (*x - max).exp();
                    }
                    let denom: f32 = row.iter().sum();
                    for p in row.iter_mut() {
                        *p /= denom;
                    }
                    let y = targets[slab.lo + r] as usize;
                    slab.losses[r] = -(row[y] + 1e-12).ln();
                    slab.correct[r] = if argmax == y { 1.0 } else { 0.0 };
                }
            });
        }

        // Loss/accuracy reduction: serial, unit-ascending — the one place
        // units meet, so it stays on the calling thread.
        let mut loss_sum = 0.0f32;
        let mut correct_sum = 0.0f32;
        for unit in 0..n_units {
            let w = uw.w(unit);
            loss_sum += w * losses[unit];
            if correct[unit] != 0.0 {
                correct_sum += w;
            }
        }
        self.fwd_seconds.set(self.fwd_seconds.get() + t_fwd.secs());

        if !want_grads {
            return Ok(PassOut { loss_sum, correct_sum, examples, grads: None });
        }

        // ---- backward (gradient of loss_sum / weight_total) -------------
        let t_bwd = Timer::start();
        let denom = weight_total.max(1e-12);

        // Stage 1: data gradients, unit rows split across workers.
        {
            let mut prs = split_rows(&mut probs[..], &unit_spans, c).into_iter();
            let mut dhs = split_rows(&mut dh1[..], &unit_spans, h).into_iter();
            let mut dns = split_rows(&mut dn0[..], &unit_spans, h).into_iter();
            let mut das = split_rows(&mut da0[..], &unit_spans, h).into_iter();
            let mut slabs = Vec::with_capacity(unit_spans.len());
            for &(lo, hi) in &unit_spans {
                slabs.push(BwdSlab {
                    lo,
                    hi,
                    probs: prs.next().unwrap(),
                    dh1: dhs.next().unwrap(),
                    dn0: dns.next().unwrap(),
                    da0: das.next().unwrap(),
                    a0: &a0[lo * h..hi * h],
                    xhat: &xhat[lo * h..hi * h],
                    inv: &inv[lo..hi],
                    a1: &a1[lo * h..hi * h],
                });
            }
            run_slabs(slabs, |slab: BwdSlab| {
                let rows = slab.hi - slab.lo;
                if rows == 0 {
                    return;
                }
                // dlogits = (softmax - onehot) * w / denom, in place.
                for r in 0..rows {
                    let w = uw.w(slab.lo + r) / denom;
                    let y = targets[slab.lo + r] as usize;
                    let row = &mut slab.probs[r * c..(r + 1) * c];
                    row[y] -= 1.0;
                    for x in row.iter_mut() {
                        *x *= w;
                    }
                }
                // da1 = dlogits . W2^T, relu-masked to dh1 (a1 == 0 ⇒ h1 <= 0).
                matmul_wt_rows(slab.probs, &params[W2], slab.dh1, rows, c, h);
                for (dh, &av) in slab.dh1.iter_mut().zip(slab.a1) {
                    if av <= 0.0 {
                        *dh = 0.0;
                    }
                }
                // dn0 = dh1 . W1^T (no mask: the norm output has no relu).
                matmul_wt_rows(slab.dh1, &params[W1], slab.dn0, rows, h, h);
                // LayerNorm backward (per example row):
                // dxhat = dn0*scale, da0 = inv/H (H dxhat − Σdxhat − xhat Σ(dxhat·xhat))
                let hf = h as f32;
                for r in 0..rows {
                    let dn = &slab.dn0[r * h..(r + 1) * h];
                    let xh = &slab.xhat[r * h..(r + 1) * h];
                    let mut s1 = 0.0f32;
                    let mut s2 = 0.0f32;
                    for j in 0..h {
                        let dxh = dn[j] * params[SCALE][j];
                        s1 += dxh;
                        s2 += dxh * xh[j];
                    }
                    let da = &mut slab.da0[r * h..(r + 1) * h];
                    let iv = slab.inv[r] / hf;
                    for j in 0..h {
                        let dxh = dn[j] * params[SCALE][j];
                        da[j] = iv * (hf * dxh - s1 - xh[j] * s2);
                    }
                }
                // relu mask for layer 0.
                for (da, &av) in slab.da0.iter_mut().zip(slab.a0) {
                    if av <= 0.0 {
                        *da = 0.0;
                    }
                }
            });
        }

        // Stage 2: weight gradients. Each worker owns disjoint weight-matrix
        // *rows* and bias *columns* of every tensor, and its kernels reduce
        // over all units ascending — so the unit reduction never crosses a
        // thread boundary.
        let mut grads: Vec<Vec<f32>> =
            self.specs.iter().map(|s| vec![0.0f32; s.numel()]).collect();
        {
            let h_spans = spans(h, threads);
            let c_spans = spans(c, threads);
            let in_spans = spans(in_dim, threads);
            let [gw0, gb0, gsc, gbi, gw1, gb1, gw2, gb2] = &mut grads[..] else {
                unreachable!("proxy has 8 parameter tensors");
            };
            let mut w0s = split_rows(gw0, &in_spans, h).into_iter();
            let mut b0s = split_rows(gb0, &h_spans, 1).into_iter();
            let mut scs = split_rows(gsc, &h_spans, 1).into_iter();
            let mut bis = split_rows(gbi, &h_spans, 1).into_iter();
            let mut w1s = split_rows(gw1, &h_spans, h).into_iter();
            let mut b1s = split_rows(gb1, &h_spans, 1).into_iter();
            let mut w2s = split_rows(gw2, &h_spans, c).into_iter();
            let mut b2s = split_rows(gb2, &c_spans, 1).into_iter();
            let mut slabs = Vec::with_capacity(threads);
            for t in 0..threads {
                slabs.push(GradSlab {
                    k0: in_spans[t],
                    kh: h_spans[t],
                    kc: c_spans[t],
                    dw0: w0s.next().unwrap(),
                    db0: b0s.next().unwrap(),
                    dscale: scs.next().unwrap(),
                    dbias: bis.next().unwrap(),
                    dw1: w1s.next().unwrap(),
                    db1: b1s.next().unwrap(),
                    dw2: w2s.next().unwrap(),
                    db2: b2s.next().unwrap(),
                });
            }
            let (a1r, probsr, n0r, dh1r) = (&a1[..], &probs[..], &n0[..], &dh1[..]);
            let (dn0r, xhatr, da0r) = (&dn0[..], &xhat[..], &da0[..]);
            run_slabs(slabs, |g: GradSlab| {
                let (klo, khi) = g.kh;
                // out layer: dW2 = a1^T dlogits, db2 = Σ dlogits
                grad_weights_rows(a1r, probsr, g.dw2, klo, khi, h, c, n_units);
                colsum_rows(probsr, g.db2, g.kc.0, g.kc.1, c, n_units);
                // trunk: dW1 = n0^T dh1, db1 = Σ dh1
                grad_weights_rows(n0r, dh1r, g.dw1, klo, khi, h, h, n_units);
                colsum_rows(dh1r, g.db1, klo, khi, h, n_units);
                // norm: dscale = Σ dn0 ⊙ xhat, dbias = Σ dn0
                colsum_mul_rows(dn0r, xhatr, g.dscale, klo, khi, h, n_units);
                colsum_rows(dn0r, g.dbias, klo, khi, h, n_units);
                // input layer: dW0 = x^T da0 (token-row scatter for LM),
                // db0 = Σ da0
                match batch {
                    StepBatch::Lm { tokens, .. } => {
                        let (tlo, thi) = g.k0;
                        for (unit, &t) in tokens.iter().enumerate() {
                            let t = t as usize;
                            if t < tlo || t >= thi {
                                continue;
                            }
                            let da = &da0r[unit * h..(unit + 1) * h];
                            let gw = &mut g.dw0[(t - tlo) * h..(t - tlo + 1) * h];
                            for (gv, &dv) in gw.iter_mut().zip(da) {
                                *gv += dv;
                            }
                        }
                    }
                    StepBatch::Image { images, .. } => {
                        grad_weights_rows(images, da0r, g.dw0, g.k0.0, g.k0.1, in_dim, h, n_units);
                    }
                }
                colsum_rows(da0r, g.db0, klo, khi, h, n_units);
            });
        }

        self.bwd_seconds.set(self.bwd_seconds.get() + t_bwd.secs());
        Ok(PassOut { loss_sum, correct_sum, examples, grads: Some(grads) })
    }

    /// The pre-tiling fused scalar pass, kept verbatim: the baseline that
    /// `BENCH_backend.json` speedups are measured against, and the
    /// bit-parity oracle for `pass_tiled`.
    #[allow(clippy::too_many_arguments)]
    fn pass_naive(
        &self,
        params: &[Vec<f32>],
        batch: &StepBatch,
        targets: &[i32],
        n_units: usize,
        uw: UnitWeight,
        weight_total: f32,
        examples: f32,
        want_grads: bool,
    ) -> Result<PassOut> {
        let d = &self.dims;
        let (h, c) = (d.hidden, d.output_dim());
        let t_fwd = Timer::start();

        // ---- forward ----------------------------------------------------
        // h0 = x . fc0.w + fc0.b (embedding row lookup for LM)
        let mut a0 = vec![0.0f32; n_units * h];
        match batch {
            StepBatch::Lm { tokens, .. } => {
                for (unit, &t) in tokens.iter().enumerate() {
                    let row = &params[W0][t as usize * h..(t as usize + 1) * h];
                    let out = &mut a0[unit * h..(unit + 1) * h];
                    for ((o, &w), &b) in out.iter_mut().zip(row).zip(&params[B0]) {
                        *o = w + b;
                    }
                }
            }
            StepBatch::Image { images, .. } => {
                let dim = d.input_dim();
                for unit in 0..n_units {
                    let x = &images[unit * dim..(unit + 1) * dim];
                    let out = &mut a0[unit * h..(unit + 1) * h];
                    out.copy_from_slice(&params[B0]);
                    for (k, &xv) in x.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &params[W0][k * h..(k + 1) * h];
                        for (o, &w) in out.iter_mut().zip(wrow) {
                            *o += xv * w;
                        }
                    }
                }
            }
        }
        // relu in place; a0 > 0 later doubles as the h0 > 0 mask.
        for x in a0.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        round_slice(self.precision, &mut a0);

        // Per-example LayerNorm: xhat = (a0 - mu) / sqrt(var + eps).
        let mut xhat = vec![0.0f32; n_units * h];
        let mut inv = vec![0.0f32; n_units];
        let mut n0 = vec![0.0f32; n_units * h];
        for unit in 0..n_units {
            let row = &a0[unit * h..(unit + 1) * h];
            let mu = row.iter().sum::<f32>() / h as f32;
            let var = row.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / h as f32;
            let iv = 1.0 / (var + LN_EPS).sqrt();
            inv[unit] = iv;
            let xh = &mut xhat[unit * h..(unit + 1) * h];
            let no = &mut n0[unit * h..(unit + 1) * h];
            for j in 0..h {
                xh[j] = (row[j] - mu) * iv;
                no[j] = xh[j] * params[SCALE][j] + params[BIAS][j];
            }
        }
        round_slice(self.precision, &mut n0);

        // h1 = n0 . fc1.w + fc1.b; a1 = relu(h1)
        let mut a1 = vec![0.0f32; n_units * h];
        for unit in 0..n_units {
            let x = &n0[unit * h..(unit + 1) * h];
            let out = &mut a1[unit * h..(unit + 1) * h];
            out.copy_from_slice(&params[B1]);
            for (k, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &params[W1][k * h..(k + 1) * h];
                for (o, &w) in out.iter_mut().zip(wrow) {
                    *o += xv * w;
                }
            }
        }
        for x in a1.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        round_slice(self.precision, &mut a1);

        // logits = a1 . out.w + out.b
        let mut logits = vec![0.0f32; n_units * c];
        for unit in 0..n_units {
            let x = &a1[unit * h..(unit + 1) * h];
            let out = &mut logits[unit * c..(unit + 1) * c];
            out.copy_from_slice(&params[B2]);
            for (k, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &params[W2][k * c..(k + 1) * c];
                for (o, &w) in out.iter_mut().zip(wrow) {
                    *o += xv * w;
                }
            }
        }
        round_slice(self.precision, &mut logits);

        // Softmax cross-entropy + top-1, mask-weighted.
        let mut probs = vec![0.0f32; n_units * c];
        let mut loss_sum = 0.0f32;
        let mut correct_sum = 0.0f32;
        for unit in 0..n_units {
            let row = &logits[unit * c..(unit + 1) * c];
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut argmax = 0;
            for (j, &x) in row.iter().enumerate() {
                if x > row[argmax] {
                    argmax = j;
                }
                probs[unit * c + j] = (x - max).exp();
            }
            let denom: f32 = probs[unit * c..(unit + 1) * c].iter().sum();
            for p in probs[unit * c..(unit + 1) * c].iter_mut() {
                *p /= denom;
            }
            let y = targets[unit] as usize;
            let w = uw.w(unit);
            loss_sum += w * -(probs[unit * c + y] + 1e-12).ln();
            if argmax == y {
                correct_sum += w;
            }
        }
        self.fwd_seconds.set(self.fwd_seconds.get() + t_fwd.secs());

        if !want_grads {
            return Ok(PassOut { loss_sum, correct_sum, examples, grads: None });
        }

        // ---- backward (gradient of loss_sum / weight_total) -------------
        let t_bwd = Timer::start();
        let denom = weight_total.max(1e-12);
        let mut grads: Vec<Vec<f32>> =
            self.specs.iter().map(|s| vec![0.0f32; s.numel()]).collect();

        // dlogits = (softmax - onehot) * w / denom
        let mut dlogits = probs; // reuse
        for unit in 0..n_units {
            let w = uw.w(unit) / denom;
            let y = targets[unit] as usize;
            let row = &mut dlogits[unit * c..(unit + 1) * c];
            row[y] -= 1.0;
            for x in row.iter_mut() {
                *x *= w;
            }
        }

        // out layer backward: dW2 = a1^T dlogits, db2 = sum dlogits,
        // da1 = dlogits . W2^T
        let mut dh1 = vec![0.0f32; n_units * h];
        {
            let (dw2, db2s) = {
                let (left, right) = grads.split_at_mut(B2);
                (&mut left[W2], &mut right[0])
            };
            for unit in 0..n_units {
                let dl = &dlogits[unit * c..(unit + 1) * c];
                let a = &a1[unit * h..(unit + 1) * h];
                for (db, &dv) in db2s.iter_mut().zip(dl) {
                    *db += dv;
                }
                let dh = &mut dh1[unit * h..(unit + 1) * h];
                for (k, &av) in a.iter().enumerate() {
                    let wrow = &params[W2][k * c..(k + 1) * c];
                    let gw = &mut dw2[k * c..(k + 1) * c];
                    let mut acc = 0.0f32;
                    for j in 0..c {
                        if av != 0.0 {
                            gw[j] += av * dl[j];
                        }
                        acc += dl[j] * wrow[j];
                    }
                    // relu mask: a1 == 0 means h1 <= 0.
                    dh[k] = if av > 0.0 { acc } else { 0.0 };
                }
            }
        }

        // trunk layer backward: dW1 = n0^T dh1, db1 = sum dh1,
        // dn0 = dh1 . W1^T
        let mut dn0 = vec![0.0f32; n_units * h];
        {
            let (dw1, db1s) = {
                let (left, right) = grads.split_at_mut(B1);
                (&mut left[W1], &mut right[0])
            };
            for unit in 0..n_units {
                let dh = &dh1[unit * h..(unit + 1) * h];
                let x = &n0[unit * h..(unit + 1) * h];
                for (db, &dv) in db1s.iter_mut().zip(dh) {
                    *db += dv;
                }
                let dn = &mut dn0[unit * h..(unit + 1) * h];
                for (k, &xv) in x.iter().enumerate() {
                    let wrow = &params[W1][k * h..(k + 1) * h];
                    let gw = &mut dw1[k * h..(k + 1) * h];
                    let mut acc = 0.0f32;
                    for j in 0..h {
                        if xv != 0.0 {
                            gw[j] += xv * dh[j];
                        }
                        acc += dh[j] * wrow[j];
                    }
                    dn[k] = acc;
                }
            }
        }

        // LayerNorm backward (per example row):
        // dscale = Σ dn0*xhat, dbias = Σ dn0, dxhat = dn0*scale,
        // da0 = inv/H (H dxhat − Σdxhat − xhat Σ(dxhat·xhat))
        let mut da0 = vec![0.0f32; n_units * h];
        {
            let (dscale, dbias) = {
                let (left, right) = grads.split_at_mut(BIAS);
                (&mut left[SCALE], &mut right[0])
            };
            let hf = h as f32;
            for unit in 0..n_units {
                let dn = &dn0[unit * h..(unit + 1) * h];
                let xh = &xhat[unit * h..(unit + 1) * h];
                let mut s1 = 0.0f32;
                let mut s2 = 0.0f32;
                for j in 0..h {
                    dscale[j] += dn[j] * xh[j];
                    dbias[j] += dn[j];
                    let dxh = dn[j] * params[SCALE][j];
                    s1 += dxh;
                    s2 += dxh * xh[j];
                }
                let da = &mut da0[unit * h..(unit + 1) * h];
                let iv = inv[unit] / hf;
                for j in 0..h {
                    let dxh = dn[j] * params[SCALE][j];
                    da[j] = iv * (hf * dxh - s1 - xh[j] * s2);
                }
            }
        }

        // relu mask for layer 0, then input layer backward.
        for (da, &av) in da0.iter_mut().zip(&a0) {
            if av <= 0.0 {
                *da = 0.0;
            }
        }
        {
            let (dw0, db0s) = {
                let (left, right) = grads.split_at_mut(B0);
                (&mut left[W0], &mut right[0])
            };
            match batch {
                StepBatch::Lm { tokens, .. } => {
                    for (unit, &t) in tokens.iter().enumerate() {
                        let da = &da0[unit * h..(unit + 1) * h];
                        let gw = &mut dw0[t as usize * h..(t as usize + 1) * h];
                        for j in 0..h {
                            gw[j] += da[j];
                            db0s[j] += da[j];
                        }
                    }
                }
                StepBatch::Image { images, .. } => {
                    let dim = d.input_dim();
                    for unit in 0..n_units {
                        let da = &da0[unit * h..(unit + 1) * h];
                        let x = &images[unit * dim..(unit + 1) * dim];
                        for (db, &dv) in db0s.iter_mut().zip(da) {
                            *db += dv;
                        }
                        for (k, &xv) in x.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            let gw = &mut dw0[k * h..(k + 1) * h];
                            for (g, &dv) in gw.iter_mut().zip(da) {
                                *g += xv * dv;
                            }
                        }
                    }
                }
            }
        }

        self.bwd_seconds.set(self.bwd_seconds.get() + t_bwd.secs());
        Ok(PassOut { loss_sum, correct_sum, examples, grads: Some(grads) })
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        match self.precision {
            Precision::F32 => "reference",
            Precision::Bf16 => "reference-bf16",
        }
    }

    fn train_step(&self, params: &[Vec<f32>], batch: &StepBatch) -> Result<(f32, Vec<Vec<f32>>)> {
        let out = self.pass(params, batch, None, true)?;
        // Unit weights sum to the example count for both families (LM
        // positions carry weight 1/seq), so this is the batch-mean loss.
        let loss = out.loss_sum / out.examples.max(1e-12);
        Ok((loss, out.grads.expect("grads requested")))
    }

    fn eval_step(
        &self,
        params: &[Vec<f32>],
        batch: &StepBatch,
        mask: &[f32],
    ) -> Result<(f32, f32, f32)> {
        let out = self.pass(params, batch, Some(mask), false)?;
        Ok((out.loss_sum, out.correct_sum, out.examples))
    }

    fn execute_seconds(&self) -> f64 {
        self.fwd_seconds.get() + self.bwd_seconds.get()
    }

    fn phase_seconds(&self) -> (f64, f64) {
        (self.fwd_seconds.get(), self.bwd_seconds.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_image_dims() -> ProxyDims {
        ProxyDims {
            family: "cnn",
            kind: TaskKind::Image,
            hidden: 6,
            batch_per_core: 4,
            vocab: 0,
            seq: 0,
            image: 2, // input_dim = 12
            classes: 5,
        }
    }

    fn tiny_lm_dims() -> ProxyDims {
        ProxyDims {
            family: "transformer",
            kind: TaskKind::Lm,
            hidden: 6,
            batch_per_core: 2,
            vocab: 7,
            seq: 3,
            image: 0,
            classes: 0,
        }
    }

    /// Big enough that every kernel spans multiple 64-wide tiles.
    fn tiled_image_dims() -> ProxyDims {
        ProxyDims {
            family: "cnn",
            kind: TaskKind::Image,
            hidden: 70,
            batch_per_core: 4,
            vocab: 0,
            seq: 0,
            image: 5, // input_dim = 75
            classes: 9,
        }
    }

    fn tiled_lm_dims() -> ProxyDims {
        ProxyDims {
            family: "transformer",
            kind: TaskKind::Lm,
            hidden: 70,
            batch_per_core: 3,
            vocab: 80,
            seq: 4,
            image: 0,
            classes: 0,
        }
    }

    fn init(specs: &[ParamSpec], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        specs
            .iter()
            .map(|s| {
                if s.name.ends_with(".scale") {
                    vec![1.0; s.numel()]
                } else if s.name.ends_with(".bias") || s.name.ends_with(".b") {
                    vec![0.0; s.numel()]
                } else {
                    let fan_in = s.shape[..s.shape.len() - 1].iter().product::<usize>().max(1);
                    rng.normal_vec(s.numel(), (1.0 / fan_in as f32).sqrt())
                }
            })
            .collect()
    }

    fn image_batch(dims: &ProxyDims, n: usize, seed: u64) -> StepBatch {
        let mut rng = Rng::new(seed);
        let dim = dims.input_dim();
        let images = rng.normal_vec(n * dim, 1.0);
        let labels = (0..n).map(|_| rng.below(dims.classes as u64) as i32).collect();
        StepBatch::Image { images, labels }
    }

    fn lm_batch(dims: &ProxyDims, batch: usize, seed: u64) -> StepBatch {
        let mut rng = Rng::new(seed);
        let n = batch * dims.seq;
        let tokens: Vec<i32> = (0..n).map(|_| rng.below(dims.vocab as u64) as i32).collect();
        let targets: Vec<i32> =
            tokens.iter().map(|&t| ((5 * t as i64 + 3) % dims.vocab as i64) as i32).collect();
        StepBatch::Lm { tokens, targets }
    }

    #[test]
    fn specs_follow_trainer_init_conventions() {
        let dims = proxy_dims("transformer").unwrap();
        let specs = param_specs_for(&dims);
        assert_eq!(specs.len(), 8);
        assert_eq!(specs[W0].shape, vec![dims.vocab, dims.hidden]);
        assert_eq!(specs[SCALE].name, "norm.scale");
        assert!(specs[BIAS].name.ends_with(".bias"));
        assert!(specs[B0].name.ends_with(".b"));
        assert_eq!(specs[W2].shape, vec![dims.hidden, dims.vocab]);
        let total: usize = specs.iter().map(ParamSpec::numel).sum();
        assert!(total > 10_000, "transformer proxy should be MLP-scale, got {total}");
    }

    /// The crux: analytic gradients must match central finite differences
    /// of the f32 forward pass, for both task families — on the tiled
    /// kernels (the default) and at multi-tile sizes.
    #[test]
    fn analytic_grads_match_finite_differences() {
        for (dims, batch) in [
            (tiny_image_dims(), image_batch(&tiny_image_dims(), 4, 11)),
            (tiny_lm_dims(), lm_batch(&tiny_lm_dims(), 2, 12)),
            (tiled_image_dims(), image_batch(&tiled_image_dims(), 3, 13)),
            (tiled_lm_dims(), lm_batch(&tiled_lm_dims(), 2, 14)),
        ] {
            let be = ReferenceBackend::with_dims(dims, Precision::F32);
            let mut params = init(be.specs(), 3);
            let (_, grads) = be.train_step(&params, &batch).unwrap();
            let eps = 5e-3f32;
            let mut rng = Rng::new(99);
            for ti in 0..params.len() {
                let n = params[ti].len();
                for _ in 0..n.min(8) {
                    let i = rng.below(n as u64) as usize;
                    let orig = params[ti][i];
                    params[ti][i] = orig + eps;
                    let (lp, _) = be.train_step(&params, &batch).unwrap();
                    params[ti][i] = orig - eps;
                    let (lm, _) = be.train_step(&params, &batch).unwrap();
                    params[ti][i] = orig;
                    let num = (lp - lm) / (2.0 * eps);
                    let ana = grads[ti][i];
                    assert!(
                        (num - ana).abs() < 1e-3 + 0.05 * num.abs(),
                        "{} tensor {ti}[{i}]: numeric {num} vs analytic {ana}",
                        be.dims().family
                    );
                }
            }
        }
    }

    /// The tiled path must reproduce the naive scalar loops *bitwise* —
    /// per-element accumulation order is part of the kernel contract.
    #[test]
    fn tiled_kernels_match_naive_bitwise() {
        for (dims, batch) in [
            (tiled_image_dims(), image_batch(&tiled_image_dims(), 5, 51)),
            (tiled_lm_dims(), lm_batch(&tiled_lm_dims(), 3, 52)),
        ] {
            for precision in [Precision::F32, Precision::Bf16] {
                let naive =
                    ReferenceBackend::with_options(dims, precision, KernelMode::Naive, 1);
                let tiled =
                    ReferenceBackend::with_options(dims, precision, KernelMode::Tiled, 1);
                let params = init(naive.specs(), 6);
                let (ln, gn) = naive.train_step(&params, &batch).unwrap();
                let (lt, gt) = tiled.train_step(&params, &batch).unwrap();
                assert_eq!(ln.to_bits(), lt.to_bits(), "{} loss", dims.family);
                assert_eq!(gn, gt, "{} grads", dims.family);
                let mask: Vec<f32> =
                    (0..batchlen(&batch, &dims)).map(|i| if i == 0 { 0.0 } else { 1.0 }).collect();
                let en = naive.eval_step(&params, &batch, &mask).unwrap();
                let et = tiled.eval_step(&params, &batch, &mask).unwrap();
                assert_eq!(en.0.to_bits(), et.0.to_bits());
                assert_eq!(en.1.to_bits(), et.1.to_bits());
            }
        }
    }

    fn batchlen(batch: &StepBatch, dims: &ProxyDims) -> usize {
        match batch {
            StepBatch::Lm { tokens, .. } => tokens.len() / dims.seq,
            StepBatch::Image { labels, .. } => labels.len(),
        }
    }

    /// Thread-count invariance: the intra-core split may not change a
    /// single bit, for any worker count (including more workers than
    /// rows).
    #[test]
    fn exec_threads_do_not_change_bits() {
        for (dims, batch) in [
            (tiled_image_dims(), image_batch(&tiled_image_dims(), 5, 61)),
            (tiled_lm_dims(), lm_batch(&tiled_lm_dims(), 3, 62)),
        ] {
            let serial = ReferenceBackend::with_dims(dims, Precision::F32);
            let params = init(serial.specs(), 8);
            let (l1, g1) = serial.train_step(&params, &batch).unwrap();
            for threads in [2, 3, 4, 7, 64] {
                let par = ReferenceBackend::with_options(
                    dims,
                    Precision::F32,
                    KernelMode::Tiled,
                    threads,
                );
                let (lt, gt) = par.train_step(&params, &batch).unwrap();
                assert_eq!(l1.to_bits(), lt.to_bits(), "loss at {threads} threads");
                assert_eq!(g1, gt, "grads at {threads} threads");
                let mask = vec![1.0; batchlen(&batch, &dims)];
                let e1 = serial.eval_step(&params, &batch, &mask).unwrap();
                let et = par.eval_step(&params, &batch, &mask).unwrap();
                assert_eq!(e1.0.to_bits(), et.0.to_bits(), "eval at {threads} threads");
            }
        }
    }

    #[test]
    fn bf16_grads_stay_close_to_f32() {
        let dims = tiny_image_dims();
        let f32_be = ReferenceBackend::with_dims(dims, Precision::F32);
        let bf_be = ReferenceBackend::with_dims(dims, Precision::Bf16);
        let params = init(f32_be.specs(), 5);
        let batch = image_batch(&dims, 8, 21);
        let (l32, g32) = f32_be.train_step(&params, &batch).unwrap();
        let (l16, g16) = bf_be.train_step(&params, &batch).unwrap();
        assert!((l32 - l16).abs() < 0.05 * l32.abs() + 1e-3, "loss {l32} vs {l16}");
        for (a, b) in g32.iter().zip(&g16) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 2e-3 + 0.05 * x.abs(), "grad {x} vs {y}");
            }
        }
    }

    #[test]
    fn masked_eval_slots_contribute_nothing() {
        let dims = tiny_image_dims();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let params = init(be.specs(), 7);
        let full = image_batch(&dims, 3, 31);
        let (li, ci, ni) = be.eval_step(&params, &full, &[1.0, 1.0, 0.0]).unwrap();
        // The same first two examples, no padding.
        let (images, labels) = match &full {
            StepBatch::Image { images, labels } => {
                (images[..2 * dims.input_dim()].to_vec(), labels[..2].to_vec())
            }
            _ => unreachable!(),
        };
        let trimmed = StepBatch::Image { images, labels };
        let (lt, ct, nt) = be.eval_step(&params, &trimmed, &[1.0, 1.0]).unwrap();
        assert_eq!(ni, 2.0);
        assert_eq!(nt, 2.0);
        assert_eq!(li, lt, "masked loss must equal the unpadded loss bitwise");
        assert_eq!(ci, ct);
    }

    #[test]
    fn passes_are_bitwise_deterministic() {
        let dims = tiny_lm_dims();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let params = init(be.specs(), 9);
        let batch = lm_batch(&dims, 4, 41);
        let (l1, g1) = be.train_step(&params, &batch).unwrap();
        let (l2, g2) = be.train_step(&params, &batch).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
    }

    #[test]
    fn phase_split_adds_up() {
        let dims = tiny_image_dims();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let params = init(be.specs(), 4);
        let batch = image_batch(&dims, 4, 71);
        be.train_step(&params, &batch).unwrap();
        be.eval_step(&params, &batch, &[1.0; 4]).unwrap();
        let (fwd, bwd) = be.phase_seconds();
        assert!(fwd > 0.0, "forward time recorded");
        assert!(bwd > 0.0, "backward time recorded");
        assert!((fwd + bwd - be.execute_seconds()).abs() < 1e-12);
    }

    #[test]
    fn adam_on_the_proxy_learns_the_planted_image_task() {
        use crate::data::synthetic::ImageTask;
        use crate::optim::{adam_step, AdamConfig, AdamState};
        let dims = proxy_dims("ssd").unwrap();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let mut params = init(be.specs(), 1);
        let task = ImageTask::new(dims.image, dims.classes, 2.0, 0xEEE);
        let mut rng = Rng::new(0);
        let mut states: Vec<AdamState> = be.specs().iter().map(|_| AdamState::default()).collect();
        let cfg = AdamConfig::default();
        let mut losses = Vec::new();
        for step in 1..=40u64 {
            let b = task.batch(&mut rng, 16);
            let batch = StepBatch::Image { images: b.images, labels: b.labels };
            let (loss, grads) = be.train_step(&params, &batch).unwrap();
            losses.push(loss);
            for ti in 0..params.len() {
                adam_step(&cfg, 3e-3, step, &mut params[ti], &grads[ti], &mut states[ti]);
            }
        }
        let first = losses[..5].iter().sum::<f32>() / 5.0;
        let last = losses[35..].iter().sum::<f32>() / 5.0;
        assert!(last < first * 0.5, "loss should halve: first {first:.3} last {last:.3}");
    }

    #[test]
    fn bad_inputs_are_clean_errors() {
        let dims = tiny_lm_dims();
        let be = ReferenceBackend::with_dims(dims, Precision::F32);
        let params = init(be.specs(), 2);
        // Token outside the vocab.
        let batch = StepBatch::Lm { tokens: vec![99; 3], targets: vec![0; 3] };
        assert!(be.train_step(&params, &batch).is_err());
        // Wrong batch kind for the proxy family.
        let batch = StepBatch::Image { images: vec![0.0; 12], labels: vec![0] };
        assert!(be.train_step(&params, &batch).is_err());
        // Wrong parameter shape.
        let mut bad = params.clone();
        bad[0].pop();
        let batch = lm_batch(&dims, 1, 1);
        assert!(be.train_step(&bad, &batch).is_err());
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(ReferenceBackend::new("bert_large", Precision::F32).is_err());
    }
}
