//! Execution runtimes behind the trainer's [`Backend`] boundary (see
//! `rust/src/runtime/README.md` for the subsystem map):
//!
//! * [`reference`] — the pure-Rust fwd/bwd executor over the
//!   `models::proxy` dense proxies: exact analytic gradients, no
//!   artifacts, deterministic. This is what tier-1 CI gates.
//! * [`Runtime`] + [`PjRtBackend`] — the PJRT path: load AOT artifacts
//!   (HLO text), compile once, execute from the training hot path.
//!
//! The PJRT pattern follows the xla_extension load_hlo flow:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. HLO *text*
//! is the interchange format (the bundled xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so each data-parallel worker
//! thread constructs its own `Runtime` — mirroring how each TPU core owns
//! its own executable image. Executables are cached per runtime.
//!
//! In the offline build the `xla` binding is the in-tree stub
//! ([`mod@xla`]): client construction fails with a clear message and every
//! artifact-dependent caller degrades gracefully (PJRT-only integration
//! tests skip, the reference backend and the simulator/scenario layers
//! never come near it).

pub mod artifact;
pub mod backend;
pub mod kernels;
pub mod reference;
mod xla;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

pub use artifact::{ArtifactMeta, Dtype, IoSpec, Manifest, ParamSpec};
pub use backend::{Backend, BackendChoice, PjRtBackend, StepBatch};
pub use reference::{param_specs_for, KernelMode, Precision, ReferenceBackend};

/// A host-side tensor (f32) with shape — the currency between the
/// coordinator (collectives, optimizers) and the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(x: f32) -> HostTensor {
        HostTensor { shape: vec![], data: vec![x] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Per-thread PJRT runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Rc<Manifest>,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative PJRT execute time (perf accounting).
    pub execute_seconds: RefCell<f64>,
    pub executions: RefCell<u64>,
}

impl Runtime {
    /// Create a runtime over the default artifacts directory.
    pub fn create() -> Result<Runtime> {
        Runtime::with_dir(Manifest::default_dir())
    }

    pub fn with_dir(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Rc::new(Manifest::load(dir)?);
        Runtime::with_manifest(manifest)
    }

    pub fn with_manifest(manifest: Rc<Manifest>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            execute_seconds: RefCell::new(0.0),
            executions: RefCell::new(0),
        })
    }

    /// Compile (or fetch cached) an artifact.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {name}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on host tensors, validating shapes against the
    /// manifest. `int_inputs` supplies values for i32 inputs (consumed in
    /// manifest order); f32 inputs come from `inputs` (same order).
    pub fn execute(
        &self,
        name: &str,
        inputs: &[&HostTensor],
        int_inputs: &[&[i32]],
    ) -> Result<Vec<HostTensor>> {
        let f32_slices: Vec<&[f32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
        self.execute_raw(name, &f32_slices, int_inputs)
    }

    /// Zero-copy variant: f32 inputs as plain slices (shapes come from the
    /// manifest, which is the source of truth anyway). This is the hot-path
    /// entry the trainer uses — no per-step tensor wrapping.
    pub fn execute_raw(
        &self,
        name: &str,
        inputs: &[&[f32]],
        int_inputs: &[&[i32]],
    ) -> Result<Vec<HostTensor>> {
        let meta = self.manifest.artifact(name)?.clone();
        let exe = self.load(name)?;

        let mut literals = Vec::with_capacity(meta.inputs.len());
        let mut fi = 0;
        let mut ii = 0;
        for spec in &meta.inputs {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            match spec.dtype {
                Dtype::F32 => {
                    let t = inputs.get(fi).with_context(|| {
                        format!("{name}: missing f32 input {} ({})", fi, spec.name)
                    })?;
                    if t.len() != spec.numel() {
                        bail!(
                            "{name}: input {} ({}) has {} elements, expected {:?}",
                            fi, spec.name, t.len(), spec.shape
                        );
                    }
                    literals.push(lit_f32(t, &dims)?);
                    fi += 1;
                }
                Dtype::I32 => {
                    let v = int_inputs.get(ii).with_context(|| {
                        format!("{name}: missing i32 input {} ({})", ii, spec.name)
                    })?;
                    if v.len() != spec.numel() {
                        bail!(
                            "{name}: i32 input {} ({}) has {} elements, expected {:?}",
                            ii, spec.name, v.len(), spec.shape
                        );
                    }
                    literals.push(lit_i32(v, &dims)?);
                    ii += 1;
                }
            }
        }
        if fi != inputs.len() || ii != int_inputs.len() {
            bail!("{name}: extra inputs supplied (f32 {fi}/{}, i32 {ii}/{})",
                  inputs.len(), int_inputs.len());
        }

        let t0 = std::time::Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        *self.execute_seconds.borrow_mut() += t0.elapsed().as_secs_f64();
        *self.executions.borrow_mut() += 1;

        // aot.py lowers with return_tuple=True: always a tuple.
        let elems = out.to_tuple()?;
        if elems.len() != meta.outputs.len() {
            bail!("{name}: got {} outputs, manifest says {}", elems.len(), meta.outputs.len());
        }
        elems
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| {
                let data: Vec<f32> = match spec.dtype {
                    Dtype::F32 => lit.to_vec::<f32>()?,
                    Dtype::I32 => lit.to_vec::<i32>()?.into_iter().map(|x| x as f32).collect(),
                };
                Ok(HostTensor::new(spec.shape.clone(), data))
            })
            .collect()
    }

    /// Warm the cache for a set of artifacts (init phase; excluded from the
    /// MLPerf clock).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Shape/plumbing tests that don't need artifacts.
    #[test]
    fn host_tensor_shape_checked() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    #[should_panic]
    fn host_tensor_bad_shape_panics() {
        HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalar_tensor() {
        let t = HostTensor::scalar(4.0);
        assert_eq!(t.shape, Vec::<usize>::new());
        assert_eq!(t.data, vec![4.0]);
    }
}
