//! Property-testing mini-framework with shrinking (no proptest crate in
//! the offline build).
//!
//! `forall(cases, gen, prop)` runs `prop` on `cases` random inputs; on
//! failure it greedily shrinks the input via the value's [`Shrink`] impl
//! and panics with the minimal counterexample. The distributed-invariants
//! suite (rust/tests/dist_invariants.rs) uses this for collective/sharding
//! properties.

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, in decreasing preference (empty = atomic).
    fn shrinks(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<usize> {
        let mut v = Vec::new();
        if *self > 0 {
            v.push(self / 2);
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<u64> {
        let mut v = Vec::new();
        if *self > 0 {
            v.push(self / 2);
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for f32 {
    fn shrinks(&self) -> Vec<f32> {
        let mut v = Vec::new();
        if *self != 0.0 {
            v.push(0.0);
            v.push(self / 2.0);
            if self.fract() != 0.0 {
                v.push(self.trunc());
            }
        }
        v
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        // Halves first (fast length reduction)...
        out.push(self[..n / 2].to_vec());
        out.push(self[n / 2..].to_vec());
        // ...then drop one element...
        if n <= 8 {
            for i in 0..n {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        }
        // ...then shrink one element.
        for i in 0..n.min(4) {
            for s in self[i].shrinks() {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> =
            self.0.shrinks().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a property check.
pub type PropResult = Result<(), String>;

/// Run a property over random inputs; shrink + panic on failure.
pub fn forall<T, G, P>(cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    let seed = std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (minimal, min_msg) = shrink_loop(input, msg, &mut prop);
            panic!(
                "property failed (case {case}, seed {seed}):\n  minimal input: {minimal:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: FnMut(&T) -> PropResult>(
    mut cur: T,
    mut msg: String,
    prop: &mut P,
) -> (T, String) {
    let mut budget = 2000;
    'outer: loop {
        for cand in cur.shrinks() {
            budget -= 1;
            if budget == 0 {
                return (cur, msg);
            }
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                continue 'outer;
            }
        }
        return (cur, msg);
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            50,
            |rng| rng.below(100) as usize,
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        // Property: all values < 10. Minimal counterexample is exactly 10.
        let result = std::panic::catch_unwind(|| {
            forall(
                200,
                |rng| rng.below(1000) as usize,
                |&x| {
                    if x < 10 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 10"))
                    }
                },
            );
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("minimal input: 10"), "{msg}");
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        // Property: no vector contains 7. Minimal counterexample: [7].
        let result = std::panic::catch_unwind(|| {
            forall(
                300,
                |rng| (0..rng.below(20) as usize).map(|_| rng.below(10) as usize).collect::<Vec<_>>(),
                |v| {
                    if v.contains(&7) {
                        Err("contains 7".into())
                    } else {
                        Ok(())
                    }
                },
            );
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("minimal input: [7]"), "{msg}");
    }

    #[test]
    fn deterministic_given_seed_env() {
        // Same default seed → same generated sequence (documented contract).
        let mut first = Vec::new();
        forall(5, |rng| rng.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second = Vec::new();
        forall(5, |rng| rng.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
