//! Host input pipeline (paper §2 "caching, host to device offload ... and
//! prefetching"; §3 GNMT "round-robin algorithm to distribute the input
//! pipeline to multiple hosts").
//!
//! * [`Prefetcher`] — a bounded producer/consumer queue on its own thread:
//!   the host prepares batches ahead of the device step, with backpressure
//!   when the device falls behind.
//! * [`HostSharding`] — round-robin assignment of workers to input hosts,
//!   plus a throughput model showing where the single-host pipeline becomes
//!   the bottleneck (the paper's 1024-worker observation).

use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::thread::JoinHandle;

/// Bounded prefetch queue fed by a producer thread.
pub struct Prefetcher<T: Send + 'static> {
    rx: Receiver<T>,
    handle: Option<JoinHandle<PrefetchStats>>,
}

/// Producer-side statistics (how often the queue pushed back).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefetchStats {
    pub produced: u64,
    pub backpressure_events: u64,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Start producing with `make(i)` for i in 0..count, `depth` batches of
    /// lookahead.
    pub fn start<F>(depth: usize, count: usize, make: F) -> Prefetcher<T>
    where
        F: Fn(usize) -> T + Send + 'static,
    {
        let (tx, rx) = sync_channel(depth);
        let handle = std::thread::spawn(move || {
            let mut stats = PrefetchStats::default();
            for i in 0..count {
                let mut item = make(i);
                stats.produced += 1;
                loop {
                    match tx.try_send(item) {
                        Ok(()) => break,
                        Err(TrySendError::Full(it)) => {
                            stats.backpressure_events += 1;
                            item = it;
                            std::thread::yield_now();
                            // Fall back to a blocking send to avoid spinning.
                            match tx.send(item) {
                                Ok(()) => break,
                                Err(_) => return stats,
                            }
                        }
                        Err(TrySendError::Disconnected(_)) => return stats,
                    }
                }
            }
            stats
        });
        Prefetcher { rx, handle: Some(handle) }
    }

    /// Blocking fetch of the next batch (None when the stream ends).
    pub fn next(&mut self) -> Option<T> {
        self.rx.recv().ok()
    }

    /// Drain and join; returns producer stats.
    pub fn finish(mut self) -> PrefetchStats {
        // Close our receiver first so a blocked producer unblocks.
        drop(std::mem::replace(&mut self.rx, {
            let (_tx, rx) = sync_channel(1);
            rx
        }));
        self.handle.take().map(|h| h.join().unwrap_or_default()).unwrap_or_default()
    }
}

/// Round-robin worker→host input assignment (paper §3 GNMT).
#[derive(Clone, Debug)]
pub struct HostSharding {
    pub hosts: usize,
    pub workers: usize,
}

impl HostSharding {
    pub fn new(hosts: usize, workers: usize) -> HostSharding {
        assert!(hosts >= 1 && workers >= 1);
        HostSharding { hosts, workers }
    }

    /// Which host feeds a worker.
    pub fn host_of(&self, worker: usize) -> usize {
        worker % self.hosts
    }

    /// Workers fed by a host.
    pub fn workers_of(&self, host: usize) -> Vec<usize> {
        (0..self.workers).filter(|w| self.host_of(*w) == host).collect()
    }

    /// Examples/second the pod can consume given per-host pipeline
    /// throughput `host_rate` (examples/s) and per-worker device demand
    /// `device_rate` (examples/s): min(host supply, device demand), where
    /// the busiest host limits supply.
    pub fn pod_throughput(&self, host_rate: f64, device_rate: f64) -> f64 {
        let max_workers_per_host = (self.workers + self.hosts - 1) / self.hosts;
        let per_worker_supply = host_rate / max_workers_per_host as f64;
        self.workers as f64 * per_worker_supply.min(device_rate)
    }

    /// Is the input pipeline the bottleneck at this scale?
    pub fn input_bound(&self, host_rate: f64, device_rate: f64) -> bool {
        let max_workers_per_host = (self.workers + self.hosts - 1) / self.hosts;
        (host_rate / max_workers_per_host as f64) < device_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn prefetcher_delivers_in_order() {
        let mut p = Prefetcher::start(4, 100, |i| i * i);
        for i in 0..100 {
            assert_eq!(p.next(), Some(i * i));
        }
        assert_eq!(p.next(), None);
        let stats = p.finish();
        assert_eq!(stats.produced, 100);
    }

    #[test]
    fn prefetcher_applies_backpressure() {
        // Slow consumer, fast producer, shallow queue: the producer must
        // observe backpressure instead of buffering unboundedly.
        let mut p = Prefetcher::start(2, 50, |i| i);
        std::thread::sleep(Duration::from_millis(20)); // let producer fill
        let mut got = 0;
        while let Some(_) = p.next() {
            got += 1;
        }
        assert_eq!(got, 50);
        let stats = p.finish();
        assert!(stats.backpressure_events > 0, "{stats:?}");
    }

    #[test]
    fn prefetcher_early_drop_unblocks_producer() {
        let p = Prefetcher::start(1, 1_000_000, |i| vec![i; 10]);
        // Consume a few then drop — producer must terminate, not hang.
        let mut p = p;
        for _ in 0..3 {
            p.next();
        }
        let stats = p.finish();
        assert!(stats.produced < 1_000_000);
    }

    #[test]
    fn round_robin_is_balanced() {
        let s = HostSharding::new(4, 1024);
        let counts: Vec<usize> = (0..4).map(|h| s.workers_of(h).len()).collect();
        assert_eq!(counts, vec![256; 4]);
    }

    #[test]
    fn single_host_bottleneck_at_scale() {
        // Paper §3: "when scaling to very large systems where we have 1024
        // workers, the single host input pipeline becomes the bottleneck."
        let host_rate = 10_000.0; // examples/s one host can preprocess
        let device_rate = 100.0; // examples/s one worker consumes
        let single = HostSharding::new(1, 1024);
        assert!(single.input_bound(host_rate, device_rate));
        // Distributing over 16 hosts removes the bottleneck.
        let multi = HostSharding::new(16, 1024);
        assert!(!multi.input_bound(host_rate, device_rate));
        assert!(multi.pod_throughput(host_rate, device_rate)
            > 10.0 * single.pod_throughput(host_rate, device_rate));
    }

    #[test]
    fn small_scale_single_host_fine() {
        // At 8 workers the single host keeps up — matching why the paper
        // only distributes the pipeline at pod scale.
        let s = HostSharding::new(1, 8);
        assert!(!s.input_bound(10_000.0, 100.0));
    }
}
