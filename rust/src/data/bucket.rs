//! Sequence bucketization (paper §3 GNMT): "To achieve good load-balance,
//! we use a window based bucketization scheme to ensure that the sequences
//! in each batch have similar length. For multi-host training, global
//! bucketization is enabled by using a single host to produce the input for
//! all workers."
//!
//! Synchronous training pads every sequence in a batch to the batch max, so
//! the padding fraction is wasted compute; bucketization minimizes it.

use crate::data::synthetic::SentencePair;
use crate::util::rng::Rng;

/// A batch of sentence pairs, padded to the max length within the batch.
#[derive(Clone, Debug)]
pub struct SeqBatch {
    pub pairs: Vec<SentencePair>,
}

impl SeqBatch {
    pub fn max_len(&self) -> usize {
        self.pairs.iter().map(|p| p.len()).max().unwrap_or(0)
    }

    pub fn real_tokens(&self) -> usize {
        self.pairs.iter().map(|p| p.len()).sum()
    }

    /// Padded token slots the synchronous step must still process.
    pub fn padded_tokens(&self) -> usize {
        self.max_len() * self.pairs.len()
    }

    /// Fraction of compute wasted on padding.
    pub fn padding_waste(&self) -> f64 {
        if self.pairs.is_empty() {
            return 0.0;
        }
        1.0 - self.real_tokens() as f64 / self.padded_tokens() as f64
    }
}

/// Aggregate padding waste over a batch stream.
pub fn total_waste(batches: &[SeqBatch]) -> f64 {
    let real: usize = batches.iter().map(|b| b.real_tokens()).sum();
    let padded: usize = batches.iter().map(|b| b.padded_tokens()).sum();
    if padded == 0 {
        0.0
    } else {
        1.0 - real as f64 / padded as f64
    }
}

/// Baseline: batch in arrival order (no length awareness).
pub fn batch_sequential(pairs: Vec<SentencePair>, batch: usize) -> Vec<SeqBatch> {
    pairs
        .chunks(batch)
        .map(|c| SeqBatch { pairs: c.to_vec() })
        .collect()
}

/// Window-based bucketization: buffer `window` examples, sort by length,
/// emit consecutive batches. With `shuffle: Some(rng)` the batch order
/// within each window is randomised (training curriculum); with `None` the
/// sorted order is kept, which is what the synchronous-step dispatcher
/// wants — consecutive batches handed to the data-parallel workers of one
/// step then have near-identical max lengths (paper §3 load balance).
/// `window` must be a multiple of `batch`.
pub fn batch_bucketized_with(
    pairs: Vec<SentencePair>,
    batch: usize,
    window: usize,
    shuffle: Option<&mut Rng>,
) -> Vec<SeqBatch> {
    assert!(window >= batch && window % batch == 0);
    let mut out = Vec::new();
    for chunk in pairs.chunks(window) {
        let mut sorted = chunk.to_vec();
        sorted.sort_by_key(|p| p.len());
        out.extend(
            sorted
                .chunks(batch)
                .map(|c| SeqBatch { pairs: c.to_vec() }),
        );
    }
    if let Some(rng) = shuffle {
        // Shuffle whole windows' batch lists while keeping each step-group
        // of consecutive batches intact is the dispatcher's job; here we
        // shuffle at batch granularity for curriculum mixing.
        rng.shuffle(&mut out);
    }
    out
}

/// Window-based bucketization with curriculum shuffling (common case).
pub fn batch_bucketized(
    pairs: Vec<SentencePair>,
    batch: usize,
    window: usize,
    rng: &mut Rng,
) -> Vec<SeqBatch> {
    batch_bucketized_with(pairs, batch, window, Some(rng))
}

/// Global bucketization: the whole (shuffled-epoch) dataset is one window —
/// what the paper's single-input-host mode achieves. Minimum possible waste
/// for a fixed batch size. Order is kept sorted (the step dispatcher hands
/// out consecutive batches to the workers of one synchronous step).
pub fn batch_global(pairs: Vec<SentencePair>, batch: usize) -> Vec<SeqBatch> {
    let window = pairs.len().max(batch).div_ceil(batch) * batch;
    batch_bucketized_with(pairs, batch, window, None)
}

/// Load imbalance across data-parallel workers for one synchronous step:
/// every worker waits for the longest batch (paper §3: "each training step
/// will wait until the longest sequence to finish"). Returns
/// max(batch max len) / mean(batch max len) over the workers' batches.
pub fn step_imbalance(worker_batches: &[&SeqBatch]) -> f64 {
    if worker_batches.is_empty() {
        return 1.0;
    }
    let lens: Vec<f64> = worker_batches.iter().map(|b| b.max_len() as f64).collect();
    let max = lens.iter().cloned().fold(0.0, f64::max);
    let mean = lens.iter().sum::<f64>() / lens.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::TranslationTask;

    fn pairs(n: usize, seed: u64) -> Vec<SentencePair> {
        TranslationTask::default().pairs(&mut Rng::new(seed), n)
    }

    #[test]
    fn bucketization_reduces_padding_waste() {
        let ps = pairs(4096, 0);
        let batch = 32;
        let seq = batch_sequential(ps.clone(), batch);
        let mut rng = Rng::new(1);
        let win = batch_bucketized(ps.clone(), batch, 512, &mut rng);
        let glob = batch_global(ps, batch);
        let (ws, ww, wg) = (total_waste(&seq), total_waste(&win), total_waste(&glob));
        assert!(ww < ws * 0.6, "window {ww} vs sequential {ws}");
        assert!(wg <= ww, "global {wg} vs window {ww}");
        assert!(wg < 0.1, "global waste should be tiny: {wg}");
    }

    #[test]
    fn bucketization_preserves_every_example() {
        let ps = pairs(1000, 2);
        let mut rng = Rng::new(3);
        let batches = batch_bucketized(ps.clone(), 16, 128, &mut rng);
        let mut seen: Vec<&SentencePair> = batches.iter().flat_map(|b| &b.pairs).collect();
        assert_eq!(seen.len(), 1000);
        let mut orig: Vec<&SentencePair> = ps.iter().collect();
        let key = |p: &&SentencePair| (p.src.clone(), p.tgt.clone());
        seen.sort_by_key(key);
        orig.sort_by_key(key);
        assert!(seen.iter().zip(&orig).all(|(a, b)| a == b));
    }

    #[test]
    fn within_batch_lengths_similar_after_bucketization() {
        let ps = pairs(2048, 4);
        let mut rng = Rng::new(5);
        let batches = batch_bucketized(ps, 32, 1024, &mut rng);
        let mean_spread: f64 = batches
            .iter()
            .map(|b| {
                let lens: Vec<usize> = b.pairs.iter().map(|p| p.len()).collect();
                (*lens.iter().max().unwrap() - *lens.iter().min().unwrap()) as f64
            })
            .sum::<f64>()
            / batches.len() as f64;
        assert!(mean_spread < 6.0, "mean within-batch spread {mean_spread}");
    }

    #[test]
    fn larger_windows_monotonically_help() {
        let ps = pairs(4096, 6);
        let mut prev = f64::INFINITY;
        for window in [64, 256, 1024, 4096] {
            let mut rng = Rng::new(7);
            let w = total_waste(&batch_bucketized(ps.clone(), 32, window, &mut rng));
            assert!(w <= prev + 0.02, "window {window}: waste {w} > prev {prev}");
            prev = w;
        }
    }

    #[test]
    fn imbalance_shrinks_with_bucketization() {
        let ps = pairs(4096, 8);
        let batch = 16;
        let workers = 8;
        let seq = batch_sequential(ps.clone(), batch);
        let buck = batch_global(ps, batch);
        let imb = |bs: &[SeqBatch]| -> f64 {
            bs.chunks(workers)
                .filter(|c| c.len() == workers)
                .map(|c| step_imbalance(&c.iter().collect::<Vec<_>>()))
                .sum::<f64>()
                / (bs.len() / workers) as f64
        };
        // NOTE: consecutive bucketized batches have similar max-lens, so
        // synchronous workers stay balanced.
        assert!(imb(&buck) < imb(&seq), "bucketized {} vs seq {}", imb(&buck), imb(&seq));
    }

    #[test]
    fn waste_metrics_edge_cases() {
        assert_eq!(total_waste(&[]), 0.0);
        let b = SeqBatch { pairs: vec![] };
        assert_eq!(b.padding_waste(), 0.0);
    }
}
