//! Host input pipeline substrate: synthetic datasets with paper-matched
//! shape statistics, window/global sequence bucketization (§3 GNMT), and
//! prefetching with round-robin multi-host distribution.

pub mod bucket;
pub mod pipeline;
pub mod synthetic;

pub use bucket::{batch_bucketized, batch_global, batch_sequential, total_waste, SeqBatch};
pub use pipeline::{HostSharding, Prefetcher};
pub use synthetic::{ImageTask, LmTask, TranslationTask};
