//! Synthetic dataset generators with the shape statistics of the paper's
//! workloads (DESIGN.md §0 substitution table: the input-pipeline
//! contributions depend on example shape/length statistics, not content).
//!
//! * [`LmTask`] — byte-level language modelling with a planted affine
//!   next-token structure plus noise: the tiny/small transformers can
//!   actually *learn* it, so loss curves are meaningful.
//! * [`ImageTask`] — image classification with a planted linear feature per
//!   class (the mini-CNN stand-in for ImageNet).
//! * [`TranslationTask`] — WMT-like sentence pairs whose lengths follow the
//!   long-tailed distribution that makes GNMT bucketization matter
//!   (paper §3: max eval length 97).

use crate::util::rng::Rng;

/// Language-model batch: `tokens[b][s]` and next-token `targets[b][s]`.
#[derive(Clone, Debug)]
pub struct LmBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Planted-structure LM task: with probability `1 - noise`,
/// `x[t+1] = (a * x[t] + b) mod vocab`; otherwise uniform.
#[derive(Clone, Debug)]
pub struct LmTask {
    pub vocab: i64,
    pub noise: f64,
    a: i64,
    b: i64,
}

impl LmTask {
    pub fn new(vocab: usize, noise: f64) -> LmTask {
        // a chosen coprime with vocab so the chain visits every token.
        LmTask { vocab: vocab as i64, noise, a: 5, b: 3 }
    }

    /// The Bayes-optimal next token (used by accuracy-ceiling tests).
    pub fn ideal_next(&self, tok: i32) -> i32 {
        ((self.a * tok as i64 + self.b) % self.vocab) as i32
    }

    pub fn batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> LmBatch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut x = rng.below(self.vocab as u64) as i32;
            for _ in 0..seq {
                tokens.push(x);
                let next = if rng.uniform() < self.noise {
                    rng.below(self.vocab as u64) as i32
                } else {
                    self.ideal_next(x)
                };
                targets.push(next);
                x = next;
            }
        }
        LmBatch { tokens, targets, batch, seq }
    }
}

/// Image-classification batch (NHWC f32 images + i32 labels).
#[derive(Clone, Debug)]
pub struct ImageBatch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch: usize,
    pub side: usize,
}

/// Planted *spatially smooth* class patterns: class c's images are noise +
/// alpha * P_c, where P_c is a random coarse 4x4x3 field bilinearly
/// upsampled to the image size and RMS-normalised. Smooth low-frequency
/// structure is what convolution + pooling stacks detect naturally, so the
/// mini-CNN learns this task in tens of steps (an unstructured random
/// direction, by contrast, looks like noise to 3x3 kernels).
#[derive(Clone, Debug)]
pub struct ImageTask {
    pub side: usize,
    pub classes: usize,
    pub alpha: f32,
    features: Vec<Vec<f32>>,
}

/// Bilinear upsample a [cs, cs, ch] field to [side, side, ch].
fn upsample_bilinear(coarse: &[f32], cs: usize, ch: usize, side: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; side * side * ch];
    let scale = cs as f32 / side as f32;
    for y in 0..side {
        for x in 0..side {
            // Sample at pixel centers.
            let fy = ((y as f32 + 0.5) * scale - 0.5).clamp(0.0, cs as f32 - 1.0);
            let fx = ((x as f32 + 0.5) * scale - 0.5).clamp(0.0, cs as f32 - 1.0);
            let (y0, x0) = (fy.floor() as usize, fx.floor() as usize);
            let (y1, x1) = ((y0 + 1).min(cs - 1), (x0 + 1).min(cs - 1));
            let (wy, wx) = (fy - y0 as f32, fx - x0 as f32);
            for c in 0..ch {
                let v00 = coarse[(y0 * cs + x0) * ch + c];
                let v01 = coarse[(y0 * cs + x1) * ch + c];
                let v10 = coarse[(y1 * cs + x0) * ch + c];
                let v11 = coarse[(y1 * cs + x1) * ch + c];
                out[(y * side + x) * ch + c] = v00 * (1.0 - wy) * (1.0 - wx)
                    + v01 * (1.0 - wy) * wx
                    + v10 * wy * (1.0 - wx)
                    + v11 * wy * wx;
            }
        }
    }
    out
}

impl ImageTask {
    pub fn new(side: usize, classes: usize, alpha: f32, seed: u64) -> ImageTask {
        let mut rng = Rng::new(seed);
        let cs = 4.min(side);
        let features = (0..classes)
            .map(|_| {
                let coarse = rng.normal_vec(cs * cs * 3, 1.0);
                let f = upsample_bilinear(&coarse, cs, 3, side);
                let rms =
                    (f.iter().map(|x| x * x).sum::<f32>() / f.len() as f32).sqrt().max(1e-6);
                f.into_iter().map(|x| x / rms).collect()
            })
            .collect();
        ImageTask { side, classes, alpha, features }
    }

    pub fn batch(&self, rng: &mut Rng, batch: usize) -> ImageBatch {
        let dim = self.side * self.side * 3;
        let mut images = Vec::with_capacity(batch * dim);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let c = rng.below(self.classes as u64) as usize;
            labels.push(c as i32);
            let feat = &self.features[c];
            for d in 0..dim {
                images.push(rng.normal_f32(0.0, 1.0) + self.alpha * feat[d]);
            }
        }
        ImageBatch { images, labels, batch, side: self.side }
    }
}

/// A sentence pair for the translation pipeline (only lengths matter for
/// the bucketization experiments; tokens are synthetic).
#[derive(Clone, Debug, PartialEq)]
pub struct SentencePair {
    pub src: Vec<i32>,
    pub tgt: Vec<i32>,
}

impl SentencePair {
    pub fn len(&self) -> usize {
        self.src.len().max(self.tgt.len())
    }
    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }
}

/// WMT-like length distribution: lognormal body, clamped to [1, max_len].
/// (Paper §3 Transformer: "97 is the length of the largest example in the
/// evaluation dataset".)
#[derive(Clone, Debug)]
pub struct TranslationTask {
    pub vocab: usize,
    pub max_len: usize,
    pub mu: f64,
    pub sigma: f64,
}

impl Default for TranslationTask {
    fn default() -> TranslationTask {
        TranslationTask { vocab: 32000, max_len: 97, mu: 3.0, sigma: 0.6 }
    }
}

impl TranslationTask {
    pub fn sample_len(&self, rng: &mut Rng) -> usize {
        let l = (self.mu + self.sigma * rng.normal()).exp();
        (l.round() as usize).clamp(1, self.max_len)
    }

    pub fn pair(&self, rng: &mut Rng) -> SentencePair {
        let sl = self.sample_len(rng);
        // Target length correlated with source (translation property).
        let tl = ((sl as f64 * (0.8 + 0.4 * rng.uniform())).round() as usize)
            .clamp(1, self.max_len);
        let gen = |rng: &mut Rng, n: usize| {
            (0..n).map(|_| rng.below(self.vocab as u64) as i32).collect()
        };
        SentencePair { src: gen(rng, sl), tgt: gen(rng, tl) }
    }

    pub fn pairs(&self, rng: &mut Rng, n: usize) -> Vec<SentencePair> {
        (0..n).map(|_| self.pair(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_targets_follow_planted_rule_at_zero_noise() {
        let task = LmTask::new(256, 0.0);
        let mut rng = Rng::new(0);
        let b = task.batch(&mut rng, 4, 32);
        for i in 0..b.tokens.len() {
            assert_eq!(b.targets[i], task.ideal_next(b.tokens[i]));
        }
    }

    #[test]
    fn lm_noise_rate_matches() {
        let task = LmTask::new(256, 0.3);
        let mut rng = Rng::new(1);
        let b = task.batch(&mut rng, 64, 64);
        let wrong = b
            .tokens
            .iter()
            .zip(&b.targets)
            .filter(|&(&t, &y)| y != task.ideal_next(t))
            .count();
        let rate = wrong as f64 / b.tokens.len() as f64;
        // Uniform noise hits the correct token 1/256 of the time.
        assert!((rate - 0.3).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn lm_tokens_in_vocab() {
        let task = LmTask::new(100, 0.5);
        let mut rng = Rng::new(2);
        let b = task.batch(&mut rng, 8, 16);
        assert!(b.tokens.iter().chain(&b.targets).all(|&t| (0..100).contains(&t)));
    }

    #[test]
    fn images_linearly_separable_at_high_alpha() {
        // Nearest-feature classification must beat chance easily.
        let task = ImageTask::new(8, 4, 3.0, 7);
        let mut rng = Rng::new(3);
        let b = task.batch(&mut rng, 64);
        let dim = 8 * 8 * 3;
        let mut correct = 0;
        for i in 0..b.batch {
            let img = &b.images[i * dim..(i + 1) * dim];
            let best = (0..4)
                .max_by(|&a, &c| {
                    let da: f32 = img.iter().zip(&task.features[a]).map(|(x, f)| x * f).sum();
                    let dc: f32 = img.iter().zip(&task.features[c]).map(|(x, f)| x * f).sum();
                    da.total_cmp(&dc)
                })
                .unwrap();
            if best as i32 == b.labels[i] {
                correct += 1;
            }
        }
        assert!(correct > 55, "correct={correct}/64");
    }

    #[test]
    fn translation_lengths_long_tailed_and_clamped() {
        let task = TranslationTask::default();
        let mut rng = Rng::new(4);
        let pairs = task.pairs(&mut rng, 2000);
        let lens: Vec<usize> = pairs.iter().map(|p| p.len()).collect();
        assert!(lens.iter().all(|&l| (1..=97).contains(&l)));
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!((15.0..35.0).contains(&mean), "mean={mean}");
        let max = *lens.iter().max().unwrap();
        assert!(max > 60, "tail too short: max={max}");
    }

    #[test]
    fn generators_are_deterministic() {
        let task = TranslationTask::default();
        let a = task.pairs(&mut Rng::new(9), 10);
        let b = task.pairs(&mut Rng::new(9), 10);
        assert_eq!(a, b);
    }
}
