//! Learning-rate schedules (paper Table 1: base LR + warmup epochs are the
//! tuned hyper-parameters; MLPerf's ResNet-50 reference uses linear warmup
//! followed by polynomial decay).

/// Linear warmup to `base_lr` over `warmup_epochs`, then polynomial decay
/// to ~0 at `train_epochs` (power 2, the MLPerf ResNet-50 reference shape).
#[derive(Clone, Copy, Debug)]
pub struct PolySchedule {
    pub base_lr: f32,
    pub warmup_epochs: f32,
    pub train_epochs: f32,
    pub power: f32,
    pub end_lr: f32,
}

impl PolySchedule {
    pub fn mlperf_resnet(base_lr: f32, warmup_epochs: f32, train_epochs: f32) -> PolySchedule {
        PolySchedule { base_lr, warmup_epochs, train_epochs, power: 2.0, end_lr: 1e-4 }
    }

    pub fn lr_at(&self, epoch: f32) -> f32 {
        if epoch < self.warmup_epochs {
            return self.base_lr * (epoch / self.warmup_epochs).max(0.0);
        }
        let span = (self.train_epochs - self.warmup_epochs).max(1e-6);
        let frac = ((epoch - self.warmup_epochs) / span).clamp(0.0, 1.0);
        self.end_lr + (self.base_lr - self.end_lr) * (1.0 - frac).powf(self.power)
    }
}

/// Inverse-sqrt with warmup (Transformer / Adam; the paper tunes warmup
/// steps and a lower peak LR for large-batch convergence).
#[derive(Clone, Copy, Debug)]
pub struct NoamSchedule {
    pub peak_lr: f32,
    pub warmup_steps: f32,
}

impl NoamSchedule {
    pub fn lr_at(&self, step: u64) -> f32 {
        let s = (step.max(1)) as f32;
        let w = self.warmup_steps.max(1.0);
        self.peak_lr * (s / w).min((w / s).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poly_warmup_is_linear() {
        let s = PolySchedule::mlperf_resnet(31.2, 25.0, 72.0);
        assert_eq!(s.lr_at(0.0), 0.0);
        assert!((s.lr_at(12.5) - 15.6).abs() < 1e-4);
        assert!((s.lr_at(25.0) - 31.2).abs() < 1e-4);
    }

    #[test]
    fn poly_decays_to_end_lr() {
        let s = PolySchedule::mlperf_resnet(31.2, 25.0, 72.0);
        assert!(s.lr_at(72.0) <= 1e-3);
        assert!(s.lr_at(100.0) <= 1e-3); // clamped past the end
        // Monotone decreasing after warmup.
        let mut prev = f32::INFINITY;
        for e in 25..=72 {
            let lr = s.lr_at(e as f32);
            assert!(lr <= prev);
            prev = lr;
        }
    }

    #[test]
    fn noam_peaks_at_warmup() {
        let s = NoamSchedule { peak_lr: 2e-3, warmup_steps: 100.0 };
        assert!(s.lr_at(100) >= s.lr_at(50));
        assert!(s.lr_at(100) >= s.lr_at(400));
        assert!((s.lr_at(100) - 2e-3).abs() < 1e-9);
        assert!((s.lr_at(400) - 1e-3).abs() < 1e-9); // 1/sqrt(4)
    }
}
