//! Optimizers in Rust (paper §3 Table 1 / Figures 5-6).
//!
//! These run on the L3 hot path: after gradient summation, the update is
//! applied either replicated (every core updates all weights) or sharded
//! (weight-update sharding — each core updates a byte-balanced shard, see
//! `crate::wus`). The math matches `python/compile/kernels/ref.py` —
//! verified by the cross-layer integration test that compares against the
//! AOT-compiled Pallas kernels at 1e-6 tolerance.
//!
//! LARS variants (paper Figures 5/6):
//! * `Scaled` — MLPerf-0.6 reference: `v = m·v + (g + β·w); w -= lr·λ·v`
//! * `Unscaled` — You et al.: `v = m·v + lr·λ·(g + β·w); w -= v`
//!
//! The unscaled variant converges in fewer epochs (Table 1: 70.6 vs 72.8,
//! and 64 with tuned momentum) — reproduced in benches/table1_lars.rs.

pub mod schedule;

/// LARS update equation variant.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LarsVariant {
    /// Paper Fig. 5 (MLPerf-0.6 reference): momentum scaled by lr at apply.
    Scaled,
    /// Paper Fig. 6 (You et al.): trust ratio folded into the buffer.
    Unscaled,
}

/// LARS hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct LarsConfig {
    pub variant: LarsVariant,
    pub eta: f32,
    pub weight_decay: f32,
    pub momentum: f32,
    /// Skip LARS adaptation for bias/BN tensors (standard practice; they
    /// get plain momentum SGD).
    pub skip_adaptation_for_1d: bool,
}

impl Default for LarsConfig {
    fn default() -> LarsConfig {
        LarsConfig {
            variant: LarsVariant::Unscaled,
            eta: 0.001,
            weight_decay: 1e-4,
            momentum: 0.9,
            skip_adaptation_for_1d: true,
        }
    }
}

/// Per-tensor LARS state = momentum buffer.
#[derive(Clone, Debug, Default)]
pub struct LarsState {
    pub v: Vec<f32>,
}

/// One fused LARS step on a flat tensor (w and state updated in place).
/// `is_1d` marks bias/BN tensors exempt from adaptation.
pub fn lars_step(
    cfg: &LarsConfig,
    lr: f32,
    w: &mut [f32],
    g: &[f32],
    state: &mut LarsState,
    is_1d: bool,
) {
    assert_eq!(w.len(), g.len());
    if state.v.is_empty() {
        state.v = vec![0.0; w.len()];
    }
    assert_eq!(state.v.len(), w.len());

    let lam = if cfg.skip_adaptation_for_1d && is_1d {
        1.0
    } else {
        // Norms in f32 (the paper's mixed-precision rule).
        let w_norm = l2_norm(w);
        let g_norm = l2_norm(g);
        cfg.eta * w_norm / (g_norm + cfg.weight_decay * w_norm + 1e-9)
    };
    let beta = cfg.weight_decay;
    let m = cfg.momentum;
    match cfg.variant {
        LarsVariant::Scaled => {
            for i in 0..w.len() {
                let update = g[i] + beta * w[i];
                state.v[i] = m * state.v[i] + update;
                w[i] -= lr * lam * state.v[i];
            }
        }
        LarsVariant::Unscaled => {
            for i in 0..w.len() {
                let update = g[i] + beta * w[i];
                state.v[i] = m * state.v[i] + lr * lam * update;
                w[i] -= state.v[i];
            }
        }
    }
}

/// Adam hyper-parameters (Transformer/GNMT in the paper).
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> AdamConfig {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Per-tensor Adam state.
#[derive(Clone, Debug, Default)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

/// One fused Adam step; `step` is 1-based.
pub fn adam_step(
    cfg: &AdamConfig,
    lr: f32,
    step: u64,
    w: &mut [f32],
    g: &[f32],
    state: &mut AdamState,
) {
    assert_eq!(w.len(), g.len());
    if state.m.is_empty() {
        state.m = vec![0.0; w.len()];
        state.v = vec![0.0; w.len()];
    }
    let b1 = cfg.beta1;
    let b2 = cfg.beta2;
    let bc1 = 1.0 - b1.powi(step as i32);
    let bc2 = 1.0 - b2.powi(step as i32);
    for i in 0..w.len() {
        state.m[i] = b1 * state.m[i] + (1.0 - b1) * g[i];
        state.v[i] = b2 * state.v[i] + (1.0 - b2) * g[i] * g[i];
        let m_hat = state.m[i] / bc1;
        let v_hat = state.v[i] / bc2;
        w[i] -= lr * m_hat / (v_hat.sqrt() + cfg.eps);
    }
}

/// Plain momentum SGD (baseline).
pub fn sgd_momentum_step(
    lr: f32,
    momentum: f32,
    w: &mut [f32],
    g: &[f32],
    v: &mut Vec<f32>,
) {
    if v.is_empty() {
        *v = vec![0.0; w.len()];
    }
    for i in 0..w.len() {
        v[i] = momentum * v[i] + g[i];
        w[i] -= lr * v[i];
    }
}

fn l2_norm(xs: &[f32]) -> f32 {
    xs.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(seed: u64, n: usize) -> Vec<f32> {
        Rng::new(seed).normal_vec(n, 1.0)
    }

    #[test]
    fn lars_scaled_matches_closed_form() {
        // Hand-computed single element: w=2, g=0.5, v=0, lr=0.1,
        // eta=0.01, beta=0 (so lam = eta*|w|/|g| = 0.04), m=0.9.
        let cfg = LarsConfig {
            variant: LarsVariant::Scaled,
            eta: 0.01,
            weight_decay: 0.0,
            momentum: 0.9,
            skip_adaptation_for_1d: false,
        };
        let mut w = vec![2.0f32];
        let mut st = LarsState::default();
        lars_step(&cfg, 0.1, &mut w, &[0.5], &mut st, false);
        // lam = 0.01 * 2 / 0.5 = 0.04; v = 0.5; w = 2 - 0.1*0.04*0.5 = 1.998
        assert!((w[0] - 1.998).abs() < 1e-6, "{}", w[0]);
        assert!((st.v[0] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn lars_unscaled_matches_closed_form() {
        let cfg = LarsConfig {
            variant: LarsVariant::Unscaled,
            eta: 0.01,
            weight_decay: 0.0,
            momentum: 0.9,
            skip_adaptation_for_1d: false,
        };
        let mut w = vec![2.0f32];
        let mut st = LarsState::default();
        lars_step(&cfg, 0.1, &mut w, &[0.5], &mut st, false);
        // v = 0.1*0.04*0.5 = 0.002; w = 2 - 0.002 = 1.998
        assert!((w[0] - 1.998).abs() < 1e-6);
        assert!((st.v[0] - 0.002).abs() < 1e-8);
    }

    #[test]
    fn variants_agree_on_first_step_diverge_after() {
        // From v=0 both variants take the same first step, then diverge —
        // the subtle difference Table 1 is about.
        let cfg_s = LarsConfig { variant: LarsVariant::Scaled, ..Default::default() };
        let cfg_u = LarsConfig { variant: LarsVariant::Unscaled, ..Default::default() };
        let g1 = randvec(1, 64);
        let g2 = randvec(2, 64);
        let mut ws = randvec(0, 64);
        let mut wu = ws.clone();
        let mut ss = LarsState::default();
        let mut su = LarsState::default();
        lars_step(&cfg_s, 0.1, &mut ws, &g1, &mut ss, false);
        lars_step(&cfg_u, 0.1, &mut wu, &g1, &mut su, false);
        for (a, b) in ws.iter().zip(&wu) {
            assert!((a - b).abs() < 1e-6);
        }
        lars_step(&cfg_s, 0.1, &mut ws, &g2, &mut ss, false);
        lars_step(&cfg_u, 0.1, &mut wu, &g2, &mut su, false);
        let diff: f32 = ws.iter().zip(&wu).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "variants should diverge once momentum is non-zero");
    }

    #[test]
    fn scaled_momentum_couples_to_lr_changes() {
        // The defining flaw of the scaled variant (why MLPerf's reference
        // differs): decaying lr mid-momentum leaves a mismatched buffer.
        // Unscaled: effective step shrinks smoothly with lr.
        // We verify the mechanical property: after an lr drop to 0, the
        // scaled variant stops moving entirely while unscaled keeps
        // applying its buffered velocity.
        let g = randvec(3, 16);
        let mut w_s = randvec(4, 16);
        let mut w_u = w_s.clone();
        let cfg_s = LarsConfig { variant: LarsVariant::Scaled, ..Default::default() };
        let cfg_u = LarsConfig { variant: LarsVariant::Unscaled, ..Default::default() };
        let mut ss = LarsState::default();
        let mut su = LarsState::default();
        lars_step(&cfg_s, 1.0, &mut w_s, &g, &mut ss, false);
        lars_step(&cfg_u, 1.0, &mut w_u, &g, &mut su, false);
        let before_s = w_s.clone();
        let before_u = w_u.clone();
        lars_step(&cfg_s, 0.0, &mut w_s, &vec![0.0; 16], &mut ss, false);
        lars_step(&cfg_u, 0.0, &mut w_u, &vec![0.0; 16], &mut su, false);
        let moved_s: f32 = w_s.iter().zip(&before_s).map(|(a, b)| (a - b).abs()).sum();
        let moved_u: f32 = w_u.iter().zip(&before_u).map(|(a, b)| (a - b).abs()).sum();
        assert_eq!(moved_s, 0.0);
        assert!(moved_u > 0.0);
    }

    #[test]
    fn lars_skips_adaptation_for_1d() {
        let cfg = LarsConfig::default();
        let mut w = vec![100.0f32; 8]; // huge norm would inflate lam
        let g = vec![1.0f32; 8];
        let mut st = LarsState::default();
        lars_step(&cfg, 0.1, &mut w, &g, &mut st, true);
        // lam == 1 → v = lr * (g + beta*w) = 0.1 * (1 + 1e-4*100) = 0.101
        assert!((st.v[0] - 0.101).abs() < 1e-6, "{}", st.v[0]);
    }

    #[test]
    fn adam_matches_closed_form_first_step() {
        let cfg = AdamConfig::default();
        let mut w = vec![1.0f32];
        let mut st = AdamState::default();
        adam_step(&cfg, 0.001, 1, &mut w, &[0.5], &mut st);
        // m=0.05, v=0.00025; m_hat=0.5, v_hat=0.25; step = lr*0.5/0.5 = lr
        assert!((w[0] - (1.0 - 0.001)).abs() < 1e-6, "{}", w[0]);
    }

    #[test]
    fn adam_step_size_bounded_by_lr() {
        // |Δw| ⪅ lr for any gradient scale (Adam's invariance).
        let cfg = AdamConfig::default();
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut w = vec![0.0f32; 32];
            let g: Vec<f32> = randvec(9, 32).iter().map(|x| x * scale).collect();
            let mut st = AdamState::default();
            adam_step(&cfg, 0.01, 1, &mut w, &g, &mut st);
            for &x in &w {
                assert!(x.abs() <= 0.0101, "scale {scale}: step {x}");
            }
        }
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut w = vec![0.0f32];
        let mut v = vec![];
        sgd_momentum_step(0.1, 0.9, &mut w, &[1.0], &mut v);
        sgd_momentum_step(0.1, 0.9, &mut w, &[1.0], &mut v);
        // v1=1, w=-0.1; v2=1.9, w=-0.29
        assert!((w[0] + 0.29).abs() < 1e-6);
    }
}
