//! Epochs-to-converge vs. batch size (paper Fig. 8): "the number of epochs
//! to converge the model to target accuracy increases for larger batch
//! sizes."
//!
//! Each model's curve is a piecewise-log-linear interpolation through
//! anchor points taken from the paper and the public MLPerf-0.6 submission
//! data: flat up to a knee batch size, then epochs grow with log2(batch).
//! The paper's explicit anchors:
//! * SSD: "22% more epochs ... increasing batch size from 256 to 1024 and
//!   an additional 27% more epochs at batch size 2048."
//! * ResNet-50: 64-72.8 epochs at batch 32K (Table 1) vs the small-batch
//!   reference of ~41 epochs (MLPerf-0.6 reference convergence).
//! * Mask-RCNN: "did not converge ... on a global batch size larger than
//!   128" — modeled as an infinite-epoch wall.

/// Piecewise-linear curve in log2(batch) space.
#[derive(Clone, Debug)]
pub struct EpochCurve {
    /// (log2(batch), epochs) anchor points, ascending.
    anchors: Vec<(f64, f64)>,
    /// Batches above this do not converge at all (None = no wall).
    pub max_converging_batch: Option<usize>,
}

impl EpochCurve {
    pub fn new(anchor_points: &[(usize, f64)], max_batch: Option<usize>) -> EpochCurve {
        assert!(anchor_points.len() >= 2);
        let anchors: Vec<(f64, f64)> =
            anchor_points.iter().map(|&(b, e)| ((b as f64).log2(), e)).collect();
        for w in anchors.windows(2) {
            assert!(w[0].0 < w[1].0, "anchors must be ascending in batch");
        }
        EpochCurve { anchors, max_converging_batch: max_batch }
    }

    /// Epochs to reach the quality target at this global batch size.
    /// None if the model does not converge at this batch (Mask-RCNN wall).
    pub fn epochs(&self, batch: usize) -> Option<f64> {
        if let Some(maxb) = self.max_converging_batch {
            if batch > maxb {
                return None;
            }
        }
        let x = (batch as f64).log2();
        let a = &self.anchors;
        if x <= a[0].0 {
            return Some(a[0].1);
        }
        if x >= a[a.len() - 1].0 {
            // Extrapolate with the last segment's slope.
            let (x0, y0) = a[a.len() - 2];
            let (x1, y1) = a[a.len() - 1];
            return Some(y1 + (y1 - y0) / (x1 - x0) * (x - x1));
        }
        for w in a.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            if x <= x1 {
                return Some(y0 + (y1 - y0) * (x - x0) / (x1 - x0));
            }
        }
        unreachable!()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::registry::all_models;

    #[test]
    fn interpolation_hits_anchors() {
        let c = EpochCurve::new(&[(256, 50.0), (1024, 61.0), (2048, 77.5)], None);
        assert_eq!(c.epochs(256), Some(50.0));
        assert_eq!(c.epochs(1024), Some(61.0));
        assert_eq!(c.epochs(2048), Some(77.5));
        // Between anchors: monotone.
        let mid = c.epochs(512).unwrap();
        assert!(mid > 50.0 && mid < 61.0);
    }

    #[test]
    fn flat_below_first_anchor() {
        let c = EpochCurve::new(&[(256, 50.0), (2048, 70.0)], None);
        assert_eq!(c.epochs(32), Some(50.0));
    }

    #[test]
    fn wall_returns_none() {
        let c = EpochCurve::new(&[(32, 20.0), (128, 25.0)], Some(128));
        assert!(c.epochs(128).is_some());
        assert!(c.epochs(256).is_none());
    }

    #[test]
    fn ssd_matches_paper_percentages() {
        // Paper Fig. 8 anchor: +22% from 256→1024, +27% more at 2048.
        let ssd = all_models().into_iter().find(|m| m.name == "ssd").unwrap();
        let e256 = ssd.epochs.epochs(256).unwrap();
        let e1024 = ssd.epochs.epochs(1024).unwrap();
        let e2048 = ssd.epochs.epochs(2048).unwrap();
        assert!((e1024 / e256 - 1.22).abs() < 0.02, "{}", e1024 / e256);
        assert!((e2048 / e1024 - 1.27).abs() < 0.02, "{}", e2048 / e1024);
    }

    #[test]
    fn all_curves_monotone_nondecreasing() {
        for m in all_models() {
            let mut prev = 0.0;
            for lb in 5..=16 {
                let b = 1usize << lb;
                if let Some(e) = m.epochs.epochs(b) {
                    assert!(
                        e + 1e-9 >= prev,
                        "{}: epochs({b}) = {e} < {prev}",
                        m.name
                    );
                    prev = e;
                }
            }
        }
    }

    #[test]
    fn resnet_epochs_at_32k_match_table1() {
        let rn = all_models().into_iter().find(|m| m.name == "resnet50").unwrap();
        let e = rn.epochs.epochs(32768).unwrap();
        // Table 1 range: 64 (tuned) to 72.8 (scaled momentum reference).
        assert!((60.0..76.0).contains(&e), "epochs at 32K = {e}");
    }

    #[test]
    fn maskrcnn_has_batch_wall_at_128() {
        let mr = all_models().into_iter().find(|m| m.name == "maskrcnn").unwrap();
        assert!(mr.epochs.epochs(128).is_some());
        assert!(mr.epochs.epochs(256).is_none());
    }
}
