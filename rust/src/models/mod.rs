//! MLPerf-0.6 model inventories, convergence curves (Fig. 8) and the
//! distributed batch-norm grouping from [19] (§2).

pub mod batchnorm;
pub mod convergence;
pub mod proxy;
pub mod registry;

pub use convergence::EpochCurve;
pub use proxy::{proxy_dims, ProxyDims, TaskKind};
pub use registry::{all_models, model, Layout, ModelProfile, Optimizer};
