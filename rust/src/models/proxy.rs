//! MLP-scale reference proxies for the MLPerf-0.6 registry models.
//!
//! The paper's benchmarks (ResNet-50, SSD, Mask-RCNN, Transformer, GNMT)
//! are far too large to run forward/backward in-process, but the *trainer*
//! — data pipeline, gradient summation, weight-update sharding, optimizer
//! choice, distributed eval — is shape- and family-generic. Each registry
//! model therefore gets a miniature dense proxy with the same task family
//! (LM for the sequence models, image classification for the vision
//! models) and a distinct width, so the live trainer exercises every §2
//! technique per model without AOT artifacts. `runtime::reference` turns
//! these dims into an executable fwd/bwd graph with exact analytic
//! gradients.
//!
//! The proxy is keyed by model *family* (the prefix before the first `_`),
//! so manifest-style keys like `transformer_tiny` resolve to the same
//! family as the registry name `transformer`.

/// Workload family of a model: drives the input pipeline and eval metric.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TaskKind {
    /// Next-token language modelling ([`crate::data::synthetic::LmTask`]).
    Lm,
    /// Image classification ([`crate::data::synthetic::ImageTask`]).
    Image,
}

/// Dense-proxy dimensions for one registry model family.
#[derive(Clone, Copy, Debug)]
pub struct ProxyDims {
    pub family: &'static str,
    pub kind: TaskKind,
    /// Hidden width of the two dense trunk layers.
    pub hidden: usize,
    /// Default per-core batch (examples for Image, sequences for Lm).
    pub batch_per_core: usize,
    /// LM vocabulary (also the logit width for Lm proxies).
    pub vocab: usize,
    /// LM sequence length.
    pub seq: usize,
    /// Image side (inputs are `side * side * 3` NHWC f32).
    pub image: usize,
    /// Image class count (logit width for Image proxies).
    pub classes: usize,
}

impl ProxyDims {
    /// Flat input feature width seen by the first dense layer.
    pub fn input_dim(&self) -> usize {
        match self.kind {
            TaskKind::Lm => self.vocab,
            TaskKind::Image => self.image * self.image * 3,
        }
    }

    /// Logit width.
    pub fn output_dim(&self) -> usize {
        match self.kind {
            TaskKind::Lm => self.vocab,
            TaskKind::Image => self.classes,
        }
    }

    /// Approximate forward FLOPs per *example* of the proxy (2 FLOPs per
    /// MAC; the LM input layer is an embedding row lookup, not a matmul).
    /// `sweep --live` fits measured seconds/example against this to get a
    /// host GFLOP/s coefficient for `costs::StepCostModel` calibration.
    pub fn flops_per_example(&self) -> f64 {
        let h = self.hidden as f64;
        let c = self.output_dim() as f64;
        let per_unit = match self.kind {
            TaskKind::Lm => h + h * h + h * c,
            TaskKind::Image => self.input_dim() as f64 * h + h * h + h * c,
        };
        let units = match self.kind {
            TaskKind::Lm => self.seq as f64,
            TaskKind::Image => 1.0,
        };
        2.0 * per_unit * units
    }

    /// Forward FLOPs per default per-core step — the live-trainer analog
    /// of a registry profile's per-step compute load.
    pub fn flops_per_step(&self) -> f64 {
        self.flops_per_example() * self.batch_per_core as f64
    }
}

/// All proxy families (the five registry models plus the `cnn`/mini family
/// the artifact pipeline uses for its trainable mini-models).
///
/// Widths are chosen so the *per-core step-time ratios* of the live
/// trainer resemble the paper's Table 1 compute ordering (per-step FLOPs,
/// see [`ProxyDims::flops_per_step`], resnet50 = 1.0):
///
/// ```text
/// resnet50 1.0 < ssd ~1.8 < gnmt ~3.5 < transformer ~6.8 < maskrcnn ~10.6
/// ```
///
/// — the same ordering as the registry's `fwd_flops_per_example`
/// (3.9e9 < 7.5e9 < 1.1e10 < 1.4e10 < 1.5e12), with Mask-RCNN's spread
/// deliberately compressed: at true scale it would dwarf every proxy and
/// make live micro-grids unusable. `sweep --live` checks the *ordering*,
/// not absolute ratios.
pub const PROXY_FAMILIES: [ProxyDims; 6] = [
    ProxyDims {
        family: "transformer",
        kind: TaskKind::Lm,
        hidden: 160,
        batch_per_core: 4,
        vocab: 64,
        seq: 16,
        image: 0,
        classes: 0,
    },
    ProxyDims {
        family: "gnmt",
        kind: TaskKind::Lm,
        hidden: 128,
        batch_per_core: 4,
        vocab: 64,
        seq: 12,
        image: 0,
        classes: 0,
    },
    ProxyDims {
        family: "resnet50",
        kind: TaskKind::Image,
        hidden: 128,
        batch_per_core: 8,
        vocab: 0,
        seq: 0,
        image: 8,
        classes: 10,
    },
    ProxyDims {
        family: "ssd",
        kind: TaskKind::Image,
        hidden: 160,
        batch_per_core: 8,
        vocab: 0,
        seq: 0,
        image: 10,
        classes: 16,
    },
    ProxyDims {
        family: "maskrcnn",
        kind: TaskKind::Image,
        hidden: 384,
        batch_per_core: 8,
        vocab: 0,
        seq: 0,
        image: 16,
        classes: 16,
    },
    ProxyDims {
        family: "cnn",
        kind: TaskKind::Image,
        hidden: 128,
        batch_per_core: 8,
        vocab: 0,
        seq: 0,
        image: 8,
        classes: 10,
    },
];

/// Resolve a model key (registry name or manifest-style `family_preset`)
/// to its proxy dims. `None` for unknown families.
pub fn proxy_dims(model: &str) -> Option<ProxyDims> {
    let family = model.split('_').next().unwrap_or(model);
    PROXY_FAMILIES.iter().find(|d| d.family == family).copied()
}

/// The known proxy family names, comma-joined (for error messages — kept
/// in sync with [`PROXY_FAMILIES`] by construction).
pub fn known_families() -> String {
    PROXY_FAMILIES.map(|d| d.family).join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registry_model_has_a_proxy() {
        for m in crate::models::all_models() {
            let d = proxy_dims(m.name).unwrap_or_else(|| panic!("no proxy for {}", m.name));
            assert!(d.hidden > 0);
            assert!(d.batch_per_core > 0);
            assert!(d.input_dim() > 0);
            assert!(d.output_dim() > 1, "{}: need ≥2 classes for CE", m.name);
        }
    }

    #[test]
    fn preset_suffixes_resolve_to_the_family() {
        assert_eq!(proxy_dims("transformer_tiny").unwrap().family, "transformer");
        assert_eq!(proxy_dims("cnn_mini").unwrap().family, "cnn");
        assert_eq!(proxy_dims("resnet50").unwrap().family, "resnet50");
        assert!(proxy_dims("bert_large").is_none());
    }

    #[test]
    fn kinds_match_the_paper_families() {
        assert_eq!(proxy_dims("transformer").unwrap().kind, TaskKind::Lm);
        assert_eq!(proxy_dims("gnmt").unwrap().kind, TaskKind::Lm);
        for img in ["resnet50", "ssd", "maskrcnn"] {
            assert_eq!(proxy_dims(img).unwrap().kind, TaskKind::Image);
        }
    }

    /// The widths must keep the registry's per-step compute ordering so
    /// live step-time ratios resemble Table 1 (`sweep --live` gates on
    /// this ordering at trainer level; this pins the static version).
    #[test]
    fn per_step_flops_follow_the_registry_ordering() {
        let f = |m: &str| proxy_dims(m).unwrap().flops_per_step();
        assert!(f("resnet50") < f("ssd"));
        assert!(f("ssd") < f("gnmt"));
        assert!(f("gnmt") < f("transformer"));
        assert!(f("transformer") < f("maskrcnn"));
        // Sensible spread: the heaviest proxy is 5-20x the lightest, so a
        // live micro-grid finishes in CI time.
        let ratio = f("maskrcnn") / f("resnet50");
        assert!((5.0..20.0).contains(&ratio), "spread {ratio:.1}");
    }
}
