//! MLP-scale reference proxies for the MLPerf-0.6 registry models.
//!
//! The paper's benchmarks (ResNet-50, SSD, Mask-RCNN, Transformer, GNMT)
//! are far too large to run forward/backward in-process, but the *trainer*
//! — data pipeline, gradient summation, weight-update sharding, optimizer
//! choice, distributed eval — is shape- and family-generic. Each registry
//! model therefore gets a miniature dense proxy with the same task family
//! (LM for the sequence models, image classification for the vision
//! models) and a distinct width, so the live trainer exercises every §2
//! technique per model without AOT artifacts. `runtime::reference` turns
//! these dims into an executable fwd/bwd graph with exact analytic
//! gradients.
//!
//! The proxy is keyed by model *family* (the prefix before the first `_`),
//! so manifest-style keys like `transformer_tiny` resolve to the same
//! family as the registry name `transformer`.

/// Workload family of a model: drives the input pipeline and eval metric.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TaskKind {
    /// Next-token language modelling ([`crate::data::synthetic::LmTask`]).
    Lm,
    /// Image classification ([`crate::data::synthetic::ImageTask`]).
    Image,
}

/// Dense-proxy dimensions for one registry model family.
#[derive(Clone, Copy, Debug)]
pub struct ProxyDims {
    pub family: &'static str,
    pub kind: TaskKind,
    /// Hidden width of the two dense trunk layers.
    pub hidden: usize,
    /// Default per-core batch (examples for Image, sequences for Lm).
    pub batch_per_core: usize,
    /// LM vocabulary (also the logit width for Lm proxies).
    pub vocab: usize,
    /// LM sequence length.
    pub seq: usize,
    /// Image side (inputs are `side * side * 3` NHWC f32).
    pub image: usize,
    /// Image class count (logit width for Image proxies).
    pub classes: usize,
}

impl ProxyDims {
    /// Flat input feature width seen by the first dense layer.
    pub fn input_dim(&self) -> usize {
        match self.kind {
            TaskKind::Lm => self.vocab,
            TaskKind::Image => self.image * self.image * 3,
        }
    }

    /// Logit width.
    pub fn output_dim(&self) -> usize {
        match self.kind {
            TaskKind::Lm => self.vocab,
            TaskKind::Image => self.classes,
        }
    }
}

/// All proxy families (the five registry models plus the `cnn`/mini family
/// the artifact pipeline uses for its trainable mini-models).
pub const PROXY_FAMILIES: [ProxyDims; 6] = [
    ProxyDims {
        family: "transformer",
        kind: TaskKind::Lm,
        hidden: 96,
        batch_per_core: 8,
        vocab: 64,
        seq: 16,
        image: 0,
        classes: 0,
    },
    ProxyDims {
        family: "gnmt",
        kind: TaskKind::Lm,
        hidden: 64,
        batch_per_core: 8,
        vocab: 48,
        seq: 12,
        image: 0,
        classes: 0,
    },
    ProxyDims {
        family: "resnet50",
        kind: TaskKind::Image,
        hidden: 96,
        batch_per_core: 8,
        vocab: 0,
        seq: 0,
        image: 8,
        classes: 10,
    },
    ProxyDims {
        family: "ssd",
        kind: TaskKind::Image,
        hidden: 64,
        batch_per_core: 8,
        vocab: 0,
        seq: 0,
        image: 8,
        classes: 8,
    },
    ProxyDims {
        family: "maskrcnn",
        kind: TaskKind::Image,
        hidden: 80,
        batch_per_core: 8,
        vocab: 0,
        seq: 0,
        image: 8,
        classes: 8,
    },
    ProxyDims {
        family: "cnn",
        kind: TaskKind::Image,
        hidden: 96,
        batch_per_core: 8,
        vocab: 0,
        seq: 0,
        image: 8,
        classes: 10,
    },
];

/// Resolve a model key (registry name or manifest-style `family_preset`)
/// to its proxy dims. `None` for unknown families.
pub fn proxy_dims(model: &str) -> Option<ProxyDims> {
    let family = model.split('_').next().unwrap_or(model);
    PROXY_FAMILIES.iter().find(|d| d.family == family).copied()
}

/// The known proxy family names, comma-joined (for error messages — kept
/// in sync with [`PROXY_FAMILIES`] by construction).
pub fn known_families() -> String {
    PROXY_FAMILIES.map(|d| d.family).join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registry_model_has_a_proxy() {
        for m in crate::models::all_models() {
            let d = proxy_dims(m.name).unwrap_or_else(|| panic!("no proxy for {}", m.name));
            assert!(d.hidden > 0);
            assert!(d.batch_per_core > 0);
            assert!(d.input_dim() > 0);
            assert!(d.output_dim() > 1, "{}: need ≥2 classes for CE", m.name);
        }
    }

    #[test]
    fn preset_suffixes_resolve_to_the_family() {
        assert_eq!(proxy_dims("transformer_tiny").unwrap().family, "transformer");
        assert_eq!(proxy_dims("cnn_mini").unwrap().family, "cnn");
        assert_eq!(proxy_dims("resnet50").unwrap().family, "resnet50");
        assert!(proxy_dims("bert_large").is_none());
    }

    #[test]
    fn kinds_match_the_paper_families() {
        assert_eq!(proxy_dims("transformer").unwrap().kind, TaskKind::Lm);
        assert_eq!(proxy_dims("gnmt").unwrap().kind, TaskKind::Lm);
        for img in ["resnet50", "ssd", "maskrcnn"] {
            assert_eq!(proxy_dims(img).unwrap().kind, TaskKind::Image);
        }
    }
}
