//! Distributed batch normalization (paper §2: "When the number of examples
//! per TPU accelerator is below a threshold, we use the distributed
//! normalization technique presented in [19]").
//!
//! Per-core batches at pod scale are tiny (ResNet-50: 16/core at 32K
//! batch over 2048 cores), so BN statistics over the local batch alone are
//! too noisy. [19] forms *normalization groups* of g cores that all-reduce
//! their per-core moments; the group mean/variance are then exact moments
//! of the union of the group's examples.

use crate::collectives::all_reduce_scalars;
use crate::fabric::Endpoint;

/// Per-core batch moments for one channel: (count, sum, sum of squares).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Moments {
    pub count: f32,
    pub sum: f32,
    pub sumsq: f32,
}

impl Moments {
    pub fn of(xs: &[f32]) -> Moments {
        Moments {
            count: xs.len() as f32,
            sum: xs.iter().sum(),
            sumsq: xs.iter().map(|x| x * x).sum(),
        }
    }

    pub fn mean(&self) -> f32 {
        self.sum / self.count
    }

    pub fn var(&self) -> f32 {
        (self.sumsq / self.count - self.mean() * self.mean()).max(0.0)
    }

    pub fn merge(&self, other: &Moments) -> Moments {
        Moments {
            count: self.count + other.count,
            sum: self.sum + other.sum,
            sumsq: self.sumsq + other.sumsq,
        }
    }
}

/// The normalization-group size rule: group enough cores that the combined
/// examples reach `target_examples` (the threshold below which local BN
/// degrades; [19] uses ≥32).
pub fn group_size(per_core_batch: usize, target_examples: usize, max_group: usize) -> usize {
    let mut g = 1;
    while g < max_group && per_core_batch * g < target_examples {
        g *= 2;
    }
    g
}

/// All-reduce per-channel moments within a normalization subgroup; returns
/// the group mean/var per channel. SPMD over the fabric.
pub fn distributed_moments(
    ep: &mut Endpoint,
    group: &[usize],
    locals: &[Moments],
) -> Vec<(f32, f32)> {
    let mut buf: Vec<f32> = Vec::with_capacity(locals.len() * 3);
    for m in locals {
        buf.extend_from_slice(&[m.count, m.sum, m.sumsq]);
    }
    all_reduce_scalars(ep, group, &mut buf);
    buf.chunks(3)
        .map(|c| {
            let m = Moments { count: c[0], sum: c[1], sumsq: c[2] };
            (m.mean(), m.var())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::run_spmd;
    use crate::util::rng::Rng;

    #[test]
    fn merged_moments_are_exact_union_moments() {
        let mut rng = Rng::new(0);
        let a = rng.normal_vec(37, 2.0);
        let b = rng.normal_vec(63, 0.5);
        let merged = Moments::of(&a).merge(&Moments::of(&b));
        let mut union = a.clone();
        union.extend_from_slice(&b);
        let exact = Moments::of(&union);
        assert!((merged.mean() - exact.mean()).abs() < 1e-5);
        assert!((merged.var() - exact.var()).abs() < 1e-4);
    }

    #[test]
    fn group_size_rule() {
        // Paper regime: 16 examples/core, want ≥32 → group of 2.
        assert_eq!(group_size(16, 32, 64), 2);
        assert_eq!(group_size(4, 32, 64), 8);
        // Already enough examples locally → no grouping.
        assert_eq!(group_size(64, 32, 64), 1);
        // Cap respected.
        assert_eq!(group_size(1, 1024, 16), 16);
    }

    #[test]
    fn distributed_moments_match_global() {
        let world = 4;
        let per_core = 8;
        // Build the global dataset deterministically; each core owns a slice.
        let all: Vec<f32> = (0..world * per_core).map(|i| (i * i % 17) as f32).collect();
        let exact = Moments::of(&all);
        let out = run_spmd(world, |ep| {
            let mine = &all[ep.rank * per_core..(ep.rank + 1) * per_core];
            let group: Vec<usize> = (0..world).collect();
            distributed_moments(ep, &group, &[Moments::of(mine)])
        });
        for r in 0..world {
            let (mean, var) = out[r][0];
            assert!((mean - exact.mean()).abs() < 1e-4);
            assert!((var - exact.var()).abs() < 1e-2);
        }
    }

    #[test]
    fn subgroup_moments_stay_in_subgroup() {
        let out = run_spmd(4, |ep| {
            let group: Vec<usize> = if ep.rank < 2 { vec![0, 1] } else { vec![2, 3] };
            let val = if ep.rank < 2 { 1.0 } else { 5.0 };
            let m = Moments::of(&[val, val]);
            distributed_moments(ep, &group, &[m])
        });
        assert!((out[0][0].0 - 1.0).abs() < 1e-6);
        assert!((out[3][0].0 - 5.0).abs() < 1e-6);
    }

    #[test]
    fn variance_reduction_with_grouping() {
        // Group statistics are less noisy: variance of the group-mean
        // estimator shrinks ~1/g. Monte-Carlo check.
        let trials = 200;
        let per_core = 4;
        let mut rng = Rng::new(42);
        let spread = |g: usize, rng: &mut Rng| -> f64 {
            let mut means = Vec::new();
            for _ in 0..trials {
                let xs = rng.normal_vec(per_core * g, 1.0);
                means.push(Moments::of(&xs).mean() as f64);
            }
            let mu = means.iter().sum::<f64>() / trials as f64;
            means.iter().map(|m| (m - mu).powi(2)).sum::<f64>() / trials as f64
        };
        let v1 = spread(1, &mut rng);
        let v8 = spread(8, &mut rng);
        assert!(v8 < v1 / 4.0, "v1={v1} v8={v8}");
    }
}
