//! MLPerf-0.6 model inventories (paper §3): parameter counts, per-example
//! FLOPs, dataset sizes, quality targets, optimizer choice, the batch-size
//! scaling policy of the Google submission (Fig. 7), and the gradient
//! tensor-size census used by the gradient-summation model.
//!
//! Numbers are from the public model descriptions and MLPerf-0.6 reference
//! implementations; they drive the *simulator* (Figs. 7-9), not the real
//! trainable mini-models (those live in python/compile).

use crate::models::convergence::EpochCurve;
use crate::netsim::cost::resnet50_gradient_bytes;

/// Optimizer used by a benchmark (determines update HBM traffic).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Optimizer {
    Lars,
    Adam,
    Sgd,
}

impl Optimizer {
    /// HBM bytes per parameter per update (reads + writes, f32 state).
    pub fn bytes_per_param(&self) -> f64 {
        match self {
            Optimizer::Lars => 20.0, // r:w,g,v w:w,v
            Optimizer::Adam => 28.0, // r:w,g,m,v w:w,m,v
            Optimizer::Sgd => 16.0,  // r:w,g,v w:w,v (momentum)
        }
    }
}

/// Data/model-parallel layout chosen for a core count (paper Fig. 7 + §3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Layout {
    pub cores: usize,
    /// Spatial/graph model-parallel degree (1 = pure data parallel).
    pub mp: usize,
    /// Data-parallel replica count = cores / mp.
    pub replicas: usize,
    pub global_batch: usize,
}

impl Layout {
    pub fn per_replica_batch(&self) -> f64 {
        self.global_batch as f64 / self.replicas as f64
    }
}

/// One MLPerf-0.6 benchmark's profile.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Trainable parameters.
    pub params: f64,
    /// Forward FLOPs per example (per sentence for the NMT models).
    pub fwd_flops_per_example: f64,
    /// HBM activation traffic per example per core (coarse).
    pub hbm_bytes_per_example: f64,
    /// MXU utilization units per example (1 for image models; ≈ tokens per
    /// sentence for sequence models — see devicesim::step_model).
    pub util_units_per_example: f64,
    pub train_examples: usize,
    pub eval_examples: usize,
    /// Eval cadence in epochs (paper: ResNet-50 every 4 epochs).
    pub eval_interval_epochs: f64,
    pub quality_target: f64,
    pub quality_metric: &'static str,
    pub optimizer: Optimizer,
    pub epochs: EpochCurve,
    /// Batch-size cap from convergence (Fig. 7/8).
    pub max_batch: usize,
    /// Max useful spatial/graph partition degree (§3).
    pub max_mp: usize,
}

impl ModelProfile {
    /// The Google-submission layout for a core count (Fig. 7 shape: only
    /// ResNet-50 scales batch aggressively; the rest stay ≤2x across the
    /// submission range and use model parallelism to keep scaling).
    pub fn layout(&self, cores: usize) -> Layout {
        assert!(cores >= 1);
        let (mp, global_batch) = match self.name {
            // ResNet-50: pure data parallel, batch 16/core up to 32K.
            "resnet50" => (1, (16 * cores).clamp(256, 32768)),
            // SSD (§3): spatial partitioning keeps per-replica batch ≥ 4
            // once data parallelism alone would drop below it.
            "ssd" => {
                let mut mp = 1;
                while mp < self.max_mp && 4 * (cores / mp) > self.max_batch {
                    mp *= 2;
                }
                let replicas = (cores / mp).max(1);
                (mp, (4 * replicas).clamp(1024, 2048))
            }
            // Mask-RCNN (§3): "on 128 and 256 cores, model parallelism is
            // enabled across 2 and 4 cores" — mp = cores/64 capped at 4;
            // replicas capped by the 128 batch wall.
            "maskrcnn" => {
                let mp = (cores / 64).clamp(1, 4).next_power_of_two();
                let mp = if mp * 64 > cores { mp / 2 } else { mp }.max(1);
                let replicas = (cores / mp).min(self.max_batch).max(1);
                (mp, replicas.min(self.max_batch))
            }
            // Transformer (§3): global 2048, 1/core at pod scale; 1024 at
            // the smaller submission scales (growth ≤ 2x, Fig. 7).
            "transformer" => (1, cores.clamp(1024, 2048)),
            // GNMT: 512 → 1024 across the range.
            "gnmt" => (1, cores.clamp(512, 1024)),
            _ => (1, cores),
        };
        let replicas = (cores / mp).min(global_batch).max(1);
        Layout { cores, mp, replicas, global_batch }
    }

    /// Largest core count the model can actually occupy (per-replica batch
    /// ≥ 1 with maximum model parallelism) — Mask-RCNN tops out at 512.
    pub fn max_useful_cores(&self) -> usize {
        self.max_batch * self.max_mp
    }

    /// Per-tensor gradient byte census (for the gradsum pipeline model).
    pub fn gradient_bytes(&self) -> Vec<f64> {
        match self.name {
            "resnet50" => resnet50_gradient_bytes(),
            "ssd" => {
                // ResNet-34 backbone (36 convs) + 12 detection heads + BNs.
                let mut v: Vec<f64> = Vec::new();
                for i in 0..36 {
                    let c = 64.0 * (1 << (i / 12)) as f64;
                    v.push(9.0 * c * c * 4.0);
                    v.push(c * 4.0);
                    v.push(c * 4.0);
                }
                for _ in 0..12 {
                    v.push(3.0 * 3.0 * 256.0 * 486.0 * 4.0);
                }
                v
            }
            "transformer" => {
                // 6+6 layers, d=1024, ff=4096 (big): qkvo + 2 ff each + LNs.
                let mut v = Vec::new();
                v.push(33708.0 * 1024.0 * 4.0); // shared embedding
                for _ in 0..12 {
                    for _ in 0..4 {
                        v.push(1024.0 * 1024.0 * 4.0);
                    }
                    v.push(1024.0 * 4096.0 * 4.0);
                    v.push(4096.0 * 1024.0 * 4.0);
                    v.push(1024.0 * 4.0);
                    v.push(1024.0 * 4.0);
                }
                v
            }
            "gnmt" => {
                // 8 encoder + 8 decoder LSTM layers @1024 + embeddings +
                // attention + softmax.
                let mut v = Vec::new();
                v.push(32000.0 * 1024.0 * 4.0 * 2.0);
                for _ in 0..16 {
                    v.push(2048.0 * 4096.0 * 4.0); // w (concat in+h)
                    v.push(4096.0 * 4.0); // bias
                }
                v.push(1024.0 * 32000.0 * 4.0); // softmax
                v
            }
            "maskrcnn" => {
                let mut v = resnet50_gradient_bytes();
                // FPN + RPN + box/mask heads.
                for _ in 0..20 {
                    v.push(256.0 * 256.0 * 9.0 * 4.0);
                }
                v.push(1024.0 * 1024.0 * 4.0 * 2.0);
                v
            }
            _ => vec![self.params * 4.0],
        }
    }
}

/// The five MLPerf-0.6 benchmarks of the paper.
pub fn all_models() -> Vec<ModelProfile> {
    vec![
        ModelProfile {
            name: "resnet50",
            params: 25.6e6,
            fwd_flops_per_example: 3.9e9, // 224x224 v1.5
            hbm_bytes_per_example: 40e6,
            util_units_per_example: 1.0,
            train_examples: 1_281_167,
            eval_examples: 50_000,
            eval_interval_epochs: 4.0, // paper §2
            quality_target: 0.759,     // MLPerf-0.6 top-1
            quality_metric: "top-1",
            optimizer: Optimizer::Lars,
            // Anchors: small-batch reference ≈ 41 epochs; Table 1 shows
            // 64-72.8 at 32K depending on the LARS variant (curve carries
            // the reference variant; Table 1 deltas applied in the bench).
            epochs: EpochCurve::new(
                &[(256, 41.0), (4096, 44.0), (16384, 55.0), (32768, 68.0)],
                None,
            ),
            max_batch: 32768,
            max_mp: 1,
        },
        ModelProfile {
            name: "ssd",
            params: 25.1e6, // ResNet-34 backbone + heads
            fwd_flops_per_example: 7.5e9, // 300x300
            hbm_bytes_per_example: 15e6,
            util_units_per_example: 1.0,
            train_examples: 118_287,
            eval_examples: 5_000,
            eval_interval_epochs: 5.0,
            quality_target: 0.23, // paper: mAP 0.23
            quality_metric: "mAP",
            optimizer: Optimizer::Sgd,
            // Paper Fig. 8: +22% epochs 256→1024, +27% more at 2048.
            epochs: EpochCurve::new(
                &[(256, 50.0), (1024, 61.0), (2048, 77.5)],
                None,
            ),
            max_batch: 2048,
            max_mp: 4, // spatial partitioning up to 4 cores (§3)
        },
        ModelProfile {
            name: "maskrcnn",
            params: 44.2e6,
            fwd_flops_per_example: 1.5e12, // ~1024px two-stage + dense FPN
            hbm_bytes_per_example: 200e6,
            util_units_per_example: 20.0, // huge image: ample parallelism
            train_examples: 118_287,
            eval_examples: 5_000,
            eval_interval_epochs: 1.0,
            quality_target: 0.377, // box AP target (v0.6)
            quality_metric: "box-AP",
            optimizer: Optimizer::Sgd,
            epochs: EpochCurve::new(
                &[(16, 13.0), (32, 14.5), (64, 16.5), (128, 18.4)],
                Some(128), // paper §3: no convergence above 128
            ),
            max_batch: 128,
            max_mp: 4, // stage-1 spatial + stage-2 graph partitioning (§3)
        },
        ModelProfile {
            name: "transformer",
            params: 210e6, // big model
            fwd_flops_per_example: 1.4e10, // ≈ 2 * P * 33 tokens
            hbm_bytes_per_example: 30e6,
            util_units_per_example: 33.0, // ~33 tokens per sentence
            train_examples: 4_500_000,
            eval_examples: 3_003,
            eval_interval_epochs: 1.0,
            quality_target: 25.0, // BLEU
            quality_metric: "BLEU",
            optimizer: Optimizer::Adam,
            epochs: EpochCurve::new(
                &[(256, 1.6), (1024, 2.0), (2048, 2.4)],
                None,
            ),
            max_batch: 2048, // paper §3: global batch 2048, 1/core
            max_mp: 1,
        },
        ModelProfile {
            name: "gnmt",
            params: 160e6,
            fwd_flops_per_example: 1.1e10,
            hbm_bytes_per_example: 80e6, // RNN: memory-bound cells (§3)
            // RNN steps serialize, but the hoisted input projection (§3)
            // batches T steps' projections → effective rows > 1.
            util_units_per_example: 4.0,
            train_examples: 3_600_000,
            eval_examples: 3_003,
            eval_interval_epochs: 1.0,
            quality_target: 24.0, // sacrebleu target v0.6
            quality_metric: "BLEU",
            optimizer: Optimizer::Adam,
            epochs: EpochCurve::new(
                &[(256, 1.8), (1024, 2.2), (2048, 2.8)],
                None,
            ),
            max_batch: 1024,
            max_mp: 1,
        },
    ]
}

pub fn model(name: &str) -> Option<ModelProfile> {
    all_models().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_models_present() {
        let names: Vec<&str> = all_models().iter().map(|m| m.name).collect();
        assert_eq!(names, vec!["resnet50", "ssd", "maskrcnn", "transformer", "gnmt"]);
    }

    #[test]
    fn fig7_shape_only_resnet_scales_batch_aggressively() {
        // Paper §4: "with the exception of ResNet-50, in all other
        // MLPerf-0.6 models batch size only increases two times or less"
        // across the scaling range used in the submission.
        for m in all_models() {
            let small = m.layout(256).global_batch;
            let large = m.layout(2048).global_batch;
            let growth = large as f64 / small as f64;
            if m.name == "resnet50" {
                assert!(growth >= 4.0, "resnet50 growth {growth}");
            } else {
                assert!(growth <= 2.0 + 1e-9, "{}: growth {growth}", m.name);
            }
        }
    }

    #[test]
    fn resnet_pod_layout_is_32k_batch() {
        let m = model("resnet50").unwrap();
        let l = m.layout(2048);
        assert_eq!(l.global_batch, 32768);
        assert_eq!(l.mp, 1);
        assert_eq!(l.per_replica_batch(), 16.0);
    }

    #[test]
    fn transformer_pod_layout_batch_one_per_core() {
        let m = model("transformer").unwrap();
        let l = m.layout(2048);
        assert_eq!(l.global_batch, 2048);
        assert_eq!(l.per_replica_batch(), 1.0);
    }

    #[test]
    fn ssd_engages_spatial_partitioning_at_scale() {
        let m = model("ssd").unwrap();
        assert_eq!(m.layout(256).mp, 1);
        let l = m.layout(2048);
        // 2048 cores exceeds the 2048-batch cap → spatial partitioning.
        assert!(l.mp > 1, "expected mp>1, got {:?}", l);
        assert!(l.replicas * l.mp == 2048);
        assert!(l.global_batch <= 2048);
    }

    #[test]
    fn maskrcnn_mp_allows_scaling_past_batch_wall() {
        let m = model("maskrcnn").unwrap();
        let l128 = m.layout(128);
        let l256 = m.layout(256);
        assert!(l256.global_batch <= 128);
        // Paper: 128 cores → mp 2; 256 cores → mp 4.
        assert_eq!(l128.mp, 2);
        assert_eq!(l256.mp, 4);
        assert_eq!(m.max_useful_cores(), 512);
    }

    #[test]
    fn gradient_census_totals_match_params() {
        for m in all_models() {
            let total: f64 = m.gradient_bytes().iter().sum();
            let expect = m.params * 4.0;
            let ratio = total / expect;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{}: census {total:.2e} vs params*4 {expect:.2e}",
                m.name
            );
        }
    }

    #[test]
    fn per_replica_batch_at_least_one() {
        for m in all_models() {
            for cores in [16, 64, 256, 1024, 2048] {
                if cores > m.max_useful_cores() {
                    continue;
                }
                let l = m.layout(cores);
                assert!(
                    l.per_replica_batch() >= 1.0,
                    "{} @ {cores}: {:?}",
                    m.name,
                    l
                );
            }
        }
    }
}
