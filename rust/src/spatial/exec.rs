//! Real spatially-partitioned convolution over the fabric (paper Fig. 3):
//! worker i owns a stripe of image rows, exchanges K/2 halo rows with its
//! stripe neighbors, and computes its output stripe. The result must be
//! bit-identical to the unpartitioned convolution — spatial partitioning is
//! an execution strategy, not a math change.
//!
//! The direct convolution here is deliberately simple (small test images);
//! the production conv runs inside the AOT-compiled HLO. This module exists
//! to validate the halo-exchange protocol with real numbers.

use crate::collectives::{all_gather_concat, halo_exchange};
use crate::fabric::Endpoint;

/// Direct 2-D convolution, NHWC = [h, w, cin] single example, HWIO weights
/// [k, k, cin, cout], stride 1, SAME zero padding. Returns [h, w, cout].
pub fn conv2d(input: &[f32], h: usize, w: usize, cin: usize,
              weights: &[f32], k: usize, cout: usize) -> Vec<f32> {
    assert_eq!(input.len(), h * w * cin);
    assert_eq!(weights.len(), k * k * cin * cout);
    assert!(k % 2 == 1, "odd kernels only");
    let pad = k / 2;
    let mut out = vec![0.0f32; h * w * cout];
    for y in 0..h {
        for x in 0..w {
            for co in 0..cout {
                let mut acc = 0.0f32;
                for ky in 0..k {
                    let iy = y as isize + ky as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = x as isize + kx as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        for ci in 0..cin {
                            let iv = input[(iy as usize * w + ix as usize) * cin + ci];
                            let wv = weights[((ky * k + kx) * cin + ci) * cout + co];
                            acc += iv * wv;
                        }
                    }
                }
                out[(y * w + x) * cout + co] = acc;
            }
        }
    }
    out
}

/// Row range owned by stripe `i` of `k` over `h` rows.
pub fn stripe_rows(h: usize, k: usize, i: usize) -> std::ops::Range<usize> {
    crate::collectives::chunk_range(h, k, i)
}

/// SPMD: compute this worker's output stripe of a conv partitioned along
/// image height across `group`, exchanging halos for the kernel's receptive
/// field. `my_stripe` is this worker's input rows [rows x w x cin].
/// Returns the worker's output rows [rows x w x cout].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_striped(
    ep: &mut Endpoint,
    group: &[usize],
    my_stripe: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    weights: &[f32],
    k: usize,
    cout: usize,
    bf16_halo: bool,
) -> Vec<f32> {
    let pos = group.iter().position(|&r| r == ep.rank).expect("not in group");
    let rows = stripe_rows(h, group.len(), pos);
    let nrows = rows.len();
    assert_eq!(my_stripe.len(), nrows * w * cin);
    let halo = k / 2;

    // Exchange halo rows (the paper's Fig. 3 communication).
    let row_elems = w * cin;
    let top_rows = &my_stripe[..halo.min(nrows) * row_elems];
    let bottom_rows = &my_stripe[(nrows - halo.min(nrows)) * row_elems..];
    let (from_above, from_below) = halo_exchange(
        ep,
        group,
        (pos > 0).then_some(top_rows),
        (pos + 1 < group.len()).then_some(bottom_rows),
        bf16_halo,
    );

    // Build the extended stripe: [halo_above + mine + halo_below].
    let above = from_above.unwrap_or_else(|| vec![0.0; halo * row_elems]);
    let below = from_below.unwrap_or_else(|| vec![0.0; halo * row_elems]);
    let pad_above = if pos == 0 { 0 } else { halo };
    let pad_below = if pos + 1 == group.len() { 0 } else { halo };
    let ext_h = nrows + pad_above + pad_below;
    let mut ext = Vec::with_capacity(ext_h * row_elems);
    if pad_above > 0 {
        ext.extend_from_slice(&above);
    }
    ext.extend_from_slice(my_stripe);
    if pad_below > 0 {
        ext.extend_from_slice(&below);
    }

    // Convolve the extended stripe, then crop the halo output rows.
    let full = conv2d(&ext, ext_h, w, cin, weights, k, cout);
    full[pad_above * w * cout..(pad_above + nrows) * w * cout].to_vec()
}

/// Convenience: run the striped conv end-to-end and gather the full output
/// on every worker (for verification against the unpartitioned conv).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_striped_gather(
    ep: &mut Endpoint,
    group: &[usize],
    full_input: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    weights: &[f32],
    k: usize,
    cout: usize,
) -> Vec<f32> {
    let pos = group.iter().position(|&r| r == ep.rank).unwrap();
    let rows = stripe_rows(h, group.len(), pos);
    let mine = &full_input[rows.start * w * cin..rows.end * w * cin];
    let out = conv2d_striped(ep, group, mine, h, w, cin, weights, k, cout, false);
    all_gather_concat(ep, group, &out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::run_spmd;
    use crate::util::rng::Rng;

    fn rand(seed: u64, n: usize) -> Vec<f32> {
        Rng::new(seed).normal_vec(n, 1.0)
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 kernel with identity channel map = copy.
        let (h, w, c) = (4, 5, 3);
        let input = rand(0, h * w * c);
        let mut ident = vec![0.0f32; c * c];
        for i in 0..c {
            ident[i * c + i] = 1.0;
        }
        let out = conv2d(&input, h, w, c, &ident, 1, c);
        assert_eq!(out, input);
    }

    #[test]
    fn conv2d_matches_manual_3x3() {
        // All-ones 3x3 kernel on a single channel = neighborhood sum.
        let (h, w) = (3, 3);
        let input: Vec<f32> = (1..=9).map(|x| x as f32).collect();
        let weights = vec![1.0f32; 9];
        let out = conv2d(&input, h, w, 1, &weights, 3, 1);
        // Center = sum of all 9 = 45; corner (0,0) = 1+2+4+5 = 12.
        assert_eq!(out[1 * 3 + 1], 45.0);
        assert_eq!(out[0], 12.0);
    }

    #[test]
    fn striped_conv_matches_unpartitioned() {
        let (h, w, cin, cout, k) = (12, 6, 3, 4, 3);
        let input = rand(1, h * w * cin);
        let weights = rand(2, k * k * cin * cout);
        let want = conv2d(&input, h, w, cin, &weights, k, cout);
        for world in [2usize, 3, 4] {
            let input = input.clone();
            let weights = weights.clone();
            let out = run_spmd(world, move |ep| {
                let group: Vec<usize> = (0..world).collect();
                conv2d_striped_gather(ep, &group, &input, h, w, cin, &weights, k, cout)
            });
            for r in 0..world {
                assert_eq!(out[r].len(), want.len());
                for (a, b) in out[r].iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "world={world} rank={r}");
                }
            }
        }
    }

    #[test]
    fn striped_conv_5x5_kernel_two_halo_rows() {
        let (h, w, cin, cout, k) = (10, 4, 2, 2, 5);
        let input = rand(3, h * w * cin);
        let weights = rand(4, k * k * cin * cout);
        let want = conv2d(&input, h, w, cin, &weights, k, cout);
        let out = run_spmd(2, move |ep| {
            conv2d_striped_gather(ep, &[0, 1], &input, h, w, cin, &weights, k, cout)
        });
        for (a, b) in out[0].iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn single_worker_stripe_is_plain_conv() {
        let (h, w, cin, cout, k) = (6, 6, 2, 3, 3);
        let input = rand(5, h * w * cin);
        let weights = rand(6, k * k * cin * cout);
        let want = conv2d(&input, h, w, cin, &weights, k, cout);
        let out = run_spmd(1, move |ep| {
            conv2d_striped_gather(ep, &[0], &input, h, w, cin, &weights, k, cout)
        });
        assert_eq!(out[0], want);
    }

    #[test]
    fn uneven_stripes_still_correct() {
        // h=7 over 3 workers → stripes of 3/2/2.
        let (h, w, cin, cout, k) = (7, 3, 1, 1, 3);
        let input = rand(7, h * w * cin);
        let weights = rand(8, k * k * cin * cout);
        let want = conv2d(&input, h, w, cin, &weights, k, cout);
        let out = run_spmd(3, move |ep| {
            conv2d_striped_gather(ep, &[0, 1, 2], &input, h, w, cin, &weights, k, cout)
        });
        for (a, b) in out[1].iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
