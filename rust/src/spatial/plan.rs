//! Spatial-partitioning planner (paper §2 Fig. 3, §3 SSD/Mask-RCNN).
//!
//! Partitions a conv stack's spatial (height) dimension over `k` cores and
//! models the resulting speedup, accounting for the three costs the paper
//! names for SSD:
//!   1. halo-exchange communication per partitioned layer,
//!   2. all-reduce calls for distributed batch norm,
//!   3. load imbalance from ops that stay on spatial worker 0,
//! plus the parallelism floor: layers whose spatial extent is smaller than
//! the partition count cannot be split ("relatively small spatial
//! dimensions ... limited parallelism from spatial partitioning of the
//! deeper layers").

use crate::devicesim::Device;
use crate::netsim::CostModel;

/// One convolutional layer's shape (square spatial).
#[derive(Clone, Copy, Debug)]
pub struct ConvLayer {
    pub spatial: usize,   // H = W
    pub in_ch: usize,
    pub out_ch: usize,
    pub kernel: usize,    // K (square)
    pub stride: usize,
}

impl ConvLayer {
    /// Forward FLOPs for one example.
    pub fn flops(&self) -> f64 {
        let out_sp = (self.spatial / self.stride).max(1) as f64;
        2.0 * out_sp * out_sp * self.in_ch as f64 * self.out_ch as f64
            * (self.kernel * self.kernel) as f64
    }

    /// Halo rows each neighbor needs for this layer (K/2 each side).
    pub fn halo_rows(&self) -> usize {
        self.kernel / 2
    }

    /// Bytes of one halo transfer (one side), bf16 activations.
    pub fn halo_bytes(&self) -> f64 {
        (self.halo_rows() * self.spatial * self.in_ch) as f64 * 2.0
    }

    /// Can this layer be split `k` ways along height?
    pub fn splittable(&self, k: usize) -> bool {
        self.spatial >= 2 * k
    }
}

/// SSD300's conv stack, coarsely (spatial 300 → 1; the deeper layers are
/// exactly the ones that stop being splittable).
pub fn ssd_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer { spatial: 300, in_ch: 3, out_ch: 64, kernel: 7, stride: 2 },
        ConvLayer { spatial: 150, in_ch: 64, out_ch: 128, kernel: 3, stride: 2 },
        ConvLayer { spatial: 75, in_ch: 128, out_ch: 256, kernel: 3, stride: 2 },
        ConvLayer { spatial: 38, in_ch: 256, out_ch: 256, kernel: 3, stride: 1 },
        ConvLayer { spatial: 38, in_ch: 256, out_ch: 512, kernel: 3, stride: 2 },
        ConvLayer { spatial: 19, in_ch: 512, out_ch: 512, kernel: 3, stride: 1 },
        ConvLayer { spatial: 19, in_ch: 512, out_ch: 256, kernel: 3, stride: 2 },
        ConvLayer { spatial: 10, in_ch: 256, out_ch: 256, kernel: 3, stride: 2 },
        ConvLayer { spatial: 5, in_ch: 256, out_ch: 256, kernel: 3, stride: 2 },
        ConvLayer { spatial: 3, in_ch: 256, out_ch: 128, kernel: 3, stride: 2 },
        ConvLayer { spatial: 1, in_ch: 128, out_ch: 128, kernel: 1, stride: 1 },
    ]
}

/// Mask-RCNN stage-1 stack (ResNet-50 backbone @ 1024px, coarser).
pub fn maskrcnn_stage1_layers() -> Vec<ConvLayer> {
    vec![
        ConvLayer { spatial: 1024, in_ch: 3, out_ch: 64, kernel: 7, stride: 2 },
        ConvLayer { spatial: 512, in_ch: 64, out_ch: 256, kernel: 3, stride: 2 },
        ConvLayer { spatial: 256, in_ch: 256, out_ch: 512, kernel: 3, stride: 2 },
        ConvLayer { spatial: 128, in_ch: 512, out_ch: 1024, kernel: 3, stride: 2 },
        ConvLayer { spatial: 64, in_ch: 1024, out_ch: 2048, kernel: 3, stride: 2 },
        ConvLayer { spatial: 32, in_ch: 2048, out_ch: 256, kernel: 3, stride: 1 },
    ]
}

/// Plan + cost estimate for a `k`-way spatial partition.
#[derive(Clone, Debug)]
pub struct SpatialPlan {
    pub k: usize,
    /// Per-layer: was it partitioned?
    pub split: Vec<bool>,
    pub t_single: f64,
    pub t_partitioned: f64,
    /// Communication share of `t_partitioned`: halo exchanges plus the
    /// distributed-BN all-reduces (the costs the `costs::HaloPhase`
    /// attribution reports separately from compute).
    pub t_comm: f64,
}

impl SpatialPlan {
    pub fn speedup(&self) -> f64 {
        self.t_single / self.t_partitioned
    }

    pub fn efficiency(&self) -> f64 {
        self.speedup() / self.k as f64
    }

    /// Fraction of the partitioned step spent communicating (0 for k = 1).
    pub fn comm_fraction(&self) -> f64 {
        if self.t_partitioned > 0.0 {
            self.t_comm / self.t_partitioned
        } else {
            0.0
        }
    }
}

/// Fraction of per-layer work that is unsharded and lands on spatial
/// worker 0 (the paper's "some TF operations are not sharded ... resulting
/// in a load-imbalance").
pub const UNSHARDED_FRACTION: f64 = 0.05;

/// Per-layer distributed batch-norm all-reduce payload: 2 moments per
/// channel, f32.
fn bn_allreduce_bytes(l: &ConvLayer) -> f64 {
    l.out_ch as f64 * 2.0 * 4.0
}

/// Plan a k-way spatial partition of `layers` and estimate the time of one
/// example's forward+backward on the device model.
pub fn plan(layers: &[ConvLayer], k: usize, dev: &Device, net: &CostModel) -> SpatialPlan {
    assert!(k >= 1);
    let mut t_single = 0.0;
    let mut t_part = 0.0;
    let mut t_comm = 0.0;
    let mut split = Vec::with_capacity(layers.len());
    for l in layers {
        // fwd+bwd ≈ 3x fwd.
        let t_layer = 3.0 * l.flops() / (dev.peak_flops * dev.mxu_efficiency);
        t_single += t_layer;
        if k == 1 {
            split.push(false);
            continue;
        }
        if l.splittable(k) {
            split.push(true);
            let sharded = t_layer * (1.0 - UNSHARDED_FRACTION) / k as f64
                + t_layer * UNSHARDED_FRACTION; // worker-0 serial part
            // Halo both directions, fwd and bwd; overlapping neighbors.
            let halo = 2.0 * net.halo_exchange(l.halo_bytes(), 2);
            // Distributed BN all-reduce across the k spatial workers.
            let bn = net.all_gather(bn_allreduce_bytes(l)) * 2.0;
            t_part += sharded + halo + bn;
            t_comm += halo + bn;
        } else {
            split.push(false);
            // Unsplittable layer runs replicated (no speedup).
            t_part += t_layer;
        }
    }
    if k == 1 {
        t_part = t_single;
    }
    SpatialPlan { k, split, t_single, t_partitioned: t_part, t_comm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devicesim::TPU_V3;
    use crate::netsim::{NetParams, Torus};

    fn net() -> CostModel {
        CostModel::new(Torus::new(2, 2), NetParams::default())
    }

    #[test]
    fn ssd_4way_speedup_matches_paper() {
        // Paper Fig. 10: "a speedup of 1.6x on 4 TPU accelerator cores
        // with model-parallelism" for SSD.
        let p = plan(&ssd_layers(), 4, &TPU_V3, &net());
        let s = p.speedup();
        assert!((1.4..1.9).contains(&s), "SSD 4-way speedup {s}");
    }

    #[test]
    fn ssd_2way_more_efficient_than_4way() {
        // Efficiency decays with k (halo + imbalance grow).
        let p2 = plan(&ssd_layers(), 2, &TPU_V3, &net());
        let p4 = plan(&ssd_layers(), 4, &TPU_V3, &net());
        assert!(p2.efficiency() > p4.efficiency());
        assert!(p2.speedup() > 1.0 && p4.speedup() > p2.speedup());
    }

    #[test]
    fn deep_layers_not_split() {
        // Paper: "The deeper layers of SSD have smaller spatial dimensions
        // ... limited parallelism from spatial partitioning."
        let p = plan(&ssd_layers(), 4, &TPU_V3, &net());
        assert!(p.split[0], "300x300 layer must split");
        assert!(!*p.split.last().unwrap(), "1x1 layer must not split");
        let n_split = p.split.iter().filter(|&&s| s).count();
        assert!(n_split < p.split.len(), "some layers must stay replicated");
    }

    #[test]
    fn maskrcnn_partitions_better_than_ssd() {
        // Mask-RCNN's 1024px images keep spatial dims large longer →
        // spatial partitioning scales better (Fig. 10 shows Mask-RCNN
        // gaining from mp too).
        let ssd = plan(&ssd_layers(), 4, &TPU_V3, &net());
        let mrcnn = plan(&maskrcnn_stage1_layers(), 4, &TPU_V3, &net());
        assert!(mrcnn.speedup() > ssd.speedup());
    }

    #[test]
    fn k1_is_identity() {
        let p = plan(&ssd_layers(), 1, &TPU_V3, &net());
        assert_eq!(p.speedup(), 1.0);
        assert_eq!(p.t_comm, 0.0);
        assert_eq!(p.comm_fraction(), 0.0);
    }

    #[test]
    fn comm_split_is_consistent() {
        // t_comm is a sub-account of t_partitioned, and it grows with k
        // (every split layer pays halo + BN).
        let p2 = plan(&ssd_layers(), 2, &TPU_V3, &net());
        let p4 = plan(&ssd_layers(), 4, &TPU_V3, &net());
        for p in [&p2, &p4] {
            assert!(p.t_comm > 0.0);
            assert!(p.t_comm < p.t_partitioned);
            assert!((0.0..1.0).contains(&p.comm_fraction()));
        }
        assert!(p4.comm_fraction() > p2.comm_fraction());
    }

    #[test]
    fn speedup_never_exceeds_k() {
        for k in [2, 4, 8] {
            let p = plan(&ssd_layers(), k, &TPU_V3, &net());
            assert!(p.speedup() <= k as f64 + 1e-9, "k={k}: {}", p.speedup());
        }
    }

    #[test]
    fn halo_bytes_scale_with_kernel() {
        let l3 = ConvLayer { spatial: 64, in_ch: 32, out_ch: 32, kernel: 3, stride: 1 };
        let l7 = ConvLayer { kernel: 7, ..l3 };
        assert_eq!(l3.halo_rows(), 1);
        assert_eq!(l7.halo_rows(), 3);
        assert!(l7.halo_bytes() > l3.halo_bytes());
    }
}
