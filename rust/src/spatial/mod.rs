//! Spatial partitioning (paper §2 Fig. 3, §3): planner with halo/imbalance
//! cost model (reproduces Fig. 10) and a real stripe-partitioned conv
//! executor validated against the unpartitioned computation.

pub mod exec;
pub mod plan;

pub use exec::{conv2d, conv2d_striped, conv2d_striped_gather, stripe_rows};
pub use plan::{maskrcnn_stage1_layers, plan, ssd_layers, ConvLayer, SpatialPlan};
